//! Microbenchmarks of the mapping substrate: bisection, recursive mapping,
//! Eq. 1 re-weighting, window search, compact-subset growth.

use tofa::apps::{lammps_proxy::LammpsProxy, MpiApp};
use tofa::mapping::recmap::{compact_subset, RecursiveMapper};
use tofa::mapping::{bisect::bisect, cost::hop_bytes_cost};
use tofa::profiler::profile_app;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::tofa::{eq1::fault_aware_distance, window::find_route_clean_window};
use tofa::topology::{DistanceMatrix, Platform, Torus, TorusDims};

fn main() {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let topo = platform.topology();
    let dist = platform.hop_matrix();

    section("mapper microbenches (512-node torus)");
    bench("hop-matrix/512", 5, || DistanceMatrix::from_topology(topo));

    for ranks in [64usize, 85, 128, 256] {
        let app = LammpsProxy::rhodopsin(ranks);
        let comm = profile_app(&app).volume;
        let verts: Vec<usize> = (0..ranks).collect();
        bench(&format!("bisect/{ranks}"), 10, || {
            bisect(&comm, &verts, ranks / 2)
        });
        bench(&format!("recmap/{ranks}-on-512"), 5, || {
            RecursiveMapper::default().map(&comm, &dist).unwrap()
        });
        let _ = app.num_ranks();
    }

    section("fault machinery");
    let mut rng = Rng::new(3);
    let mut outage = vec![0.0; 512];
    for f in rng.sample_distinct(512, 16) {
        outage[f] = 0.02;
    }
    bench("eq1/fault-aware-distance/512", 5, || {
        fault_aware_distance(topo, &outage)
    });
    bench("window/route-clean-64", 10, || {
        find_route_clean_window(&outage, 64, topo)
    });
    bench("compact-subset/85-of-512", 10, || {
        compact_subset(&dist, &(0..512).collect::<Vec<_>>(), 85)
    });

    section("mapping quality (hop-bytes, lower is better)");
    let app = LammpsProxy::rhodopsin(64);
    let comm = profile_app(&app).volume;
    let p = RecursiveMapper::default().map(&comm, &dist).unwrap();
    println!(
        "{:<44} {:>14.1} MB*hop",
        "quality/recmap-64",
        hop_bytes_cost(&comm, &dist, &p.assignment) / 1e6
    );
    let block: Vec<usize> = (0..64).collect();
    println!(
        "{:<44} {:>14.1} MB*hop",
        "quality/block-64",
        hop_bytes_cost(&comm, &dist, &block) / 1e6
    );

    let _ = Torus::new(TorusDims::new(2, 2, 2));
}
