//! Bench for the incremental placement-cost engine (TopoIndex) and the
//! event-driven max-min solver, against the dense reference
//! implementations they replaced.
//!
//! Three sections, each asserting bit-identity before timing:
//!
//! * Eq. 1 at the paper's scale (512-node torus, 8 faulty @ 2%): dense
//!   re-route of all pairs vs clean-copy + incidence-driven patching.
//!   The acceptance floor is >= 5x (enforced in CI-manual `tests/perf.rs`
//!   at >= 3x to absorb runner noise).
//! * Route-clean window search: dense per-start closure re-routing vs the
//!   sliding dirty-pair count, on the adversarial layout (flaky node every
//!   65 ids: every candidate has clean endpoints but a dirty closure, so
//!   the dense path re-routes at every start) and the easy layout (window
//!   at the front).
//! * Max-min phase solve on switch-heavy fabrics (fat-tree k=8,
//!   dragonfly 9x4x2x2), where the link array dwarfs any phase's touched
//!   set: full-array bottleneck scans vs the CSR active list.
//!
//! Emits `BENCH_cost_engine.json` at the repo root.

use tofa::report::bench::{bench, section, write_bench_json, JsonValue, Measurement};
use tofa::rng::Rng;
use tofa::sim::network::{Flow, NetSim};
use tofa::tofa::eq1::{fault_aware_distance, fault_aware_distance_indexed};
use tofa::tofa::window::{find_route_clean_window, find_route_clean_window_indexed};
use tofa::topology::{
    CostWorkspace, Dragonfly, DragonflyParams, FatTree, TopoIndex, Topology, Torus, TorusDims,
};

fn speedup(dense: &Measurement, fast: &Measurement) -> f64 {
    dense.median.as_secs_f64() / fast.median.as_secs_f64().max(1e-12)
}

fn pair_json(case: &str, dense: &Measurement, fast: &Measurement) -> JsonValue {
    JsonValue::obj()
        .set("case", JsonValue::Str(case.to_string()))
        .set("dense", dense.to_json())
        .set("indexed", fast.to_json())
        .set("speedup_vs_naive", JsonValue::Num(speedup(dense, fast)))
}

fn eq1_section(entries: &mut Vec<JsonValue>) {
    section("Eq. 1: dense re-route vs incremental patch (512 nodes, 8 faulty @ 2%)");
    let t = Torus::new(TorusDims::new(8, 8, 8));
    let mut rng = Rng::new(42);
    let mut outage = vec![0.0; 512];
    for f in rng.sample_distinct(512, 8) {
        outage[f] = 0.02;
    }
    let build = bench("topo-index/build-512", 5, || TopoIndex::build(&t));
    let index = TopoIndex::build(&t);
    let mut ws = CostWorkspace::new();
    // bit-identity before timing
    let dense_m = fault_aware_distance(&t, &outage);
    let fast_m = fault_aware_distance_indexed(&index, &t, &outage, &mut ws);
    let identical = dense_m
        .as_slice()
        .iter()
        .zip(fast_m.as_slice())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "incremental Eq. 1 diverged from the dense path");
    let dense = bench("eq1/dense-512x8", 10, || fault_aware_distance(&t, &outage));
    let fast = bench("eq1/indexed-512x8", 10, || {
        fault_aware_distance_indexed(&index, &t, &outage, &mut ws)
    });
    let total_pairs = 512 * 511 / 2;
    println!(
        "eq1 speedup {:.2}x  patched {}/{} pairs ({:.1}%)  incidence {} entries",
        speedup(&dense, &fast),
        ws.pairs_patched(),
        total_pairs,
        100.0 * ws.pairs_patched() as f64 / total_pairs as f64,
        index.incidence_len(),
    );
    entries.push(
        pair_json("eq1-512x8", &dense, &fast)
            .set("bit_identical", JsonValue::Bool(identical))
            .set("index_build", build.to_json())
            .set("incidence_entries", JsonValue::Int(index.incidence_len() as u64))
            .set("pairs_patched", JsonValue::Int(ws.pairs_patched() as u64))
            .set("pairs_total", JsonValue::Int(total_pairs as u64)),
    );
}

fn window_section(entries: &mut Vec<JsonValue>) {
    section("route-clean window: dense closure re-route vs sliding dirty-pair count");
    let t = Torus::new(TorusDims::new(8, 8, 8));
    let index = TopoIndex::build(&t);
    let mut ws = CostWorkspace::new();
    // adversarial: flaky every 65 ids -> plenty of 64-runs with clean
    // endpoints, every closure dirty (wrap routes transit the flaky node)
    let mut hard = vec![0.0; 512];
    for i in (0..512).step_by(65) {
        hard[i] = 0.02;
    }
    // easy: faults at the back, first window valid immediately
    let mut easy = vec![0.0; 512];
    for i in 448..456 {
        easy[i] = 0.02;
    }
    for (what, outage) in [("hard", &hard), ("easy", &easy)] {
        let want = find_route_clean_window(outage, 64, &t);
        let got = find_route_clean_window_indexed(&index, outage, 64, &mut ws);
        assert_eq!(got, want, "indexed window diverged ({what})");
        let dense = bench(&format!("window/dense-{what}"), 10, || {
            find_route_clean_window(outage, 64, &t)
        });
        let fast = bench(&format!("window/indexed-{what}"), 10, || {
            find_route_clean_window_indexed(&index, outage, 64, &mut ws)
        });
        println!("window/{what} speedup {:.2}x", speedup(&dense, &fast));
        entries.push(
            pair_json(&format!("window-{what}"), &dense, &fast)
                .set("window_found", JsonValue::Bool(want.is_some())),
        );
    }
}

fn maxmin_flows(topo: &dyn Topology, net: &NetSim, n_flows: usize, seed: u64) -> Vec<Flow> {
    let mut rng = Rng::new(seed);
    let n = topo.num_nodes();
    let mut flows = Vec::with_capacity(n_flows);
    while flows.len() < n_flows {
        let u = rng.below_usize(n);
        let v = rng.below_usize(n);
        if u == v {
            continue;
        }
        let links = topo
            .route(u, v)
            .iter()
            .map(|l| net.slot(l.src, l.dst))
            .collect();
        flows.push(Flow {
            links,
            bytes: (rng.below(1_000_000) + 1) as f64,
        });
    }
    flows
}

fn maxmin_section(entries: &mut Vec<JsonValue>) {
    section("max-min solve: full link-array scans vs CSR active list (64 flows/phase)");
    let fabrics: Vec<(&str, Box<dyn Topology>)> = vec![
        ("torus-8x8x8", Box::new(Torus::new(TorusDims::new(8, 8, 8)))),
        ("fattree-8", Box::new(FatTree::new(8).unwrap())),
        (
            "dragonfly-9x4x2x2",
            Box::new(Dragonfly::new(DragonflyParams::new(9, 4, 2, 2)).unwrap()),
        ),
    ];
    for (what, topo) in &fabrics {
        let mut net = NetSim::new(topo.as_ref(), 1.25e9, 1e-6);
        let flows = maxmin_flows(topo.as_ref(), &net, 64, 7);
        let a = net.phase_duration(&flows);
        let b = net.phase_duration_reference(&flows);
        assert_eq!(a.to_bits(), b.to_bits(), "CSR solver diverged ({what})");
        let dense = bench(&format!("maxmin/dense-{what}"), 10, || {
            net.phase_duration_reference(&flows)
        });
        let fast = bench(&format!("maxmin/csr-{what}"), 10, || {
            net.phase_duration(&flows)
        });
        println!("maxmin/{what} speedup {:.2}x", speedup(&dense, &fast));
        entries.push(pair_json(&format!("maxmin-{what}"), &dense, &fast));
    }
}

fn main() {
    let mut entries = Vec::new();
    eq1_section(&mut entries);
    window_section(&mut entries);
    maxmin_section(&mut entries);
    let payload = JsonValue::obj().set("entries", JsonValue::Arr(entries));
    write_bench_json("cost_engine", payload).expect("write BENCH_cost_engine.json");
}
