//! Bench for the cluster-level event-driven scheduler (`slurm::sched`):
//! a contended 100-job workload per topology family, FIFO vs conservative
//! backfill, default-slurm vs TOFA placement.
//!
//! Reports makespan, mean queue wait, utilization, abort/backfill counts,
//! and the engine's wall-clock (events/s figure of merit), and emits
//! `BENCH_scheduler.json` at the repo root for the perf CI artifact
//! upload.

use std::sync::Arc;
use std::time::Instant;

use tofa::mapping::PlacementPolicy;
use tofa::report::bench::{section, write_bench_json, JsonValue};
use tofa::sim::fault::FaultSpec;
use tofa::slurm::sched::{run_sweep, SchedConfig, WorkloadSpec};
use tofa::topology::{Dragonfly, DragonflyParams, FatTree, Platform, TorusDims};

fn platforms() -> Vec<Platform> {
    vec![
        Platform::paper_default(TorusDims::new(8, 8, 8)), // 512 nodes
        Platform::paper_default_on(Arc::new(FatTree::new(8).unwrap())), // 128 nodes
        Platform::paper_default_on(Arc::new(
            Dragonfly::new(DragonflyParams::new(9, 4, 4, 2)).unwrap(), // 144 nodes
        )),
    ]
}

fn main() {
    let mut topo_payloads = Vec::new();
    for plat in platforms() {
        let kind = plat.topology().kind().to_string();
        let n = plat.num_nodes();
        section(&format!(
            "sched: 100 jobs on {} ({n} nodes), iid {} faulty @ 2%",
            plat.topology().describe(),
            n / 32,
        ));
        let workload = WorkloadSpec::paper_like(n);
        let fault = FaultSpec::Iid {
            n_faulty: n / 32,
            p_f: 0.02,
        };
        let cells = [
            (PlacementPolicy::DefaultSlurm, false),
            (PlacementPolicy::Tofa, false),
            (PlacementPolicy::DefaultSlurm, true),
            (PlacementPolicy::Tofa, true),
        ];
        let config = SchedConfig {
            seed: 42,
            ..Default::default()
        };
        let t0 = Instant::now();
        let sweep = run_sweep(&plat, &workload, &fault, &cells, &config, 4).unwrap();
        let wall = t0.elapsed();
        let mut cell_payloads = Vec::new();
        for cell in &sweep {
            let r = &cell.result;
            let queue = if cell.backfill { "backfill" } else { "fifo" };
            println!(
                "{:<44} makespan {:>9.2} s  wait {:>8.3} s  util {:>5.1}%  \
                 aborts {:>3}  backfills {:>3}  events {:>5}",
                format!("{kind}/{queue}/{}", cell.placement),
                r.makespan_s,
                r.mean_wait_s,
                100.0 * r.utilization,
                r.total_aborts,
                r.backfills,
                r.trace.len(),
            );
            cell_payloads.push(
                JsonValue::obj()
                    .set("placement", JsonValue::Str(cell.placement.to_string()))
                    .set("queue", JsonValue::Str(queue.to_string()))
                    .set("makespan_s", JsonValue::Num(r.makespan_s))
                    .set("mean_wait_s", JsonValue::Num(r.mean_wait_s))
                    .set("max_wait_s", JsonValue::Num(r.max_wait_s))
                    .set("utilization", JsonValue::Num(r.utilization))
                    .set("completed", JsonValue::Int(r.completed as u64))
                    .set("failed", JsonValue::Int(r.failed as u64))
                    .set("exhausted", JsonValue::Int(r.exhausted as u64))
                    .set("total_aborts", JsonValue::Int(r.total_aborts as u64))
                    .set("backfills", JsonValue::Int(r.backfills as u64))
                    .set("trace_events", JsonValue::Int(r.trace.len() as u64)),
            );
        }
        let events: usize = sweep.iter().map(|c| c.result.trace.len()).sum();
        println!(
            "{:<44} {:>12?}  ({:.0} events/s across 4 cells)",
            format!("{kind}/sweep-wallclock"),
            wall,
            events as f64 / wall.as_secs_f64(),
        );
        topo_payloads.push(
            JsonValue::obj()
                .set("topology", JsonValue::Str(kind))
                .set("nodes", JsonValue::Int(n as u64))
                .set("wall_ns", JsonValue::Int(wall.as_nanos() as u64))
                .set("cells", JsonValue::Arr(cell_payloads)),
        );
    }
    let payload = JsonValue::obj()
        .set("jobs", JsonValue::Int(100))
        .set("topologies", JsonValue::Arr(topo_payloads));
    write_bench_json("scheduler", payload).expect("write BENCH_scheduler.json");
}
