//! Bench for Figure 3b: LAMMPS timesteps/s across rank counts & policies.

use tofa::apps::{lammps_proxy::LammpsProxy, MpiApp};
use tofa::mapping::{place, PlacementPolicy};
use tofa::profiler::profile_app;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::sim::executor::Simulator;
use tofa::topology::{Platform, TorusDims};

fn main() {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    section("Figure 3b: LAMMPS timesteps/s (simulated) + pipeline wall-clock");
    for ranks in [32usize, 64, 128, 256] {
        let app = LammpsProxy::rhodopsin(ranks);
        let comm = profile_app(&app).volume;
        let dist = platform.hop_matrix();
        for policy in [
            PlacementPolicy::DefaultSlurm,
            PlacementPolicy::Random,
            PlacementPolicy::Greedy,
            PlacementPolicy::Scotch,
        ] {
            let mut rng = Rng::new(1);
            let p = place(policy, &comm, &dist, &mut rng).unwrap();
            let mut sim = Simulator::new(&app, &platform);
            let v = sim.metric_value(&p.assignment);
            println!(
                "{:<44} {:>10.1} timesteps/s",
                format!("lammps-{ranks}/{policy}"),
                v
            );
        }
        // wall-clock of the full profile->place->simulate pipeline
        bench(&format!("pipeline/lammps-{ranks}/scotch"), 3, || {
            let comm = profile_app(&app).volume;
            let mut rng = Rng::new(1);
            let p = place(PlacementPolicy::Scotch, &comm, &dist, &mut rng).unwrap();
            let mut sim = Simulator::new(&app, &platform);
            sim.metric_value(&p.assignment)
        });
    }
}
