//! Bench for Figure 3a: NPB-DT class C placement + simulation per policy.
//!
//! Reports (a) the paper's metric — simulated execution time per policy —
//! and (b) wall-clock cost of producing each placement.

use tofa::apps::npb_dt::NpbDt;
use tofa::mapping::{place, PlacementPolicy};
use tofa::profiler::profile_app;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::sim::executor::Simulator;
use tofa::topology::{Platform, TorusDims};

fn main() {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let app = NpbDt::class_c();
    let comm = profile_app(&app).volume;
    let dist = platform.hop_matrix();

    section("Figure 3a: placement wall-clock (85 ranks on 512 nodes)");
    for policy in [
        PlacementPolicy::DefaultSlurm,
        PlacementPolicy::Random,
        PlacementPolicy::Greedy,
        PlacementPolicy::Scotch,
    ] {
        bench(&format!("place/{policy}"), 10, || {
            let mut rng = Rng::new(1);
            place(policy, &comm, &dist, &mut rng).unwrap()
        });
    }

    section("Figure 3a: simulated NPB-DT execution time (the paper's bars)");
    for policy in [
        PlacementPolicy::DefaultSlurm,
        PlacementPolicy::Random,
        PlacementPolicy::Greedy,
        PlacementPolicy::Scotch,
    ] {
        let mut rng = Rng::new(1);
        let p = place(policy, &comm, &dist, &mut rng).unwrap();
        let mut sim = Simulator::new(&app, &platform);
        let secs = sim.metric_value(&p.assignment);
        println!("{:<44} simulated {:>10.3} s", format!("npb-dt-c/{policy}"), secs);
        bench(&format!("simulate/{policy}"), 5, || {
            let mut s = Simulator::new(&app, &platform);
            s.success_time(&p.assignment)
        });
    }
}
