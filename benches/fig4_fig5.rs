//! Bench for Figures 4 / 5a / 5b: the batch resilience experiments.
//!
//! Reports both the paper's metrics (batch completion time, abort ratio)
//! and the wall-clock cost of a full 100-instance batch per policy —
//! demonstrating the JobProfile fast path (EXPERIMENTS.md §Perf).

use tofa::apps::npb_dt::NpbDt;
use tofa::apps::{lammps_proxy::LammpsProxy, MpiApp};
use tofa::batch::{BatchConfig, BatchRunner};
use tofa::mapping::PlacementPolicy;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::sim::failure::FaultScenario;
use tofa::topology::{Platform, TorusDims};

fn run_case(title: &str, app: &dyn MpiApp, n_faulty: usize) {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let mut runner = BatchRunner::new(app, &platform);
    let config = BatchConfig {
        instances: 100,
        n_faulty,
        p_f: 0.02,
        ..Default::default()
    };
    section(title);
    let mut master = Rng::new(42);
    let mut scen_rng = master.fork(1);
    let scenario = FaultScenario::random(512, n_faulty, 0.02, &mut scen_rng);
    for policy in [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa] {
        let mut rng = scen_rng.fork(7);
        let res = runner
            .run_batch(policy, &scenario, &config, &mut rng)
            .unwrap();
        println!(
            "{:<44} completion {:>10.1} s  abort ratio {:>5.1}%",
            format!("batch/{policy}"),
            res.completion_s,
            100.0 * res.abort_ratio()
        );
        bench(&format!("batch-wallclock/{policy}"), 5, || {
            let mut rng = scen_rng.fork(8);
            runner
                .run_batch(policy, &scenario, &config, &mut rng)
                .unwrap()
        });
    }
}

fn main() {
    run_case(
        "Figure 4: NPB-DT class C, 16 faulty @ 2%, 100-instance batch",
        &NpbDt::class_c(),
        16,
    );
    run_case(
        "Figure 5a: LAMMPS 64p, 8 faulty @ 2%",
        &LammpsProxy::rhodopsin(64),
        8,
    );
    run_case(
        "Figure 5b: LAMMPS 64p, 16 faulty @ 2%",
        &LammpsProxy::rhodopsin(64),
        16,
    );
}
