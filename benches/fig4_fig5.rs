//! Bench for Figures 4 / 5a / 5b: the batch resilience experiments.
//!
//! Reports the paper's metrics (batch completion time, abort ratio), the
//! wall-clock cost of a full 100-instance batch per policy — demonstrating
//! the JobProfile fast path (EXPERIMENTS.md §Perf) — and the parallel
//! engine's speedup on the full `(batch, policy)` sweep at 1/2/4 workers.

use std::time::Instant;

use tofa::apps::npb_dt::NpbDt;
use tofa::apps::{lammps_proxy::LammpsProxy, MpiApp};
use tofa::batch::{run_grid, BatchConfig, BatchRunner, Parallelism};
use tofa::mapping::PlacementPolicy;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::sim::fault::{FaultScenario, FaultSpec};
use tofa::topology::{Platform, TorusDims};

fn run_case(title: &str, app: &dyn MpiApp, n_faulty: usize) {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let mut runner = BatchRunner::new(app, &platform);
    let config = BatchConfig {
        instances: 100,
        ..Default::default()
    };
    section(title);
    let mut master = Rng::new(42);
    let mut scen_rng = master.fork(1);
    let scenario = FaultScenario::random(512, n_faulty, 0.02, &mut scen_rng);
    for policy in [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa] {
        let mut rng = scen_rng.fork(7);
        let res = runner
            .run_batch(policy, &scenario, &config, &mut rng)
            .unwrap();
        println!(
            "{:<44} completion {:>10.1} s  abort ratio {:>5.1}%",
            format!("batch/{policy}"),
            res.completion_s,
            100.0 * res.abort_ratio()
        );
        bench(&format!("batch-wallclock/{policy}"), 5, || {
            let mut rng = scen_rng.fork(8);
            runner
                .run_batch(policy, &scenario, &config, &mut rng)
                .unwrap()
        });
    }
}

/// The full Fig. 4-style sweep (batches x {default, tofa}) at several
/// worker counts. Fresh runner (and thus fresh phase cache) per point so
/// each measures cold-cache wall-clock; the checksum shows worker-count
/// invariance of the results.
fn sweep_speedup() {
    section("parallel sweep: 10 batches x 2 policies, NPB-DT, 16 faulty @ 2%");
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let app = NpbDt::class_c();
    let policies = [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa];
    let mut serial_wall = None;
    for workers in [1usize, 2, 4] {
        let runner = BatchRunner::new(&app, &platform);
        let config = BatchConfig {
            instances: 100,
            fault: FaultSpec::Iid {
                n_faulty: 16,
                p_f: 0.02,
            },
            parallelism: Parallelism::fixed(workers),
            ..Default::default()
        };
        let t0 = Instant::now();
        let grid = run_grid(&runner, &policies, &config, 10, 42).unwrap();
        let wall = t0.elapsed();
        let checksum: f64 = grid.cells.iter().map(|c| c.result.completion_s).sum();
        let speedup = match serial_wall {
            None => {
                serial_wall = Some(wall);
                1.0
            }
            Some(base) => base.as_secs_f64() / wall.as_secs_f64(),
        };
        println!(
            "{:<44} {:>12?}  speedup {:>5.2}x  slowest shard {:>12?}  \
             cache hit-rate {:>5.1}%  checksum {:.3}",
            format!("sweep/{workers}-workers"),
            wall,
            speedup,
            grid.telemetry.slowest_shard(),
            100.0 * grid.telemetry.hit_rate(),
            checksum,
        );
    }
}

fn main() {
    run_case(
        "Figure 4: NPB-DT class C, 16 faulty @ 2%, 100-instance batch",
        &NpbDt::class_c(),
        16,
    );
    run_case(
        "Figure 5a: LAMMPS 64p, 8 faulty @ 2%",
        &LammpsProxy::rhodopsin(64),
        8,
    );
    run_case(
        "Figure 5b: LAMMPS 64p, 16 faulty @ 2%",
        &LammpsProxy::rhodopsin(64),
        16,
    );
    sweep_speedup();
}
