//! Bench for Figures 4 / 5a / 5b: the batch resilience experiments.
//!
//! Reports the paper's metrics (batch completion time, abort ratio), the
//! wall-clock cost of a full 100-instance batch per policy — demonstrating
//! the JobProfile fast path (EXPERIMENTS.md §Perf) — and the parallel
//! engine's speedup on the full `(batch, policy)` sweep at 1/2/4 workers.
//!
//! Emits `BENCH_fig4_fig5.json` at the repo root: per-figure wall-clock
//! and abort statistics, sweep speedups per worker count, and phase-cache
//! hit rates.

use std::time::Instant;

use tofa::apps::npb_dt::NpbDt;
use tofa::apps::{lammps_proxy::LammpsProxy, MpiApp};
use tofa::batch::{run_grid, BatchConfig, BatchRunner, Parallelism};
use tofa::mapping::PlacementPolicy;
use tofa::report::bench::{bench, section, write_bench_json, JsonValue};
use tofa::rng::Rng;
use tofa::sim::fault::{FaultScenario, FaultSpec};
use tofa::topology::{Platform, TorusDims};

fn run_case(title: &str, app: &dyn MpiApp, n_faulty: usize) -> JsonValue {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let mut runner = BatchRunner::new(app, &platform);
    let config = BatchConfig {
        instances: 100,
        ..Default::default()
    };
    section(title);
    let mut master = Rng::new(42);
    let mut scen_rng = master.fork(1);
    let scenario = FaultScenario::random(512, n_faulty, 0.02, &mut scen_rng);
    let mut policies = Vec::new();
    for policy in [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa] {
        let mut rng = scen_rng.fork(7);
        let res = runner
            .run_batch(policy, &scenario, &config, &mut rng)
            .unwrap();
        println!(
            "{:<44} completion {:>10.1} s  abort ratio {:>5.1}%",
            format!("batch/{policy}"),
            res.completion_s,
            100.0 * res.abort_ratio()
        );
        let wall = bench(&format!("batch-wallclock/{policy}"), 5, || {
            let mut rng = scen_rng.fork(8);
            runner
                .run_batch(policy, &scenario, &config, &mut rng)
                .unwrap()
        });
        policies.push(
            JsonValue::obj()
                .set("policy", JsonValue::Str(policy.to_string()))
                .set("completion_s", JsonValue::Num(res.completion_s))
                .set("abort_ratio", JsonValue::Num(res.abort_ratio()))
                .set("cache_hit_rate", JsonValue::Num(res.telemetry.hit_rate()))
                .set("wallclock", wall.to_json()),
        );
    }
    JsonValue::obj()
        .set("case", JsonValue::Str(title.to_string()))
        .set("n_faulty", JsonValue::Int(n_faulty as u64))
        .set("policies", JsonValue::Arr(policies))
}

/// The full Fig. 4-style sweep (batches x {default, tofa}) at several
/// worker counts. Fresh runner (and thus fresh phase cache) per point so
/// each measures cold-cache wall-clock; the checksum shows worker-count
/// invariance of the results.
fn sweep_speedup() -> JsonValue {
    section("parallel sweep: 10 batches x 2 policies, NPB-DT, 16 faulty @ 2%");
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let app = NpbDt::class_c();
    let policies = [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa];
    let mut serial_wall = None;
    let mut points = Vec::new();
    for workers in [1usize, 2, 4] {
        let runner = BatchRunner::new(&app, &platform);
        let config = BatchConfig {
            instances: 100,
            fault: FaultSpec::Iid {
                n_faulty: 16,
                p_f: 0.02,
            },
            parallelism: Parallelism::fixed(workers),
            ..Default::default()
        };
        let t0 = Instant::now();
        let grid = run_grid(&runner, &policies, &config, 10, 42).unwrap();
        let wall = t0.elapsed();
        let checksum: f64 = grid.cells.iter().map(|c| c.result.completion_s).sum();
        let speedup = match serial_wall {
            None => {
                serial_wall = Some(wall);
                1.0
            }
            Some(base) => base.as_secs_f64() / wall.as_secs_f64(),
        };
        println!(
            "{:<44} {:>12?}  speedup {:>5.2}x  slowest shard {:>12?}  \
             cache hit-rate {:>5.1}%  checksum {:.3}",
            format!("sweep/{workers}-workers"),
            wall,
            speedup,
            grid.telemetry.slowest_shard(),
            100.0 * grid.telemetry.hit_rate(),
            checksum,
        );
        points.push(
            JsonValue::obj()
                .set("workers", JsonValue::Int(workers as u64))
                .set("wall_ns", JsonValue::Int(wall.as_nanos() as u64))
                .set("speedup_vs_serial", JsonValue::Num(speedup))
                .set(
                    "slowest_shard_ns",
                    JsonValue::Int(grid.telemetry.slowest_shard().as_nanos() as u64),
                )
                .set("cache_hit_rate", JsonValue::Num(grid.telemetry.hit_rate()))
                .set("checksum", JsonValue::Num(checksum)),
        );
    }
    JsonValue::obj()
        .set(
            "case",
            JsonValue::Str("sweep 10 batches x 2 policies, NPB-DT".to_string()),
        )
        .set("points", JsonValue::Arr(points))
}

fn main() {
    let cases = vec![
        run_case(
            "Figure 4: NPB-DT class C, 16 faulty @ 2%, 100-instance batch",
            &NpbDt::class_c(),
            16,
        ),
        run_case(
            "Figure 5a: LAMMPS 64p, 8 faulty @ 2%",
            &LammpsProxy::rhodopsin(64),
            8,
        ),
        run_case(
            "Figure 5b: LAMMPS 64p, 16 faulty @ 2%",
            &LammpsProxy::rhodopsin(64),
            16,
        ),
    ];
    let sweep = sweep_speedup();
    let payload = JsonValue::obj()
        .set("cases", JsonValue::Arr(cases))
        .set("sweep", sweep);
    write_bench_json("fig4_fig5", payload).expect("write BENCH_fig4_fig5.json");
}
