//! Recovery-policy sweep: the same 400-job campaign on the paper's
//! 8x8x8 torus under every fault model x recovery policy cell
//! (abort-resubmit, checkpoint/restart, ULFM-style shrink-and-continue).
//!
//! The headline metric is **lost node-seconds** — capacity held without
//! useful progress (rolled-back intervals, checkpoint writes, shrink
//! degradation). Under the correlated-rack and trace fault models the
//! bench asserts both recovery policies waste strictly less than
//! abort-resubmit; the aggregates land in `BENCH_recovery.json` at the
//! repo root for the perf CI artifact upload.

use std::sync::Arc;

use tofa::mapping::PlacementPolicy;
use tofa::report::bench::{section, write_bench_json, JsonValue};
use tofa::sim::fault::{FaultSpec, FaultTrace};
use tofa::slurm::sched::{run_campaign, Arrivals, CampaignWorkload, RecoveryPolicy, SchedConfig};
use tofa::topology::{Platform, TorusDims};

const CELLS: &[(PlacementPolicy, bool)] = &[
    (PlacementPolicy::DefaultSlurm, false),
    (PlacementPolicy::Tofa, true),
];

/// All four fault models, sized to the platform. The trace staggers
/// 1-second outages over a quarter of the machine so multi-node failures
/// land mid-run — the case shrink-and-continue exists for.
fn fault_models(n: usize) -> Vec<FaultSpec> {
    let mut trace_text = format!("nodes {n}\n");
    for (i, node) in (0..n).step_by(4).enumerate() {
        let start = 0.01 * (i % 100) as f64;
        trace_text.push_str(&format!("{node} {start} {}\n", start + 1.0));
    }
    vec![
        FaultSpec::Iid {
            n_faulty: n / 8,
            p_f: 0.3,
        },
        FaultSpec::CorrelatedRacks {
            domains: 8,
            p_domain: 0.5,
        },
        FaultSpec::Weibull {
            n_faulty: n / 8,
            shape: 0.7,
            p_horizon: 0.3,
            horizon_s: 0.5,
        },
        FaultSpec::Trace {
            trace: Arc::new(FaultTrace::parse(trace_text.as_bytes()).unwrap()),
        },
    ]
}

fn main() {
    let plat = Platform::paper_default(TorusDims::new(8, 8, 8));
    let n = plat.num_nodes();
    let spec = CampaignWorkload {
        jobs: 400,
        arrivals: Arrivals::Poisson { mean_gap_s: 0.01 },
        ..CampaignWorkload::paper_like(n)
    };
    let jobs = spec.generate().unwrap();
    let policies = [
        RecoveryPolicy::AbortResubmit,
        RecoveryPolicy::CheckpointRestart { interval_s: 0.5 },
        RecoveryPolicy::ShrinkContinue,
    ];
    let mut model_payloads = Vec::new();
    for fault in fault_models(n) {
        let name = fault.model_name();
        section(&format!(
            "recovery: {} jobs, {} cells, fault model {name}",
            jobs.len(),
            CELLS.len()
        ));
        let mut lost = Vec::new();
        let mut policy_payloads = Vec::new();
        for recovery in policies {
            let config = SchedConfig {
                max_restarts: 5,
                recovery,
                ckpt_cost_s: 0.002,
                seed: 42,
                ..Default::default()
            };
            let cells = run_campaign(&plat, &jobs, &fault, CELLS, &config, 4).unwrap();
            let total_lost: f64 = cells.iter().map(|c| c.metrics.lost_node_s).sum();
            let completed: usize = cells.iter().map(|c| c.metrics.completed).sum();
            let aborts: usize = cells.iter().map(|c| c.metrics.total_aborts).sum();
            let ckpts: u64 = cells.iter().map(|c| c.metrics.ckpts).sum();
            let shrinks: u64 = cells.iter().map(|c| c.metrics.shrinks).sum();
            let fallbacks: u64 = cells.iter().map(|c| c.metrics.shrink_fallbacks).sum();
            let wall: f64 = cells.iter().map(|c| c.wall.as_secs_f64()).sum();
            println!(
                "{:<28} lost {:>10.1} node-s  done {:>4}  aborts {:>4}  \
                 ckpts {:>5}  shrinks {:>4} (+{} fallback)  wall {:.3} s",
                format!("{name}/{recovery}"),
                total_lost,
                completed,
                aborts,
                ckpts,
                shrinks,
                fallbacks,
                wall,
            );
            lost.push(total_lost);
            policy_payloads.push(
                JsonValue::obj()
                    .set("recovery", JsonValue::Str(recovery.to_string()))
                    .set("lost_node_s", JsonValue::Num(total_lost))
                    .set("completed", JsonValue::Int(completed as u64))
                    .set("total_aborts", JsonValue::Int(aborts as u64))
                    .set("ckpts", JsonValue::Int(ckpts))
                    .set("shrinks", JsonValue::Int(shrinks))
                    .set("shrink_fallbacks", JsonValue::Int(fallbacks))
                    .set("cells", JsonValue::Arr(cells.iter().map(|c| c.json()).collect())),
            );
        }
        // the acceptance property: under multi-node (rack / trace)
        // outages, paying for checkpoints or shrinking beats rerunning
        // whole jobs from scratch
        if matches!(
            fault,
            FaultSpec::CorrelatedRacks { .. } | FaultSpec::Trace { .. }
        ) {
            assert!(
                lost[1] < lost[0],
                "{name}: checkpointing lost {} node-s vs abort {}",
                lost[1],
                lost[0]
            );
            assert!(
                lost[2] < lost[0],
                "{name}: shrink lost {} node-s vs abort {}",
                lost[2],
                lost[0]
            );
        }
        model_payloads.push(
            JsonValue::obj()
                .set("fault", JsonValue::Str(name.to_string()))
                .set("policies", JsonValue::Arr(policy_payloads)),
        );
    }
    let payload = JsonValue::obj()
        .set("nodes", JsonValue::Int(n as u64))
        .set("jobs", JsonValue::Int(jobs.len() as u64))
        .set("models", JsonValue::Arr(model_payloads));
    write_bench_json("recovery", payload).expect("write BENCH_recovery.json");
}
