//! Bench for the implicit hop metric: closed-form distances vs the dense
//! TopoIndex, and the O(n)-memory path that serves 100k-node platforms.
//!
//! Three sections:
//!
//! * Parity at the paper's scale (512-node torus): dense lookups vs the
//!   closed forms, asserting bit-identity over every pair before timing,
//!   plus the job-sized `extract` both modes share.
//! * Scaling at 1k / 10k / 100k nodes, implicit-only beyond the dense
//!   limit: hop-query throughput, the lazy route-clean window search, and
//!   a candidate-sized Eq. 1 submatrix. Each entry records what the dense
//!   n^2 matrix *would* cost (4 bytes per entry) and whether the
//!   `DENSE_NODE_LIMIT` guard allows it — 100k nodes is ~42 GB, refused.
//! * A whole TOFA placement (64 ranks, window path) on the 102400-node
//!   torus, start to finish, with no O(n^2) state ever built.
//!
//! Emits `BENCH_implicit_metric.json` at the repo root.

use tofa::commgraph::CommMatrix;
use tofa::report::bench::{bench, section, write_bench_json, JsonValue, Measurement};
use tofa::rng::Rng;
use tofa::tofa::eq1::fault_aware_submatrix;
use tofa::tofa::placer::{TofaPath, TofaPlacer};
use tofa::tofa::window::find_route_clean_window_implicit;
use tofa::topology::{CostWorkspace, MetricMode, Platform, TopoIndex, TorusDims, DENSE_NODE_LIMIT};

fn speedup(dense: &Measurement, fast: &Measurement) -> f64 {
    dense.median.as_secs_f64() / fast.median.as_secs_f64().max(1e-12)
}

/// What the dense hop matrix would occupy: n^2 f32 entries.
fn dense_matrix_bytes(n: usize) -> u64 {
    (n as u64) * (n as u64) * 4
}

fn random_comm(rng: &mut Rng, n: usize) -> CommMatrix {
    let mut c = CommMatrix::new(n);
    for _ in 0..n * 2 {
        let i = rng.below_usize(n);
        let j = rng.below_usize(n);
        if i != j {
            c.add_sym(i, j, (rng.below(1_000_000) + 1) as f64);
        }
    }
    c
}

/// The first-x-line fault layout every section shares: a few flaky nodes
/// in the y=0 row, so the window search has to slide past the whole row.
fn front_line_outage(n: usize) -> Vec<f64> {
    let mut outage = vec![0.0; n];
    for f in [0usize, 3, 17, 40] {
        outage[f] = 0.05;
    }
    outage
}

fn parity_section(entries: &mut Vec<JsonValue>) {
    section("hop queries: dense TopoIndex lookups vs closed forms (512 nodes)");
    let plat = Platform::paper_default(TorusDims::new(8, 8, 8));
    let implicit = plat.clone().with_metric(MetricMode::Implicit);
    let n = plat.num_nodes();
    let build = bench("index/build-512", 5, || {
        TopoIndex::build(plat.topology())
    });
    let (d, i) = (plat.hop_oracle(), implicit.hop_oracle());
    let mut identical = true;
    for u in 0..n {
        for v in 0..n {
            identical &= d.hops(u, v).to_bits() == i.hops(u, v).to_bits();
        }
    }
    assert!(identical, "implicit hops diverged from the dense matrix");
    let dense = bench("hops/dense-512", 10, || {
        let mut acc = 0.0f32;
        for u in 0..n {
            for v in 0..n {
                acc += d.hops(u, v);
            }
        }
        acc
    });
    let fast = bench("hops/implicit-512", 10, || {
        let mut acc = 0.0f32;
        for u in 0..n {
            for v in 0..n {
                acc += i.hops(u, v);
            }
        }
        acc
    });
    let window: Vec<usize> = (64..128).collect();
    let extract = bench("extract/implicit-64of512", 10, || i.extract(&window));
    println!(
        "hops-512: implicit is {:.2}x the dense lookup cost (parity of values asserted)",
        1.0 / speedup(&dense, &fast).max(1e-12)
    );
    entries.push(
        JsonValue::obj()
            .set("case", JsonValue::Str("parity-512".to_string()))
            .set("bit_identical", JsonValue::Bool(identical))
            .set("index_build", build.to_json())
            .set("dense", dense.to_json())
            .set("implicit", fast.to_json())
            .set("extract_64", extract.to_json()),
    );
}

fn scale_section(entries: &mut Vec<JsonValue>) {
    section("scaling: implicit metric at 1k / 10k / 100k nodes, O(n) memory");
    let sizes = [
        ("1k", TorusDims::new(10, 10, 10)),
        ("10k", TorusDims::new(25, 20, 20)),
        ("100k", TorusDims::new(64, 40, 40)),
    ];
    for (what, dims) in sizes {
        let plat = Platform::paper_default(dims).with_metric(MetricMode::Implicit);
        let n = plat.num_nodes();
        let oracle = plat.hop_oracle();
        let mut rng = Rng::new(9);
        let pairs: Vec<(usize, usize)> = (0..100_000)
            .map(|_| (rng.below_usize(n), rng.below_usize(n)))
            .collect();
        let queries = bench(&format!("hops/implicit-{what}"), 10, || {
            let mut acc = 0.0f32;
            for &(u, v) in &pairs {
                acc += oracle.hops(u, v);
            }
            acc
        });
        let outage = front_line_outage(n);
        let mut ws = CostWorkspace::new();
        assert!(
            find_route_clean_window_implicit(plat.topology(), &outage, 64, &mut ws).is_some(),
            "{what}: no route-clean window found"
        );
        let win = bench(&format!("window/implicit-{what}"), 5, || {
            find_route_clean_window_implicit(plat.topology(), &outage, 64, &mut ws)
        });
        let subset: Vec<usize> = (n / 2..n / 2 + 64).collect();
        let sub = bench(&format!("eq1-submatrix/implicit-{what}"), 5, || {
            fault_aware_submatrix(plat.topology(), &outage, &subset, &mut ws)
        });
        let refused = n > DENSE_NODE_LIMIT;
        println!(
            "{what}: {n} nodes — dense matrix would be {:.1} MB {}",
            dense_matrix_bytes(n) as f64 / 1e6,
            if refused { "(refused)" } else { "(allowed)" },
        );
        entries.push(
            JsonValue::obj()
                .set("case", JsonValue::Str(format!("scale-{what}")))
                .set("nodes", JsonValue::Int(n as u64))
                .set("dense_matrix_bytes", JsonValue::Int(dense_matrix_bytes(n)))
                .set("dense_refused", JsonValue::Bool(refused))
                .set("hops_100k_queries", queries.to_json())
                .set("window_search_64", win.to_json())
                .set("eq1_submatrix_64", sub.to_json()),
        );
    }
}

fn placement_section(entries: &mut Vec<JsonValue>) {
    section("whole TOFA placement on the 102400-node torus (64 ranks)");
    let plat = Platform::paper_default(TorusDims::new(64, 40, 40))
        .with_metric(MetricMode::Implicit);
    let n = plat.num_nodes();
    assert!(plat.try_topo_index().is_err(), "dense index must be refused");
    let mut rng = Rng::new(11);
    let comm = random_comm(&mut rng, 64);
    let outage = front_line_outage(n);
    let placer = TofaPlacer::default();
    let placed = placer.place(&comm, &plat, &outage).expect("placement");
    assert_eq!(placed.path, TofaPath::Window);
    assert_eq!(placed.assignment.len(), 64);
    let m = bench("place/implicit-100k", 5, || {
        placer.place(&comm, &plat, &outage).unwrap()
    });
    println!(
        "place-100k: {:.2} ms median, window path, dense index refused",
        m.median.as_secs_f64() * 1e3
    );
    entries.push(
        JsonValue::obj()
            .set("case", JsonValue::Str("place-100k".to_string()))
            .set("nodes", JsonValue::Int(n as u64))
            .set("ranks", JsonValue::Int(64))
            .set("path", JsonValue::Str("window".to_string()))
            .set("place", m.to_json()),
    );
}

fn main() {
    let mut entries = Vec::new();
    parity_section(&mut entries);
    scale_section(&mut entries);
    placement_section(&mut entries);
    let payload = JsonValue::obj().set("entries", JsonValue::Arr(entries));
    write_bench_json("implicit_metric", payload).expect("write BENCH_implicit_metric.json");
}
