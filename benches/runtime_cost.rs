//! PJRT runtime bench: the L1/L2 artifact's batched mapping-cost evaluator
//! vs the scalar Rust reference, across job sizes.
//!
//! The artifact computes at padded (N_PAD=256, M_PAD=512, K=32) shapes, so
//! its throughput is flat in N while the Rust loop is O(K * N^2); the
//! crossover (see EXPERIMENTS.md §Perf) is around N ~ 200.

use tofa::commgraph::CommMatrix;
use tofa::mapping::cost::hop_bytes_cost;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::runtime::{default_artifacts_dir, CostEvaluator};
use tofa::topology::{DistanceMatrix, Torus, TorusDims};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("model.manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    }
    let mut eval = CostEvaluator::load(&dir).expect("load artifacts");
    println!("platform: {}  shapes: {:?}", eval.platform_name(), eval.shapes());
    let torus = Torus::new(TorusDims::new(8, 8, 8));
    let dist = DistanceMatrix::from_torus_hops(&torus);

    for n in [64usize, 128, 256] {
        section(&format!("batched mapping cost, N={n}, K=32"));
        let mut rng = Rng::new(5);
        let mut comm = CommMatrix::new(n);
        for _ in 0..n * 4 {
            let i = rng.below_usize(n);
            let j = rng.below_usize(n);
            if i != j {
                comm.add_sym(i, j, (rng.below(1_000_000) + 1) as f64);
            }
        }
        let candidates: Vec<Vec<usize>> =
            (0..32).map(|_| rng.sample_distinct(512, n)).collect();

        // cross-check once
        let pjrt = eval.batch_costs(&comm, &dist, &candidates).unwrap();
        for (k, cand) in candidates.iter().enumerate() {
            let want = hop_bytes_cost(&comm, &dist, cand);
            assert!(
                (pjrt[k] - want).abs() / want.max(1.0) < 1e-4,
                "mismatch at N={n} k={k}"
            );
        }

        bench(&format!("pjrt/batch32-n{n}"), 10, || {
            eval.batch_costs(&comm, &dist, &candidates).unwrap()
        });
        bench(&format!("rust-scalar/batch32-n{n}"), 10, || {
            candidates
                .iter()
                .map(|c| hop_bytes_cost(&comm, &dist, c))
                .collect::<Vec<f64>>()
        });
    }
}
