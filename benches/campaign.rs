//! Bench for trace-driven heavy-traffic scheduler campaigns
//! (`slurm::sched::workload` + `slurm::sched::campaign`), three scales:
//!
//! * a 1 000-job diurnal campaign per topology family, all four
//!   (placement × queue) cells;
//! * the acceptance heavyweight: a fixed-seed 10 000-job campaign on a
//!   10 000-node torus (implicit metric — no O(n²) state), FIFO cells;
//! * a 100 000-node `NodeLedger` microbench: per-decision churn through
//!   the incremental free-run index, and its O(log n) queries against
//!   the retained O(n) scan reference.
//!
//! Emits `BENCH_campaign.json` at the repo root with events-per-second
//! plus p50/p95/p99 wait and slowdown per cell, for the perf CI
//! artifact upload.

use std::sync::Arc;

use tofa::mapping::PlacementPolicy;
use tofa::report::bench::{bench, section, write_bench_json, JsonValue};
use tofa::sim::fault::FaultSpec;
use tofa::slurm::sched::{
    run_campaign, Arrivals, CampaignCell, CampaignWorkload, NodeLedger, SchedConfig,
};
use tofa::topology::{Dragonfly, DragonflyParams, FatTree, Platform, TorusDims};

const FULL_CELLS: &[(PlacementPolicy, bool)] = &[
    (PlacementPolicy::DefaultSlurm, false),
    (PlacementPolicy::Tofa, false),
    (PlacementPolicy::DefaultSlurm, true),
    (PlacementPolicy::Tofa, true),
];

fn platforms() -> Vec<Platform> {
    vec![
        Platform::paper_default(TorusDims::new(8, 8, 8)), // 512 nodes
        Platform::paper_default_on(Arc::new(FatTree::new(8).unwrap())), // 128 nodes
        Platform::paper_default_on(Arc::new(
            Dragonfly::new(DragonflyParams::new(9, 4, 4, 2)).unwrap(), // 144 nodes
        )),
    ]
}

/// Print one line per cell and return the cells' JSON payloads.
fn print_cells(kind: &str, cells: &[CampaignCell]) -> Vec<JsonValue> {
    cells
        .iter()
        .map(|cell| {
            let m = &cell.metrics;
            let queue = if cell.backfill { "backfill" } else { "fifo" };
            println!(
                "{:<36} done {:>5}/{:<5} wait p50/p95/p99 {:>7.3}/{:>7.3}/{:>7.3} s  \
                 slow p50/p99 {:>5.2}/{:>6.2}  util {:>5.1}%  {:>9.0} events/s",
                format!("{kind}/{queue}/{}", cell.placement),
                m.completed,
                m.total_jobs,
                m.wait.p50,
                m.wait.p95,
                m.wait.p99,
                m.slowdown.p50,
                m.slowdown.p99,
                100.0 * m.utilization,
                cell.events_per_s(),
            );
            cell.json()
        })
        .collect()
}

fn main() {
    let mut topo_payloads = Vec::new();

    // 1 000-job diurnal campaigns, one per topology family
    for plat in platforms() {
        let kind = plat.topology().kind().to_string();
        let n = plat.num_nodes();
        section(&format!(
            "campaign: 1000 jobs, diurnal arrivals, {} ({n} nodes)",
            plat.topology().describe()
        ));
        let spec = CampaignWorkload {
            jobs: 1000,
            arrivals: Arrivals::Diurnal {
                mean_gap_s: 0.02,
                day_s: 10.0,
                peak_to_trough: 4.0,
            },
            ..CampaignWorkload::paper_like(n)
        };
        let jobs = spec.generate().unwrap();
        let fault = FaultSpec::Iid {
            n_faulty: n / 32,
            p_f: 0.02,
        };
        let config = SchedConfig {
            seed: 42,
            ..Default::default()
        };
        let cells = run_campaign(&plat, &jobs, &fault, FULL_CELLS, &config, 4).unwrap();
        let cell_payloads = print_cells(&kind, &cells);
        topo_payloads.push(
            JsonValue::obj()
                .set("topology", JsonValue::Str(kind))
                .set("nodes", JsonValue::Int(n as u64))
                .set("jobs", JsonValue::Int(jobs.len() as u64))
                .set("cells", JsonValue::Arr(cell_payloads)),
        );
    }

    // the acceptance heavyweight: 10 000 jobs on 10 000 nodes, implicit
    // metric (the dense n^2 matrix is never built), FIFO cells
    section("campaign: 10000 jobs on a 10000-node torus (implicit metric)");
    let plat = Platform::paper_default(TorusDims::new(25, 20, 20));
    assert_eq!(plat.num_nodes(), 10_000);
    let spec = CampaignWorkload {
        jobs: 10_000,
        mix: vec![(32, 0.5), (64, 0.3), (128, 0.2)],
        steps_min: 1,
        steps_max: 2,
        arrivals: Arrivals::Poisson { mean_gap_s: 0.005 },
        seed: 42,
    };
    let jobs = spec.generate().unwrap();
    let fault = FaultSpec::Iid {
        n_faulty: 100,
        p_f: 0.02,
    };
    let fifo_cells: &[(PlacementPolicy, bool)] = &[
        (PlacementPolicy::DefaultSlurm, false),
        (PlacementPolicy::Tofa, false),
    ];
    let config = SchedConfig {
        seed: 42,
        ..Default::default()
    };
    let cells = run_campaign(&plat, &jobs, &fault, fifo_cells, &config, 2).unwrap();
    let heavy_payloads = print_cells("torus-10k", &cells);

    // 100k-node ledger: churn + queries through the incremental index,
    // with the O(n) scans as the reference costs
    section("ledger: incremental free-run index vs O(n) scans, 100000 nodes");
    let n = 100_000usize;
    let mut ledger = NodeLedger::new(n);
    for (job, start) in (0..n).step_by(128).enumerate() {
        // allocate alternating 64-node blocks: ~780 fragments to index
        let nodes: Vec<usize> = (start..start + 64).collect();
        ledger.allocate(job as u64, &nodes).unwrap();
    }
    let churn_nodes: Vec<usize> = (64..128).collect();
    let churn = bench("ledger/alloc-release-64-of-100k", 2000, || {
        ledger.allocate(u64::MAX, &churn_nodes).unwrap();
        ledger.release(u64::MAX)
    });
    let index_q = bench("ledger/fragmentation-query-index", 2000, || {
        (ledger.largest_free_run(), ledger.free_runs())
    });
    let scan_q = bench("ledger/fragmentation-query-scan", 50, || {
        (ledger.largest_free_run_scan(), ledger.free_runs_scan())
    });
    assert_eq!(ledger.largest_free_run(), ledger.largest_free_run_scan());
    assert_eq!(ledger.free_runs(), ledger.free_runs_scan());
    println!(
        "index query {:?} vs scan {:?} per call ({:.0}x)",
        index_q.median,
        scan_q.median,
        scan_q.median.as_secs_f64() / index_q.median.as_secs_f64().max(1e-12),
    );

    let payload = JsonValue::obj()
        .set("topologies", JsonValue::Arr(topo_payloads))
        .set(
            "heavy_10k_jobs_10k_nodes",
            JsonValue::obj()
                .set("nodes", JsonValue::Int(10_000))
                .set("jobs", JsonValue::Int(10_000))
                .set("cells", JsonValue::Arr(heavy_payloads)),
        )
        .set(
            "ledger_100k",
            JsonValue::obj()
                .set("nodes", JsonValue::Int(100_000))
                .set("churn", churn.to_json())
                .set("query_index", index_q.to_json())
                .set("query_scan", scan_q.to_json()),
        );
    write_bench_json("campaign", payload).expect("write BENCH_campaign.json");
}
