//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * Eq. 1 inflation factor (1, 10, 100 — the paper found small factors
//!   gave "only a marginal decrease" in abort probability);
//! * window policy: first endpoint-clean vs route-closure-clean window;
//! * refinement on/off in the recursive mapper;
//! * edge-weight metric: traffic volume (G_v) vs message count (G_m) —
//!   the paper tested both and chose volume.

use tofa::apps::{lammps_proxy::LammpsProxy, npb_dt::NpbDt, MpiApp};
use tofa::batch::{BatchConfig, BatchRunner};
use tofa::mapping::recmap::RecursiveMapper;
use tofa::mapping::{cost::hop_bytes_cost, PlacementPolicy};
use tofa::profiler::profile_app;
use tofa::report::bench::section;
use tofa::rng::Rng;
use tofa::sim::executor::Simulator;
use tofa::sim::fault::FaultScenario;
use tofa::tofa::placer::{TofaConfig, TofaPlacer};
use tofa::topology::{Platform, TorusDims};

/// Abort ratio of TOFA batches when the window check is endpoint-only
/// (emulated by degrading the placer via a pre-inflated outage vector is
/// not possible from outside, so we compare full TOFA against
/// Default-Slurm and Scotch-without-refinement instead).
fn main() {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));

    section("ablation: mapper refinement on/off (LAMMPS 64, hop-bytes MB*hop)");
    let app = LammpsProxy::rhodopsin(64);
    let comm = profile_app(&app).volume;
    let dist = platform.hop_matrix();
    for (label, refine) in [("refine-on", true), ("refine-off", false)] {
        let mapper = RecursiveMapper {
            refine,
            ..Default::default()
        };
        let p = mapper.map(&comm, &dist).unwrap();
        let mut sim = Simulator::new(&app, &platform);
        println!(
            "{:<44} {:>12.1} MB*hop  {:>8.1} ts/s",
            label,
            hop_bytes_cost(&comm, &dist, &p.assignment) / 1e6,
            sim.metric_value(&p.assignment)
        );
    }

    section("ablation: G_v (volume) vs G_m (messages) edge weights (NPB-DT)");
    let dt = NpbDt::class_c();
    let prof = profile_app(&dt);
    for (label, graph) in [("weights=volume", &prof.volume), ("weights=messages", &prof.messages)]
    {
        let p = RecursiveMapper::default().map(graph, &dist).unwrap();
        let mut sim = Simulator::new(&dt, &platform);
        println!(
            "{:<44} simulated {:>10.3} s",
            label,
            sim.metric_value(&p.assignment)
        );
    }

    section("ablation: TOFA vs Default under growing fault counts (LAMMPS 64)");
    let app64 = LammpsProxy::rhodopsin(64);
    let mut runner = BatchRunner::new(&app64, &platform);
    for n_faulty in [4usize, 8, 16, 32, 64] {
        let mut master = Rng::new(7);
        let mut scen_rng = master.fork(n_faulty as u64);
        let scenario = FaultScenario::random(512, n_faulty, 0.02, &mut scen_rng);
        let config = BatchConfig {
            instances: 100,
            ..Default::default()
        };
        let mut out = Vec::new();
        for policy in [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa] {
            let mut rng = scen_rng.fork(3);
            let r = runner.run_batch(policy, &scenario, &config, &mut rng).unwrap();
            out.push((r.completion_s, r.abort_ratio()));
        }
        println!(
            "n_f={:<3} default {:>8.1}s ({:>4.1}% abort)   tofa {:>8.1}s ({:>4.1}% abort)",
            n_faulty,
            out[0].0,
            100.0 * out[0].1,
            out[1].0,
            100.0 * out[1].1
        );
    }

    section("ablation: TOFA path taken vs fault count (window availability)");
    let comm64 = profile_app(&app64).volume;
    for n_faulty in [4usize, 8, 16, 32, 64, 128] {
        let mut master = Rng::new(11);
        let mut counts = (0usize, 0usize, 0usize); // window/weighted/other
        for t in 0..20u64 {
            let mut rng = master.fork(t * 131 + n_faulty as u64);
            let scenario = FaultScenario::random(512, n_faulty, 0.02, &mut rng);
            let placement = TofaPlacer::new(TofaConfig::default())
                .place(&comm64, &platform, &scenario.true_outage())
                .unwrap();
            match placement.path {
                tofa::tofa::placer::TofaPath::Window => counts.0 += 1,
                tofa::tofa::placer::TofaPath::FaultWeighted => counts.1 += 1,
                tofa::tofa::placer::TofaPath::FaultFree => counts.2 += 1,
            }
        }
        println!(
            "n_f={:<4} window {:>2}/20  fault-weighted {:>2}/20",
            n_faulty, counts.0, counts.1
        );
    }
}
