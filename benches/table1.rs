//! Bench for Table 1: LAMMPS 256p across torus arrangements.

use tofa::apps::lammps_proxy::LammpsProxy;
use tofa::mapping::{place, PlacementPolicy};
use tofa::profiler::profile_app;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::sim::executor::Simulator;
use tofa::topology::{Platform, TorusDims};

fn main() {
    let app = LammpsProxy::rhodopsin(256);
    let comm = profile_app(&app).volume;
    section("Table 1: LAMMPS 256p timesteps/s per arrangement (simulated)");
    for arr in ["8x8x8", "4x8x16", "8x4x16", "4x4x32", "4x32x4"] {
        let dims = TorusDims::parse(arr).unwrap();
        let platform = Platform::paper_default(dims);
        let dist = platform.hop_matrix();
        for policy in [PlacementPolicy::DefaultSlurm, PlacementPolicy::Scotch] {
            let mut rng = Rng::new(1);
            let p = place(policy, &comm, &dist, &mut rng).unwrap();
            let mut sim = Simulator::new(&app, &platform);
            let v = sim.metric_value(&p.assignment);
            println!("{:<44} {:>10.1} timesteps/s", format!("{arr}/{policy}"), v);
        }
    }
    section("Table 1: end-to-end wall-clock per arrangement (scotch)");
    for arr in ["8x8x8", "4x32x4"] {
        let dims = TorusDims::parse(arr).unwrap();
        let platform = Platform::paper_default(dims);
        let dist = platform.hop_matrix();
        bench(&format!("table1/{arr}/scotch-pipeline"), 3, || {
            let mut rng = Rng::new(1);
            let p = place(PlacementPolicy::Scotch, &comm, &dist, &mut rng).unwrap();
            let mut sim = Simulator::new(&app, &platform);
            sim.metric_value(&p.assignment)
        });
    }
}
