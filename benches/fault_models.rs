//! Fault-model sweep: the Fig. 5a batch experiment under each of the four
//! pluggable fault models (i.i.d. Bernoulli, correlated racks, Weibull
//! lifetimes, trace replay), Default-Slurm vs TOFA.
//!
//! Reports the paper's metrics (batch completion, abort ratio) per model
//! plus the wall-clock of the grid sweep, so regressions in any model's
//! sampling hot path show up alongside its statistical behaviour.

use std::sync::Arc;
use std::time::Instant;

use tofa::apps::lammps_proxy::LammpsProxy;
use tofa::batch::{run_grid, BatchConfig, BatchRunner, Parallelism};
use tofa::mapping::PlacementPolicy;
use tofa::report::bench::section;
use tofa::rng::Rng;
use tofa::sim::fault::{FaultSpec, FaultTrace};
use tofa::topology::{Platform, TorusDims};

/// A synthetic LANL-style trace: every faulty node gets a few down
/// intervals spread over the batch's trace-time span. Deterministic via
/// the seeded RNG, so the bench is reproducible.
fn synthetic_trace(num_nodes: usize, flaky: usize, span_s: f64, rng: &mut Rng) -> FaultTrace {
    let mut text = format!("nodes {num_nodes}\n");
    for node in rng.sample_distinct(num_nodes, flaky) {
        for _ in 0..3 {
            let start = rng.f64() * span_s;
            let len = 0.001 + rng.f64() * 0.05 * span_s;
            text.push_str(&format!("{node} {start} {}\n", start + len));
        }
    }
    FaultTrace::parse(text.as_bytes()).expect("synthetic trace parses")
}

fn main() {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let app = LammpsProxy::rhodopsin(64);
    let policies = [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa];
    let (batches, instances) = (4usize, 100usize);

    let mut trace_rng = Rng::new(2027);
    // ~100 instances x ~0.3 s per run: a 60 s span covers the batch
    let trace = Arc::new(synthetic_trace(512, 16, 60.0, &mut trace_rng));

    let specs: Vec<(&str, FaultSpec)> = vec![
        (
            "iid (paper: 8 faulty @ 2%)",
            FaultSpec::Iid {
                n_faulty: 8,
                p_f: 0.02,
            },
        ),
        (
            "correlated (1 rack @ 5%)",
            FaultSpec::CorrelatedRacks {
                domains: 1,
                p_domain: 0.05,
            },
        ),
        (
            "weibull (8 faulty, k=0.7, p=2% @ 1s)",
            FaultSpec::Weibull {
                n_faulty: 8,
                shape: 0.7,
                p_horizon: 0.02,
                horizon_s: 1.0,
            },
        ),
        ("trace (16 flaky, 3 intervals each)", FaultSpec::Trace { trace }),
    ];

    section(&format!(
        "fault-model sweep: LAMMPS 64p, {batches} batches x {instances} instances, \
         default vs tofa"
    ));
    for (label, fault) in specs {
        let runner = BatchRunner::new(&app, &platform);
        let config = BatchConfig {
            instances,
            fault,
            parallelism: Parallelism::auto(),
            ..Default::default()
        };
        let t0 = Instant::now();
        let grid = run_grid(&runner, &policies, &config, batches, 42).unwrap();
        let wall = t0.elapsed();
        let mut acc = [(0.0f64, 0usize), (0.0f64, 0usize)]; // default, tofa
        for cell in &grid.cells {
            let slot = usize::from(cell.policy == PlacementPolicy::Tofa);
            acc[slot].0 += cell.result.completion_s;
            acc[slot].1 += cell.result.aborted_instances;
        }
        let total = (batches * instances) as f64;
        println!(
            "{label:<40} default {:>9.1}s ({:>4.1}% abort)  tofa {:>9.1}s ({:>4.1}% abort)  \
             wall {wall:>10.3?}",
            acc[0].0,
            100.0 * acc[0].1 as f64 / total,
            acc[1].0,
            100.0 * acc[1].1 as f64 / total,
        );
    }
}
