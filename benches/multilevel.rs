//! Bench for the multilevel coarsen–map–refine mapper: quality and
//! wall-clock against the flat recursive mapper at paper-adjacent sizes,
//! then the scaling sweep the flat mappers cannot enter — up to a
//! million ranks on the 102 400-node torus under the implicit metric,
//! with worker-count bit-identity asserted at the top size.
//!
//! The flat recmap/KL substrate is quadratic-ish in the rank count, so it
//! is expected to win or tie at 256–512 ranks and must lose by 1024; the
//! multilevel mapper's per-rank cost should stay roughly flat through the
//! scaling sweep (near-linear total cost).
//!
//! Emits `BENCH_multilevel.json` at the repo root.

use tofa::commgraph::SparseComm;
use tofa::mapping::multilevel::{hop_bytes_sparse, MultilevelMapper};
use tofa::mapping::recmap::RecursiveMapper;
use tofa::report::bench::{bench, section, write_bench_json, JsonValue, Measurement};
use tofa::topology::{MetricMode, Platform, TorusDims};

fn speedup(slow: &Measurement, fast: &Measurement) -> f64 {
    slow.median.as_secs_f64() / fast.median.as_secs_f64().max(1e-12)
}

/// Head-to-head with the flat recursive mapper on a dense 1024-node
/// torus: same stencil graphs, same Eq. 1 cost, both wall-clocks.
fn quality_section(entries: &mut Vec<JsonValue>) {
    section("multilevel vs recmap: quality and wall-clock (1024-node torus, dense)");
    let plat = Platform::paper_default(TorusDims::new(16, 8, 8));
    let dist = plat.hop_matrix();
    let oracle = plat.hop_oracle();
    let hosts: Vec<usize> = (0..plat.num_nodes()).collect();
    let ml = MultilevelMapper::default();
    let rec = RecursiveMapper::default();
    let mut wall_ratio_at_1024 = 0.0;
    for (px, py) in [(16usize, 16usize), (32, 16), (32, 32)] {
        let n = px * py;
        let g = SparseComm::stencil2d(px, py, 1e6);
        let comm = g.to_matrix();
        let cost = |a: &[usize]| hop_bytes_sparse(&g, a, |u, v| f64::from(oracle.hops(u, v)));
        let m_ml = bench(&format!("multilevel/{n}-on-1024"), 3, || {
            ml.map_sparse(&g, &oracle, &hosts).unwrap()
        });
        let m_rec = bench(&format!("recmap/{n}-on-1024"), 3, || rec.map(&comm, &dist).unwrap());
        let p_ml = ml.map_sparse(&g, &oracle, &hosts).unwrap();
        let p_rec = rec.map(&comm, &dist).unwrap();
        let (c_ml, c_rec) = (cost(&p_ml.assignment), cost(&p_rec.assignment));
        let ratio = speedup(&m_rec, &m_ml);
        if n == 1024 {
            wall_ratio_at_1024 = ratio;
        }
        println!(
            "{n} ranks: multilevel {:.1} vs recmap {:.1} MB*hop; {ratio:.2}x faster",
            c_ml / 1e6,
            c_rec / 1e6
        );
        entries.push(
            JsonValue::obj()
                .set("case", JsonValue::Str(format!("quality-{n}")))
                .set("ranks", JsonValue::Int(n as u64))
                .set("multilevel", m_ml.to_json())
                .set("recmap", m_rec.to_json())
                .set("multilevel_hop_bytes", JsonValue::Num(c_ml))
                .set("recmap_hop_bytes", JsonValue::Num(c_rec))
                .set("recmap_over_multilevel_wall", JsonValue::Num(ratio)),
        );
    }
    // the asymptotic claim: the flat mapper may win at 256-512 ranks,
    // but by 1024 the multilevel mapper must be ahead on wall-clock
    assert!(
        wall_ratio_at_1024 >= 1.0,
        "multilevel slower than recmap at 1024 ranks ({wall_ratio_at_1024:.2}x)"
    );
}

/// Scaling sweep on the 102 400-node torus: 4k to 1M ranks, implicit
/// metric, no O(n^2) state anywhere.
fn scaling_section(entries: &mut Vec<JsonValue>) {
    section("multilevel scaling: 4k -> 1M ranks on the 102400-node torus (implicit)");
    let plat =
        Platform::paper_default(TorusDims::new(64, 40, 40)).with_metric(MetricMode::Implicit);
    let nodes = plat.num_nodes();
    let oracle = plat.hop_oracle();
    let hosts: Vec<usize> = (0..nodes).collect();
    for (px, py) in [(64usize, 64usize), (256, 256), (1024, 1024)] {
        let n = px * py;
        let cap = n.div_ceil(nodes);
        let g = SparseComm::stencil2d(px, py, 1e6);
        let mapper = MultilevelMapper {
            max_per_node: cap,
            ..MultilevelMapper::default()
        };
        let iters = if n >= 1 << 20 { 1 } else { 2 };
        let m = bench(&format!("multilevel/{n}-on-100k"), iters, || {
            mapper.map_sparse(&g, &oracle, &hosts).unwrap()
        });
        let p = mapper.map_sparse(&g, &oracle, &hosts).unwrap();
        let cost = |a: &[usize]| hop_bytes_sparse(&g, a, |u, v| f64::from(oracle.hops(u, v)));
        let c = cost(&p.assignment);
        let per_rank_us = m.median.as_secs_f64() * 1e6 / n as f64;
        println!(
            "{n} ranks (cap {cap}): {:.2} s median, {per_rank_us:.2} us/rank, {:.1} MB*hop",
            m.median.as_secs_f64(),
            c / 1e6
        );
        entries.push(
            JsonValue::obj()
                .set("case", JsonValue::Str(format!("scale-{n}")))
                .set("ranks", JsonValue::Int(n as u64))
                .set("max_per_node", JsonValue::Int(cap as u64))
                .set("map", m.to_json())
                .set("us_per_rank", JsonValue::Num(per_rank_us))
                .set("hop_bytes", JsonValue::Num(c)),
        );
        if n == 1 << 20 {
            acceptance_checks(&g, &plat, &p, cap, entries);
        }
    }
}

/// The ISSUE acceptance bar, checked on the million-rank result: the
/// per-node cap holds, block placement does not beat the mapper, and
/// 2- and 4-worker runs are bit-identical to the serial one.
fn acceptance_checks(
    g: &SparseComm,
    plat: &Platform,
    serial: &tofa::mapping::Placement,
    cap: usize,
    entries: &mut Vec<JsonValue>,
) {
    section("million-rank acceptance: cap, quality floor, worker bit-identity");
    let nodes = plat.num_nodes();
    let oracle = plat.hop_oracle();
    let hosts: Vec<usize> = (0..nodes).collect();
    let mut counts = vec![0u32; nodes];
    for &node in &serial.assignment {
        counts[node] += 1;
    }
    assert!(
        counts.iter().all(|&c| c as usize <= cap),
        "per-node cap {cap} violated"
    );
    let cost = |a: &[usize]| hop_bytes_sparse(g, a, |u, v| f64::from(oracle.hops(u, v)));
    // block packing at the same cap (baselines::block_placement cannot
    // oversubscribe, so build the slot/cap layout directly)
    let block: Vec<usize> = (0..g.len()).map(|s| s / cap).collect();
    let (c_ml, c_block) = (cost(&serial.assignment), cost(&block));
    assert!(c_ml <= c_block, "multilevel lost to block packing: {c_ml} vs {c_block}");
    let mut identical = true;
    for workers in [2usize, 4] {
        let mapper = MultilevelMapper {
            workers,
            max_per_node: cap,
            ..MultilevelMapper::default()
        };
        let p = mapper.map_sparse(g, &oracle, &hosts).unwrap();
        identical &= p.assignment == serial.assignment;
        assert_eq!(p.assignment, serial.assignment, "diverged at {workers} workers");
    }
    println!(
        "1M acceptance: cap {cap} held, {:.1} vs block {:.1} MB*hop, workers bit-identical",
        c_ml / 1e6,
        c_block / 1e6
    );
    entries.push(
        JsonValue::obj()
            .set("case", JsonValue::Str("acceptance-1M".to_string()))
            .set("worker_bit_identical", JsonValue::Bool(identical))
            .set("hop_bytes", JsonValue::Num(c_ml))
            .set("block_hop_bytes", JsonValue::Num(c_block)),
    );
}

fn main() {
    let mut entries = Vec::new();
    quality_section(&mut entries);
    scaling_section(&mut entries);
    let payload = JsonValue::obj().set("entries", JsonValue::Arr(entries));
    write_bench_json("multilevel", payload).expect("write BENCH_multilevel.json");
}
