//! Cross-topology sweep: the same workload, placement pipeline, and batch
//! experiment on all three platform families (torus, fat-tree, dragonfly).
//!
//! Reports per topology: the structural profile (nodes, links, diameter,
//! bisection links), the cost of building the hop matrix and a TOFA
//! placement, and a reduced Fig. 5-style batch grid under the correlated
//! fault model (racks = X-lines / pods / groups respectively) — the
//! experiment the paper could not run beyond the torus.

use std::sync::Arc;

use tofa::apps::lammps_proxy::LammpsProxy;
use tofa::batch::{run_grid, BatchConfig, BatchRunner, Parallelism};
use tofa::mapping::PlacementPolicy;
use tofa::profiler::profile_app;
use tofa::report::bench::{bench, section};
use tofa::rng::Rng;
use tofa::sim::fault::FaultSpec;
use tofa::tofa::TofaPlacer;
use tofa::topology::{ArchGraph, Dragonfly, DragonflyParams, FatTree, Platform, TorusDims};

fn platforms() -> Vec<Platform> {
    vec![
        Platform::paper_default(TorusDims::new(8, 8, 8)), // 512 nodes
        Platform::paper_default_on(Arc::new(FatTree::new(8).unwrap())), // 128 nodes
        Platform::paper_default_on(Arc::new(
            Dragonfly::new(DragonflyParams::new(9, 4, 4, 2)).unwrap(), // 144 nodes
        )),
    ]
}

fn main() {
    section("topology structural profile");
    for plat in platforms() {
        let t = plat.topology();
        let dist = plat.hop_matrix();
        // graph-level eccentricity over the full vertex set (switches
        // included), from the physical-link graph
        let g = ArchGraph::from_topology(t);
        let far = g.pseudo_peripheral(0);
        let ecc = g.bfs_hops(far).into_iter().filter(|&d| d != usize::MAX).max().unwrap();
        println!(
            "{:<44} {:>5} nodes {:>6} links  diameter {:>2} (graph {:>2})  bisection {:>4}  racks {:>3}",
            t.describe(),
            t.num_nodes(),
            t.all_links().len(),
            dist.max(),
            ecc,
            t.bisection_links(),
            t.num_racks(),
        );
    }

    section("hop matrix + TOFA placement per topology (LAMMPS 64p)");
    let app = LammpsProxy::rhodopsin(64);
    let comm = profile_app(&app).volume;
    for plat in platforms() {
        let kind = plat.topology().kind();
        bench(&format!("hop-matrix/{kind}"), 5, || plat.hop_matrix());
        let mut outage = vec![0.0; plat.num_nodes()];
        let mut rng = Rng::new(3);
        for f in rng.sample_distinct(plat.num_nodes(), plat.num_nodes() / 32) {
            outage[f] = 0.02;
        }
        bench(&format!("tofa-place/{kind}"), 5, || {
            TofaPlacer::default().place(&comm, &plat, &outage).unwrap()
        });
    }

    section("batch grid under correlated domains (2 batches x 2 policies x 25)");
    let policies = [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa];
    for plat in platforms() {
        let kind = plat.topology().kind();
        let runner = BatchRunner::new(&app, &plat);
        let config = BatchConfig {
            instances: 25,
            fault: FaultSpec::CorrelatedRacks {
                domains: 2,
                p_domain: 0.05,
            },
            parallelism: Parallelism::fixed(2),
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let grid = run_grid(&runner, &policies, &config, 2, 42).unwrap();
        let wall = t0.elapsed();
        let (mut sum_d, mut sum_t) = (0.0f64, 0.0f64);
        for pair in grid.cells.chunks(2) {
            sum_d += pair[0].result.completion_s;
            sum_t += pair[1].result.completion_s;
        }
        println!(
            "{:<44} default {:>9.1} s  tofa {:>9.1} s  improvement {:>5.1}%  wall {:?}",
            format!("grid/{kind}"),
            sum_d,
            sum_t,
            (sum_d - sum_t) / sum_d * 100.0,
            wall
        );
    }
}
