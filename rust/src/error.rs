//! Crate-wide error type.

use std::fmt;

/// Errors surfaced by the TOFA library.
#[derive(Debug)]
pub enum Error {
    /// A placement request cannot be satisfied (e.g. more ranks than nodes).
    Placement(String),
    /// Topology construction / routing errors.
    Topology(String),
    /// An operation needs a topology family the platform does not have
    /// (e.g. the torus-only FATT topology file format).
    UnsupportedTopology(String),
    /// Simulation invariant violations.
    Simulation(String),
    /// Fault-model configuration / trace parse errors.
    Fault(String),
    /// PJRT runtime / artifact errors.
    Runtime(String),
    /// Slurm-lite protocol errors.
    Slurm(String),
    /// Workload-trace parse / generator configuration errors.
    Workload(String),
    /// I/O or parse errors.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Placement(m) => write!(f, "placement error: {m}"),
            Error::Topology(m) => write!(f, "topology error: {m}"),
            Error::UnsupportedTopology(m) => write!(f, "unsupported topology: {m}"),
            Error::Simulation(m) => write!(f, "simulation error: {m}"),
            Error::Fault(m) => write!(f, "fault-model error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Slurm(m) => write!(f, "slurm error: {m}"),
            Error::Workload(m) => write!(f, "workload error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
