//! `slurm::sched` — a discrete-event cluster scheduler running
//! **concurrent** jobs on shared allocation state.
//!
//! The paper's Section 5.2 pushes batches of 100 MPI jobs through a Slurm
//! queue; [`crate::batch`] reproduces the *accounting* of that experiment
//! but schedules one job at a time against an always-empty cluster. This
//! module models the cluster itself: a [`NodeLedger`] (per-node
//! free/busy/down occupancy owned by the
//! [`crate::slurm::controller::Controller`]), jobs with arrival / start /
//! end times, and an event loop over job arrivals, job completions, abort
//! -> resubmit cycles, and heartbeat health epochs. FANS/TOFA select only
//! from the ledger's free nodes (the candidate mask threaded through
//! [`crate::slurm::plugins::fans::FansPlugin::select`]), so fault-aware
//! placement now interacts with *fragmentation*: under contention the
//! free set shreds, TOFA's consecutive-id windows vanish, and placement
//! falls back to the Eq. 1 fault-weighted path — the candidate-set-shape
//! effect the QAP mapping literature observes for restricted node sets.
//!
//! Two queueing policies:
//!
//! * **FIFO** — strict arrival order; the head blocks the queue until it
//!   fits.
//! * **Conservative backfill** — when the head does not fit, compute its
//!   *shadow time* (the earliest instant enough capacity could exist:
//!   exact end times of running jobs, clamped further to the next
//!   heartbeat epoch when Down-node recovery could free capacity sooner)
//!   and start later jobs now iff they are guaranteed to finish by then.
//!   The simulator knows each run's exact duration at start time (where
//!   real Slurm would trust the walltime limit), so a backfilled job can
//!   **never** delay the head — asserted per decision via
//!   [`SchedResult::backfill_audit`].
//!
//! Everything is deterministic: events are ordered by `(time, sequence)`,
//! per-(job, attempt) fault draws come from [`Rng::stream`], and the
//! sweep fan-out ([`run_sweep`]) shards cells with the same machinery as
//! the batch engine, so results are bit-identical for every worker count.
//!
//! The ledger at the heart of it all:
//!
//! ```
//! use tofa::slurm::sched::NodeLedger;
//!
//! let mut ledger = NodeLedger::new(8);
//! ledger.allocate(1, &[2, 3, 4]).unwrap();
//! assert_eq!(ledger.num_free(), 5);
//! assert_eq!(ledger.free_nodes(), vec![0, 1, 5, 6, 7]);
//! // release returns the freed ids (idempotent — not a Result)
//! assert_eq!(ledger.release(1), vec![2, 3, 4]);
//! assert_eq!(ledger.num_free(), 8);
//! ```

pub mod campaign;
pub mod ledger;
pub mod recovery;
pub mod workload;

pub use campaign::{run_campaign, CampaignCell, CampaignMetrics};
pub use ledger::{NodeLedger, NodeState};
pub use recovery::{shrink_degradation, RecoveryPolicy};
pub use workload::{Arrivals, CampaignWorkload, TraceConfig};

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::apps::lammps_proxy::LammpsProxy;
use crate::batch::parallel::run_sharded;
use crate::commgraph::CommMatrix;
use crate::error::{Error, Result};
use crate::mapping::PlacementPolicy;
use crate::profiler::profile_app;
use crate::rng::{streams, Rng};
use crate::sim::cache::PhaseCache;
use crate::sim::executor::Simulator;
use crate::sim::fault::{FaultCtx, FaultScenario, FaultSpec};
use crate::slurm::controller::Controller;
use crate::slurm::jobs::{JobRecord, JobRequest, JobState};
use crate::topology::Platform;

/// Stop pushing heartbeat epochs after this many consecutive epochs with
/// nothing running and no arrivals left (pending jobs that the health
/// process will clearly never unblock — e.g. permanently-down nodes — are
/// then parked as `Failed` by the starvation drain instead of beating
/// forever).
const MAX_IDLE_HEARTBEATS: u32 = 1000;

/// One job of a scheduler workload: an application class (ranks, steps)
/// arriving at a simulated time.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedJobSpec {
    /// Job name (reporting).
    pub name: String,
    /// MPI ranks requested.
    pub ranks: usize,
    /// Application timesteps (LAMMPS-proxy workload intensity).
    pub steps: usize,
    /// Simulated arrival time.
    pub arrival_s: f64,
}

/// Workload generator: `jobs` jobs drawn from a rank-size `mix`, arriving
/// all at once (`mean_interarrival_s == 0`, the paper's batch dump) or as
/// a Poisson-like process with exponential interarrival times.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Jobs to generate (paper: 100).
    pub jobs: usize,
    /// Mean interarrival gap in simulated seconds (0 = all at t = 0).
    pub mean_interarrival_s: f64,
    /// `(ranks, weight)` job-size mix; weights are normalized.
    pub mix: Vec<(usize, f64)>,
    /// Timesteps per job (workload intensity knob).
    pub steps: usize,
    /// Workload RNG seed (sizes + arrival gaps).
    pub seed: u64,
}

impl WorkloadSpec {
    /// A mix scaled to the platform: small (n/32) / medium (n/16) / large
    /// (n/8) jobs at 50/30/20 %, 100 jobs, batch-dump arrivals.
    pub fn paper_like(num_nodes: usize) -> Self {
        let unit = (num_nodes / 32).max(2);
        WorkloadSpec {
            jobs: 100,
            mean_interarrival_s: 0.0,
            mix: vec![(unit, 0.5), (unit * 2, 0.3), (unit * 4, 0.2)],
            steps: 3,
            seed: 7,
        }
    }

    /// Materialize the job list (deterministic in `self.seed`).
    pub fn generate(&self) -> Vec<SchedJobSpec> {
        assert!(!self.mix.is_empty(), "empty job-size mix");
        let total_w: f64 = self.mix.iter().map(|(_, w)| w).sum();
        assert!(total_w > 0.0, "job-size mix has zero total weight");
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0f64;
        (0..self.jobs)
            .map(|i| {
                let mut pick = rng.f64() * total_w;
                let mut ranks = self.mix[self.mix.len() - 1].0;
                for &(r, w) in &self.mix {
                    if pick < w {
                        ranks = r;
                        break;
                    }
                    pick -= w;
                }
                if self.mean_interarrival_s > 0.0 && i > 0 {
                    // exponential interarrival (Poisson process)
                    t += -self.mean_interarrival_s * (1.0 - rng.f64()).ln();
                }
                SchedJobSpec {
                    name: format!("lammps:{ranks}"),
                    ranks,
                    steps: self.steps,
                    arrival_s: t,
                }
            })
            .collect()
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Placement policy every job requests (`--distribution`).
    pub placement: PlacementPolicy,
    /// Conservative backfill on top of FIFO.
    pub backfill: bool,
    /// Give up on a job after this many aborts (terminal `Failed`).
    pub max_restarts: u32,
    /// Heartbeat health-epoch period in simulated seconds (0 = disabled).
    /// Each epoch samples a down-state from the fault scenario and flips
    /// non-busy ledger nodes free <-> down accordingly.
    pub heartbeat_period_s: f64,
    /// What a failed run does next: abort → resubmit (default, the
    /// paper's model, bit-identical to the pre-recovery scheduler),
    /// checkpoint/restart, or ULFM-style shrink-and-continue.
    pub recovery: RecoveryPolicy,
    /// Wall-clock cost of one checkpoint write (only read under
    /// [`RecoveryPolicy::CheckpointRestart`]).
    pub ckpt_cost_s: f64,
    /// Base seed (placement RNG + per-(job, attempt) fault streams).
    pub seed: u64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            placement: PlacementPolicy::Tofa,
            backfill: false,
            max_restarts: 100,
            heartbeat_period_s: 0.0,
            recovery: RecoveryPolicy::AbortResubmit,
            ckpt_cost_s: 0.05,
            seed: 42,
        }
    }
}

impl SchedConfig {
    /// Validate the recovery/scheduler knobs: degenerate checkpoint
    /// intervals/costs and non-finite heartbeat periods are typed
    /// [`Error::Workload`]s naming the offending field. Called by
    /// [`run_sweep`] and [`run_campaign`] before any cell runs.
    pub fn validate(&self) -> Result<()> {
        self.recovery.validate(self.ckpt_cost_s)?;
        if !self.heartbeat_period_s.is_finite() || self.heartbeat_period_s < 0.0 {
            return Err(Error::Workload(format!(
                "heartbeat_period_s must be finite and >= 0, got {}",
                self.heartbeat_period_s
            )));
        }
        Ok(())
    }
}

/// One entry of the deterministic event trace (the scheduler's ground
/// truth for tests: worker-count invariance compares whole traces, the
/// no-overlap invariant replays `Start`/`End`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Simulated time of the event.
    pub t: f64,
    /// What happened.
    pub kind: TraceKind,
}

/// Event trace entry kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceKind {
    /// Job arrived in the queue.
    Submit {
        /// Job id.
        job: u64,
    },
    /// Job launched on `nodes` (exclusive allocation).
    Start {
        /// Job id.
        job: u64,
        /// Allocated nodes.
        nodes: Vec<usize>,
        /// True if the launch jumped the queue via backfill.
        backfilled: bool,
    },
    /// Job released its nodes; `aborted` runs are resubmitted or failed.
    End {
        /// Job id.
        job: u64,
        /// True if the run aborted (down node in the touched set).
        aborted: bool,
    },
    /// Job left the system as `Failed` (unplaceable / starved / budget
    /// exhausted).
    Fail {
        /// Job id.
        job: u64,
    },
    /// Heartbeat health epoch applied to the ledger.
    Heartbeat {
        /// Epoch counter.
        epoch: u64,
        /// Nodes the epoch sampled as down.
        down: usize,
    },
    /// Checkpoint `k` of the current run committed (checkpoint/restart).
    Ckpt {
        /// Job id.
        job: u64,
        /// Checkpoint index within the run (1-based).
        k: u32,
    },
    /// ULFM-style shrink-replace: the ranks hosted on `lost` moved to
    /// `repl`; survivors kept their nodes and the job continues degraded.
    Shrink {
        /// Job id.
        job: u64,
        /// Hosts lost to the failure (now `Down` in the ledger).
        lost: Vec<usize>,
        /// Replacement hosts (newly added to the allocation).
        repl: Vec<usize>,
    },
}

/// One committed backfill decision, for the never-delays-the-head audit.
#[derive(Debug, Clone, PartialEq)]
pub struct BackfillAudit {
    /// The backfilled job.
    pub job: u64,
    /// The queue head it jumped over.
    pub head: u64,
    /// Commit time.
    pub t: f64,
    /// The head's shadow time at commit — a lower bound on when the head
    /// could possibly start; the backfilled job was guaranteed (exact
    /// durations) to release its nodes by then. Without heartbeat churn
    /// the shadow is exact, so `head.start_s <= shadow` holds; with
    /// health epochs the head may start later than the (recovery-
    /// optimistic) bound, but never *because of* the backfilled job.
    pub shadow: f64,
}

/// One point of the cluster-occupancy timeline, sampled after the
/// scheduling pass at each distinct event timestamp. The fragmentation
/// fields read the ledger's incremental free-run index, so sampling is
/// O(log n) even on 100k-node platforms.
#[derive(Debug, Clone, PartialEq)]
pub struct OccupancySample {
    /// Simulated time of the sample.
    pub t: f64,
    /// Busy nodes.
    pub busy: usize,
    /// Down nodes.
    pub down: usize,
    /// Longest run of consecutive free node ids.
    pub largest_free_run: usize,
    /// Number of maximal free runs.
    pub free_runs: usize,
}

/// Result of one scheduler run.
#[derive(Debug, Clone)]
pub struct SchedResult {
    /// Batch completion: time the last job left the system.
    pub makespan_s: f64,
    /// Mean queue wait over jobs that launched at least once.
    pub mean_wait_s: f64,
    /// Max queue wait.
    pub max_wait_s: f64,
    /// Busy node-seconds / (nodes x makespan).
    pub utilization: f64,
    /// Jobs that completed.
    pub completed: usize,
    /// Jobs that left as `Failed` without exhausting restarts
    /// (unplaceable or starved).
    pub failed: usize,
    /// Jobs that exhausted their restart budget.
    pub exhausted: usize,
    /// Total aborts (each cost one held-allocation run interval).
    pub total_aborts: usize,
    /// Committed backfill decisions.
    pub backfills: usize,
    /// Node-seconds held without useful progress: rollback intervals,
    /// checkpoint write costs, shrink degradation overhead, and work
    /// revoked by shrink fallbacks.
    pub lost_node_s: f64,
    /// Checkpoints committed (checkpoint/restart).
    pub ckpts: u64,
    /// Successful shrink-replace recoveries (shrink-and-continue).
    pub shrinks: u64,
    /// Shrink failures that fell back to abort → resubmit (no surviving
    /// rank lost a host, no free replacements, or the per-run replace
    /// budget ran out).
    pub shrink_fallbacks: u64,
    /// Jobs submitted.
    pub total_jobs: usize,
    /// Terminal job records (`squeue`-style accounting: every submitted
    /// job appears exactly once, with times and outcome filled in).
    pub records: Vec<JobRecord>,
    /// Deterministic event trace.
    pub trace: Vec<TraceEvent>,
    /// Per-decision backfill audit.
    pub backfill_audit: Vec<BackfillAudit>,
    /// Occupancy/fragmentation timeline (one sample per distinct event
    /// timestamp, after that instant's scheduling pass).
    pub occupancy: Vec<OccupancySample>,
}

impl SchedResult {
    /// Sum of per-job completion intervals (the paper's batch-completion
    /// accounting: one run interval per launch, aborted or not).
    pub fn total_completion_s(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.completion_s.unwrap_or(0.0))
            .sum()
    }

    /// Sorted queue-wait samples (jobs that launched at least once).
    pub fn wait_samples(&self) -> Vec<f64> {
        let mut ws: Vec<f64> = self.records.iter().filter_map(JobRecord::wait_s).collect();
        ws.sort_by(f64::total_cmp);
        ws
    }

    /// Sorted slowdown samples over completed jobs: turnaround
    /// (`end - submit`) over accumulated run time (`completion_s`) — the
    /// queueing-theory "how much longer than its own runtime did this job
    /// spend in the system" ratio (1.0 = never waited). Jobs with a zero
    /// accumulated runtime are skipped, so the samples are always finite.
    pub fn slowdown_samples(&self) -> Vec<f64> {
        let mut ss: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.state == JobState::Completed)
            .filter_map(|r| {
                let run = r.completion_s?;
                let end = r.end_s?;
                (run > 0.0).then(|| (end - r.submit_s) / run)
            })
            .collect();
        ss.sort_by(f64::total_cmp);
        ss
    }
}

/// Discrete-event heap entry: `(time bits, sequence, event)`; times are
/// non-negative so the f64 bit pattern orders numerically, and the
/// sequence makes simultaneous events fire in creation order.
type HeapEntry = Reverse<(u64, u64, Event)>;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    Arrival { spec: u32 },
    JobEnd { job: u64, aborted: bool },
    Heartbeat { epoch: u64 },
    /// Checkpoint `k` of run `attempt` commits (checkpoint/restart only).
    Checkpoint { job: u64, attempt: u32, k: u32 },
    /// The current shrink segment of run `attempt` hits its failure
    /// instant: re-place the lost ranks and continue, or fall back to
    /// abort → resubmit (shrink-and-continue only).
    ShrinkReplace { job: u64, attempt: u32 },
}

/// One application class of the workload (distinct `(ranks, steps)`), with
/// its profiled comm graph and a simulator sharing the phase cache.
struct AppClass {
    ranks: usize,
    steps: usize,
    comm: CommMatrix,
    sim: Simulator,
}

/// Checkpoint/restart state of one run (fixed at plan time).
struct CkptRun {
    /// `record.fault_draws` at launch (staleness guard for events).
    attempt: u32,
    /// `record.progress` at launch.
    base_progress: f64,
    /// Progress fraction one checkpoint commits (`interval_s / success_s`).
    ck_frac: f64,
    /// Useful-work seconds between checkpoint writes.
    interval_s: f64,
    /// Wall-clock cost of one checkpoint write.
    cost_s: f64,
    /// Fault-free seconds of work remaining at launch.
    work_s: f64,
    /// Checkpoints this run will commit (`kmax` clean, `j` on abort).
    committed: u32,
}

/// Shrink-and-continue state of the *current segment* of one run.
struct ShrinkRun {
    /// `record.fault_draws` for this segment's fault draw.
    attempt: u32,
    /// True if this segment ends in a `ShrinkReplace` event (a failure);
    /// false if it runs clean to `JobEnd`.
    fails: bool,
    /// Fraction of the whole job durably done at segment start.
    frac_done: f64,
    /// Collective-cost degradation factor in force this segment.
    degrade: f64,
    /// Fault-free seconds of work this segment covers.
    seg_work: f64,
    /// Failure location within the segment (uniform draw; failing
    /// segments complete `seg_u * seg_work` useful seconds first).
    seg_u: f64,
    /// Hosts the failing draw takes down (empty for clean segments or
    /// transit-only failures — the latter force a fallback).
    lost_hosts: Vec<usize>,
    /// Useful seconds committed by earlier segments of this run (revoked
    /// if the run falls back to abort → resubmit).
    work_credited: f64,
    /// Shrink-replaces performed this run (bounded by `max_restarts`).
    replaces: u32,
}

/// What the in-flight run does on failure (per-run recovery state).
enum RunRecovery {
    /// Abort → resubmit: the run holds one full interval and ends.
    Abort,
    /// Checkpoint/restart state.
    Ckpt(CkptRun),
    /// Shrink-and-continue segment state.
    Shrink(Box<ShrinkRun>),
}

/// A fully-resolved run (or first shrink segment): wall-clock duration,
/// terminal abort flag, and the recovery state to carry on the running
/// job. Pure in `(job, fault_draws, assignment, progress)`, so backfill
/// can probe and roll back without consuming randomness.
struct RunPlan {
    duration: f64,
    aborted: bool,
    attempt: u32,
    rec: RunRecovery,
}

impl RunPlan {
    /// True if the run can outlive `duration` (a failing shrink segment
    /// continues after its `ShrinkReplace` event), which disqualifies it
    /// from conservative backfill.
    fn extends_past_end(&self) -> bool {
        matches!(&self.rec, RunRecovery::Shrink(s) if s.fails)
    }
}

struct RunningJob {
    record: JobRecord,
    end_s: f64,
    duration: f64,
    rec: RunRecovery,
}

/// The event-driven cluster scheduler.
pub struct ClusterScheduler {
    platform: Platform,
    controller: Controller,
    config: SchedConfig,
    scenario: FaultScenario,
    specs: Vec<SchedJobSpec>,
    classes: Vec<AppClass>,
    /// spec index -> class index.
    class_of_spec: Vec<usize>,
    /// job id -> class index (ids are assigned sequentially at arrival).
    class_of_job: Vec<usize>,
    /// job id -> accumulated completion interval (paper accounting).
    acc_completion: Vec<f64>,
    heap: BinaryHeap<HeapEntry>,
    seq: u64,
    running: Vec<RunningJob>,
    arrivals_left: usize,
    idle_heartbeats: u32,
    /// When the next heartbeat epoch fires (`f64::INFINITY` once the
    /// chain stops or when heartbeats are disabled). Backfill uses it to
    /// bound how early Down-node recovery could free capacity.
    next_heartbeat_s: f64,
    stream_base: u64,
    hb_base: u64,
    /// Stream base for recovery-time draws (checkpoint/shrink failure
    /// locations); a separate base so the fault streams stay untouched
    /// and `AbortResubmit` remains bit-identical.
    recovery_base: u64,
    trace: Vec<TraceEvent>,
    backfill_audit: Vec<BackfillAudit>,
    occupancy: Vec<OccupancySample>,
    busy_node_s: f64,
    backfills: usize,
    completed: usize,
    failed: usize,
    exhausted: usize,
    total_aborts: usize,
    lost_node_s: f64,
    ckpts: u64,
    shrinks: u64,
    shrink_fallbacks: u64,
    now: f64,
}

impl ClusterScheduler {
    /// Build a scheduler for a generated workload under a fault scenario.
    pub fn new(
        platform: &Platform,
        workload: &WorkloadSpec,
        scenario: FaultScenario,
        config: SchedConfig,
    ) -> Self {
        Self::with_jobs(platform, workload.generate(), scenario, config)
    }

    /// Build a scheduler for an explicit job list with a private phase
    /// cache.
    pub fn with_jobs(
        platform: &Platform,
        specs: Vec<SchedJobSpec>,
        scenario: FaultScenario,
        config: SchedConfig,
    ) -> Self {
        Self::with_jobs_cached(platform, specs, scenario, config, Arc::new(PhaseCache::new()))
    }

    /// Build a scheduler for an explicit job list reusing `cache` —
    /// sweeps pass one cache so cells replay each other's network solves
    /// (sharing never changes results; see [`PhaseCache`]). Outage
    /// estimates are oracle (the scenario's true per-node vector), the
    /// mode the batch experiments default to.
    pub fn with_jobs_cached(
        platform: &Platform,
        specs: Vec<SchedJobSpec>,
        scenario: FaultScenario,
        config: SchedConfig,
        cache: Arc<PhaseCache>,
    ) -> Self {
        assert_eq!(scenario.num_nodes(), platform.num_nodes());
        let mut controller = Controller::new(platform.clone(), config.seed);
        controller.set_outage_estimates(&scenario.true_outage());
        // one simulator per distinct app class, all on the shared cache
        let mut classes: Vec<AppClass> = Vec::new();
        let mut class_of_spec = Vec::with_capacity(specs.len());
        for s in &specs {
            let found = classes
                .iter()
                .position(|c| c.ranks == s.ranks && c.steps == s.steps);
            let idx = match found {
                Some(i) => i,
                None => {
                    let app = LammpsProxy::tiny(s.ranks, s.steps);
                    classes.push(AppClass {
                        ranks: s.ranks,
                        steps: s.steps,
                        comm: profile_app(&app).volume,
                        sim: Simulator::with_cache(&app, platform, Arc::clone(&cache)),
                    });
                    classes.len() - 1
                }
            };
            class_of_spec.push(idx);
        }
        // all three bases come from the central rng::streams registry
        // (the `rng-stream-registry` lint enforces this); the derivation
        // is bit-identical to the historical three sequential draws off
        // Rng::new(seed ^ SCHED_SALT), so traces are unchanged
        let stream_base = streams::sched_base(config.seed, streams::SCHED_JOB_DRAW);
        let hb_base = streams::sched_base(config.seed, streams::SCHED_HEARTBEAT_DRAW);
        let recovery_base = streams::sched_base(config.seed, streams::SCHED_RECOVERY_DRAW);
        let mut sched = ClusterScheduler {
            platform: platform.clone(),
            controller,
            config,
            scenario,
            specs,
            classes,
            class_of_spec,
            class_of_job: Vec::new(),
            acc_completion: Vec::new(),
            heap: BinaryHeap::new(),
            seq: 0,
            running: Vec::new(),
            arrivals_left: 0,
            idle_heartbeats: 0,
            next_heartbeat_s: f64::INFINITY,
            stream_base,
            hb_base,
            recovery_base,
            trace: Vec::new(),
            backfill_audit: Vec::new(),
            occupancy: Vec::new(),
            busy_node_s: 0.0,
            backfills: 0,
            completed: 0,
            failed: 0,
            exhausted: 0,
            total_aborts: 0,
            lost_node_s: 0.0,
            ckpts: 0,
            shrinks: 0,
            shrink_fallbacks: 0,
            now: 0.0,
        };
        for i in 0..sched.specs.len() {
            let t = sched.specs[i].arrival_s;
            sched.push_event(t, Event::Arrival { spec: i as u32 });
        }
        sched.arrivals_left = sched.specs.len();
        if sched.config.heartbeat_period_s > 0.0 {
            let period = sched.config.heartbeat_period_s;
            sched.next_heartbeat_s = period;
            sched.push_event(period, Event::Heartbeat { epoch: 1 });
        }
        sched
    }

    fn push_event(&mut self, t: f64, ev: Event) {
        debug_assert!(t >= 0.0 && t.is_finite());
        self.seq += 1;
        self.heap.push(Reverse((t.to_bits(), self.seq, ev)));
    }

    /// Run the event loop to completion and report.
    pub fn run(mut self) -> SchedResult {
        while let Some(Reverse((t_bits, _, ev))) = self.heap.pop() {
            let t = f64::from_bits(t_bits);
            self.now = t;
            self.handle(t, ev);
            // drain every event at this timestamp before scheduling, so
            // simultaneous completions free all their nodes first
            while let Some(&Reverse((nt, _, _))) = self.heap.peek() {
                if nt != t_bits {
                    break;
                }
                let Some(Reverse((_, _, ev))) = self.heap.pop() else {
                    break;
                };
                self.handle(t, ev);
            }
            self.try_schedule(t);
            let sample = {
                let ledger = self.controller.ledger();
                OccupancySample {
                    t,
                    busy: ledger.num_busy(),
                    down: ledger.num_down(),
                    largest_free_run: ledger.largest_free_run(),
                    free_runs: ledger.free_runs(),
                }
            };
            self.occupancy.push(sample);
        }
        // no events left: anything still pending can never start (e.g.
        // permanently down nodes under FIFO) — park it as Failed so no
        // job ever silently disappears from the accounting
        while let Some(mut record) = self.controller.take_pending(0) {
            record.error = Some("starved: no remaining event can free enough nodes".into());
            let id = record.id;
            let t = self.now;
            self.controller.complete(record, JobState::Failed);
            self.failed += 1;
            self.trace.push(TraceEvent {
                t,
                kind: TraceKind::Fail { job: id },
            });
        }
        self.report()
    }

    fn handle(&mut self, t: f64, ev: Event) {
        match ev {
            Event::Arrival { spec } => {
                let s = &self.specs[spec as usize];
                let ranks = s.ranks;
                let class = self.class_of_spec[spec as usize];
                let request = JobRequest {
                    name: s.name.clone(),
                    ranks,
                    distribution: self.config.placement,
                    comm_graph: Some(self.classes[class].comm.clone()),
                };
                let id = self.controller.submit_at(request, t);
                debug_assert_eq!(id as usize, self.class_of_job.len());
                self.class_of_job.push(class);
                self.acc_completion.push(0.0);
                self.arrivals_left -= 1;
                self.trace.push(TraceEvent {
                    t,
                    kind: TraceKind::Submit { job: id },
                });
                // reject jobs no platform state could ever host right at
                // submit time with a typed error (they used to churn in
                // the queue until the starvation drain parked them with a
                // generic failure)
                let num_nodes = self.platform.num_nodes();
                if ranks > num_nodes {
                    let pos = self.controller.pending_len() - 1;
                    // invariant: submit() pushed this job onto pending right
                    // above, so the queue is non-empty and `pos` is in range
                    let mut record = self.controller.take_pending(pos).expect("just submitted");
                    debug_assert_eq!(record.id, id);
                    record.error = Some(
                        Error::Workload(format!(
                            "job {id} requests {ranks} ranks but the platform hosts \
                             {num_nodes} nodes"
                        ))
                        .to_string(),
                    );
                    record.end_s = Some(t);
                    self.controller.complete(record, JobState::Failed);
                    self.failed += 1;
                    self.trace.push(TraceEvent {
                        t,
                        kind: TraceKind::Fail { job: id },
                    });
                }
            }
            Event::JobEnd { job, aborted } => {
                let pos = self
                    .running
                    .iter()
                    .position(|r| r.record.id == job)
                    // invariant: JobEnd events are only pushed by launch(),
                    // which inserts the job into `running` first
                    .expect("JobEnd for a job that is not running");
                let rj = self.running.remove(pos);
                let mut record = rj.record;
                let nodes = record.assignment.as_ref().map_or(0, Vec::len);
                self.busy_node_s += rj.duration * nodes as f64;
                self.acc_completion[job as usize] += rj.duration;
                // useful/lost split of this run's wall clock: rolled-back
                // intervals, checkpoint writes, and shrink degradation all
                // count as lost node-seconds
                let (useful_run, lost_run) = match &rj.rec {
                    RunRecovery::Abort => {
                        if aborted {
                            (0.0, rj.duration)
                        } else {
                            (rj.duration, 0.0)
                        }
                    }
                    RunRecovery::Ckpt(c) => {
                        if aborted {
                            let u = c.committed as f64 * c.interval_s;
                            (u, rj.duration - u)
                        } else {
                            (c.work_s, rj.duration - c.work_s)
                        }
                    }
                    RunRecovery::Shrink(s) => (s.seg_work, rj.duration - s.seg_work),
                };
                record.useful_s += useful_run;
                record.lost_node_s += lost_run * nodes as f64;
                self.lost_node_s += lost_run * nodes as f64;
                self.trace.push(TraceEvent {
                    t,
                    kind: TraceKind::End { job, aborted },
                });
                if !aborted {
                    let acc = self.acc_completion[job as usize];
                    let aborts = record.aborts;
                    self.controller
                        .complete_with(record, JobState::Completed, acc, aborts, t);
                    self.completed += 1;
                } else {
                    record.aborts += 1;
                    self.total_aborts += 1;
                    if record.aborts >= self.config.max_restarts {
                        record.error = Some(format!(
                            "exhausted restart budget after {} aborts",
                            record.aborts
                        ));
                        let acc = self.acc_completion[job as usize];
                        let aborts = record.aborts;
                        self.controller
                            .complete_with(record, JobState::Failed, acc, aborts, t);
                        self.exhausted += 1;
                        self.trace.push(TraceEvent {
                            t,
                            kind: TraceKind::Fail { job },
                        });
                    } else {
                        // abort -> resubmit at the queue tail: the restart
                        // re-queues like a fresh arrival (original
                        // submit_s and abort count are kept)
                        self.controller.resubmit(record);
                    }
                }
            }
            Event::Checkpoint { job, attempt, k } => {
                // commit durable progress for a still-running attempt; a
                // stale event (the attempt it belonged to already ended)
                // is a no-op thanks to the attempt guard
                if let Some(rj) = self.running.iter_mut().find(|r| r.record.id == job) {
                    if let RunRecovery::Ckpt(c) = &rj.rec {
                        if c.attempt == attempt {
                            rj.record.progress = (c.base_progress + k as f64 * c.ck_frac).min(1.0);
                            rj.record.ckpts += 1;
                            self.ckpts += 1;
                            self.trace.push(TraceEvent {
                                t,
                                kind: TraceKind::Ckpt { job, k },
                            });
                        }
                    }
                }
            }
            Event::ShrinkReplace { job, attempt } => {
                let pos = self
                    .running
                    .iter()
                    .position(|r| r.record.id == job)
                    // invariant: ShrinkReplace is only pushed while the
                    // shrunk job sits in `running` with a Shrink recovery
                    .expect("ShrinkReplace for a job that is not running");
                let rj = self.running.remove(pos);
                let mut record = rj.record;
                let RunRecovery::Shrink(mut sr) = rj.rec else {
                    // invariant: see above — the pushing site pairs the
                    // event with RunRecovery::Shrink
                    unreachable!("ShrinkReplace for a non-shrink run");
                };
                debug_assert_eq!(sr.attempt, attempt);
                let nodes = record.assignment.as_ref().map_or(0, Vec::len);
                self.busy_node_s += rj.duration * nodes as f64;
                self.acc_completion[job as usize] += rj.duration;
                let seg_done = sr.seg_u * sr.seg_work;
                // survivors keep their nodes; the lost ranks' load moves to
                // free nodes. Fall back to abort → resubmit when the draw
                // took down no held host (transit-only failure), the
                // replace budget is spent, or no placement exists.
                let can_replace =
                    !sr.lost_hosts.is_empty() && sr.replaces < self.config.max_restarts;
                let replaced = if can_replace {
                    self.controller.shrink_replace(&mut record, &sr.lost_hosts).ok()
                } else {
                    None
                };
                match replaced {
                    Some((lost_ranks, repl)) => {
                        let lost_seg = rj.duration - seg_done;
                        record.useful_s += seg_done;
                        record.lost_node_s += lost_seg * nodes as f64;
                        self.lost_node_s += lost_seg * nodes as f64;
                        sr.work_credited += seg_done;
                        sr.frac_done += sr.seg_u * (1.0 - sr.frac_done);
                        sr.degrade *= shrink_degradation(nodes, &lost_ranks);
                        sr.replaces += 1;
                        record.shrinks += 1;
                        self.shrinks += 1;
                        self.trace.push(TraceEvent {
                            t,
                            kind: TraceKind::Shrink {
                                job,
                                lost: sr.lost_hosts.clone(),
                                repl,
                            },
                        });
                        // plan the remainder on the patched assignment as a
                        // fresh segment with its own fault + recovery draws
                        let class = self.class_of_job[job as usize];
                        // invariant: the job was running, and launch() only
                        // runs records that carry an assignment
                        let assignment = record.assignment.clone().expect("running without nodes");
                        let next_attempt = record.fault_draws;
                        record.fault_draws = next_attempt + 1;
                        let profile = self.classes[class].sim.prepare(&assignment);
                        let mut ctx = profile.fault_ctx(job);
                        ctx.attempt = next_attempt;
                        let mut rng = Rng::stream(
                            self.stream_base ^ job.wrapping_mul(0x9E3779B97F4A7C15),
                            next_attempt as u64,
                        );
                        let down = self.scenario.sample_down(&ctx, &mut rng);
                        let u = self.recovery_u(job, next_attempt);
                        let pr = profile.resolve_partial(&down, sr.frac_done, u);
                        sr.attempt = next_attempt;
                        sr.seg_work = pr.remaining_s;
                        sr.seg_u = u;
                        sr.fails = pr.aborted;
                        let duration;
                        if pr.aborted {
                            sr.lost_hosts = assignment
                                .iter()
                                .copied()
                                .filter(|&n| down[n])
                                .collect();
                            duration = u * sr.seg_work * sr.degrade;
                            self.push_event(
                                t + duration,
                                Event::ShrinkReplace {
                                    job,
                                    attempt: next_attempt,
                                },
                            );
                        } else {
                            sr.lost_hosts = Vec::new();
                            duration = sr.seg_work * sr.degrade;
                            self.push_event(
                                t + duration,
                                Event::JobEnd {
                                    job,
                                    aborted: false,
                                },
                            );
                        }
                        self.running.push(RunningJob {
                            record,
                            end_s: t + duration,
                            duration,
                            rec: RunRecovery::Shrink(sr),
                        });
                    }
                    None => {
                        // fallback: revoke every useful second this run had
                        // credited and abort → resubmit with the standard
                        // restart budget semantics
                        self.shrink_fallbacks += 1;
                        record.useful_s -= sr.work_credited;
                        let revoked = (sr.work_credited + rj.duration) * nodes as f64;
                        record.lost_node_s += revoked;
                        self.lost_node_s += revoked;
                        self.trace.push(TraceEvent {
                            t,
                            kind: TraceKind::End { job, aborted: true },
                        });
                        record.aborts += 1;
                        self.total_aborts += 1;
                        if record.aborts >= self.config.max_restarts {
                            record.error = Some(format!(
                                "exhausted restart budget after {} aborts",
                                record.aborts
                            ));
                            let acc = self.acc_completion[job as usize];
                            let aborts = record.aborts;
                            self.controller
                                .complete_with(record, JobState::Failed, acc, aborts, t);
                            self.exhausted += 1;
                            self.trace.push(TraceEvent {
                                t,
                                kind: TraceKind::Fail { job },
                            });
                        } else {
                            self.controller.resubmit(record);
                        }
                    }
                }
            }
            Event::Heartbeat { epoch } => {
                let ctx = FaultCtx::new(epoch, self.config.heartbeat_period_s);
                let mut rng = Rng::stream(self.hb_base, epoch);
                let down = self.scenario.sample_down(&ctx, &mut rng);
                self.controller.ledger_mut().apply_health(&down);
                self.trace.push(TraceEvent {
                    t,
                    kind: TraceKind::Heartbeat {
                        epoch,
                        down: down.iter().filter(|&&d| d).count(),
                    },
                });
                // keep beating while there is work the epochs can affect;
                // give up after a long streak of idle epochs (pending jobs
                // blocked on nodes that never come back) so the loop
                // terminates and the starvation drain accounts for them
                if self.running.is_empty() && self.arrivals_left == 0 {
                    self.idle_heartbeats += 1;
                } else {
                    self.idle_heartbeats = 0;
                }
                let work_left = self.arrivals_left > 0
                    || !self.running.is_empty()
                    || self.controller.pending_len() > 0;
                if work_left && self.idle_heartbeats < MAX_IDLE_HEARTBEATS {
                    self.next_heartbeat_s = t + self.config.heartbeat_period_s;
                    self.push_event(
                        t + self.config.heartbeat_period_s,
                        Event::Heartbeat { epoch: epoch + 1 },
                    );
                } else {
                    self.next_heartbeat_s = f64::INFINITY;
                }
            }
        }
    }

    /// FIFO pass: launch head jobs while they fit; when the head does not
    /// fit, optionally backfill behind it.
    fn try_schedule(&mut self, now: f64) {
        loop {
            let (head_id, ranks) = match self.controller.peek_pending(0) {
                Some(h) => (h.id, h.request.ranks),
                None => return,
            };
            let fits_now = ranks <= self.controller.ledger().num_free();
            let fits_ever = ranks <= self.platform.num_nodes();
            if fits_now || !fits_ever {
                // attempt the head: placeable now, or permanently
                // unplaceable (selection then fails and the controller
                // parks the record as Failed — accounted, not lost)
                match self.controller.try_schedule_at(0) {
                    Some(Ok(record)) => self.launch_scheduled(record, now, false),
                    Some(Err(_)) => {
                        self.failed += 1;
                        self.trace.push(TraceEvent {
                            t: now,
                            kind: TraceKind::Fail { job: head_id },
                        });
                    }
                    None => return,
                }
                continue;
            }
            // head must wait for releases
            if self.config.backfill {
                self.backfill(now);
            }
            return;
        }
    }

    /// Conservative backfill: jobs behind the head may start now iff they
    /// are guaranteed to release their nodes by the head's shadow time.
    fn backfill(&mut self, now: f64) {
        let (head_id, head_ranks) = match self.controller.peek_pending(0) {
            Some(h) => (h.id, h.request.ranks),
            None => return,
        };
        // shadow time: walk running jobs by end time, accumulating the
        // nodes they release, until the head fits
        let mut releases: Vec<(u64, usize)> = self
            .running
            .iter()
            .map(|r| (r.end_s.to_bits(), r.record.assignment.as_ref().map_or(0, Vec::len)))
            .collect();
        releases.sort_unstable();
        let free = self.controller.ledger().num_free();
        let mut avail = free;
        let mut shadow = f64::INFINITY;
        for &(end_bits, n) in &releases {
            avail += n;
            if avail >= head_ranks {
                shadow = f64::from_bits(end_bits);
                break;
            }
        }
        // heartbeat epochs can also *add* capacity by recovering Down
        // nodes, so with any node currently down the head might start as
        // early as the first epoch whose recoveries (plus releases by
        // then) cover it. Clamp the shadow to that earliest-possible
        // start, keeping the no-delay guarantee under health churn.
        let down = self.controller.ledger().num_down();
        if down > 0 && self.next_heartbeat_s.is_finite() {
            let mut avail = free + down;
            let mut recovery_shadow = self.next_heartbeat_s.max(now);
            if avail < head_ranks {
                let mut found = false;
                for &(end_bits, n) in &releases {
                    avail += n;
                    if avail >= head_ranks {
                        recovery_shadow = recovery_shadow.max(f64::from_bits(end_bits));
                        found = true;
                        break;
                    }
                }
                if !found {
                    recovery_shadow = f64::INFINITY;
                }
            }
            shadow = shadow.min(recovery_shadow);
        }
        if !shadow.is_finite() {
            // even with every running job done (and every down node
            // recovered) the head cannot fit; there is no reservation to
            // protect and no point backfilling against it this round
            return;
        }
        let mut pos = 1usize;
        loop {
            let (cand_id, cand_ranks) = match self.controller.peek_pending(pos) {
                Some(c) => (c.id, c.request.ranks),
                None => return,
            };
            if cand_ranks > self.controller.ledger().num_free() {
                pos += 1;
                continue;
            }
            match self.controller.try_schedule_at(pos) {
                Some(Ok(record)) => {
                    let class = self.class_of_job[record.id as usize];
                    // invariant: try_schedule_at only returns Ok(record)
                    // after the placement policy assigned nodes
                    let assignment = record.assignment.clone().expect("running without nodes");
                    let plan = self.plan_run(&record, class, &assignment);
                    if !plan.extends_past_end() && now + plan.duration <= shadow + 1e-12 {
                        // guaranteed to be gone before the head can start
                        // (failing shrink segments are excluded outright —
                        // they keep their nodes past the planned end)
                        self.backfill_audit.push(BackfillAudit {
                            job: record.id,
                            head: head_id,
                            t: now,
                            shadow,
                        });
                        self.launch(record, now, plan, true);
                        // the candidate list shifted left; rescan at pos
                    } else {
                        // would overrun the shadow: roll the allocation
                        // back and leave the job where it was
                        self.controller.rollback_schedule(pos, record);
                        pos += 1;
                    }
                }
                Some(Err(_)) => {
                    // capacity was pre-checked, so this is a genuine
                    // selection failure; the record is parked Failed
                    self.failed += 1;
                    self.trace.push(TraceEvent {
                        t: now,
                        kind: TraceKind::Fail { job: cand_id },
                    });
                }
                None => return,
            }
        }
    }

    /// Plan and launch a freshly-scheduled head job.
    fn launch_scheduled(&mut self, record: JobRecord, now: f64, backfilled: bool) {
        let class = self.class_of_job[record.id as usize];
        // invariant: callers pass records fresh out of try_schedule/
        // try_backfill, which always attach an assignment
        let assignment = record.assignment.clone().expect("running without nodes");
        let plan = self.plan_run(&record, class, &assignment);
        self.launch(record, now, plan, backfilled);
    }

    /// First uniform draw of the per-(job, attempt) recovery stream — the
    /// failure location within a run. Independent of the fault stream, so
    /// abort-resubmit runs consume exactly the draws they always did, and
    /// idempotent per attempt, so a backfill probe and its later commit
    /// see the same value.
    fn recovery_u(&self, job: u64, attempt: u32) -> f64 {
        let mut rng = Rng::stream(
            self.recovery_base ^ job.wrapping_mul(0x9E3779B97F4A7C15),
            attempt as u64,
        );
        rng.f64()
    }

    /// Plan run `record.fault_draws` of a job under `assignment`: one
    /// `prepare()` (phase-cache backed) plus one down-state draw from the
    /// per-(job, attempt) fault stream — and, for the recovery policies,
    /// one uniform draw from the independent recovery stream locating the
    /// failure within the run. Pure in `(record, assignment)`, so event
    /// interleaving cannot change outcomes and a backfill probe can be
    /// rolled back safely.
    fn plan_run(&mut self, record: &JobRecord, class: usize, assignment: &[usize]) -> RunPlan {
        let job = record.id;
        let attempt = record.fault_draws;
        let profile = self.classes[class].sim.prepare(assignment);
        let mut ctx = profile.fault_ctx(job);
        ctx.attempt = attempt;
        let mut rng = Rng::stream(
            self.stream_base ^ job.wrapping_mul(0x9E3779B97F4A7C15),
            attempt as u64,
        );
        let down = self.scenario.sample_down(&ctx, &mut rng);
        match self.config.recovery {
            RecoveryPolicy::AbortResubmit => {
                let (duration, aborted) = profile.resolve(&down);
                RunPlan {
                    duration,
                    aborted,
                    attempt,
                    rec: RunRecovery::Abort,
                }
            }
            RecoveryPolicy::CheckpointRestart { interval_s } => {
                let cost_s = self.config.ckpt_cost_s;
                let base_progress = record.progress;
                let work_s = profile.remaining_s(base_progress);
                // checkpoints that fit strictly inside the remaining work
                // (one landing exactly at completion would be pure waste)
                let kmax = ((work_s / interval_s) - 1e-9).floor().max(0.0) as u32;
                let ck_frac = interval_s / profile.success_s;
                let u = self.recovery_u(job, attempt);
                let pr = profile.resolve_partial(&down, base_progress, u);
                let (duration, committed, aborted) = if pr.aborted {
                    // invariant: resolve_partial sets failure_s on every
                    // aborted outcome
                    let f = pr.failure_s.expect("aborted run without failure time");
                    let j = ((f / interval_s).floor() as u32).min(kmax);
                    (f + j as f64 * cost_s, j, true)
                } else {
                    (work_s + kmax as f64 * cost_s, kmax, false)
                };
                RunPlan {
                    duration,
                    aborted,
                    attempt,
                    rec: RunRecovery::Ckpt(CkptRun {
                        attempt,
                        base_progress,
                        ck_frac,
                        interval_s,
                        cost_s,
                        work_s,
                        committed,
                    }),
                }
            }
            RecoveryPolicy::ShrinkContinue => {
                let base = record.progress;
                let seg_work = profile.remaining_s(base);
                let u = self.recovery_u(job, attempt);
                let pr = profile.resolve_partial(&down, base, u);
                let fails = pr.aborted;
                let duration = if fails {
                    // invariant: resolve_partial sets failure_s on every
                    // aborted outcome
                    pr.failure_s.expect("aborted run without failure time")
                } else {
                    seg_work
                };
                let lost_hosts: Vec<usize> = if fails {
                    assignment.iter().copied().filter(|&n| down[n]).collect()
                } else {
                    Vec::new()
                };
                RunPlan {
                    duration,
                    aborted: fails,
                    attempt,
                    rec: RunRecovery::Shrink(Box::new(ShrinkRun {
                        attempt,
                        fails,
                        frac_done: base,
                        degrade: 1.0,
                        seg_work,
                        seg_u: u,
                        lost_hosts,
                        work_credited: 0.0,
                        replaces: 0,
                    })),
                }
            }
        }
    }

    fn launch(&mut self, mut record: JobRecord, now: f64, plan: RunPlan, backfilled: bool) {
        // invariant: every caller hands records straight from the
        // scheduler with an assignment attached
        let nodes = record.assignment.clone().expect("running without nodes");
        if record.start_s.is_none() {
            record.start_s = Some(now);
        }
        // the launch commits this attempt's fault + recovery draws
        record.fault_draws = plan.attempt + 1;
        let end = now + plan.duration;
        self.trace.push(TraceEvent {
            t: now,
            kind: TraceKind::Start {
                job: record.id,
                nodes,
                backfilled,
            },
        });
        if backfilled {
            self.backfills += 1;
        }
        match &plan.rec {
            RunRecovery::Abort => {
                self.push_event(
                    end,
                    Event::JobEnd {
                        job: record.id,
                        aborted: plan.aborted,
                    },
                );
            }
            RunRecovery::Ckpt(c) => {
                // checkpoint k commits after k work intervals and k write
                // costs; a tie with the run's end resolves checkpoint-first
                // because the Checkpoint events are pushed (sequenced)
                // before the JobEnd
                for k in 1..=c.committed {
                    self.push_event(
                        now + k as f64 * (c.interval_s + c.cost_s),
                        Event::Checkpoint {
                            job: record.id,
                            attempt: plan.attempt,
                            k,
                        },
                    );
                }
                self.push_event(
                    end,
                    Event::JobEnd {
                        job: record.id,
                        aborted: plan.aborted,
                    },
                );
            }
            RunRecovery::Shrink(s) => {
                if s.fails {
                    self.push_event(
                        end,
                        Event::ShrinkReplace {
                            job: record.id,
                            attempt: plan.attempt,
                        },
                    );
                } else {
                    self.push_event(
                        end,
                        Event::JobEnd {
                            job: record.id,
                            aborted: false,
                        },
                    );
                }
            }
        }
        self.running.push(RunningJob {
            record,
            end_s: end,
            duration: plan.duration,
            rec: plan.rec,
        });
    }

    fn report(self) -> SchedResult {
        let records = self.controller.finished().to_vec();
        debug_assert_eq!(
            records.len(),
            self.specs.len(),
            "job lost: {} submitted, {} accounted",
            self.specs.len(),
            records.len()
        );
        let waits: Vec<f64> = records.iter().filter_map(JobRecord::wait_s).collect();
        let mean_wait_s = if waits.is_empty() {
            0.0
        } else {
            waits.iter().sum::<f64>() / waits.len() as f64
        };
        let max_wait_s = waits.iter().cloned().fold(0.0, f64::max);
        // makespan is the last *job* event — never the trailing (possibly
        // idle) heartbeat epochs, which would inflate it and deflate
        // utilization
        let makespan_s = records
            .iter()
            .filter_map(|r| r.end_s)
            .fold(0.0, f64::max);
        let utilization = if makespan_s > 0.0 {
            self.busy_node_s / (self.platform.num_nodes() as f64 * makespan_s)
        } else {
            0.0
        };
        SchedResult {
            makespan_s,
            mean_wait_s,
            max_wait_s,
            utilization,
            completed: self.completed,
            failed: self.failed,
            exhausted: self.exhausted,
            total_aborts: self.total_aborts,
            backfills: self.backfills,
            lost_node_s: self.lost_node_s,
            ckpts: self.ckpts,
            shrinks: self.shrinks,
            shrink_fallbacks: self.shrink_fallbacks,
            total_jobs: self.specs.len(),
            records,
            trace: self.trace,
            backfill_audit: self.backfill_audit,
            occupancy: self.occupancy,
        }
    }
}

/// One cell of a scheduler sweep: a placement policy x backfill setting.
#[derive(Debug, Clone)]
pub struct SchedCell {
    /// Placement policy the cell ran under.
    pub placement: PlacementPolicy,
    /// Whether conservative backfill was enabled.
    pub backfill: bool,
    /// The run's result.
    pub result: SchedResult,
}

/// Run a `(placement x backfill)` scheduler sweep on `workers` threads
/// (0 = one per core, clamped to the cell count). Every cell realizes the
/// **same** fault scenario from `(seed)` — the paper's paired comparison —
/// and the per-cell engines are fully deterministic, so results are
/// bit-identical for every worker count.
pub fn run_sweep(
    platform: &Platform,
    workload: &WorkloadSpec,
    fault: &FaultSpec,
    cells: &[(PlacementPolicy, bool)],
    config: &SchedConfig,
    workers: usize,
) -> Result<Vec<SchedCell>> {
    config.validate()?;
    let workers = if workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        workers
    };
    // force the shared TopoIndex once (dense metric only), like
    // BatchRunner::new, and share one phase cache so cells reuse each
    // other's network solves
    if platform.resolved_metric().is_dense() {
        platform.topo_index();
    }
    let cache = Arc::new(PhaseCache::new());
    let (results, _) = run_sharded(cells.len(), workers.min(cells.len().max(1)), |i| {
        let (placement, backfill) = cells[i];
        let mut scen_rng = Rng::stream(config.seed, 0);
        let scenario = fault.realize(platform, &mut scen_rng)?;
        let cell_cfg = SchedConfig {
            placement,
            backfill,
            ..config.clone()
        };
        let sched = ClusterScheduler::with_jobs_cached(
            platform,
            workload.generate(),
            scenario,
            cell_cfg,
            Arc::clone(&cache),
        );
        Ok(SchedCell {
            placement,
            backfill,
            result: sched.run(),
        })
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TorusDims;

    fn workload(jobs: usize, ranks: usize) -> WorkloadSpec {
        WorkloadSpec {
            jobs,
            mean_interarrival_s: 0.0,
            mix: vec![(ranks, 1.0)],
            steps: 2,
            seed: 5,
        }
    }

    #[test]
    fn workload_generation_is_deterministic_and_sized() {
        let w = WorkloadSpec {
            jobs: 20,
            mean_interarrival_s: 0.5,
            mix: vec![(4, 0.5), (8, 0.5)],
            steps: 2,
            seed: 9,
        };
        let a = w.generate();
        let b = w.generate();
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        assert!(a.iter().all(|j| j.ranks == 4 || j.ranks == 8));
        // arrival times are non-decreasing
        assert!(a.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
        assert!(a.last().unwrap().arrival_s > 0.0);
        // batch dump: all at t = 0
        let dump = workload(5, 4).generate();
        assert!(dump.iter().all(|j| j.arrival_s == 0.0));
    }

    #[test]
    fn fault_free_fifo_completes_every_job() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let w = workload(12, 16); // 4 jobs fit at once on 64 nodes
        let scenario = FaultScenario::none(64);
        let sched = ClusterScheduler::new(&plat, &w, scenario, SchedConfig::default());
        let res = sched.run();
        assert_eq!(res.completed, 12);
        assert_eq!(res.failed + res.exhausted, 0);
        assert_eq!(res.records.len(), 12);
        assert!(res.makespan_s > 0.0);
        // contention: 12 x 16 ranks on 64 nodes => queue wait is real
        assert!(res.mean_wait_s > 0.0, "no queue wait under 3x contention");
        assert!(res.utilization > 0.0 && res.utilization <= 1.0 + 1e-9);
        // every record carries its outcome
        for r in &res.records {
            assert_eq!(r.state, JobState::Completed);
            assert!(r.completion_s.unwrap() > 0.0);
            assert!(r.end_s.unwrap() >= r.start_s.unwrap());
        }
    }

    #[test]
    fn concurrent_jobs_share_the_makespan_but_not_nodes() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let w = workload(4, 16);
        let scenario = FaultScenario::none(64);
        let res = ClusterScheduler::new(&plat, &w, scenario, SchedConfig::default()).run();
        // 4 x 16 = 64 ranks fit simultaneously: no waiting, overlap in time
        assert_eq!(res.completed, 4);
        assert_eq!(res.mean_wait_s, 0.0);
        // replay the trace: occupancy must never overlap
        let mut held: Vec<Option<u64>> = vec![None; 64];
        let mut overlapped_in_time = false;
        let mut running = 0usize;
        for ev in &res.trace {
            match &ev.kind {
                TraceKind::Start { job, nodes, .. } => {
                    running += 1;
                    overlapped_in_time |= running > 1;
                    for &n in nodes {
                        assert!(held[n].is_none(), "node {n} double-held");
                        held[n] = Some(*job);
                    }
                }
                TraceKind::End { job, .. } => {
                    running -= 1;
                    for h in held.iter_mut() {
                        if *h == Some(*job) {
                            *h = None;
                        }
                    }
                }
                _ => {}
            }
        }
        assert!(overlapped_in_time, "jobs never overlapped in time");
    }

    #[test]
    fn oversized_job_fails_but_stays_accounted() {
        let plat = Platform::paper_default(TorusDims::new(2, 2, 2)); // 8 nodes
        let w = WorkloadSpec {
            jobs: 3,
            mean_interarrival_s: 0.0,
            mix: vec![(16, 1.0)], // 16 ranks > 8 nodes
            steps: 2,
            seed: 1,
        };
        let scenario = FaultScenario::none(8);
        let res = ClusterScheduler::new(&plat, &w, scenario, SchedConfig::default()).run();
        assert_eq!(res.completed, 0);
        assert_eq!(res.failed, 3);
        assert_eq!(res.records.len(), 3, "jobs lost from accounting");
        assert!(res
            .records
            .iter()
            .all(|r| r.state == JobState::Failed && r.error.is_some()));
        // rejected right at submit, with a typed workload error naming
        // the request and the platform — never queued, never starved
        for r in &res.records {
            let err = r.error.as_deref().unwrap();
            assert!(err.contains("workload error"), "untyped error: {err}");
            assert!(err.contains("16 ranks"), "error lacks the request: {err}");
            assert!(err.contains("8 nodes"), "error lacks the platform: {err}");
            assert!(r.start_s.is_none(), "rejected job somehow launched");
            assert_eq!(r.end_s, Some(0.0), "rejected at submit time");
        }
    }

    #[test]
    fn abort_resubmit_exhaustion_is_counted() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let w = workload(2, 4);
        // nodes 0 and 4 always down: block placement lands job 0 on node
        // 0 and job 1 on node 4, so every run of both jobs aborts
        let scenario = FaultScenario::iid(vec![0, 4], 1.0, 16);
        let cfg = SchedConfig {
            placement: PlacementPolicy::DefaultSlurm,
            max_restarts: 3,
            ..Default::default()
        };
        let res = ClusterScheduler::new(&plat, &w, scenario, cfg).run();
        assert_eq!(res.records.len(), 2);
        assert_eq!(res.exhausted, 2);
        assert_eq!(res.total_aborts, 6);
        for r in &res.records {
            assert_eq!(r.state, JobState::Failed);
            assert_eq!(r.aborts, 3);
            assert!(r.error.as_deref().unwrap().contains("exhausted"));
            // each abort held the allocation for one run interval
            assert!(r.completion_s.unwrap() > 0.0);
        }
    }

    #[test]
    fn tofa_dodges_down_nodes_where_block_aborts() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let w = workload(6, 8);
        let scenario = FaultScenario::iid(vec![0, 1, 2], 1.0, 64);
        let fifo = |placement| {
            let cfg = SchedConfig {
                placement,
                max_restarts: 50,
                ..Default::default()
            };
            ClusterScheduler::new(&plat, &w, scenario.clone(), cfg).run()
        };
        let tofa = fifo(PlacementPolicy::Tofa);
        // TOFA never *hosts* ranks on the known-down nodes, so every job
        // completes within the restart budget (a concurrent job can still
        // abort on a flaky transit when fragmentation leaves only
        // endpoint-clean windows)
        assert_eq!(tofa.completed, 6);
        assert_eq!(tofa.exhausted, 0);
        for ev in &tofa.trace {
            if let TraceKind::Start { job, nodes, .. } = &ev.kind {
                for down in [0usize, 1, 2] {
                    assert!(!nodes.contains(&down), "job {job} hosted on down {down}");
                }
            }
        }
        let block = fifo(PlacementPolicy::DefaultSlurm);
        assert!(block.total_aborts > 0, "block dodged always-down nodes?");
        assert!(block.total_aborts > tofa.total_aborts);
    }

    #[test]
    fn backfill_fills_holes_and_never_delays_the_head() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        // two long 48-rank jobs head the queue; short 16-rank jobs behind
        // them can only run early via backfill
        let mut specs = Vec::new();
        for i in 0..2 {
            specs.push(SchedJobSpec {
                name: format!("big{i}"),
                ranks: 48,
                steps: 6,
                arrival_s: 0.0,
            });
        }
        for i in 0..4 {
            specs.push(SchedJobSpec {
                name: format!("small{i}"),
                ranks: 16,
                steps: 2,
                arrival_s: 0.0,
            });
        }
        let scenario = FaultScenario::none(64);
        let run = |backfill| {
            let cfg = SchedConfig {
                backfill,
                ..Default::default()
            };
            ClusterScheduler::with_jobs(&plat, specs.clone(), scenario.clone(), cfg).run()
        };
        let fifo = run(false);
        let bf = run(true);
        assert_eq!(fifo.backfills, 0);
        assert!(bf.backfills > 0, "workload never backfilled");
        assert_eq!(bf.completed, fifo.completed);
        // the audit holds: every head a job jumped over started by its
        // shadow time
        for a in &bf.backfill_audit {
            let head_start = bf
                .records
                .iter()
                .find(|r| r.id == a.head)
                .and_then(|r| r.start_s)
                .expect("head never started");
            assert!(
                head_start <= a.shadow + 1e-9,
                "head {} started {} after its shadow {}",
                a.head,
                head_start,
                a.shadow
            );
        }
        // conservative backfill with exact durations cannot hurt makespan
        assert!(bf.makespan_s <= fifo.makespan_s + 1e-9);
        // and here it strictly helps the small jobs' waits
        assert!(bf.mean_wait_s < fifo.mean_wait_s);
    }

    #[test]
    fn heartbeat_epochs_mark_nodes_down_and_up() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let w = WorkloadSpec {
            jobs: 6,
            mean_interarrival_s: 0.3,
            mix: vec![(4, 1.0)],
            steps: 2,
            seed: 2,
        };
        let scenario = FaultScenario::iid(vec![3, 9], 0.5, 16);
        let cfg = SchedConfig {
            heartbeat_period_s: 0.1,
            ..Default::default()
        };
        let res = ClusterScheduler::new(&plat, &w, scenario, cfg).run();
        let beats = res
            .trace
            .iter()
            .filter(|e| matches!(e.kind, TraceKind::Heartbeat { .. }))
            .count();
        assert!(beats > 0, "no heartbeat epochs fired");
        assert_eq!(res.completed + res.failed + res.exhausted, 6);
        // makespan pins to the last job end, not the trailing heartbeat
        let last_end = res
            .records
            .iter()
            .filter_map(|r| r.end_s)
            .fold(0.0, f64::max);
        assert_eq!(res.makespan_s.to_bits(), last_end.to_bits());
        let last_beat = res
            .trace
            .iter()
            .filter(|e| matches!(e.kind, TraceKind::Heartbeat { .. }))
            .map(|e| e.t)
            .fold(0.0, f64::max);
        assert!(
            last_beat >= last_end,
            "heartbeats stopped before the work did"
        );
    }

    #[test]
    fn backfill_under_heartbeat_churn_keeps_accounting_consistent() {
        // health epochs add/remove capacity while backfill reserves
        // against the (recovery-clamped) shadow: every job must still
        // reach a terminal state and the no-overlap invariant must hold
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let w = WorkloadSpec {
            jobs: 8,
            mean_interarrival_s: 0.1,
            mix: vec![(4, 0.5), (10, 0.5)],
            steps: 2,
            seed: 13,
        };
        let scenario = FaultScenario::iid(vec![2, 7, 11], 0.5, 16);
        let cfg = SchedConfig {
            backfill: true,
            heartbeat_period_s: 0.05,
            max_restarts: 30,
            ..Default::default()
        };
        let res = ClusterScheduler::new(&plat, &w, scenario, cfg).run();
        assert_eq!(res.records.len(), 8);
        assert_eq!(res.completed + res.failed + res.exhausted, 8);
        let mut held: Vec<Option<u64>> = vec![None; 16];
        for ev in &res.trace {
            match &ev.kind {
                TraceKind::Start { job, nodes, .. } => {
                    for &n in nodes {
                        assert!(held[n].is_none(), "node {n} double-held");
                        held[n] = Some(*job);
                    }
                }
                TraceKind::End { job, .. } => {
                    for h in held.iter_mut() {
                        if *h == Some(*job) {
                            *h = None;
                        }
                    }
                }
                _ => {}
            }
        }
    }

    #[test]
    fn sweep_is_deterministic_for_any_worker_count() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let w = workload(8, 4);
        let fault = FaultSpec::Iid {
            n_faulty: 3,
            p_f: 0.4,
        };
        let cells = [
            (PlacementPolicy::DefaultSlurm, false),
            (PlacementPolicy::Tofa, false),
            (PlacementPolicy::DefaultSlurm, true),
            (PlacementPolicy::Tofa, true),
        ];
        let cfg = SchedConfig::default();
        let run = |workers| run_sweep(&plat, &w, &fault, &cells, &cfg, workers).unwrap();
        let serial = run(1);
        for workers in [2usize, 4] {
            let par = run(workers);
            assert_eq!(par.len(), serial.len());
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.result.trace, b.result.trace, "{workers} workers");
                assert_eq!(
                    a.result.makespan_s.to_bits(),
                    b.result.makespan_s.to_bits(),
                    "{workers} workers"
                );
            }
        }
    }
}
