//! `NodeLedger` — per-node occupancy state for the cluster scheduler.
//!
//! The controller used to hand every job the full platform: two "Running"
//! jobs silently overlapped on the same nodes and queue wait was never
//! modeled. The ledger is the shared allocation state that fixes that:
//! every node is `Free`, `Busy(job)`, or `Down`, allocations are exclusive
//! (allocating a non-free node is an error), and the FANS/TOFA selection
//! path draws its candidate set from [`NodeLedger::free_nodes`].
//!
//! Fragmentation statistics ([`NodeLedger::largest_free_run`],
//! [`NodeLedger::free_runs`]) expose the quantity TOFA's consecutive-id
//! window search actually depends on: under contention the free set
//! fragments, clean windows disappear, and placement falls back to the
//! Eq. 1 fault-weighted path — the candidate-set-shape effect the
//! QAP-mapping literature observes for restricted node sets.

use crate::error::{Error, Result};

/// Occupancy state of one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Available for allocation.
    Free,
    /// Held by the job with this id.
    Busy(u64),
    /// Administratively down (heartbeat epoch marked it unhealthy).
    Down,
}

/// Per-node free/busy/down ledger with exclusive allocate/release.
#[derive(Debug, Clone)]
pub struct NodeLedger {
    state: Vec<NodeState>,
    free: usize,
    /// Live allocations in allocation order: `(job id, nodes)`.
    /// A `Vec` (not a hash map) so every walk over it is deterministic.
    allocs: Vec<(u64, Vec<usize>)>,
}

impl NodeLedger {
    /// All-free ledger over `num_nodes` nodes.
    pub fn new(num_nodes: usize) -> Self {
        NodeLedger {
            state: vec![NodeState::Free; num_nodes],
            free: num_nodes,
            allocs: Vec::new(),
        }
    }

    /// Total nodes tracked.
    pub fn num_nodes(&self) -> usize {
        self.state.len()
    }

    /// Currently free nodes.
    pub fn num_free(&self) -> usize {
        self.free
    }

    /// Currently busy nodes.
    pub fn num_busy(&self) -> usize {
        self.allocs.iter().map(|(_, ns)| ns.len()).sum()
    }

    /// Currently down nodes.
    pub fn num_down(&self) -> usize {
        self.state.len() - self.free - self.num_busy()
    }

    /// State of one node.
    pub fn state_of(&self, node: usize) -> NodeState {
        self.state[node]
    }

    /// True if `node` is free.
    pub fn is_free(&self, node: usize) -> bool {
        self.state[node] == NodeState::Free
    }

    /// Ascending ids of the free nodes — the candidate set FANS selects
    /// from.
    pub fn free_nodes(&self) -> Vec<usize> {
        (0..self.state.len()).filter(|&n| self.is_free(n)).collect()
    }

    /// Jobs currently holding nodes, in allocation order.
    pub fn running_jobs(&self) -> impl Iterator<Item = (u64, &[usize])> {
        self.allocs.iter().map(|(j, ns)| (*j, ns.as_slice()))
    }

    /// Exclusively allocate `nodes` to `job`. Every node must be free and
    /// the job must not already hold an allocation; violating either is an
    /// error (and means the caller bypassed the candidate mask).
    pub fn allocate(&mut self, job: u64, nodes: &[usize]) -> Result<()> {
        if self.allocs.iter().any(|(j, _)| *j == job) {
            return Err(Error::Slurm(format!("job {job} already holds nodes")));
        }
        for (i, &n) in nodes.iter().enumerate() {
            match self.state.get(n) {
                Some(NodeState::Free) => {}
                Some(s) => {
                    return Err(Error::Slurm(format!(
                        "job {job} allocation overlaps node {n} ({s:?})"
                    )))
                }
                None => {
                    return Err(Error::Slurm(format!(
                        "job {job} allocation references node {n} beyond the platform"
                    )))
                }
            }
            if nodes[..i].contains(&n) {
                return Err(Error::Slurm(format!(
                    "job {job} allocation lists node {n} twice"
                )));
            }
        }
        for &n in nodes {
            self.state[n] = NodeState::Busy(job);
        }
        self.free -= nodes.len();
        self.allocs.push((job, nodes.to_vec()));
        Ok(())
    }

    /// Release whatever `job` holds; returns the freed node ids (empty if
    /// the job held nothing — release is idempotent).
    pub fn release(&mut self, job: u64) -> Vec<usize> {
        let Some(pos) = self.allocs.iter().position(|(j, _)| *j == job) else {
            return Vec::new();
        };
        let (_, nodes) = self.allocs.remove(pos);
        for &n in &nodes {
            debug_assert_eq!(self.state[n], NodeState::Busy(job));
            self.state[n] = NodeState::Free;
        }
        self.free += nodes.len();
        nodes
    }

    /// Apply a health epoch: free nodes flagged in `down` go `Down`, down
    /// nodes no longer flagged return to `Free`. Busy nodes are left
    /// untouched — a failure under a running job surfaces as that job's
    /// abort, and the node re-enters the ledger at release time.
    pub fn apply_health(&mut self, down: &[bool]) {
        assert_eq!(down.len(), self.state.len());
        for (n, &d) in down.iter().enumerate() {
            match (self.state[n], d) {
                (NodeState::Free, true) => {
                    self.state[n] = NodeState::Down;
                    self.free -= 1;
                }
                (NodeState::Down, false) => {
                    self.state[n] = NodeState::Free;
                    self.free += 1;
                }
                _ => {}
            }
        }
    }

    /// Length of the longest run of consecutive free node ids (the largest
    /// window TOFA could possibly use).
    pub fn largest_free_run(&self) -> usize {
        let mut best = 0usize;
        let mut run = 0usize;
        for n in 0..self.state.len() {
            if self.is_free(n) {
                run += 1;
                best = best.max(run);
            } else {
                run = 0;
            }
        }
        best
    }

    /// Number of maximal free runs (fragmentation: more runs for the same
    /// free count = a more shredded candidate set).
    pub fn free_runs(&self) -> usize {
        let mut runs = 0usize;
        let mut in_run = false;
        for n in 0..self.state.len() {
            match (self.is_free(n), in_run) {
                (true, false) => {
                    runs += 1;
                    in_run = true;
                }
                (false, true) => in_run = false,
                _ => {}
            }
        }
        runs
    }

    /// Internal-consistency audit (used by tests and debug assertions):
    /// allocation lists and per-node states must agree, and the free count
    /// must match the state vector.
    pub fn assert_consistent(&self) {
        let mut owner = vec![None::<u64>; self.state.len()];
        for (job, nodes) in &self.allocs {
            for &n in nodes {
                assert!(
                    owner[n].is_none(),
                    "node {n} allocated to jobs {} and {job}",
                    owner[n].unwrap()
                );
                owner[n] = Some(*job);
                assert_eq!(self.state[n], NodeState::Busy(*job));
            }
        }
        let free = self
            .state
            .iter()
            .filter(|&&s| s == NodeState::Free)
            .count();
        assert_eq!(free, self.free, "free count drifted");
        for (n, s) in self.state.iter().enumerate() {
            if let NodeState::Busy(j) = s {
                assert_eq!(owner[n], Some(*j), "node {n} busy without allocation");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_release_roundtrip() {
        let mut l = NodeLedger::new(8);
        assert_eq!(l.num_free(), 8);
        l.allocate(1, &[0, 2, 5]).unwrap();
        assert_eq!(l.num_free(), 5);
        assert_eq!(l.num_busy(), 3);
        assert_eq!(l.state_of(2), NodeState::Busy(1));
        assert_eq!(l.free_nodes(), vec![1, 3, 4, 6, 7]);
        l.assert_consistent();
        let freed = l.release(1);
        assert_eq!(freed, vec![0, 2, 5]);
        assert_eq!(l.num_free(), 8);
        l.assert_consistent();
        // release is idempotent
        assert!(l.release(1).is_empty());
    }

    #[test]
    fn overlapping_allocation_is_rejected() {
        let mut l = NodeLedger::new(4);
        l.allocate(1, &[1, 2]).unwrap();
        assert!(l.allocate(2, &[2, 3]).is_err());
        // the failed allocation must not leak partial state
        assert_eq!(l.state_of(3), NodeState::Free);
        assert_eq!(l.num_free(), 2);
        l.assert_consistent();
        // double allocation by the same job is also rejected
        assert!(l.allocate(1, &[3]).is_err());
        // out-of-range node
        assert!(l.allocate(3, &[9]).is_err());
        // duplicate node within one request
        assert!(l.allocate(4, &[0, 0]).is_err());
        assert_eq!(l.num_free(), 2);
        l.assert_consistent();
    }

    #[test]
    fn health_epochs_toggle_only_non_busy_nodes() {
        let mut l = NodeLedger::new(4);
        l.allocate(7, &[1]).unwrap();
        l.apply_health(&[true, true, false, false]);
        assert_eq!(l.state_of(0), NodeState::Down);
        assert_eq!(l.state_of(1), NodeState::Busy(7), "busy survives health");
        assert_eq!(l.num_free(), 2);
        assert_eq!(l.num_down(), 1);
        l.apply_health(&[false; 4]);
        assert_eq!(l.state_of(0), NodeState::Free);
        assert_eq!(l.num_free(), 3);
        l.assert_consistent();
    }

    #[test]
    fn fragmentation_stats() {
        let mut l = NodeLedger::new(10);
        assert_eq!(l.largest_free_run(), 10);
        assert_eq!(l.free_runs(), 1);
        l.allocate(1, &[3]).unwrap();
        l.allocate(2, &[7]).unwrap();
        // free: 0..3, 4..7, 8..10
        assert_eq!(l.largest_free_run(), 3);
        assert_eq!(l.free_runs(), 3);
    }
}
