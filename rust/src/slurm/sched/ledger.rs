//! `NodeLedger` — per-node occupancy state for the cluster scheduler.
//!
//! The controller used to hand every job the full platform: two "Running"
//! jobs silently overlapped on the same nodes and queue wait was never
//! modeled. The ledger is the shared allocation state that fixes that:
//! every node is `Free`, `Busy(job)`, or `Down`, allocations are exclusive
//! (allocating a non-free node is an error), and the FANS/TOFA selection
//! path draws its candidate set from [`NodeLedger::free_nodes`].
//!
//! Fragmentation statistics ([`NodeLedger::largest_free_run`],
//! [`NodeLedger::free_runs`]) expose the quantity TOFA's consecutive-id
//! window search actually depends on: under contention the free set
//! fragments, clean windows disappear, and placement falls back to the
//! Eq. 1 fault-weighted path — the candidate-set-shape effect the
//! QAP-mapping literature observes for restricted node sets.
//!
//! # Incremental free-run index
//!
//! Campaign-scale scheduling (tens of thousands of jobs on up to 100k-node
//! implicit-metric platforms) turned the original O(n) per-decision scans
//! into the event loop's wall. The ledger therefore maintains a sorted
//! free-run index — `runs: BTreeMap<start, len>` over the maximal runs of
//! consecutive free node ids, plus a `run_lens` length multiset — updated
//! in O(log n) per node transition (a node leaving the free set splits at
//! most one run in two; a node entering it merges at most two runs into
//! one). [`NodeLedger::largest_free_run`] and [`NodeLedger::free_runs`]
//! read the index in O(log n)/O(1), and [`NodeLedger::free_nodes`] expands
//! the runs in ascending order without touching the state vector.
//!
//! Per the dense-reference pattern (ARCHITECTURE.md), the original O(n)
//! scans are retained as [`NodeLedger::largest_free_run_scan`],
//! [`NodeLedger::free_runs_scan`], and [`NodeLedger::free_nodes_scan`]:
//! they remain the bit-identity ground truth the index is property-tested
//! against, and [`NodeLedger::assert_consistent`] rebuilds the index from
//! the state vector and compares.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Occupancy state of one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Available for allocation.
    Free,
    /// Held by the job with this id.
    Busy(u64),
    /// Administratively down (heartbeat epoch marked it unhealthy).
    Down,
}

/// Per-node free/busy/down ledger with exclusive allocate/release and an
/// incremental sorted free-run index (O(log n) per node transition).
#[derive(Debug, Clone)]
pub struct NodeLedger {
    state: Vec<NodeState>,
    free: usize,
    busy: usize,
    /// Live allocations in allocation order: `(job id, nodes)`.
    /// A `Vec` (not a hash map) so every walk over it is deterministic.
    allocs: Vec<(u64, Vec<usize>)>,
    /// Maximal runs of consecutive free node ids: start → length.
    runs: BTreeMap<usize, usize>,
    /// Multiset of run lengths: length → how many runs have it.
    run_lens: BTreeMap<usize, usize>,
}

impl NodeLedger {
    /// All-free ledger over `num_nodes` nodes.
    pub fn new(num_nodes: usize) -> Self {
        let mut runs = BTreeMap::new();
        let mut run_lens = BTreeMap::new();
        if num_nodes > 0 {
            runs.insert(0, num_nodes);
            run_lens.insert(num_nodes, 1);
        }
        NodeLedger {
            state: vec![NodeState::Free; num_nodes],
            free: num_nodes,
            busy: 0,
            allocs: Vec::new(),
            runs,
            run_lens,
        }
    }

    /// Total nodes tracked.
    pub fn num_nodes(&self) -> usize {
        self.state.len()
    }

    /// Currently free nodes.
    pub fn num_free(&self) -> usize {
        self.free
    }

    /// Currently busy nodes.
    pub fn num_busy(&self) -> usize {
        self.busy
    }

    /// Currently down nodes.
    pub fn num_down(&self) -> usize {
        self.state.len() - self.free - self.busy
    }

    /// State of one node.
    pub fn state_of(&self, node: usize) -> NodeState {
        self.state[node]
    }

    /// True if `node` is free.
    pub fn is_free(&self, node: usize) -> bool {
        self.state[node] == NodeState::Free
    }

    /// Ascending ids of the free nodes — the candidate set FANS selects
    /// from. Expanded from the run index (output order is identical to the
    /// retained [`NodeLedger::free_nodes_scan`] reference).
    ///
    /// This materializes a job-independent `Vec` per call; scheduler hot
    /// paths should prefer [`NodeLedger::free_nodes_iter`] and reuse a
    /// scratch buffer. Retained as the iterator's bit-identity reference.
    pub fn free_nodes(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.free);
        for (&start, &len) in &self.runs {
            out.extend(start..start + len);
        }
        out
    }

    /// Lazy ascending iterator over the free node ids, served straight
    /// from the incremental free-run index — no allocation, O(log n) to
    /// start, O(1) amortized per item. Yields exactly the sequence
    /// [`NodeLedger::free_nodes`] collects (regression-tested under
    /// random op sequences).
    pub fn free_nodes_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.runs.iter().flat_map(|(&start, &len)| start..start + len)
    }

    /// O(n) state-vector scan for the free set — the bit-identity
    /// reference [`NodeLedger::free_nodes`] is property-tested against.
    pub fn free_nodes_scan(&self) -> Vec<usize> {
        (0..self.state.len()).filter(|&n| self.is_free(n)).collect()
    }

    /// Jobs currently holding nodes, in allocation order.
    pub fn running_jobs(&self) -> impl Iterator<Item = (u64, &[usize])> {
        self.allocs.iter().map(|(j, ns)| (*j, ns.as_slice()))
    }

    /// Exclusively allocate `nodes` to `job`. Every node must be free and
    /// the job must not already hold an allocation; violating either is an
    /// error (and means the caller bypassed the candidate mask).
    pub fn allocate(&mut self, job: u64, nodes: &[usize]) -> Result<()> {
        if self.allocs.iter().any(|(j, _)| *j == job) {
            return Err(Error::Slurm(format!("job {job} already holds nodes")));
        }
        for (i, &n) in nodes.iter().enumerate() {
            match self.state.get(n) {
                Some(NodeState::Free) => {}
                Some(s) => {
                    return Err(Error::Slurm(format!(
                        "job {job} allocation overlaps node {n} ({s:?})"
                    )))
                }
                None => {
                    return Err(Error::Slurm(format!(
                        "job {job} allocation references node {n} beyond the platform"
                    )))
                }
            }
            if nodes[..i].contains(&n) {
                return Err(Error::Slurm(format!(
                    "job {job} allocation lists node {n} twice"
                )));
            }
        }
        for &n in nodes {
            self.state[n] = NodeState::Busy(job);
            self.index_unfree(n);
        }
        self.free -= nodes.len();
        self.busy += nodes.len();
        self.allocs.push((job, nodes.to_vec()));
        Ok(())
    }

    /// Release whatever `job` holds; returns the freed node ids (empty if
    /// the job held nothing — release is idempotent).
    pub fn release(&mut self, job: u64) -> Vec<usize> {
        let Some(pos) = self.allocs.iter().position(|(j, _)| *j == job) else {
            return Vec::new();
        };
        let (_, nodes) = self.allocs.remove(pos);
        for &n in &nodes {
            debug_assert_eq!(self.state[n], NodeState::Busy(job));
            self.state[n] = NodeState::Free;
            self.index_free(n);
        }
        self.free += nodes.len();
        self.busy -= nodes.len();
        nodes
    }

    /// Partial failure under a running job (shrink-and-continue): each of
    /// `nodes` must be `Busy(job)`; they transition to `Down` and leave
    /// the job's allocation. Both states are non-free, so the free-run
    /// index is untouched. Errors leave the ledger unchanged.
    pub fn fail_nodes(&mut self, job: u64, nodes: &[usize]) -> Result<()> {
        let Some(pos) = self.allocs.iter().position(|(j, _)| *j == job) else {
            return Err(Error::Slurm(format!("job {job} holds no allocation")));
        };
        for (i, &n) in nodes.iter().enumerate() {
            if self.state.get(n) != Some(&NodeState::Busy(job)) {
                return Err(Error::Slurm(format!(
                    "node {n} is not held by job {job}"
                )));
            }
            if nodes[..i].contains(&n) {
                return Err(Error::Slurm(format!(
                    "job {job} failure lists node {n} twice"
                )));
            }
        }
        for &n in nodes {
            self.state[n] = NodeState::Down;
        }
        self.allocs[pos].1.retain(|n| !nodes.contains(n));
        self.busy -= nodes.len();
        Ok(())
    }

    /// Grow a live allocation (shrink-and-continue replacements): each of
    /// `nodes` must be `Free` and `job` must already hold an allocation.
    /// Errors leave the ledger unchanged.
    pub fn extend_allocation(&mut self, job: u64, nodes: &[usize]) -> Result<()> {
        let Some(pos) = self.allocs.iter().position(|(j, _)| *j == job) else {
            return Err(Error::Slurm(format!("job {job} holds no allocation")));
        };
        for (i, &n) in nodes.iter().enumerate() {
            match self.state.get(n) {
                Some(NodeState::Free) => {}
                Some(s) => {
                    return Err(Error::Slurm(format!(
                        "job {job} extension overlaps node {n} ({s:?})"
                    )))
                }
                None => {
                    return Err(Error::Slurm(format!(
                        "job {job} extension references node {n} beyond the platform"
                    )))
                }
            }
            if nodes[..i].contains(&n) {
                return Err(Error::Slurm(format!(
                    "job {job} extension lists node {n} twice"
                )));
            }
        }
        for &n in nodes {
            self.state[n] = NodeState::Busy(job);
            self.index_unfree(n);
        }
        self.free -= nodes.len();
        self.busy += nodes.len();
        self.allocs[pos].1.extend_from_slice(nodes);
        Ok(())
    }

    /// Apply a health epoch: free nodes flagged in `down` go `Down`, down
    /// nodes no longer flagged return to `Free`. Busy nodes are left
    /// untouched — a failure under a running job surfaces as that job's
    /// abort, and the node re-enters the ledger at release time.
    pub fn apply_health(&mut self, down: &[bool]) {
        assert_eq!(down.len(), self.state.len());
        for (n, &d) in down.iter().enumerate() {
            match (self.state[n], d) {
                (NodeState::Free, true) => {
                    self.state[n] = NodeState::Down;
                    self.index_unfree(n);
                    self.free -= 1;
                }
                (NodeState::Down, false) => {
                    self.state[n] = NodeState::Free;
                    self.index_free(n);
                    self.free += 1;
                }
                _ => {}
            }
        }
    }

    /// Length of the longest run of consecutive free node ids (the largest
    /// window TOFA could possibly use). O(log n) off the length multiset.
    pub fn largest_free_run(&self) -> usize {
        self.run_lens.keys().next_back().copied().unwrap_or(0)
    }

    /// Number of maximal free runs (fragmentation: more runs for the same
    /// free count = a more shredded candidate set). O(1) off the index.
    pub fn free_runs(&self) -> usize {
        self.runs.len()
    }

    /// O(n) scan reference for [`NodeLedger::largest_free_run`].
    pub fn largest_free_run_scan(&self) -> usize {
        let mut best = 0usize;
        let mut run = 0usize;
        for n in 0..self.state.len() {
            if self.is_free(n) {
                run += 1;
                best = best.max(run);
            } else {
                run = 0;
            }
        }
        best
    }

    /// O(n) scan reference for [`NodeLedger::free_runs`].
    pub fn free_runs_scan(&self) -> usize {
        let mut runs = 0usize;
        let mut in_run = false;
        for n in 0..self.state.len() {
            match (self.is_free(n), in_run) {
                (true, false) => {
                    runs += 1;
                    in_run = true;
                }
                (false, true) => in_run = false,
                _ => {}
            }
        }
        runs
    }

    /// Remove one occurrence of `len` from the length multiset.
    fn lens_remove(&mut self, len: usize) {
        match self.run_lens.get_mut(&len) {
            Some(c) if *c > 1 => *c -= 1,
            Some(_) => {
                self.run_lens.remove(&len);
            }
            None => debug_assert!(false, "run length {len} missing from multiset"),
        }
    }

    /// Add one occurrence of `len` to the length multiset.
    fn lens_add(&mut self, len: usize) {
        *self.run_lens.entry(len).or_insert(0) += 1;
    }

    /// `node` just left the free set: split the run containing it. The
    /// caller has already flipped `state[node]` away from `Free`.
    fn index_unfree(&mut self, node: usize) {
        let (start, len) = self
            .runs
            .range(..=node)
            .next_back()
            .map(|(&s, &l)| (s, l))
            // invariant: the caller verified state[node] was Free, and
            // every free node belongs to exactly one indexed run
            .expect("node leaving the free set is not in any run");
        debug_assert!(start <= node && node < start + len, "run index drifted");
        self.runs.remove(&start);
        self.lens_remove(len);
        if node > start {
            self.runs.insert(start, node - start);
            self.lens_add(node - start);
        }
        if start + len > node + 1 {
            self.runs.insert(node + 1, start + len - node - 1);
            self.lens_add(start + len - node - 1);
        }
    }

    /// `node` just entered the free set: merge with the adjacent runs (at
    /// most one on each side). The caller has already flipped
    /// `state[node]` to `Free`.
    fn index_free(&mut self, node: usize) {
        let left = self
            .runs
            .range(..node)
            .next_back()
            .map(|(&s, &l)| (s, l))
            .filter(|&(s, l)| s + l == node);
        let right = self.runs.get(&(node + 1)).map(|&l| (node + 1, l));
        let start = left.map_or(node, |(s, _)| s);
        let len = 1 + left.map_or(0, |(_, l)| l) + right.map_or(0, |(_, l)| l);
        if let Some((ls, ll)) = left {
            self.runs.remove(&ls);
            self.lens_remove(ll);
        }
        if let Some((rs, rl)) = right {
            self.runs.remove(&rs);
            self.lens_remove(rl);
        }
        self.runs.insert(start, len);
        self.lens_add(len);
    }

    /// Internal-consistency audit (used by tests and debug assertions):
    /// allocation lists and per-node states must agree, the free/busy
    /// counts must match the state vector, and the incremental free-run
    /// index must equal the index rebuilt from the state vector.
    pub fn assert_consistent(&self) {
        let mut owner = vec![None::<u64>; self.state.len()];
        for (job, nodes) in &self.allocs {
            for &n in nodes {
                assert!(
                    owner[n].is_none(),
                    "node {n} allocated to jobs {} and {job}",
                    // invariant: the message only renders when the
                    // is_none() check failed, so the value is present
                    owner[n].unwrap()
                );
                owner[n] = Some(*job);
                assert_eq!(self.state[n], NodeState::Busy(*job));
            }
        }
        let free = self
            .state
            .iter()
            .filter(|&&s| s == NodeState::Free)
            .count();
        assert_eq!(free, self.free, "free count drifted");
        let busy = self
            .state
            .iter()
            .filter(|s| matches!(s, NodeState::Busy(_)))
            .count();
        assert_eq!(busy, self.busy, "busy count drifted");
        for (n, s) in self.state.iter().enumerate() {
            if let NodeState::Busy(j) = s {
                assert_eq!(owner[n], Some(*j), "node {n} busy without allocation");
            }
        }
        // Rebuild the free-run index from the state vector and compare.
        let mut want_runs = BTreeMap::new();
        let mut want_lens = BTreeMap::new();
        let mut n = 0usize;
        while n < self.state.len() {
            if self.is_free(n) {
                let start = n;
                while n < self.state.len() && self.is_free(n) {
                    n += 1;
                }
                want_runs.insert(start, n - start);
                *want_lens.entry(n - start).or_insert(0usize) += 1;
            } else {
                n += 1;
            }
        }
        assert_eq!(self.runs, want_runs, "free-run index drifted from state");
        assert_eq!(self.run_lens, want_lens, "run-length multiset drifted");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn allocate_release_roundtrip() {
        let mut l = NodeLedger::new(8);
        assert_eq!(l.num_free(), 8);
        l.allocate(1, &[0, 2, 5]).unwrap();
        assert_eq!(l.num_free(), 5);
        assert_eq!(l.num_busy(), 3);
        assert_eq!(l.state_of(2), NodeState::Busy(1));
        assert_eq!(l.free_nodes(), vec![1, 3, 4, 6, 7]);
        l.assert_consistent();
        let freed = l.release(1);
        assert_eq!(freed, vec![0, 2, 5]);
        assert_eq!(l.num_free(), 8);
        l.assert_consistent();
        // release is idempotent
        assert!(l.release(1).is_empty());
    }

    #[test]
    fn overlapping_allocation_is_rejected() {
        let mut l = NodeLedger::new(4);
        l.allocate(1, &[1, 2]).unwrap();
        assert!(l.allocate(2, &[2, 3]).is_err());
        // the failed allocation must not leak partial state
        assert_eq!(l.state_of(3), NodeState::Free);
        assert_eq!(l.num_free(), 2);
        l.assert_consistent();
        // double allocation by the same job is also rejected
        assert!(l.allocate(1, &[3]).is_err());
        // out-of-range node
        assert!(l.allocate(3, &[9]).is_err());
        // duplicate node within one request
        assert!(l.allocate(4, &[0, 0]).is_err());
        assert_eq!(l.num_free(), 2);
        l.assert_consistent();
    }

    #[test]
    fn partial_failure_and_extension_keep_the_ledger_consistent() {
        let mut l = NodeLedger::new(8);
        l.allocate(1, &[0, 1, 2, 3]).unwrap();
        // lose nodes 1 and 3 under the running job
        l.fail_nodes(1, &[1, 3]).unwrap();
        assert_eq!(l.state_of(1), NodeState::Down);
        assert_eq!(l.state_of(3), NodeState::Down);
        assert_eq!(l.num_busy(), 2);
        assert_eq!(l.num_down(), 2);
        assert_eq!(l.num_free(), 4);
        l.assert_consistent();
        // replace them with free nodes 5 and 6
        l.extend_allocation(1, &[5, 6]).unwrap();
        assert_eq!(l.state_of(5), NodeState::Busy(1));
        assert_eq!(l.num_busy(), 4);
        assert_eq!(l.num_free(), 2);
        let (_, held) = l
            .running_jobs()
            .next()
            .map(|(j, ns)| (j, ns.to_vec()))
            .unwrap();
        assert_eq!(held, vec![0, 2, 5, 6]);
        l.assert_consistent();
        // releasing frees exactly the surviving + replacement nodes
        assert_eq!(l.release(1), vec![0, 2, 5, 6]);
        assert_eq!(l.num_down(), 2);
        l.assert_consistent();
    }

    #[test]
    fn partial_failure_and_extension_reject_bad_inputs() {
        let mut l = NodeLedger::new(6);
        l.allocate(1, &[0, 1]).unwrap();
        // node not held by the job
        assert!(l.fail_nodes(1, &[2]).is_err());
        // unknown job
        assert!(l.fail_nodes(9, &[0]).is_err());
        assert!(l.extend_allocation(9, &[2]).is_err());
        // extension onto a busy node / out of range / duplicate
        assert!(l.extend_allocation(1, &[0]).is_err());
        assert!(l.extend_allocation(1, &[9]).is_err());
        assert!(l.extend_allocation(1, &[2, 2]).is_err());
        // failed calls left no partial state behind
        assert_eq!(l.num_busy(), 2);
        assert_eq!(l.num_free(), 4);
        l.assert_consistent();
    }

    #[test]
    fn health_epochs_toggle_only_non_busy_nodes() {
        let mut l = NodeLedger::new(4);
        l.allocate(7, &[1]).unwrap();
        l.apply_health(&[true, true, false, false]);
        assert_eq!(l.state_of(0), NodeState::Down);
        assert_eq!(l.state_of(1), NodeState::Busy(7), "busy survives health");
        assert_eq!(l.num_free(), 2);
        assert_eq!(l.num_down(), 1);
        l.apply_health(&[false; 4]);
        assert_eq!(l.state_of(0), NodeState::Free);
        assert_eq!(l.num_free(), 3);
        l.assert_consistent();
    }

    #[test]
    fn fragmentation_stats() {
        let mut l = NodeLedger::new(10);
        assert_eq!(l.largest_free_run(), 10);
        assert_eq!(l.free_runs(), 1);
        l.allocate(1, &[3]).unwrap();
        l.allocate(2, &[7]).unwrap();
        // free: 0..3, 4..7, 8..10
        assert_eq!(l.largest_free_run(), 3);
        assert_eq!(l.free_runs(), 3);
        l.assert_consistent();
    }

    #[test]
    fn index_matches_scan_references() {
        let mut l = NodeLedger::new(12);
        l.allocate(1, &[0, 5, 6, 11]).unwrap();
        l.apply_health(&[
            false, true, false, false, false, false, false, false, true, false, false, false,
        ]);
        assert_eq!(l.free_nodes(), l.free_nodes_scan());
        assert_eq!(l.largest_free_run(), l.largest_free_run_scan());
        assert_eq!(l.free_runs(), l.free_runs_scan());
        l.assert_consistent();
        l.release(1);
        assert_eq!(l.free_nodes(), l.free_nodes_scan());
        assert_eq!(l.largest_free_run(), l.largest_free_run_scan());
        assert_eq!(l.free_runs(), l.free_runs_scan());
        l.assert_consistent();
    }

    #[test]
    fn empty_and_single_node_ledgers() {
        let l = NodeLedger::new(0);
        assert_eq!(l.largest_free_run(), 0);
        assert_eq!(l.free_runs(), 0);
        assert!(l.free_nodes().is_empty());
        l.assert_consistent();

        let mut l = NodeLedger::new(1);
        assert_eq!(l.largest_free_run(), 1);
        l.allocate(1, &[0]).unwrap();
        assert_eq!(l.largest_free_run(), 0);
        assert_eq!(l.free_runs(), 0);
        l.release(1);
        assert_eq!(l.largest_free_run(), 1);
        l.assert_consistent();
    }

    #[test]
    fn randomized_transitions_keep_index_and_scan_bit_identical() {
        let mut rng = Rng::new(0x1ed6e4);
        let mut l = NodeLedger::new(64);
        let mut next_job = 0u64;
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..400 {
            match rng.below(3) {
                0 => {
                    let free = l.free_nodes();
                    if !free.is_empty() {
                        let want = 1 + rng.below_usize(free.len().min(8));
                        let nodes: Vec<usize> = rng
                            .sample_distinct(free.len(), want)
                            .into_iter()
                            .map(|i| free[i])
                            .collect();
                        l.allocate(next_job, &nodes).unwrap();
                        live.push(next_job);
                        next_job += 1;
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = rng.below_usize(live.len());
                        let job = live.swap_remove(i);
                        assert!(!l.release(job).is_empty());
                    }
                }
                _ => {
                    let down: Vec<bool> =
                        (0..l.num_nodes()).map(|_| rng.bernoulli(0.15)).collect();
                    l.apply_health(&down);
                }
            }
            assert_eq!(l.free_nodes(), l.free_nodes_scan());
            let lazy: Vec<usize> = l.free_nodes_iter().collect();
            assert_eq!(lazy, l.free_nodes(), "iterator must match the Vec path");
            assert_eq!(l.largest_free_run(), l.largest_free_run_scan());
            assert_eq!(l.free_runs(), l.free_runs_scan());
            l.assert_consistent();
        }
    }
}
