//! Workload-trace ingestion and bursty synthetic generators for
//! campaign-scale scheduling.
//!
//! The campaign driver ([`crate::slurm::sched::campaign`]) pushes tens of
//! thousands of jobs through the cluster scheduler. This module produces
//! those job lists two ways:
//!
//! * **Trace ingestion** — [`parse_swf`] reads SWF-style (Standard
//!   Workload Format) logs: whitespace-separated fields, `;` comments,
//!   job id / submit / wait / runtime / processor columns. [`parse_fb`]
//!   reads the FB-2010-like TSV shape replayed by the
//!   `network-scheduling-simulator` exemplar (SNIPPETS.md): tab-separated
//!   job id, submit time, inter-arrival gap, and map/shuffle/reduce byte
//!   volumes, with ranks derived from total bytes. Both return typed
//!   [`Error::Workload`] values for malformed, truncated, or out-of-order
//!   lines — never panics — and [`to_swf`] serializes a job list back so
//!   generate → serialize → parse round-trips to identical
//!   [`SchedJobSpec`]s.
//! * **Synthetic generation** — [`CampaignWorkload`] draws job sizes from
//!   a weighted mix (like [`crate::slurm::sched::WorkloadSpec`]) but adds
//!   bursty arrival processes ([`Arrivals`]): Poisson, a diurnal
//!   day/night cycle (piecewise-linear triangular rate profile — no libm
//!   trig, so traces are bit-identical across platforms), and
//!   flash-crowd bursts over a Poisson baseline.
//!
//! ```
//! use tofa::slurm::sched::workload::{Arrivals, CampaignWorkload};
//!
//! let w = CampaignWorkload {
//!     jobs: 8,
//!     mix: vec![(4, 0.5), (8, 0.5)],
//!     steps_min: 1,
//!     steps_max: 3,
//!     arrivals: Arrivals::Poisson { mean_gap_s: 0.2 },
//!     seed: 11,
//! };
//! let jobs = w.generate().unwrap();
//! assert_eq!(jobs.len(), 8);
//! // arrivals are sorted and sizes come from the mix
//! assert!(jobs.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
//! assert!(jobs.iter().all(|j| j.ranks == 4 || j.ranks == 8));
//! ```

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::slurm::sched::SchedJobSpec;

/// Knobs mapping trace units (wall-clock seconds, bytes) onto the
/// simulator's job model (LAMMPS-proxy timesteps, MPI ranks).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Wall-clock seconds of recorded runtime per simulated timestep
    /// (SWF runtimes divide by this; [`to_swf`] multiplies back).
    pub seconds_per_step: f64,
    /// Upper clamp on derived timesteps (runtime outliers otherwise turn
    /// into enormous simulated jobs).
    pub max_steps: usize,
    /// Bytes of recorded I/O volume per MPI rank (FB-style traces derive
    /// ranks from map+shuffle+reduce bytes, and timesteps from shuffle
    /// bytes, at this granularity).
    pub bytes_per_rank: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seconds_per_step: 3600.0,
            max_steps: 8,
            bytes_per_rank: 1 << 30,
        }
    }
}

/// SWF comment leader.
fn swf_comment(line: &str) -> bool {
    line.trim_start().starts_with(';')
}

/// Parse one mandatory numeric field, with the line number and field name
/// in the error.
fn field<T: std::str::FromStr>(raw: &str, line_no: usize, what: &str) -> Result<T> {
    raw.parse().map_err(|_| {
        Error::Workload(format!("line {line_no}: bad {what} field {raw:?}"))
    })
}

/// Derive timesteps from a recorded runtime.
///
/// The runtime must be finite and non-negative: SWF logs use `-1` for
/// "unknown", and a NaN would otherwise round-trip through the clamp as a
/// silent 1-step job (NaN comparisons are all false, so `clamp` passes
/// the garbage through its lower bound). Both are typed
/// [`Error::Workload`]s naming the offending line, as is a degenerate
/// `seconds_per_step` that turns a finite runtime into a non-finite step
/// count.
fn steps_of_runtime(runtime_s: f64, cfg: &TraceConfig, line_no: usize) -> Result<usize> {
    if !runtime_s.is_finite() || runtime_s < 0.0 {
        return Err(Error::Workload(format!(
            "line {line_no}: unknown runtime {runtime_s} (refusing -1 placeholders)"
        )));
    }
    let steps = (runtime_s / cfg.seconds_per_step).round();
    if !steps.is_finite() {
        return Err(Error::Workload(format!(
            "line {line_no}: runtime {runtime_s} at {} s/step gives a non-finite step count",
            cfg.seconds_per_step
        )));
    }
    Ok((steps as i64).clamp(1, cfg.max_steps.max(1) as i64) as usize)
}

/// Parse an SWF-style (Standard Workload Format) trace: `;` comments,
/// whitespace-separated fields per job — id, submit time, wait time,
/// runtime, allocated processors (requested processors, field 8, is the
/// fallback when the allocated count is unknown). Submit times must be
/// non-decreasing; malformed, truncated, or out-of-order lines are typed
/// [`Error::Workload`]s.
pub fn parse_swf<R: Read>(r: R, cfg: &TraceConfig) -> Result<Vec<SchedJobSpec>> {
    let mut jobs = Vec::new();
    let mut prev_submit = f64::NEG_INFINITY;
    for (i, line) in BufReader::new(r).lines().enumerate() {
        let line_no = i + 1;
        let line = line?;
        if swf_comment(&line) || line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() < 5 {
            return Err(Error::Workload(format!(
                "line {line_no}: truncated SWF record ({} fields, need >= 5)",
                fields.len()
            )));
        }
        let _id: i64 = field(fields[0], line_no, "job id")?;
        let submit: f64 = field(fields[1], line_no, "submit time")?;
        if !submit.is_finite() || submit < 0.0 {
            return Err(Error::Workload(format!(
                "line {line_no}: negative or non-finite submit time {submit}"
            )));
        }
        if submit < prev_submit {
            return Err(Error::Workload(format!(
                "line {line_no}: out-of-order submit time {submit} after {prev_submit}"
            )));
        }
        prev_submit = submit;
        let runtime: f64 = field(fields[3], line_no, "runtime")?;
        let mut procs: i64 = field(fields[4], line_no, "allocated processors")?;
        if procs <= 0 {
            if let Some(req) = fields.get(7).copied() {
                procs = field(req, line_no, "requested processors")?;
            }
        }
        if procs <= 0 {
            return Err(Error::Workload(format!(
                "line {line_no}: unknown processor count (allocated and requested both <= 0)"
            )));
        }
        let ranks = procs as usize;
        jobs.push(SchedJobSpec {
            name: format!("lammps:{ranks}"),
            ranks,
            steps: steps_of_runtime(runtime, cfg, line_no)?,
            arrival_s: submit,
        });
    }
    Ok(jobs)
}

/// Parse an FB-2010-like TSV trace (the SWIM / `network-scheduling-
/// simulator` shape): tab-separated job id, submit time, inter-arrival
/// gap, then map / shuffle / reduce byte volumes. Ranks are the total
/// byte volume at [`TraceConfig::bytes_per_rank`] granularity (at least
/// 1); timesteps grow with shuffle volume. Same error discipline as
/// [`parse_swf`]: typed [`Error::Workload`]s, never panics.
pub fn parse_fb<R: Read>(r: R, cfg: &TraceConfig) -> Result<Vec<SchedJobSpec>> {
    let mut jobs = Vec::new();
    let mut prev_submit = f64::NEG_INFINITY;
    for (i, line) in BufReader::new(r).lines().enumerate() {
        let line_no = i + 1;
        let line = line?;
        if line.trim_start().starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').map(str::trim).collect();
        if fields.len() < 6 {
            return Err(Error::Workload(format!(
                "line {line_no}: truncated FB record ({} fields, need >= 6)",
                fields.len()
            )));
        }
        let id = fields[0];
        let submit: f64 = field(fields[1], line_no, "submit time")?;
        if !submit.is_finite() || submit < 0.0 {
            return Err(Error::Workload(format!(
                "line {line_no}: negative or non-finite submit time {submit}"
            )));
        }
        if submit < prev_submit {
            return Err(Error::Workload(format!(
                "line {line_no}: out-of-order submit time {submit} after {prev_submit}"
            )));
        }
        prev_submit = submit;
        let map_b: u64 = field(fields[3], line_no, "map bytes")?;
        let shuffle_b: u64 = field(fields[4], line_no, "shuffle bytes")?;
        let reduce_b: u64 = field(fields[5], line_no, "reduce bytes")?;
        let per_rank = cfg.bytes_per_rank.max(1);
        let total = map_b as u128 + shuffle_b as u128 + reduce_b as u128;
        let ranks = ((total / per_rank as u128) as usize).max(1);
        let steps = (1 + (shuffle_b / per_rank) as usize).min(cfg.max_steps.max(1));
        jobs.push(SchedJobSpec {
            name: format!("fb:{id}"),
            ranks,
            steps,
            arrival_s: submit,
        });
    }
    Ok(jobs)
}

/// Serialize a job list as an SWF-style trace. [`parse_swf`] on the
/// output (with the same `cfg`) reproduces the input exactly: arrivals
/// are written with Rust's shortest-round-trip float formatting and
/// timesteps invert through [`TraceConfig::seconds_per_step`].
pub fn to_swf(jobs: &[SchedJobSpec], cfg: &TraceConfig) -> String {
    let mut out = String::from(
        "; SWF-style trace (fields: id submit wait runtime procs, rest -1)\n",
    );
    for (i, j) in jobs.iter().enumerate() {
        let runtime = j.steps as f64 * cfg.seconds_per_step;
        out.push_str(&format!(
            "{} {} -1 {} {} -1 -1 {} -1 -1 1 -1 -1 -1 -1 -1 -1 -1\n",
            i + 1,
            j.arrival_s,
            runtime,
            j.ranks,
            j.ranks,
        ));
    }
    out
}

/// Load a trace by file extension: `.swf` → [`parse_swf`], `.tsv` →
/// [`parse_fb`]; anything else is a typed error.
pub fn load_trace(path: &Path, cfg: &TraceConfig) -> Result<Vec<SchedJobSpec>> {
    let ext = path
        .extension()
        .and_then(|e| e.to_str())
        .unwrap_or_default()
        .to_ascii_lowercase();
    let file = std::fs::File::open(path)?;
    match ext.as_str() {
        "swf" => parse_swf(file, cfg),
        "tsv" => parse_fb(file, cfg),
        _ => Err(Error::Workload(format!(
            "unknown trace extension {:?} (expected .swf or .tsv)",
            path.display()
        ))),
    }
}

/// Shift arrivals so the earliest job arrives at t = 0 (traces often
/// start mid-epoch).
pub fn rebase_arrivals(jobs: &mut [SchedJobSpec]) {
    let first = jobs
        .iter()
        .map(|j| j.arrival_s)
        .fold(f64::INFINITY, f64::min);
    if first.is_finite() && first > 0.0 {
        for j in jobs.iter_mut() {
            j.arrival_s -= first;
        }
    }
}

/// Multiply every arrival by `factor` — traces record wall-clock days
/// while the simulator's job durations are O(seconds), so campaigns
/// compress recorded time to recreate the original contention level.
pub fn scale_arrivals(jobs: &mut [SchedJobSpec], factor: f64) {
    assert!(factor.is_finite() && factor >= 0.0, "bad arrival scale");
    for j in jobs.iter_mut() {
        j.arrival_s *= factor;
    }
}

/// Clamp rank counts to the platform size so recorded jobs bigger than
/// the simulated machine queue instead of insta-failing as unplaceable.
pub fn clamp_ranks(jobs: &mut [SchedJobSpec], max_ranks: usize) {
    assert!(max_ranks > 0, "cannot clamp ranks to 0");
    for j in jobs.iter_mut() {
        if j.ranks > max_ranks {
            j.ranks = max_ranks;
        }
    }
}

/// Arrival process of a synthetic campaign workload.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrivals {
    /// Everything at t = 0 (the paper's batch dump).
    Batch,
    /// Poisson process: exponential gaps with this mean.
    Poisson {
        /// Mean interarrival gap in simulated seconds.
        mean_gap_s: f64,
    },
    /// Day/night cycle: a Poisson process at peak rate `1/mean_gap_s`,
    /// thinned by a triangular (piecewise-linear) rate profile that dips
    /// to `1/peak_to_trough` of the peak at the start of each day and
    /// peaks mid-day. Triangular instead of sinusoidal so the profile
    /// needs no libm trig and campaigns stay bit-identical everywhere.
    Diurnal {
        /// Mean interarrival gap at the mid-day peak.
        mean_gap_s: f64,
        /// Cycle length in simulated seconds.
        day_s: f64,
        /// Peak-to-trough rate ratio (>= 1).
        peak_to_trough: f64,
    },
    /// Flash crowd: a Poisson baseline plus `bursts` dumps of
    /// `burst_jobs` jobs, each burst spread uniformly over
    /// `burst_span_s` starting at a random instant of the baseline span.
    FlashCrowd {
        /// Baseline mean interarrival gap.
        mean_gap_s: f64,
        /// Number of flash crowds.
        bursts: usize,
        /// Jobs per flash crowd (taken out of the total job budget).
        burst_jobs: usize,
        /// Seconds over which each crowd's arrivals spread.
        burst_span_s: f64,
    },
}

/// Synthetic campaign workload: job sizes from a weighted mix, timesteps
/// uniform in `[steps_min, steps_max]`, arrivals from a bursty process.
/// The heavier-duty sibling of [`crate::slurm::sched::WorkloadSpec`]
/// (kept separate so the existing batch-dump API stays stable).
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignWorkload {
    /// Total jobs to generate.
    pub jobs: usize,
    /// `(ranks, weight)` job-size mix; weights are normalized.
    pub mix: Vec<(usize, f64)>,
    /// Minimum timesteps per job.
    pub steps_min: usize,
    /// Maximum timesteps per job (inclusive).
    pub steps_max: usize,
    /// Arrival process.
    pub arrivals: Arrivals,
    /// Workload RNG seed (sizes, steps, and arrival draws).
    pub seed: u64,
}

impl CampaignWorkload {
    /// A heavy-traffic mix scaled to the platform: the paper's small /
    /// medium / large split at 50/30/20 %, 500 jobs, Poisson arrivals
    /// fast enough to keep a deep queue.
    pub fn paper_like(num_nodes: usize) -> Self {
        let unit = (num_nodes / 32).max(2);
        CampaignWorkload {
            jobs: 500,
            mix: vec![(unit, 0.5), (unit * 2, 0.3), (unit * 4, 0.2)],
            steps_min: 1,
            steps_max: 3,
            arrivals: Arrivals::Poisson { mean_gap_s: 0.05 },
            seed: 7,
        }
    }

    /// Materialize the job list (deterministic in `self.seed`): arrival
    /// times first — sorted, non-decreasing — then sizes and steps drawn
    /// per job in arrival order. Configuration problems (empty mix,
    /// non-positive gaps, inverted step bounds) are typed
    /// [`Error::Workload`]s.
    pub fn generate(&self) -> Result<Vec<SchedJobSpec>> {
        if self.mix.is_empty() {
            return Err(Error::Workload("empty job-size mix".into()));
        }
        let total_w: f64 = self.mix.iter().map(|(_, w)| w).sum();
        if !total_w.is_finite() || total_w <= 0.0 {
            return Err(Error::Workload("job-size mix has zero total weight".into()));
        }
        if self.mix.iter().any(|&(r, w)| r == 0 || w < 0.0) {
            return Err(Error::Workload(
                "job-size mix has a zero-rank class or negative weight".into(),
            ));
        }
        if self.steps_min == 0 || self.steps_min > self.steps_max {
            return Err(Error::Workload(format!(
                "bad step bounds [{}, {}]",
                self.steps_min, self.steps_max
            )));
        }
        let mut rng = Rng::new(self.seed);
        let arrivals = self.arrival_times(&mut rng)?;
        debug_assert_eq!(arrivals.len(), self.jobs);
        Ok(arrivals
            .into_iter()
            .map(|t| {
                let mut pick = rng.f64() * total_w;
                let mut ranks = self.mix[self.mix.len() - 1].0;
                for &(r, w) in &self.mix {
                    if pick < w {
                        ranks = r;
                        break;
                    }
                    pick -= w;
                }
                let steps =
                    self.steps_min + rng.below_usize(self.steps_max - self.steps_min + 1);
                SchedJobSpec {
                    name: format!("lammps:{ranks}"),
                    ranks,
                    steps,
                    arrival_s: t,
                }
            })
            .collect())
    }

    /// Sorted arrival instants for all `self.jobs` jobs.
    fn arrival_times(&self, rng: &mut Rng) -> Result<Vec<f64>> {
        let exp = |rng: &mut Rng, mean: f64| -mean * (1.0 - rng.f64()).ln();
        let mut ts = Vec::with_capacity(self.jobs);
        match self.arrivals {
            Arrivals::Batch => ts.resize(self.jobs, 0.0),
            Arrivals::Poisson { mean_gap_s } => {
                if !mean_gap_s.is_finite() || mean_gap_s <= 0.0 {
                    return Err(Error::Workload(format!(
                        "Poisson mean gap must be positive, got {mean_gap_s}"
                    )));
                }
                let mut t = 0.0;
                for i in 0..self.jobs {
                    if i > 0 {
                        t += exp(rng, mean_gap_s);
                    }
                    ts.push(t);
                }
            }
            Arrivals::Diurnal {
                mean_gap_s,
                day_s,
                peak_to_trough,
            } => {
                let ok = mean_gap_s.is_finite()
                    && mean_gap_s > 0.0
                    && day_s.is_finite()
                    && day_s > 0.0
                    && peak_to_trough.is_finite()
                    && peak_to_trough >= 1.0;
                if !ok {
                    return Err(Error::Workload(format!(
                        "bad diurnal parameters (gap {mean_gap_s}, day {day_s}, \
                         peak/trough {peak_to_trough})"
                    )));
                }
                // Poisson thinning against the triangular profile:
                // candidates at the peak rate, accepted with the profile's
                // relative rate at that instant.
                let mut t = 0.0;
                while ts.len() < self.jobs {
                    t += exp(rng, mean_gap_s);
                    let phase = (t / day_s).fract();
                    let tri = 1.0 - (2.0 * phase - 1.0).abs(); // 0 at day start, 1 mid-day
                    let rate = (1.0 + (peak_to_trough - 1.0) * tri) / peak_to_trough;
                    if rng.f64() < rate {
                        ts.push(t);
                    }
                }
                rebase_times(&mut ts);
            }
            Arrivals::FlashCrowd {
                mean_gap_s,
                bursts,
                burst_jobs,
                burst_span_s,
            } => {
                let ok = mean_gap_s.is_finite()
                    && mean_gap_s > 0.0
                    && burst_span_s.is_finite()
                    && burst_span_s >= 0.0;
                if !ok {
                    return Err(Error::Workload(format!(
                        "bad flash-crowd parameters (gap {mean_gap_s}, span {burst_span_s})"
                    )));
                }
                let crowd = (bursts * burst_jobs).min(self.jobs);
                let base = self.jobs - crowd;
                let mut t = 0.0;
                for i in 0..base {
                    if i > 0 {
                        t += exp(rng, mean_gap_s);
                    }
                    ts.push(t);
                }
                let span = t.max(mean_gap_s);
                let mut left = crowd;
                for _ in 0..bursts {
                    if left == 0 {
                        break;
                    }
                    let n = burst_jobs.min(left);
                    left -= n;
                    let start = rng.f64() * span;
                    for _ in 0..n {
                        ts.push(start + rng.f64() * burst_span_s);
                    }
                }
                ts.sort_by(f64::total_cmp);
            }
        }
        Ok(ts)
    }
}

/// Shift a sorted time vector so it starts at 0.
fn rebase_times(ts: &mut [f64]) {
    if let Some(&first) = ts.first() {
        if first > 0.0 {
            for t in ts.iter_mut() {
                *t -= first;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted(jobs: &[SchedJobSpec]) -> bool {
        jobs.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s)
    }

    #[test]
    fn generators_are_deterministic_and_sorted() {
        for arrivals in [
            Arrivals::Batch,
            Arrivals::Poisson { mean_gap_s: 0.3 },
            Arrivals::Diurnal {
                mean_gap_s: 0.2,
                day_s: 10.0,
                peak_to_trough: 4.0,
            },
            Arrivals::FlashCrowd {
                mean_gap_s: 0.3,
                bursts: 2,
                burst_jobs: 10,
                burst_span_s: 0.5,
            },
        ] {
            let w = CampaignWorkload {
                jobs: 50,
                mix: vec![(4, 0.6), (8, 0.4)],
                steps_min: 1,
                steps_max: 3,
                arrivals,
                seed: 3,
            };
            let a = w.generate().unwrap();
            let b = w.generate().unwrap();
            assert_eq!(a, b, "{:?} not deterministic", w.arrivals);
            assert_eq!(a.len(), 50);
            assert!(sorted(&a), "{:?} arrivals unsorted", w.arrivals);
            assert!(a[0].arrival_s >= 0.0);
            assert!(a
                .iter()
                .all(|j| (j.ranks == 4 || j.ranks == 8) && (1..=3).contains(&j.steps)));
        }
    }

    #[test]
    fn bad_configs_are_typed_errors() {
        let base = CampaignWorkload {
            jobs: 4,
            mix: vec![(4, 1.0)],
            steps_min: 1,
            steps_max: 2,
            arrivals: Arrivals::Batch,
            seed: 1,
        };
        let cases = [
            CampaignWorkload {
                mix: vec![],
                ..base.clone()
            },
            CampaignWorkload {
                mix: vec![(4, 0.0)],
                ..base.clone()
            },
            CampaignWorkload {
                mix: vec![(0, 1.0)],
                ..base.clone()
            },
            CampaignWorkload {
                steps_min: 3,
                steps_max: 2,
                ..base.clone()
            },
            CampaignWorkload {
                arrivals: Arrivals::Poisson { mean_gap_s: 0.0 },
                ..base.clone()
            },
            CampaignWorkload {
                arrivals: Arrivals::Diurnal {
                    mean_gap_s: 0.1,
                    day_s: -1.0,
                    peak_to_trough: 2.0,
                },
                ..base.clone()
            },
        ];
        for bad in cases {
            match bad.generate() {
                Err(Error::Workload(_)) => {}
                other => panic!("expected Workload error for {bad:?}, got {other:?}"),
            }
        }
    }

    #[test]
    fn swf_round_trip_is_identity() {
        let w = CampaignWorkload {
            jobs: 40,
            mix: vec![(4, 0.5), (8, 0.3), (16, 0.2)],
            steps_min: 1,
            steps_max: 5,
            arrivals: Arrivals::Poisson { mean_gap_s: 0.7 },
            seed: 99,
        };
        let jobs = w.generate().unwrap();
        let cfg = TraceConfig {
            max_steps: 5,
            ..TraceConfig::default()
        };
        let text = to_swf(&jobs, &cfg);
        let parsed = parse_swf(text.as_bytes(), &cfg).unwrap();
        assert_eq!(jobs, parsed);
    }

    #[test]
    fn helpers_rebase_scale_clamp() {
        let mut jobs = vec![
            SchedJobSpec {
                name: "a".into(),
                ranks: 100,
                steps: 1,
                arrival_s: 10.0,
            },
            SchedJobSpec {
                name: "b".into(),
                ranks: 4,
                steps: 1,
                arrival_s: 30.0,
            },
        ];
        rebase_arrivals(&mut jobs);
        assert_eq!(jobs[0].arrival_s, 0.0);
        assert_eq!(jobs[1].arrival_s, 20.0);
        scale_arrivals(&mut jobs, 0.5);
        assert_eq!(jobs[1].arrival_s, 10.0);
        clamp_ranks(&mut jobs, 64);
        assert_eq!(jobs[0].ranks, 64);
        assert_eq!(jobs[1].ranks, 4);
    }
}
