//! In-job recovery policies for the cluster scheduler.
//!
//! The paper's failure model is all-or-nothing: a node outage aborts the
//! job and the scheduler resubmits it from scratch
//! ([`RecoveryPolicy::AbortResubmit`], the golden-locked default). This
//! module adds the two execution-level alternatives the resilience
//! literature pits against fault-aware *placement*:
//!
//! * [`RecoveryPolicy::CheckpointRestart`] — the job writes a checkpoint
//!   every `interval_s` seconds of useful progress (paying a configurable
//!   write cost per checkpoint); on failure it resubmits with only the
//!   since-last-checkpoint work remaining, so an abort costs at most one
//!   checkpoint interval of lost work instead of the whole run.
//! * [`RecoveryPolicy::ShrinkContinue`] — ULFM-style: on failure the
//!   surviving ranks keep their nodes, the lost ranks' communication load
//!   is re-placed onto free nodes via the candidate-mask
//!   [`crate::slurm::plugins::fans::FansPlugin::select`] path mid-job, and
//!   the job continues at a degraded collective cost derived from the
//!   [`crate::profiler::collectives`] schedules.
//!
//! Everything is deterministic: recovery-time draws come from a dedicated
//! `Rng::stream` base (see [`crate::slurm::sched::ClusterScheduler`]), and
//! the degradation factor below is a pure function of the communicator
//! size and the replaced ranks.

use std::fmt;

use crate::error::{Error, Result};
use crate::profiler::collectives::{expand, schedule_bytes, CollectiveKind};

/// Collective-cost penalty at full replacement: a job whose ranks were all
/// re-placed mid-run pays `1 + SHRINK_PENALTY` on its remaining work. The
/// per-failure factor scales with the replaced ranks' share of the
/// allreduce schedule traffic (see [`shrink_degradation`]).
pub const SHRINK_PENALTY: f64 = 0.5;

/// Per-job recovery policy: what the scheduler does when a run aborts.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum RecoveryPolicy {
    /// Abort → resubmit from scratch (the paper's model; bit-identical to
    /// the pre-recovery scheduler).
    #[default]
    AbortResubmit,
    /// Periodic checkpoints every `interval_s` seconds of progress; a
    /// failed run resumes from the last committed checkpoint.
    CheckpointRestart {
        /// Useful-work seconds between checkpoint writes.
        interval_s: f64,
    },
    /// ULFM-style shrink-and-continue: survivors keep their nodes, lost
    /// ranks are re-placed on free nodes, the job continues degraded.
    ShrinkContinue,
}

impl fmt::Display for RecoveryPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecoveryPolicy::AbortResubmit => write!(f, "abort"),
            RecoveryPolicy::CheckpointRestart { interval_s } => {
                write!(f, "ckpt:{interval_s}")
            }
            RecoveryPolicy::ShrinkContinue => write!(f, "shrink"),
        }
    }
}

impl RecoveryPolicy {
    /// Parse a `--recovery=` CLI value: `abort`, `ckpt:<interval_s>`, or
    /// `shrink`. Degenerate checkpoint intervals (zero, negative, NaN,
    /// infinite) are typed [`Error::Workload`]s naming the field.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "abort" => Ok(RecoveryPolicy::AbortResubmit),
            "shrink" => Ok(RecoveryPolicy::ShrinkContinue),
            _ => {
                let Some(iv) = s.strip_prefix("ckpt:") else {
                    return Err(Error::Workload(format!(
                        "recovery policy '{s}' is not abort, ckpt:<interval>, or shrink"
                    )));
                };
                let interval_s: f64 = iv.parse().map_err(|_| {
                    Error::Workload(format!(
                        "checkpoint interval_s '{iv}' is not a number"
                    ))
                })?;
                let policy = RecoveryPolicy::CheckpointRestart { interval_s };
                policy.validate(0.0)?;
                Ok(policy)
            }
        }
    }

    /// Validate the policy together with the scheduler's checkpoint write
    /// cost: the interval must be finite and positive, the cost finite and
    /// non-negative. Errors are typed [`Error::Workload`]s naming the
    /// offending field.
    pub fn validate(&self, ckpt_cost_s: f64) -> Result<()> {
        if let RecoveryPolicy::CheckpointRestart { interval_s } = self {
            if !interval_s.is_finite() || *interval_s <= 0.0 {
                return Err(Error::Workload(format!(
                    "checkpoint interval_s must be finite and > 0, got {interval_s}"
                )));
            }
            if !ckpt_cost_s.is_finite() || ckpt_cost_s < 0.0 {
                return Err(Error::Workload(format!(
                    "checkpoint ckpt_cost_s must be finite and >= 0, got {ckpt_cost_s}"
                )));
            }
        }
        Ok(())
    }

    /// True for the golden-locked default (no new events, no extra RNG
    /// draws — the pre-recovery scheduler bit-for-bit).
    pub fn is_abort(&self) -> bool {
        matches!(self, RecoveryPolicy::AbortResubmit)
    }
}

/// Collective-cost degradation factor after a shrink-replace: surviving
/// ranks now reach the replacements over colder paths, modeled as
/// `1 + SHRINK_PENALTY * share`, where `share` is the replaced ranks'
/// fraction of the recursive-doubling allreduce schedule traffic for an
/// `n`-rank communicator. Pure in `(n, replaced)` — no RNG — and
/// monotone: replacing more ranks degrades at least as much.
pub fn shrink_degradation(n: usize, replaced: &[usize]) -> f64 {
    if n <= 1 || replaced.is_empty() {
        return 1.0;
    }
    let rounds = expand(CollectiveKind::Allreduce, n, 1.0);
    let total = schedule_bytes(&rounds);
    if total <= 0.0 {
        return 1.0;
    }
    let mut hit = vec![false; n];
    for &r in replaced {
        if r < n {
            hit[r] = true;
        }
    }
    let touched: f64 = rounds
        .iter()
        .flat_map(|r| r.iter())
        .filter(|m| hit[m.src] || hit[m.dst])
        .map(|m| m.bytes)
        .sum();
    1.0 + SHRINK_PENALTY * (touched / total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_all_three_policies() {
        assert_eq!(
            RecoveryPolicy::parse("abort").unwrap(),
            RecoveryPolicy::AbortResubmit
        );
        assert_eq!(
            RecoveryPolicy::parse("shrink").unwrap(),
            RecoveryPolicy::ShrinkContinue
        );
        assert_eq!(
            RecoveryPolicy::parse("ckpt:0.5").unwrap(),
            RecoveryPolicy::CheckpointRestart { interval_s: 0.5 }
        );
        for p in ["abort", "shrink", "ckpt:0.25"] {
            let policy = RecoveryPolicy::parse(p).unwrap();
            assert_eq!(policy.to_string(), p);
        }
    }

    #[test]
    fn degenerate_recovery_configs_are_typed_errors() {
        for bad in ["ckpt:0", "ckpt:-1", "ckpt:NaN", "ckpt:inf", "ckpt:x", "ulfm", ""] {
            let err = RecoveryPolicy::parse(bad).unwrap_err().to_string();
            assert!(err.contains("workload error"), "{bad}: {err}");
        }
        // the interval error names the field
        let err = RecoveryPolicy::parse("ckpt:0").unwrap_err().to_string();
        assert!(err.contains("interval_s"), "{err}");
        // negative / NaN checkpoint cost is rejected by validate
        let p = RecoveryPolicy::CheckpointRestart { interval_s: 1.0 };
        for bad_cost in [-0.5, f64::NAN, f64::INFINITY] {
            let err = p.validate(bad_cost).unwrap_err().to_string();
            assert!(err.contains("ckpt_cost_s"), "{err}");
        }
        p.validate(0.0).unwrap();
        RecoveryPolicy::AbortResubmit.validate(f64::NAN).unwrap();
    }

    #[test]
    fn degradation_is_bounded_and_monotone() {
        assert_eq!(shrink_degradation(1, &[0]), 1.0);
        assert_eq!(shrink_degradation(8, &[]), 1.0);
        let one = shrink_degradation(8, &[3]);
        let two = shrink_degradation(8, &[3, 5]);
        let all = shrink_degradation(8, &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(one > 1.0);
        assert!(two >= one, "{two} < {one}");
        assert!((all - (1.0 + SHRINK_PENALTY)).abs() < 1e-12, "{all}");
        assert!(one <= 1.0 + SHRINK_PENALTY + 1e-12);
        // deterministic
        assert_eq!(one.to_bits(), shrink_degradation(8, &[3]).to_bits());
    }
}
