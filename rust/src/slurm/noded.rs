//! `slurmd`-lite: the per-node daemon.
//!
//! One OS thread per simulated compute node. Hosts the node-side SPANK
//! plugins: **NodeState** (replies to controller heartbeats, suppressing
//! the reply when the node is emulated as down at that poll — the paper's
//! "when a node is in the failed state it is not able to respond to
//! probes") and **LoadMatrix** (serves the stored communication graph of a
//! pending job to the controller).

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use super::plugins::node_state::NodeStatePlugin;
use super::protocol::{HeartbeatReply, ToNode};
use crate::commgraph::CommMatrix;

/// Handle to a spawned node daemon.
#[derive(Debug)]
pub struct NodeHandle {
    /// Node id.
    pub id: usize,
    /// Command channel into the daemon.
    pub tx: Sender<ToNode>,
    join: Option<JoinHandle<()>>,
}

impl NodeHandle {
    /// Ask the daemon to stop and join its thread.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(ToNode::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for NodeHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(ToNode::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Spawn a node daemon thread.
///
/// `node_state` decides heartbeat behaviour; `load_matrix` is the comm
/// graph staged on this node (if any).
pub fn spawn(
    id: usize,
    mut node_state: NodeStatePlugin,
    load_matrix: Option<CommMatrix>,
) -> NodeHandle {
    let (tx, rx) = channel::<ToNode>();
    let join = std::thread::spawn(move || {
        while let Ok(msg) = rx.recv() {
            match msg {
                ToNode::Heartbeat { seq, reply } => {
                    if node_state.responds() {
                        // a gone controller just means the poll timed out
                        let _ = reply.send(HeartbeatReply { seq, node: id });
                    }
                    // down: drop the reply sender — controller sees a miss
                }
                ToNode::FetchLoadMatrix { reply } => {
                    let _ = reply.send(load_matrix.clone());
                }
                ToNode::Shutdown => break,
            }
        }
    });
    NodeHandle {
        id,
        tx,
        join: Some(join),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn healthy_node_replies() {
        let h = spawn(3, NodeStatePlugin::healthy(), None);
        let (tx, rx) = channel();
        h.tx.send(ToNode::Heartbeat { seq: 1, reply: tx }).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(r, HeartbeatReply { seq: 1, node: 3 });
        h.shutdown();
    }

    #[test]
    fn down_node_never_replies() {
        let h = spawn(0, NodeStatePlugin::flaky(1.0, 7), None);
        let (tx, rx) = channel();
        h.tx.send(ToNode::Heartbeat { seq: 9, reply: tx }).unwrap();
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err());
        h.shutdown();
    }

    #[test]
    fn load_matrix_served() {
        let mut m = CommMatrix::new(2);
        m.add_sym(0, 1, 5.0);
        let h = spawn(1, NodeStatePlugin::healthy(), Some(m.clone()));
        let (tx, rx) = channel();
        h.tx.send(ToNode::FetchLoadMatrix { reply: tx }).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), Some(m));
        h.shutdown();
    }
}
