//! Heartbeat history and outage-probability estimation policies.
//!
//! The Fault-Aware Slurmctld plugin records, per node, the outcome of every
//! heartbeat probe (`HB(i)` in the paper). "Node outage probability can be
//! inferred by post-processing the history of each node's heartbeats";
//! the paper suggests empirical frequency and (weighted) moving averages —
//! all three are implemented here.
//!
//! Estimation is fully per-node: it consumes the **generalized** outage
//! vector any [`crate::sim::fault::FaultModel`] produces (non-uniform
//! probabilities included), not just the paper's shared `p_f` — see
//! [`probe_histories`] for the offline probe simulation the batch engine
//! uses.

use crate::rng::Rng;

/// Per-node heartbeat history (true = replied, false = missed).
#[derive(Debug, Clone, Default)]
pub struct HeartbeatHistory {
    outcomes: Vec<bool>,
}

impl HeartbeatHistory {
    /// Record one probe outcome.
    pub fn record(&mut self, replied: bool) {
        self.outcomes.push(replied);
    }

    /// Number of probes.
    pub fn len(&self) -> usize {
        self.outcomes.len()
    }

    /// True if no probes recorded.
    pub fn is_empty(&self) -> bool {
        self.outcomes.is_empty()
    }

    /// Missed-probe count.
    pub fn misses(&self) -> usize {
        self.outcomes.iter().filter(|&&r| !r).count()
    }

    /// Raw outcomes, oldest first.
    pub fn outcomes(&self) -> &[bool] {
        &self.outcomes
    }
}

/// Outage estimation policies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OutagePolicy {
    /// misses / probes over the whole history.
    Empirical,
    /// misses / probes over the last `window` probes.
    MovingAverage { window: usize },
    /// Exponentially weighted: newer probes weigh more.
    Ewma { alpha: f64 },
}

impl OutagePolicy {
    /// Estimate a node's outage probability from its history.
    pub fn estimate(&self, h: &HeartbeatHistory) -> f64 {
        let o = h.outcomes();
        if o.is_empty() {
            return 0.0;
        }
        match *self {
            OutagePolicy::Empirical => h.misses() as f64 / o.len() as f64,
            OutagePolicy::MovingAverage { window } => {
                let w = window.min(o.len()).max(1);
                let tail = &o[o.len() - w..];
                tail.iter().filter(|&&r| !r).count() as f64 / w as f64
            }
            OutagePolicy::Ewma { alpha } => {
                let mut est = 0.0;
                for &replied in o {
                    let x = if replied { 0.0 } else { 1.0 };
                    est = alpha * x + (1.0 - alpha) * est;
                }
                est
            }
        }
    }

    /// Estimate every node's outage probability from its history — the
    /// vectorized form the fault-aware selection path consumes.
    pub fn estimate_all(&self, histories: &[HeartbeatHistory]) -> Vec<f64> {
        histories.iter().map(|h| self.estimate(h)).collect()
    }
}

/// Simulate `rounds` heartbeat probes per node against a generalized
/// per-node outage vector (the node side of the protocol, offline): node
/// `i` misses each probe independently with probability `truth[i]`.
///
/// Nodes with zero outage never draw from `rng`, so for the paper's
/// i.i.d. model this consumes exactly the draws the seed repo's inline
/// estimator did — the batch-level determinism contract is preserved.
pub fn probe_histories(truth: &[f64], rounds: usize, rng: &mut Rng) -> Vec<HeartbeatHistory> {
    truth
        .iter()
        .map(|&p| {
            let mut h = HeartbeatHistory::default();
            for _ in 0..rounds {
                let replied = if p <= 0.0 { true } else { !rng.bernoulli(p) };
                h.record(replied);
            }
            h
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(pattern: &[bool]) -> HeartbeatHistory {
        let mut h = HeartbeatHistory::default();
        for &p in pattern {
            h.record(p);
        }
        h
    }

    #[test]
    fn empirical_frequency() {
        let h = hist(&[true, true, false, true]);
        assert_eq!(OutagePolicy::Empirical.estimate(&h), 0.25);
    }

    #[test]
    fn empty_history_is_zero() {
        let h = HeartbeatHistory::default();
        for p in [
            OutagePolicy::Empirical,
            OutagePolicy::MovingAverage { window: 4 },
            OutagePolicy::Ewma { alpha: 0.2 },
        ] {
            assert_eq!(p.estimate(&h), 0.0);
        }
    }

    #[test]
    fn moving_average_forgets_old_misses() {
        // old misses, recent clean
        let mut o = vec![false; 5];
        o.extend(vec![true; 20]);
        let h = hist(&o);
        assert_eq!(OutagePolicy::MovingAverage { window: 10 }.estimate(&h), 0.0);
        assert!(OutagePolicy::Empirical.estimate(&h) > 0.0);
    }

    #[test]
    fn ewma_tracks_recent() {
        let mut o = vec![true; 50];
        o.extend(vec![false; 10]);
        let h = hist(&o);
        let est = OutagePolicy::Ewma { alpha: 0.3 }.estimate(&h);
        assert!(est > 0.9, "est={est}");
    }

    #[test]
    fn perfect_node_estimates_zero() {
        let h = hist(&[true; 100]);
        assert_eq!(OutagePolicy::Empirical.estimate(&h), 0.0);
        assert_eq!(OutagePolicy::Ewma { alpha: 0.1 }.estimate(&h), 0.0);
    }

    #[test]
    fn probe_histories_track_non_uniform_truth() {
        let truth = [0.0, 0.1, 0.6, 0.0, 0.9];
        let mut rng = Rng::new(12);
        let est = OutagePolicy::Empirical.estimate_all(&probe_histories(&truth, 2000, &mut rng));
        for (i, (&t, &e)) in truth.iter().zip(&est).enumerate() {
            assert!((t - e).abs() < 0.05, "node {i}: truth {t} vs est {e}");
        }
        // ordering of a non-uniform vector is recovered
        assert!(est[4] > est[2] && est[2] > est[1] && est[1] > est[0]);
    }

    #[test]
    fn clean_nodes_consume_no_rng_draws() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        probe_histories(&[0.0, 0.0, 0.0], 50, &mut a);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
