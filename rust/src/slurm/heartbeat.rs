//! Heartbeat history and outage-probability estimation policies.
//!
//! The Fault-Aware Slurmctld plugin records, per node, the outcome of every
//! heartbeat probe (`HB(i)` in the paper). "Node outage probability can be
//! inferred by post-processing the history of each node's heartbeats";
//! the paper suggests empirical frequency and (weighted) moving averages —
//! all three are implemented here.

/// Per-node heartbeat history (true = replied, false = missed).
#[derive(Debug, Clone, Default)]
pub struct HeartbeatHistory {
    outcomes: Vec<bool>,
}

impl HeartbeatHistory {
    /// Record one probe outcome.
    pub fn record(&mut self, replied: bool) {
        self.outcomes.push(replied);
    }

    /// Number of probes.
    pub fn len(&self) -> usize {
        self.outcomes.len()
    }

    /// True if no probes recorded.
    pub fn is_empty(&self) -> bool {
        self.outcomes.is_empty()
    }

    /// Missed-probe count.
    pub fn misses(&self) -> usize {
        self.outcomes.iter().filter(|&&r| !r).count()
    }

    /// Raw outcomes, oldest first.
    pub fn outcomes(&self) -> &[bool] {
        &self.outcomes
    }
}

/// Outage estimation policies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OutagePolicy {
    /// misses / probes over the whole history.
    Empirical,
    /// misses / probes over the last `window` probes.
    MovingAverage { window: usize },
    /// Exponentially weighted: newer probes weigh more.
    Ewma { alpha: f64 },
}

impl OutagePolicy {
    /// Estimate a node's outage probability from its history.
    pub fn estimate(&self, h: &HeartbeatHistory) -> f64 {
        let o = h.outcomes();
        if o.is_empty() {
            return 0.0;
        }
        match *self {
            OutagePolicy::Empirical => h.misses() as f64 / o.len() as f64,
            OutagePolicy::MovingAverage { window } => {
                let w = window.min(o.len()).max(1);
                let tail = &o[o.len() - w..];
                tail.iter().filter(|&&r| !r).count() as f64 / w as f64
            }
            OutagePolicy::Ewma { alpha } => {
                let mut est = 0.0;
                for &replied in o {
                    let x = if replied { 0.0 } else { 1.0 };
                    est = alpha * x + (1.0 - alpha) * est;
                }
                est
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(pattern: &[bool]) -> HeartbeatHistory {
        let mut h = HeartbeatHistory::default();
        for &p in pattern {
            h.record(p);
        }
        h
    }

    #[test]
    fn empirical_frequency() {
        let h = hist(&[true, true, false, true]);
        assert_eq!(OutagePolicy::Empirical.estimate(&h), 0.25);
    }

    #[test]
    fn empty_history_is_zero() {
        let h = HeartbeatHistory::default();
        for p in [
            OutagePolicy::Empirical,
            OutagePolicy::MovingAverage { window: 4 },
            OutagePolicy::Ewma { alpha: 0.2 },
        ] {
            assert_eq!(p.estimate(&h), 0.0);
        }
    }

    #[test]
    fn moving_average_forgets_old_misses() {
        // old misses, recent clean
        let mut o = vec![false; 5];
        o.extend(vec![true; 20]);
        let h = hist(&o);
        assert_eq!(OutagePolicy::MovingAverage { window: 10 }.estimate(&h), 0.0);
        assert!(OutagePolicy::Empirical.estimate(&h) > 0.0);
    }

    #[test]
    fn ewma_tracks_recent() {
        let mut o = vec![true; 50];
        o.extend(vec![false; 10]);
        let h = hist(&o);
        let est = OutagePolicy::Ewma { alpha: 0.3 }.estimate(&h);
        assert!(est > 0.9, "est={est}");
    }

    #[test]
    fn perfect_node_estimates_zero() {
        let h = hist(&[true; 100]);
        assert_eq!(OutagePolicy::Empirical.estimate(&h), 0.0);
        assert_eq!(OutagePolicy::Ewma { alpha: 0.1 }.estimate(&h), 0.0);
    }
}
