//! srun-lite: the user-facing job launcher.
//!
//! Supports the paper's extension: `--distribution=tofa` plus
//! `--load-matrix=<file>` ("an srun command issued with distribution=TOFA
//! and a file resembling the application's communication graph will enable
//! Slurm to spawn each task on the node selected by our resource
//! allocation approach").

use std::path::PathBuf;

use super::jobs::JobRequest;
use crate::commgraph::io;
use crate::error::{Error, Result};
use crate::mapping::PlacementPolicy;

/// Parsed srun arguments.
#[derive(Debug, Clone)]
pub struct SrunArgs {
    /// `-n` / `--ntasks`.
    pub ntasks: usize,
    /// `--distribution`.
    pub distribution: PlacementPolicy,
    /// `--load-matrix` file.
    pub load_matrix: Option<PathBuf>,
    /// Job name.
    pub name: String,
}

/// Parse an srun-style argument list (subset).
pub fn parse_args(args: &[&str]) -> Result<SrunArgs> {
    let mut ntasks = None;
    let mut distribution = PlacementPolicy::DefaultSlurm;
    let mut load_matrix = None;
    let mut name = "job".to_string();
    let mut it = args.iter().peekable();
    while let Some(&a) = it.next() {
        if let Some(v) = a.strip_prefix("--ntasks=") {
            ntasks = Some(
                v.parse()
                    .map_err(|_| Error::Slurm(format!("bad --ntasks: {v}")))?,
            );
        } else if a == "-n" {
            let v = it
                .next()
                .ok_or_else(|| Error::Slurm("-n needs a value".into()))?;
            ntasks = Some(
                v.parse()
                    .map_err(|_| Error::Slurm(format!("bad -n: {v}")))?,
            );
        } else if let Some(v) = a.strip_prefix("--distribution=") {
            distribution = PlacementPolicy::parse(v)
                .ok_or_else(|| Error::Slurm(format!("unknown distribution: {v}")))?;
        } else if let Some(v) = a.strip_prefix("--load-matrix=") {
            load_matrix = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--job-name=") {
            name = v.to_string();
        } else {
            return Err(Error::Slurm(format!("unknown srun argument: {a}")));
        }
    }
    Ok(SrunArgs {
        ntasks: ntasks.ok_or_else(|| Error::Slurm("missing --ntasks".into()))?,
        distribution,
        load_matrix,
        name,
    })
}

/// Turn parsed args into a job request (loads the comm graph file).
pub fn build_request(args: &SrunArgs) -> Result<JobRequest> {
    let comm_graph = match &args.load_matrix {
        Some(p) => {
            let m = io::load(p)?;
            if m.len() != args.ntasks {
                return Err(Error::Slurm(format!(
                    "--load-matrix has {} ranks but --ntasks={}",
                    m.len(),
                    args.ntasks
                )));
            }
            Some(m)
        }
        None => None,
    };
    if comm_graph.is_none()
        && matches!(
            args.distribution,
            PlacementPolicy::Tofa | PlacementPolicy::Scotch | PlacementPolicy::Greedy
        )
    {
        return Err(Error::Slurm(format!(
            "--distribution={} requires --load-matrix",
            args.distribution
        )));
    }
    Ok(JobRequest {
        name: args.name.clone(),
        ranks: args.ntasks,
        distribution: args.distribution,
        comm_graph,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::commgraph::CommMatrix;

    #[test]
    fn parses_paper_invocation() {
        let a = parse_args(&[
            "--ntasks=85",
            "--distribution=tofa",
            "--load-matrix=/tmp/g.txt",
            "--job-name=npb-dt",
        ])
        .unwrap();
        assert_eq!(a.ntasks, 85);
        assert_eq!(a.distribution, PlacementPolicy::Tofa);
        assert!(a.load_matrix.is_some());
    }

    #[test]
    fn rejects_unknown_args_and_missing_ntasks() {
        assert!(parse_args(&["--bogus"]).is_err());
        assert!(parse_args(&["--distribution=tofa"]).is_err());
    }

    #[test]
    fn tofa_requires_load_matrix() {
        let a = parse_args(&["--ntasks=4", "--distribution=tofa"]).unwrap();
        assert!(build_request(&a).is_err());
    }

    #[test]
    fn default_distribution_needs_no_matrix() {
        let a = parse_args(&["-n", "4"]).unwrap();
        let r = build_request(&a).unwrap();
        assert_eq!(r.distribution, PlacementPolicy::DefaultSlurm);
        assert_eq!(r.ranks, 4);
    }

    #[test]
    fn matrix_rank_mismatch_rejected() {
        let dir = std::env::temp_dir().join("tofa-srun-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        io::save(&CommMatrix::new(3), &p).unwrap();
        let a = parse_args(&[
            "--ntasks=4",
            "--distribution=tofa",
            &format!("--load-matrix={}", p.display()),
        ])
        .unwrap();
        assert!(build_request(&a).is_err());
    }
}
