//! Controller <-> node daemon messages.
//!
//! Transport is std mpsc channels: one command channel into each daemon
//! thread, and per-request reply channels (the oneshot pattern).

use std::sync::mpsc::Sender;

use crate::commgraph::CommMatrix;

/// Messages a node daemon accepts.
#[derive(Debug)]
pub enum ToNode {
    /// Heartbeat probe `Hb(t, i)`; the daemon replies on `reply` unless the
    /// node is emulated as down at this poll (it then drops the sender,
    /// which the controller observes as a timeout/miss).
    Heartbeat {
        seq: u64,
        reply: Sender<HeartbeatReply>,
    },
    /// Fetch the staged communication graph for a pending job (LoadMatrix
    /// plugin path: compute node -> controller).
    FetchLoadMatrix {
        reply: Sender<Option<CommMatrix>>,
    },
    /// Shut the daemon down.
    Shutdown,
}

/// A heartbeat reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeartbeatReply {
    /// Echoed sequence number.
    pub seq: u64,
    /// Node id.
    pub node: usize,
}
