//! FIFO scheduling queue (the paper batches 100 instances per queue).

use std::collections::VecDeque;

use super::jobs::{JobRecord, JobRequest, JobState};

/// FIFO job queue with id assignment (squeue-visible state).
#[derive(Debug, Default)]
pub struct JobQueue {
    next_id: u64,
    pending: VecDeque<JobRecord>,
    finished: Vec<JobRecord>,
}

impl JobQueue {
    /// Create an empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a request; returns the assigned job id.
    pub fn submit(&mut self, request: JobRequest) -> u64 {
        self.submit_at(request, 0.0)
    }

    /// Enqueue a request arriving at simulated time `now`.
    pub fn submit_at(&mut self, request: JobRequest, now: f64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let mut record = JobRecord::new(id, request);
        record.submit_s = now;
        self.pending.push_back(record);
        id
    }

    /// Re-enqueue an existing record at the queue tail (scheduler
    /// resubmission after an abort). The record keeps its id, original
    /// arrival time, and abort count.
    pub fn resubmit(&mut self, mut record: JobRecord) {
        record.state = JobState::Pending;
        record.assignment = None;
        self.pending.push_back(record);
    }

    /// Pop the next pending job.
    pub fn next(&mut self) -> Option<JobRecord> {
        self.pending.pop_front()
    }

    /// Pop the pending job at position `pos` (0 = head). Backfill pulls
    /// candidates from behind the head with this.
    pub fn take_at(&mut self, pos: usize) -> Option<JobRecord> {
        self.pending.remove(pos)
    }

    /// Put a record back at position `pos` (backfill rollback).
    pub fn insert_at(&mut self, pos: usize, record: JobRecord) {
        let pos = pos.min(self.pending.len());
        self.pending.insert(pos, record);
    }

    /// The pending job at position `pos`, if any.
    pub fn peek_at(&self, pos: usize) -> Option<&JobRecord> {
        self.pending.get(pos)
    }

    /// Iterate the pending records in queue order.
    pub fn iter_pending(&self) -> impl Iterator<Item = &JobRecord> {
        self.pending.iter()
    }

    /// Record a finished job. `state` must be terminal
    /// ([`JobState::is_terminal`]) — retiring a `Pending`/`Running` record
    /// is a scheduler bug (it is how jobs used to vanish from accounting).
    pub fn finish(&mut self, mut record: JobRecord, state: JobState) {
        assert!(
            state.is_terminal(),
            "job {} finished in non-terminal state {state:?}",
            record.id
        );
        record.state = state;
        self.finished.push(record);
    }

    /// Pending count.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Finished records.
    pub fn finished(&self) -> &[JobRecord] {
        &self.finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::PlacementPolicy;

    fn req() -> JobRequest {
        JobRequest {
            name: "j".into(),
            ranks: 2,
            distribution: PlacementPolicy::DefaultSlurm,
            comm_graph: None,
        }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut q = JobQueue::new();
        let a = q.submit(req());
        let b = q.submit(req());
        assert!(a < b);
        assert_eq!(q.next().unwrap().id, a);
        assert_eq!(q.next().unwrap().id, b);
        assert!(q.next().is_none());
    }

    #[test]
    fn finished_records_kept() {
        let mut q = JobQueue::new();
        q.submit(req());
        let r = q.next().unwrap();
        q.finish(r, JobState::Completed);
        assert_eq!(q.finished().len(), 1);
        assert_eq!(q.finished()[0].state, JobState::Completed);
    }

    #[test]
    fn submit_at_records_arrival_time() {
        let mut q = JobQueue::new();
        q.submit_at(req(), 3.25);
        let r = q.next().unwrap();
        assert_eq!(r.submit_s, 3.25);
    }

    #[test]
    fn take_and_insert_preserve_order() {
        let mut q = JobQueue::new();
        let a = q.submit(req());
        let b = q.submit(req());
        let c = q.submit(req());
        // pull the middle job, then put it back where it was
        let mid = q.take_at(1).unwrap();
        assert_eq!(mid.id, b);
        assert_eq!(q.pending_len(), 2);
        q.insert_at(1, mid);
        let order: Vec<u64> = q.iter_pending().map(|r| r.id).collect();
        assert_eq!(order, vec![a, b, c]);
        assert!(q.take_at(7).is_none());
    }

    #[test]
    fn resubmit_goes_to_the_tail_and_stays_pending() {
        let mut q = JobQueue::new();
        let a = q.submit_at(req(), 1.0);
        let b = q.submit(req());
        let mut r = q.next().unwrap();
        r.aborts = 2;
        r.state = JobState::Running;
        r.assignment = Some(vec![0, 1]);
        q.resubmit(r);
        assert_eq!(q.next().unwrap().id, b);
        let back = q.next().unwrap();
        assert_eq!(back.id, a);
        assert_eq!(back.state, JobState::Pending);
        assert_eq!(back.aborts, 2);
        assert_eq!(back.submit_s, 1.0);
        assert!(back.assignment.is_none());
    }

    #[test]
    #[should_panic(expected = "non-terminal state")]
    fn finish_rejects_non_terminal_states() {
        let mut q = JobQueue::new();
        q.submit(req());
        let r = q.next().unwrap();
        q.finish(r, JobState::Running);
    }
}
