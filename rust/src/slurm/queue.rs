//! FIFO scheduling queue (the paper batches 100 instances per queue).

use std::collections::VecDeque;

use super::jobs::{JobRecord, JobRequest, JobState};

/// FIFO job queue with id assignment (squeue-visible state).
#[derive(Debug, Default)]
pub struct JobQueue {
    next_id: u64,
    pending: VecDeque<JobRecord>,
    finished: Vec<JobRecord>,
}

impl JobQueue {
    /// Create an empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a request; returns the assigned job id.
    pub fn submit(&mut self, request: JobRequest) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back(JobRecord::new(id, request));
        id
    }

    /// Pop the next pending job.
    pub fn next(&mut self) -> Option<JobRecord> {
        self.pending.pop_front()
    }

    /// Record a finished job.
    pub fn finish(&mut self, mut record: JobRecord, state: JobState) {
        record.state = state;
        self.finished.push(record);
    }

    /// Pending count.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Finished records.
    pub fn finished(&self) -> &[JobRecord] {
        &self.finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::PlacementPolicy;

    fn req() -> JobRequest {
        JobRequest {
            name: "j".into(),
            ranks: 2,
            distribution: PlacementPolicy::DefaultSlurm,
            comm_graph: None,
        }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut q = JobQueue::new();
        let a = q.submit(req());
        let b = q.submit(req());
        assert!(a < b);
        assert_eq!(q.next().unwrap().id, a);
        assert_eq!(q.next().unwrap().id, b);
        assert!(q.next().is_none());
    }

    #[test]
    fn finished_records_kept() {
        let mut q = JobQueue::new();
        q.submit(req());
        let r = q.next().unwrap();
        q.finish(r, JobState::Completed);
        assert_eq!(q.finished().len(), 1);
        assert_eq!(q.finished()[0].state, JobState::Completed);
    }
}
