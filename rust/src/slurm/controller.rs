//! slurmctld-lite: the controller.
//!
//! Owns the job queue, the plugin set, and the node daemons. The flow for
//! one job mirrors the paper's Fig. 2: srun submits a request (optionally
//! carrying the LoadMatrix comm graph); FANS combines the comm graph, the
//! FATT routing/topology info, and the Fault-Aware-Slurmctld outage
//! estimates to produce the task layout `T`; the job then executes (here:
//! in the SimGrid-lite simulator, driven by [`crate::batch`]).

use super::jobs::{JobRecord, JobRequest, JobState};
use super::noded::NodeHandle;
use super::plugins::fans::FansPlugin;
use super::plugins::fatt::FattPlugin;
use super::plugins::fault_ctld::FaultCtldPlugin;
use super::plugins::node_state::NodeStatePlugin;
use super::queue::JobQueue;
use crate::error::Result;
use crate::mapping::Placement;
use crate::rng::Rng;
use crate::slurm::heartbeat::OutagePolicy;
use crate::topology::Platform;

/// The controller: queue + plugins + (optionally) live node daemons.
pub struct Controller {
    platform: Platform,
    queue: JobQueue,
    fans: FansPlugin,
    fatt: FattPlugin,
    fault_ctld: FaultCtldPlugin,
    nodes: Vec<NodeHandle>,
    rng: Rng,
    /// Injected estimates (offline mode); overrides heartbeat-derived ones.
    offline_estimates: Option<Vec<f64>>,
}

impl Controller {
    /// Build a controller for a platform (no node daemons yet).
    pub fn new(platform: Platform, seed: u64) -> Self {
        let n = platform.num_nodes();
        // share the platform's TopoIndex cell: FATT's transit registry and
        // the FANS placer then reuse one route-sweep precompute
        let fatt = FattPlugin::on_platform(&platform);
        Controller {
            platform,
            queue: JobQueue::new(),
            fans: FansPlugin::default(),
            fatt,
            fault_ctld: FaultCtldPlugin::new(n, OutagePolicy::Empirical),
            nodes: Vec::new(),
            rng: Rng::new(seed),
            offline_estimates: None,
        }
    }

    /// Spawn one node daemon per platform node. `outage_p[i] > 0` makes
    /// node `i`'s NodeState plugin flaky (ground-truth emulation).
    pub fn spawn_node_daemons(&mut self, outage_p: &[f64], seed: u64) {
        assert_eq!(outage_p.len(), self.platform.num_nodes());
        self.nodes = outage_p
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let st = if p > 0.0 {
                    NodeStatePlugin::flaky(p, seed ^ (i as u64).wrapping_mul(0x9E37))
                } else {
                    NodeStatePlugin::healthy()
                };
                super::noded::spawn(i, st, None)
            })
            .collect();
    }

    /// Run `rounds` of heartbeat collection against the live daemons.
    pub fn collect_heartbeats(&mut self, rounds: usize) {
        self.fault_ctld.collect(&self.nodes, rounds);
    }

    /// Shut down all node daemons.
    pub fn shutdown_node_daemons(&mut self) {
        for h in self.nodes.drain(..) {
            h.shutdown();
        }
    }

    /// Inject outage estimates directly (offline mode, used by the batch
    /// driver when daemons are not spawned).
    pub fn set_outage_estimates(&mut self, estimates: &[f64]) {
        self.offline_estimates = Some(estimates.to_vec());
    }

    /// Current outage estimates (heartbeat-derived, or injected).
    pub fn outage_estimates(&self) -> Vec<f64> {
        if let Some(e) = &self.offline_estimates {
            e.clone()
        } else {
            self.fault_ctld.outage_estimates()
        }
    }

    /// Submit a job.
    pub fn submit(&mut self, request: JobRequest) -> u64 {
        self.queue.submit(request)
    }

    /// Allocate nodes for the next pending job; returns the record with
    /// its assignment filled in (state = Running).
    pub fn schedule_next(&mut self) -> Option<Result<JobRecord>> {
        let mut record = self.queue.next()?;
        let outage = self.outage_estimates();
        let comm = match &record.request.comm_graph {
            Some(c) => c.clone(),
            None => crate::commgraph::CommMatrix::new(record.request.ranks),
        };
        let placement: Result<Placement> = self.fans.select(
            record.request.distribution,
            &comm,
            &self.platform,
            &outage,
            &mut self.rng,
        );
        Some(placement.map(|p| {
            record.assignment = Some(p.assignment);
            record.state = JobState::Running;
            record
        }))
    }

    /// Mark a job finished.
    pub fn complete(&mut self, record: JobRecord, state: JobState) {
        self.queue.finish(record, state);
    }

    /// Finished job records.
    pub fn finished(&self) -> &[JobRecord] {
        self.queue.finished()
    }

    /// The FATT plugin (routing oracle).
    pub fn fatt(&self) -> &FattPlugin {
        &self.fatt
    }

    /// The platform.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lammps_proxy::LammpsProxy, MpiApp};
    use crate::mapping::PlacementPolicy;
    use crate::profiler::profile_app;
    use crate::topology::TorusDims;

    fn request(ranks: usize, dist: PlacementPolicy) -> JobRequest {
        let app = LammpsProxy::tiny(ranks, 2);
        JobRequest {
            name: "lammps".into(),
            ranks,
            distribution: dist,
            comm_graph: Some(profile_app(&app).volume),
        }
    }

    #[test]
    fn end_to_end_heartbeats_inform_tofa() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 1);
        let mut truth = vec![0.0; 64];
        truth[0] = 0.8; // very flaky first node
        truth[1] = 0.8;
        ctl.spawn_node_daemons(&truth, 99);
        ctl.collect_heartbeats(40);
        let est = ctl.outage_estimates();
        assert!(est[0] > 0.3, "est[0]={}", est[0]);
        assert_eq!(est[5], 0.0);

        ctl.submit(request(8, PlacementPolicy::Tofa));
        let rec = ctl.schedule_next().unwrap().unwrap();
        let assign = rec.assignment.unwrap();
        assert!(!assign.contains(&0), "TOFA used flaky node 0");
        assert!(!assign.contains(&1), "TOFA used flaky node 1");
        ctl.shutdown_node_daemons();
    }

    #[test]
    fn offline_estimates_drive_selection() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 2);
        let mut est = vec![0.0; 64];
        est[3] = 0.5;
        ctl.set_outage_estimates(&est);
        ctl.submit(request(8, PlacementPolicy::Tofa));
        let rec = ctl.schedule_next().unwrap().unwrap();
        assert!(!rec.assignment.unwrap().contains(&3));
    }

    #[test]
    fn default_distribution_is_block() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 3);
        ctl.submit(request(6, PlacementPolicy::DefaultSlurm));
        let rec = ctl.schedule_next().unwrap().unwrap();
        assert_eq!(rec.assignment.unwrap(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn queue_drains_in_order() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 4);
        let a = ctl.submit(request(4, PlacementPolicy::Random));
        let b = ctl.submit(request(4, PlacementPolicy::Random));
        assert_eq!(ctl.schedule_next().unwrap().unwrap().id, a);
        assert_eq!(ctl.schedule_next().unwrap().unwrap().id, b);
        assert!(ctl.schedule_next().is_none());
    }
}
