//! slurmctld-lite: the controller.
//!
//! Owns the job queue, the plugin set, the node-occupancy ledger, and the
//! node daemons. The flow for one job mirrors the paper's Fig. 2: srun
//! submits a request (optionally carrying the LoadMatrix comm graph); FANS
//! combines the comm graph, the FATT routing/topology info, and the
//! Fault-Aware-Slurmctld outage estimates to produce the task layout `T`
//! — restricted to the ledger's free nodes; the job then executes (here:
//! in the SimGrid-lite simulator, driven by [`crate::batch`] for dedicated
//! batches or [`crate::slurm::sched`] for a shared cluster).

use super::jobs::{JobRecord, JobRequest, JobState};
use super::noded::NodeHandle;
use super::plugins::fans::FansPlugin;
use super::plugins::fatt::FattPlugin;
use super::plugins::fault_ctld::FaultCtldPlugin;
use super::plugins::node_state::NodeStatePlugin;
use super::queue::JobQueue;
use super::sched::NodeLedger;
use crate::error::{Error, Result};
use crate::mapping::Placement;
use crate::rng::Rng;
use crate::slurm::heartbeat::OutagePolicy;
use crate::topology::Platform;

/// The controller: queue + plugins + ledger + (optionally) node daemons.
pub struct Controller {
    platform: Platform,
    queue: JobQueue,
    fans: FansPlugin,
    fatt: FattPlugin,
    fault_ctld: FaultCtldPlugin,
    ledger: NodeLedger,
    nodes: Vec<NodeHandle>,
    rng: Rng,
    /// Injected estimates (offline mode); overrides heartbeat-derived ones.
    offline_estimates: Option<Vec<f64>>,
    /// Reused buffer for the free-node candidate list, refilled from the
    /// ledger's lazy iterator on each scheduling attempt.
    free_scratch: Vec<usize>,
}

impl Controller {
    /// Build a controller for a platform (no node daemons yet).
    pub fn new(platform: Platform, seed: u64) -> Self {
        let n = platform.num_nodes();
        // share the platform's TopoIndex cell: FATT's transit registry and
        // the FANS placer then reuse one route-sweep precompute
        let fatt = FattPlugin::on_platform(&platform);
        Controller {
            platform,
            queue: JobQueue::new(),
            fans: FansPlugin::default(),
            fatt,
            fault_ctld: FaultCtldPlugin::new(n, OutagePolicy::Empirical),
            ledger: NodeLedger::new(n),
            nodes: Vec::new(),
            rng: Rng::new(seed),
            offline_estimates: None,
            free_scratch: Vec::new(),
        }
    }

    /// Spawn one node daemon per platform node. `outage_p[i] > 0` makes
    /// node `i`'s NodeState plugin flaky (ground-truth emulation).
    pub fn spawn_node_daemons(&mut self, outage_p: &[f64], seed: u64) {
        assert_eq!(outage_p.len(), self.platform.num_nodes());
        self.nodes = outage_p
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let st = if p > 0.0 {
                    NodeStatePlugin::flaky(p, seed ^ (i as u64).wrapping_mul(0x9E37))
                } else {
                    NodeStatePlugin::healthy()
                };
                super::noded::spawn(i, st, None)
            })
            .collect();
    }

    /// Run `rounds` of heartbeat collection against the live daemons.
    pub fn collect_heartbeats(&mut self, rounds: usize) {
        self.fault_ctld.collect(&self.nodes, rounds);
    }

    /// Shut down all node daemons.
    pub fn shutdown_node_daemons(&mut self) {
        for h in self.nodes.drain(..) {
            h.shutdown();
        }
    }

    /// Inject outage estimates directly (offline mode, used by the batch
    /// driver when daemons are not spawned).
    pub fn set_outage_estimates(&mut self, estimates: &[f64]) {
        self.offline_estimates = Some(estimates.to_vec());
    }

    /// Current outage estimates (heartbeat-derived, or injected).
    pub fn outage_estimates(&self) -> Vec<f64> {
        if let Some(e) = &self.offline_estimates {
            e.clone()
        } else {
            self.fault_ctld.outage_estimates()
        }
    }

    /// Submit a job.
    pub fn submit(&mut self, request: JobRequest) -> u64 {
        self.queue.submit(request)
    }

    /// Submit a job arriving at simulated time `now`.
    pub fn submit_at(&mut self, request: JobRequest, now: f64) -> u64 {
        self.queue.submit_at(request, now)
    }

    /// Allocate nodes for the next pending job; returns the record with
    /// its assignment filled in (state = Running) and the nodes held in
    /// the ledger.
    ///
    /// If resource selection fails the job is **not** dropped: the record
    /// is parked in `finished` as [`JobState::Failed`] with the error
    /// recorded, so every submitted job stays accounted for (it used to
    /// vanish — neither pending nor finished).
    pub fn schedule_next(&mut self) -> Option<Result<JobRecord>> {
        self.try_schedule_at(0)
    }

    /// Like [`Controller::schedule_next`] for the pending job at queue
    /// position `pos` (backfill pulls candidates from behind the head).
    pub fn try_schedule_at(&mut self, pos: usize) -> Option<Result<JobRecord>> {
        let mut record = self.queue.take_at(pos)?;
        let outage = self.outage_estimates();
        let comm = match &record.request.comm_graph {
            Some(c) => c.clone(),
            None => crate::commgraph::CommMatrix::new(record.request.ranks),
        };
        // Candidate list: when every node is free, pass None — FANS
        // reduces a full mask to the unrestricted path anyway, so this is
        // bit-identical and skips materializing the list entirely. The
        // partial case refills a reused buffer from the ledger's lazy
        // free-run iterator instead of allocating a fresh Vec per attempt.
        let candidates = if self.ledger.num_free() == self.ledger.num_nodes() {
            None
        } else {
            self.free_scratch.clear();
            self.free_scratch.extend(self.ledger.free_nodes_iter());
            Some(self.free_scratch.as_slice())
        };
        let placement: Result<Placement> = self.fans.select(
            record.request.distribution,
            &comm,
            &self.platform,
            &outage,
            candidates,
            &mut self.rng,
        );
        let placement = placement.and_then(|p| {
            self.ledger.allocate(record.id, &p.assignment)?;
            Ok(p)
        });
        match placement {
            Ok(p) => {
                record.assignment = Some(p.assignment);
                record.state = JobState::Running;
                Some(Ok(record))
            }
            Err(e) => {
                // job-loss bugfix: park the record as Failed instead of
                // dropping it on the floor
                record.error = Some(e.to_string());
                self.queue.finish(record, JobState::Failed);
                Some(Err(e))
            }
        }
    }

    /// ULFM-style shrink-replace for a *running* job: the ranks hosted on
    /// `lost_hosts` are re-placed onto currently-free nodes via the same
    /// candidate-mask FANS selection path as a fresh launch, the ledger
    /// marks the lost hosts `Down` and grows the allocation by the
    /// replacements, and the record's assignment is patched in place.
    /// Returns `(lost rank indices, replacement hosts)` — `replacements[i]`
    /// is the new host of rank `lost_ranks[i]`. On error nothing changes
    /// (the caller falls back to abort → resubmit).
    pub fn shrink_replace(
        &mut self,
        record: &mut JobRecord,
        lost_hosts: &[usize],
    ) -> Result<(Vec<usize>, Vec<usize>)> {
        let assignment = record
            .assignment
            .as_ref()
            .ok_or_else(|| Error::Slurm("shrink-replace without an assignment".into()))?;
        let lost_ranks: Vec<usize> = assignment
            .iter()
            .enumerate()
            .filter(|(_, host)| lost_hosts.contains(host))
            .map(|(r, _)| r)
            .collect();
        let k = lost_ranks.len();
        if k == 0 {
            return Err(Error::Slurm("shrink-replace with no lost ranks".into()));
        }
        if self.ledger.num_free() < k {
            return Err(Error::Slurm(format!(
                "shrink-replace needs {k} free nodes, {} available",
                self.ledger.num_free()
            )));
        }
        // the lost ranks' comm load as a k x k submatrix of the job's
        // comm graph: FANS re-places exactly that load on the free set
        let sub = match &record.request.comm_graph {
            Some(c) => {
                let mut m = crate::commgraph::CommMatrix::new(k);
                for (i, &ri) in lost_ranks.iter().enumerate() {
                    for (j, &rj) in lost_ranks.iter().enumerate() {
                        m.set(i, j, c.get(ri, rj));
                    }
                }
                m
            }
            None => crate::commgraph::CommMatrix::new(k),
        };
        let outage = self.outage_estimates();
        self.free_scratch.clear();
        self.free_scratch.extend(self.ledger.free_nodes_iter());
        let placement = self.fans.select(
            record.request.distribution,
            &sub,
            &self.platform,
            &outage,
            Some(self.free_scratch.as_slice()),
            &mut self.rng,
        )?;
        self.ledger.fail_nodes(record.id, lost_hosts)?;
        self.ledger
            .extend_allocation(record.id, &placement.assignment)?;
        // invariant: callers reach this path only for running jobs, which
        // always carry an assignment (checked at the top of this fn)
        let assignment = record.assignment.as_mut().expect("checked above");
        for (i, &r) in lost_ranks.iter().enumerate() {
            assignment[r] = placement.assignment[i];
        }
        Ok((lost_ranks, placement.assignment))
    }

    /// Mark a job finished: release its ledger allocation and retire the
    /// record. `state` must be terminal (asserted by the queue).
    pub fn complete(&mut self, record: JobRecord, state: JobState) {
        self.ledger.release(record.id);
        self.queue.finish(record, state);
    }

    /// Mark a job finished with its simulated outcome: fills
    /// `completion_s`, `aborts`, and `end_s` on the record (they used to
    /// stay `None`/0 forever), releases the allocation, and retires it.
    pub fn complete_with(
        &mut self,
        mut record: JobRecord,
        state: JobState,
        completion_s: f64,
        aborts: u32,
        end_s: f64,
    ) {
        record.completion_s = Some(completion_s);
        record.aborts = aborts;
        record.end_s = Some(end_s);
        self.complete(record, state);
    }

    /// Re-enqueue a running job after an abort (scheduler resubmission):
    /// releases its nodes and pushes the record to the queue tail.
    pub fn resubmit(&mut self, record: JobRecord) {
        self.ledger.release(record.id);
        self.queue.resubmit(record);
    }

    /// Undo a tentative [`Controller::try_schedule_at`]: release the
    /// allocation and put the record back at queue position `pos`
    /// (conservative backfill probes placements this way).
    pub fn rollback_schedule(&mut self, pos: usize, mut record: JobRecord) {
        self.ledger.release(record.id);
        record.state = JobState::Pending;
        record.assignment = None;
        self.queue.insert_at(pos, record);
    }

    /// Remove and return the pending job at queue position `pos` without
    /// scheduling it (the scheduler's starvation drain).
    pub fn take_pending(&mut self, pos: usize) -> Option<JobRecord> {
        self.queue.take_at(pos)
    }

    /// Finished job records.
    pub fn finished(&self) -> &[JobRecord] {
        self.queue.finished()
    }

    /// Pending job count.
    pub fn pending_len(&self) -> usize {
        self.queue.pending_len()
    }

    /// The pending job at queue position `pos`.
    pub fn peek_pending(&self, pos: usize) -> Option<&JobRecord> {
        self.queue.peek_at(pos)
    }

    /// The node-occupancy ledger.
    pub fn ledger(&self) -> &NodeLedger {
        &self.ledger
    }

    /// Mutable ledger access (heartbeat health epochs).
    pub fn ledger_mut(&mut self) -> &mut NodeLedger {
        &mut self.ledger
    }

    /// The FATT plugin (routing oracle).
    pub fn fatt(&self) -> &FattPlugin {
        &self.fatt
    }

    /// The platform.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lammps_proxy::LammpsProxy, MpiApp};
    use crate::mapping::PlacementPolicy;
    use crate::profiler::profile_app;
    use crate::topology::TorusDims;

    fn request(ranks: usize, dist: PlacementPolicy) -> JobRequest {
        let app = LammpsProxy::tiny(ranks, 2);
        JobRequest {
            name: "lammps".into(),
            ranks,
            distribution: dist,
            comm_graph: Some(profile_app(&app).volume),
        }
    }

    #[test]
    fn end_to_end_heartbeats_inform_tofa() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 1);
        let mut truth = vec![0.0; 64];
        truth[0] = 0.8; // very flaky first node
        truth[1] = 0.8;
        ctl.spawn_node_daemons(&truth, 99);
        ctl.collect_heartbeats(40);
        let est = ctl.outage_estimates();
        assert!(est[0] > 0.3, "est[0]={}", est[0]);
        assert_eq!(est[5], 0.0);

        ctl.submit(request(8, PlacementPolicy::Tofa));
        let rec = ctl.schedule_next().unwrap().unwrap();
        let assign = rec.assignment.unwrap();
        assert!(!assign.contains(&0), "TOFA used flaky node 0");
        assert!(!assign.contains(&1), "TOFA used flaky node 1");
        ctl.shutdown_node_daemons();
    }

    #[test]
    fn offline_estimates_drive_selection() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 2);
        let mut est = vec![0.0; 64];
        est[3] = 0.5;
        ctl.set_outage_estimates(&est);
        ctl.submit(request(8, PlacementPolicy::Tofa));
        let rec = ctl.schedule_next().unwrap().unwrap();
        assert!(!rec.assignment.unwrap().contains(&3));
    }

    #[test]
    fn default_distribution_is_block() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 3);
        ctl.submit(request(6, PlacementPolicy::DefaultSlurm));
        let rec = ctl.schedule_next().unwrap().unwrap();
        assert_eq!(rec.assignment.unwrap(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn queue_drains_in_order() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 4);
        let a = ctl.submit(request(4, PlacementPolicy::Random));
        let b = ctl.submit(request(4, PlacementPolicy::Random));
        assert_eq!(ctl.schedule_next().unwrap().unwrap().id, a);
        assert_eq!(ctl.schedule_next().unwrap().unwrap().id, b);
        assert!(ctl.schedule_next().is_none());
    }

    #[test]
    fn concurrent_running_jobs_never_share_nodes() {
        // the overlap bug: two Running jobs used to both get the full
        // platform; the ledger now makes allocations exclusive
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 5);
        ctl.submit(request(6, PlacementPolicy::DefaultSlurm));
        ctl.submit(request(6, PlacementPolicy::DefaultSlurm));
        let a = ctl.schedule_next().unwrap().unwrap();
        let b = ctl.schedule_next().unwrap().unwrap();
        let an = a.assignment.clone().unwrap();
        let bn = b.assignment.clone().unwrap();
        for n in &bn {
            assert!(!an.contains(n), "node {n} allocated twice");
        }
        // block over the remaining free nodes is sequential after a's
        assert_eq!(an, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(bn, vec![6, 7, 8, 9, 10, 11]);
        ctl.ledger().assert_consistent();
        assert_eq!(ctl.ledger().num_busy(), 12);
        ctl.complete(a, JobState::Completed);
        assert_eq!(ctl.ledger().num_busy(), 6);
        ctl.complete(b, JobState::Completed);
        assert_eq!(ctl.ledger().num_free(), 64);
    }

    #[test]
    fn unplaceable_job_is_parked_as_failed_not_lost() {
        // job-loss regression: more ranks than free nodes used to make
        // the record vanish (neither pending nor finished)
        let plat = Platform::paper_default(TorusDims::new(2, 2, 2)); // 8 nodes
        let mut ctl = Controller::new(plat, 6);
        ctl.submit(request(16, PlacementPolicy::DefaultSlurm));
        let r = ctl.schedule_next().unwrap();
        assert!(r.is_err());
        assert_eq!(ctl.pending_len(), 0);
        assert_eq!(ctl.finished().len(), 1, "job lost from accounting");
        let rec = &ctl.finished()[0];
        assert_eq!(rec.state, JobState::Failed);
        assert!(rec.error.as_deref().unwrap().contains("ranks"), "{rec:?}");
        // the failed attempt must not leak ledger state
        assert_eq!(ctl.ledger().num_free(), 8);
    }

    #[test]
    fn shrink_replace_repairs_a_running_job_in_place() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 8);
        ctl.submit(request(6, PlacementPolicy::DefaultSlurm));
        let mut rec = ctl.schedule_next().unwrap().unwrap();
        let before = rec.assignment.clone().unwrap();
        assert_eq!(before, vec![0, 1, 2, 3, 4, 5]);
        // lose two of the six hosts mid-run
        let (lost_ranks, repl) = ctl.shrink_replace(&mut rec, &[1, 4]).unwrap();
        assert_eq!(lost_ranks, vec![1, 4]);
        assert_eq!(repl.len(), 2);
        let after = rec.assignment.clone().unwrap();
        // survivors kept their nodes, lost ranks moved to the replacements
        for r in [0usize, 2, 3, 5] {
            assert_eq!(after[r], before[r], "survivor rank {r} moved");
        }
        assert_eq!(after[1], repl[0]);
        assert_eq!(after[4], repl[1]);
        for &n in &repl {
            assert!(!before.contains(&n), "replacement {n} was already held");
            assert_eq!(ctl.ledger().state_of(n), crate::slurm::sched::NodeState::Busy(rec.id));
        }
        assert_eq!(ctl.ledger().state_of(1), crate::slurm::sched::NodeState::Down);
        assert_eq!(ctl.ledger().state_of(4), crate::slurm::sched::NodeState::Down);
        ctl.ledger().assert_consistent();
        // a host set disjoint from the allocation is a typed error and
        // leaves everything unchanged
        assert!(ctl.shrink_replace(&mut rec, &[60]).is_err());
        ctl.ledger().assert_consistent();
    }

    #[test]
    fn complete_with_fills_outcome_fields() {
        // dead-fields regression: completion_s / aborts / end_s used to
        // stay empty forever
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut ctl = Controller::new(plat, 7);
        ctl.submit_at(request(4, PlacementPolicy::DefaultSlurm), 1.5);
        let mut rec = ctl.schedule_next().unwrap().unwrap();
        rec.start_s = Some(2.0);
        ctl.complete_with(rec, JobState::Completed, 3.25, 2, 5.25);
        let done = &ctl.finished()[0];
        assert_eq!(done.state, JobState::Completed);
        assert_eq!(done.completion_s, Some(3.25));
        assert_eq!(done.aborts, 2);
        assert_eq!(done.submit_s, 1.5);
        assert_eq!(done.end_s, Some(5.25));
        assert!((done.wait_s().unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(ctl.ledger().num_free(), 64);
    }
}
