//! Fault-Aware Topology (FATT) plugin.
//!
//! Controller-side: holds the platform's [`Topology`] (built at slurmctld
//! init) and exports the routing function `R(u, v)` — including
//! intermediate transit vertices, which Slurm's stock topology plugins do
//! not expose (the reason the paper had to write FATT). The paper's
//! artifact is the 3-D torus variant, parsed from a topology file (one
//! entry per node: id plus x, y, z coordinates); fat-tree and dragonfly
//! platforms plug in behind the same trait via
//! [`FattPlugin::with_topology`].

use std::io::{BufRead, BufReader, Read};
use std::sync::{Arc, OnceLock};

use crate::error::{Error, Result};
use crate::topology::{Platform, TopoIndex, Topology, Torus, TorusDims};

/// The FATT plugin: platform topology + routing oracle.
#[derive(Debug, Clone)]
pub struct FattPlugin {
    topo: Arc<dyn Topology>,
    /// Lazily-built transit registry (node -> paths it serves), shared by
    /// every clone of the plugin like the controller shares the platform.
    index: Arc<OnceLock<TopoIndex>>,
}

impl FattPlugin {
    /// Build directly from torus dimensions (the paper's platform).
    pub fn new(dims: TorusDims) -> Self {
        Self::with_topology(Arc::new(Torus::new(dims)))
    }

    /// Build for any topology (fat-tree / dragonfly platforms).
    pub fn with_topology(topo: Arc<dyn Topology>) -> Self {
        FattPlugin {
            topo,
            index: Arc::new(OnceLock::new()),
        }
    }

    /// Build for a platform, **sharing** its [`TopoIndex`] cell: the
    /// transit registry and the placer's incremental engines then pay the
    /// one-time route sweep once between them (this is how the controller
    /// wires FATT up at slurmctld init).
    pub fn on_platform(platform: &Platform) -> Self {
        FattPlugin {
            topo: platform.topology_arc(),
            index: platform.index_cell(),
        }
    }

    /// Parse the topology file format described in the paper: a header
    /// `dims X Y Z` followed by one `id x y z` line per node. Validates
    /// that every node appears exactly once with row-major-consistent
    /// coordinates.
    pub fn from_topology_file<R: Read>(r: R) -> Result<Self> {
        let mut lines = BufReader::new(r).lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::Topology("empty topology file".into()))??;
        let hp: Vec<&str> = header.split_whitespace().collect();
        if hp.len() != 4 || hp[0] != "dims" {
            return Err(Error::Topology(format!("bad topology header: {header}")));
        }
        let parse = |s: &str| {
            s.parse::<usize>()
                .map_err(|_| Error::Topology(format!("bad number: {s}")))
        };
        let dims = TorusDims::new(parse(hp[1])?, parse(hp[2])?, parse(hp[3])?);
        let torus = Torus::new(dims);
        let mut seen = vec![false; dims.nodes()];
        for line in lines {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let p: Vec<&str> = line.split_whitespace().collect();
            if p.len() != 4 {
                return Err(Error::Topology(format!("bad topology entry: {line}")));
            }
            let (id, x, y, z) = (parse(p[0])?, parse(p[1])?, parse(p[2])?, parse(p[3])?);
            if id >= dims.nodes() || x >= dims.x || y >= dims.y || z >= dims.z {
                return Err(Error::Topology(format!("entry out of range: {line}")));
            }
            if torus.id(x, y, z) != id {
                return Err(Error::Topology(format!(
                    "entry {line}: coordinates disagree with row-major id"
                )));
            }
            if seen[id] {
                return Err(Error::Topology(format!("duplicate node id {id}")));
            }
            seen[id] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err(Error::Topology("topology file missing nodes".into()));
        }
        Ok(FattPlugin::with_topology(Arc::new(torus)))
    }

    /// Emit the topology file for this platform. The file format stores
    /// torus coordinates, so this returns
    /// [`Error::UnsupportedTopology`] for fat-tree/dragonfly platforms
    /// (their parameters travel on the CLI instead).
    pub fn to_topology_file(&self) -> Result<String> {
        let torus = self.topo.as_torus().ok_or_else(|| {
            Error::UnsupportedTopology(format!(
                "the topology file format is torus-only ({} platform)",
                self.topo.kind()
            ))
        })?;
        let d = torus.dims();
        let mut out = format!("dims {} {} {}\n", d.x, d.y, d.z);
        for id in 0..torus.num_nodes() {
            let (x, y, z) = torus.coords(id);
            out.push_str(&format!("{id} {x} {y} {z}\n"));
        }
        Ok(out)
    }

    /// The routing function `R(u, v)`.
    pub fn route(&self, u: usize, v: usize) -> Vec<crate::topology::Link> {
        self.topo.route(u, v)
    }

    /// Intermediate transit vertices for `u -> v` (the registry entry the
    /// paper maintains: vertex -> paths it serves as intermediate hop).
    pub fn intermediates(&self, u: usize, v: usize) -> Vec<usize> {
        self.topo.intermediates(u, v)
    }

    /// The full transit registry of Section 4, inverted: for every compute
    /// node, the pairs whose fixed route it serves. Backed by the shared
    /// [`TopoIndex`] (built once per plugin, reused by every clone); the
    /// incremental Eq. 1 / window engines consume the same structure.
    /// Switch/router vertices are not listed — they never fail, so no
    /// consumer ever asks for their paths.
    pub fn transit_index(&self) -> &TopoIndex {
        self.index.get_or_init(|| TopoIndex::build(self.topo.as_ref()))
    }

    /// The pairs `(u, v)` whose route `R(u, v)` transits (or terminates
    /// at) compute node `node` — the paper's per-node registry export,
    /// offered to external schedulers/tooling. The in-tree FANS path does
    /// not call this: it consumes the same `TopoIndex` directly through
    /// the incremental window/Eq. 1 engines. Allocates the answer; callers
    /// iterating many nodes should use
    /// [`TopoIndex::pairs_through`] on [`Self::transit_index`] instead.
    pub fn paths_through(&self, node: usize) -> Vec<(usize, usize)> {
        self.transit_index().pairs_through(node).collect()
    }

    /// Hop distance under the platform's metric (torus rings, fat-tree
    /// LCA levels, dragonfly local/global tiers).
    pub fn hops(&self, u: usize, v: usize) -> usize {
        self.topo.hops(u, v)
    }

    /// Failure-domain (rack) count: torus X-lines, fat-tree pods,
    /// dragonfly groups — each topology defines its own decomposition.
    pub fn num_racks(&self) -> usize {
        self.topo.num_racks()
    }

    /// The rack a node belongs to.
    pub fn rack_of(&self, node: usize) -> usize {
        self.topo.rack_of(node)
    }

    /// Aggregate a generalized per-node outage vector (any fault model's
    /// [`crate::sim::fault::FaultModel::true_outage`], uniform or not)
    /// into per-rack means — the topology-level view a correlated-outage
    /// scheduler reasons about.
    pub fn rack_outage(&self, outage: &[f64]) -> Vec<f64> {
        debug_assert_eq!(outage.len(), self.topo.num_nodes());
        (0..self.num_racks())
            .map(|r| {
                let members = self.topo.rack_members(r);
                // detlint: allow(float-discipline, racks are non-empty by Topology construction)
                members.iter().map(|&n| outage[n]).sum::<f64>() / members.len() as f64
            })
            .collect()
    }

    /// Underlying topology.
    pub fn topology(&self) -> &dyn Topology {
        self.topo.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_roundtrip() {
        let f = FattPlugin::new(TorusDims::new(4, 2, 2));
        let text = f.to_topology_file().unwrap();
        let back = FattPlugin::from_topology_file(text.as_bytes()).unwrap();
        assert_eq!(
            back.topology().as_torus().unwrap().dims(),
            TorusDims::new(4, 2, 2)
        );
    }

    #[test]
    fn rejects_missing_and_duplicate_nodes() {
        let mut text = String::from("dims 2 1 1\n0 0 0 0\n");
        assert!(FattPlugin::from_topology_file(text.as_bytes()).is_err()); // missing 1
        text.push_str("0 0 0 0\n");
        assert!(FattPlugin::from_topology_file(text.as_bytes()).is_err()); // dup
    }

    #[test]
    fn rejects_inconsistent_coords() {
        let text = "dims 2 2 1\n0 0 0 0\n1 0 1 0\n2 1 0 0\n3 1 1 0\n";
        // id 1 should be (1,0,0) row-major; (0,1,0) is id 2.
        assert!(FattPlugin::from_topology_file(text.as_bytes()).is_err());
    }

    #[test]
    fn routing_exported() {
        let f = FattPlugin::new(TorusDims::new(8, 8, 8));
        let r = f.route(0, 2);
        assert_eq!(r.len(), 2);
        assert_eq!(f.intermediates(0, 2), vec![1]);
        assert_eq!(f.hops(0, 2), 2);
    }

    #[test]
    fn non_torus_platforms_export_switch_transits() {
        use crate::topology::FatTree;
        let ft = FatTree::new(4).unwrap();
        let n = Topology::num_nodes(&ft);
        let f = FattPlugin::with_topology(Arc::new(ft));
        // topology file is a torus-only artifact
        assert!(f.to_topology_file().is_err());
        // cross-pod route transits switches only
        let inter = f.intermediates(0, 4);
        assert_eq!(inter.len(), 5);
        assert!(inter.iter().all(|&x| x >= n));
        assert_eq!(f.hops(0, 4), 6);
        // racks are pods
        assert_eq!(f.num_racks(), 4);
        assert_eq!(f.rack_of(5), 1);
    }

    #[test]
    fn topology_file_export_is_typed_per_family() {
        use crate::topology::{Dragonfly, DragonflyParams, FatTree};
        // torus: the paper's artifact, exports fine
        let torus = FattPlugin::new(TorusDims::new(2, 2, 1));
        assert!(torus.to_topology_file().is_ok());
        // fat-tree and dragonfly: a typed UnsupportedTopology, not a panic
        let others: Vec<FattPlugin> = vec![
            FattPlugin::with_topology(Arc::new(FatTree::new(4).unwrap())),
            FattPlugin::with_topology(Arc::new(
                Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap(),
            )),
        ];
        for plugin in &others {
            let err = plugin.to_topology_file().unwrap_err();
            assert!(
                matches!(err, Error::UnsupportedTopology(_)),
                "{}: {err:?}",
                plugin.topology().kind()
            );
            assert!(err.to_string().contains("unsupported topology"), "{err}");
        }
    }

    #[test]
    fn transit_registry_agrees_with_intermediates() {
        let f = FattPlugin::new(TorusDims::new(4, 2, 1));
        // node 1 serves exactly the pairs whose route crosses it (plus its
        // own pairs: endpoints are link endpoints too)
        for (u, v) in f.paths_through(1) {
            let touches = u == 1
                || v == 1
                || f.route(u, v).iter().any(|l| l.src == 1 || l.dst == 1);
            assert!(touches, "({u},{v}) listed but does not touch node 1");
        }
        // inverse direction: every pair with node 1 as intermediate is in
        // the registry
        for u in 0..8 {
            for v in (u + 1)..8 {
                if f.intermediates(u, v).contains(&1) {
                    assert!(
                        f.paths_through(1).contains(&(u, v)),
                        "({u},{v}) transits 1 but is not registered"
                    );
                }
            }
        }
        // clones share the one registry
        let clone = f.clone();
        assert!(std::ptr::eq(f.transit_index(), clone.transit_index()));
    }

    #[test]
    fn on_platform_shares_the_platform_index() {
        // the controller wiring must not duplicate the route sweep: the
        // plugin's registry IS the platform's TopoIndex
        let plat = Platform::paper_default(TorusDims::new(4, 2, 2));
        let f = FattPlugin::on_platform(&plat);
        assert!(std::ptr::eq(f.transit_index(), plat.topo_index()));
    }

    #[test]
    fn rack_outage_aggregates_non_uniform_vectors() {
        let f = FattPlugin::new(TorusDims::new(4, 2, 1));
        assert_eq!(f.num_racks(), 2);
        assert_eq!(f.rack_of(3), 0);
        assert_eq!(f.rack_of(4), 1);
        let mut outage = vec![0.0; 8];
        outage[0] = 0.4;
        outage[1] = 0.2;
        outage[5] = 0.1;
        let racks = f.rack_outage(&outage);
        assert!((racks[0] - 0.15).abs() < 1e-12);
        assert!((racks[1] - 0.025).abs() < 1e-12);
    }
}
