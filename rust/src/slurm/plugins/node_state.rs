//! NodeState SPANK plugin (node side).
//!
//! Runs once at slurmd init; its job is to answer the controller's
//! heartbeats. In the simulated cluster it also *emulates* the node's
//! ground-truth failure behaviour: a flaky node misses a probe with its
//! outage probability.

use crate::rng::Rng;

/// Node-side heartbeat behaviour.
#[derive(Debug)]
pub struct NodeStatePlugin {
    outage_p: f64,
    rng: Rng,
}

impl NodeStatePlugin {
    /// A node that always replies.
    pub fn healthy() -> Self {
        NodeStatePlugin {
            outage_p: 0.0,
            rng: Rng::new(0),
        }
    }

    /// A node that misses probes with probability `p` (deterministic given
    /// `seed`).
    pub fn flaky(p: f64, seed: u64) -> Self {
        NodeStatePlugin {
            outage_p: p,
            rng: Rng::new(seed),
        }
    }

    /// Whether this probe gets a reply.
    pub fn responds(&mut self) -> bool {
        self.outage_p <= 0.0 || !self.rng.bernoulli(self.outage_p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_always_responds() {
        let mut n = NodeStatePlugin::healthy();
        assert!((0..1000).all(|_| n.responds()));
    }

    #[test]
    fn flaky_misses_at_rate() {
        let mut n = NodeStatePlugin::flaky(0.3, 42);
        let misses = (0..10_000).filter(|_| !n.responds()).count();
        let rate = misses as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.03, "rate={rate}");
    }
}
