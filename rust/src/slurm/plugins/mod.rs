//! The five plugins of the paper's Slurm integration (Fig. 2).
//!
//! | Paper plugin | Module | Runs on |
//! |---|---|---|
//! | Fault-Aware Slurmctld (heartbeats)   | [`fault_ctld`]  | controller |
//! | NodeState (SPANK)                    | [`node_state`]  | every node |
//! | LoadMatrix (SPANK)                   | [`load_matrix`] | every node |
//! | Fault-Aware Torus Topology (FATT)    | [`fatt`]        | controller |
//! | Fault-Aware Node Selection (FANS)    | [`fans`]        | controller |

pub mod fans;
pub mod fatt;
pub mod fault_ctld;
pub mod load_matrix;
pub mod node_state;
