//! Fault-Aware Node Selection (FANS) plugin.
//!
//! The resource-selection plugin that "performs the actual allocation of
//! resources": it combines the communication graph (LoadMatrix), the
//! routing/topology information (FATT) and the outage estimates
//! (Fault-Aware Slurmctld), and invokes the graph mapper — TOFA's
//! Listing 1.1 — to produce the process -> node table `T`.
//!
//! When the job does not request `--distribution=tofa`, FANS falls through
//! to the standard policies so TOFA "does not interfere with the standard
//! resource allocation path of Slurm".

use crate::commgraph::CommMatrix;
use crate::error::Result;
use crate::mapping::{self, Placement, PlacementPolicy};
use crate::rng::Rng;
use crate::tofa::placer::{TofaPlacer, TofaPlacement};
use crate::topology::Platform;

/// The FANS plugin.
#[derive(Debug, Clone, Default)]
pub struct FansPlugin {
    placer: TofaPlacer,
}

impl FansPlugin {
    /// Build with a custom TOFA placer.
    pub fn new(placer: TofaPlacer) -> Self {
        FansPlugin { placer }
    }

    /// Allocate nodes for a job.
    ///
    /// * `policy` — the srun `--distribution` value.
    /// * `comm` — communication graph (required for greedy/scotch/tofa).
    /// * `outage` — per-node outage estimates from the heartbeat plugin.
    pub fn select(
        &self,
        policy: PlacementPolicy,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
        rng: &mut Rng,
    ) -> Result<Placement> {
        match policy {
            PlacementPolicy::Tofa => self.placer.placement(comm, platform, outage),
            _ => {
                // borrow the platform's shared clean hop matrix instead of
                // rebuilding an O(n^2) matrix per selection (bit-identical
                // values; see TopoIndex)
                let dist = platform.topo_index().clean_hops();
                mapping::place(policy, comm, dist, rng)
            }
        }
    }

    /// Full TOFA selection with path reporting. The `outage` vector is
    /// the generalized per-node probabilities of **any**
    /// [`crate::sim::fault::FaultModel`] (correlated, Weibull, trace),
    /// not just the paper's uniform `p_f`.
    pub fn select_tofa(
        &self,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
    ) -> Result<TofaPlacement> {
        self.placer.place(comm, platform, outage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lammps_proxy::LammpsProxy, MpiApp};
    use crate::profiler::profile_app;
    use crate::topology::TorusDims;

    #[test]
    fn all_policies_yield_valid_placements() {
        let app = LammpsProxy::tiny(16, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let outage = vec![0.0; 64];
        let fans = FansPlugin::default();
        let mut rng = Rng::new(5);
        for policy in PlacementPolicy::all() {
            let p = fans
                .select(policy, &comm, &plat, &outage, &mut rng)
                .unwrap();
            p.validate(64).unwrap();
            assert_eq!(p.num_ranks(), 16, "{policy}");
        }
    }

    #[test]
    fn tofa_avoids_estimated_flaky_nodes() {
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut outage = vec![0.0; 64];
        outage[0] = 0.5; // first node flaky: block would use it, TOFA won't
        let fans = FansPlugin::default();
        let p = fans.select_tofa(&comm, &plat, &outage).unwrap();
        assert!(!p.assignment.contains(&0));
    }

    #[test]
    fn selection_avoids_correlated_domain_outage_vector() {
        use crate::sim::fault::{CorrelatedDomains, FaultModel};
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        // whole first rack (nodes 0..4) flaky as a unit: FANS consumes
        // the model's generalized (non-uniform) per-node outage vector
        let model = CorrelatedDomains::racks(&plat, &[0], 0.4);
        let fans = FansPlugin::default();
        let mut rng = Rng::new(8);
        let p = fans
            .select(PlacementPolicy::Tofa, &comm, &plat, &model.true_outage(), &mut rng)
            .unwrap();
        for n in plat.rack_members(0) {
            assert!(!p.assignment.contains(&n), "used flaky-rack node {n}");
        }
    }
}
