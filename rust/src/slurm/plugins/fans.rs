//! Fault-Aware Node Selection (FANS) plugin.
//!
//! The resource-selection plugin that "performs the actual allocation of
//! resources": it combines the communication graph (LoadMatrix), the
//! routing/topology information (FATT) and the outage estimates
//! (Fault-Aware Slurmctld), and invokes the graph mapper — TOFA's
//! Listing 1.1 — to produce the process -> node table `T`.
//!
//! When the job does not request `--distribution=tofa`, FANS falls through
//! to the standard policies so TOFA "does not interfere with the standard
//! resource allocation path of Slurm".
//!
//! On a shared cluster the controller's
//! [`crate::slurm::sched::NodeLedger`] owns which nodes are actually
//! available; [`FansPlugin::select`] takes that candidate set as an
//! optional mask. With `None` (a dedicated cluster — the batch engine's
//! mode) selection is over the full platform, bit-identical to the
//! pre-scheduler code.

use crate::commgraph::{CommMatrix, SparseComm};
use crate::error::Result;
use crate::mapping::multilevel::MultilevelMapper;
use crate::mapping::{self, Placement, PlacementPolicy};
use crate::rng::Rng;
use crate::tofa::placer::{TofaPlacement, TofaPlacer};
use crate::topology::metric::check_materialize;
use crate::topology::Platform;

/// The FANS plugin.
#[derive(Debug, Clone, Default)]
pub struct FansPlugin {
    placer: TofaPlacer,
}

impl FansPlugin {
    /// Build with a custom TOFA placer.
    pub fn new(placer: TofaPlacer) -> Self {
        FansPlugin { placer }
    }

    /// Allocate nodes for a job.
    ///
    /// * `policy` — the srun `--distribution` value.
    /// * `comm` — communication graph (required for greedy/scotch/tofa).
    /// * `outage` — per-node outage estimates from the heartbeat plugin.
    /// * `candidates` — the ledger's free nodes (ascending), or `None`
    ///   for the whole platform. Every policy then selects only from the
    ///   candidates: the clean hop matrix (dense
    ///   [`crate::topology::TopoIndex`] or the implicit metric's
    ///   closed forms, per [`Platform::hop_oracle`]) is extracted to the
    ///   candidate set for the standard policies, and the TOFA
    ///   window/Eq. 1 paths run mask-aware.
    ///
    /// [`PlacementPolicy::Multilevel`] never extracts a candidate-sized
    /// distance matrix: it converts `comm` to a [`SparseComm`] and runs
    /// the coarsen–map–refine mapper directly against the hop oracle, so
    /// it stays usable on implicit 100k-node platforms where the other
    /// standard policies would refuse to materialize distances.
    pub fn select(
        &self,
        policy: PlacementPolicy,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
        candidates: Option<&[usize]>,
        rng: &mut Rng,
    ) -> Result<Placement> {
        // an all-free ledger is the dedicated-cluster case: drop the mask
        // so the standard policies borrow the shared clean hop matrix
        // instead of cloning an O(n^2) extract per selection (results are
        // bit-identical — the masked paths reduce to the unmasked ones
        // when every node is a candidate)
        let candidates = candidates.filter(|free| free.len() < platform.num_nodes());
        let oracle = platform.hop_oracle();
        match candidates {
            None => match policy {
                PlacementPolicy::Tofa => self.placer.placement(comm, platform, outage),
                PlacementPolicy::Multilevel => {
                    let g = SparseComm::from_matrix(comm);
                    let hosts: Vec<usize> = (0..platform.num_nodes()).collect();
                    MultilevelMapper::default().map_sparse(&g, &oracle, &hosts)
                }
                _ => match oracle.index() {
                    // borrow the platform's shared clean hop matrix instead
                    // of rebuilding an O(n^2) matrix per selection
                    // (bit-identical values; see TopoIndex)
                    Some(index) => mapping::place(policy, comm, index.clean_hops(), rng),
                    None => {
                        // the standard policies need the whole matrix; an
                        // implicit platform refuses a cluster-scale one
                        check_materialize(platform.num_nodes())?;
                        let all: Vec<usize> = (0..platform.num_nodes()).collect();
                        let dist = oracle.extract(&all);
                        mapping::place(policy, comm, &dist, rng)
                    }
                },
            },
            Some(free) => {
                if policy == PlacementPolicy::Tofa {
                    let mut mask = vec![false; platform.num_nodes()];
                    for &n in free {
                        mask[n] = true;
                    }
                    return self.placer.placement_within(comm, platform, outage, &mask);
                }
                if policy == PlacementPolicy::Multilevel {
                    // sparse path: candidate host list goes straight to the
                    // mapper, no per-selection distance extract at all
                    let g = SparseComm::from_matrix(comm);
                    return MultilevelMapper::default().map_sparse(&g, &oracle, free);
                }
                // standard policies run on the clean hop matrix restricted
                // to the candidates, then relabel back to platform ids —
                // block placement over the extract is exactly Slurm's
                // "sequential over available nodes"
                if !oracle.is_dense() {
                    check_materialize(free.len())?;
                }
                let sub = oracle.extract(free);
                let local = mapping::place(policy, comm, &sub, rng)?;
                Ok(Placement::new(
                    local.assignment.iter().map(|&li| free[li]).collect(),
                ))
            }
        }
    }

    /// Full TOFA selection with path reporting. The `outage` vector is
    /// the generalized per-node probabilities of **any**
    /// [`crate::sim::fault::FaultModel`] (correlated, Weibull, trace),
    /// not just the paper's uniform `p_f`.
    pub fn select_tofa(
        &self,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
    ) -> Result<TofaPlacement> {
        self.placer.place(comm, platform, outage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lammps_proxy::LammpsProxy, MpiApp};
    use crate::profiler::profile_app;
    use crate::topology::TorusDims;

    #[test]
    fn all_policies_yield_valid_placements() {
        let app = LammpsProxy::tiny(16, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let outage = vec![0.0; 64];
        let fans = FansPlugin::default();
        let mut rng = Rng::new(5);
        for policy in PlacementPolicy::all() {
            let p = fans
                .select(policy, &comm, &plat, &outage, None, &mut rng)
                .unwrap();
            p.validate(64).unwrap();
            assert_eq!(p.num_ranks(), 16, "{policy}");
        }
    }

    #[test]
    fn tofa_avoids_estimated_flaky_nodes() {
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut outage = vec![0.0; 64];
        outage[0] = 0.5; // first node flaky: block would use it, TOFA won't
        let fans = FansPlugin::default();
        let p = fans.select_tofa(&comm, &plat, &outage).unwrap();
        assert!(!p.assignment.contains(&0));
    }

    #[test]
    fn selection_avoids_correlated_domain_outage_vector() {
        use crate::sim::fault::{CorrelatedDomains, FaultModel};
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        // whole first rack (nodes 0..4) flaky as a unit: FANS consumes
        // the model's generalized (non-uniform) per-node outage vector
        let model = CorrelatedDomains::racks(&plat, &[0], 0.4);
        let fans = FansPlugin::default();
        let mut rng = Rng::new(8);
        let p = fans
            .select(
                PlacementPolicy::Tofa,
                &comm,
                &plat,
                &model.true_outage(),
                None,
                &mut rng,
            )
            .unwrap();
        for n in plat.rack_members(0) {
            assert!(!p.assignment.contains(&n), "used flaky-rack node {n}");
        }
    }

    #[test]
    fn every_policy_respects_the_candidate_mask() {
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let outage = vec![0.0; 64];
        // only every other node free — a heavily fragmented ledger
        let free: Vec<usize> = (0..64).step_by(2).collect();
        let fans = FansPlugin::default();
        let mut rng = Rng::new(21);
        for policy in PlacementPolicy::all() {
            let p = fans
                .select(policy, &comm, &plat, &outage, Some(&free), &mut rng)
                .unwrap();
            p.validate(64).unwrap();
            for &n in &p.assignment {
                assert!(free.contains(&n), "{policy} used busy node {n}");
            }
        }
    }

    #[test]
    fn full_candidate_set_matches_unmasked_selection() {
        // the all-free fast path must be bit-identical to passing None
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let mut outage = vec![0.0; 64];
        outage[5] = 0.3;
        let all: Vec<usize> = (0..64).collect();
        let fans = FansPlugin::default();
        for policy in PlacementPolicy::all() {
            let mut rng_a = Rng::new(31);
            let mut rng_b = Rng::new(31);
            let unmasked = fans
                .select(policy, &comm, &plat, &outage, None, &mut rng_a)
                .unwrap();
            let masked = fans
                .select(policy, &comm, &plat, &outage, Some(&all), &mut rng_b)
                .unwrap();
            assert_eq!(masked, unmasked, "{policy}");
        }
    }

    #[test]
    fn implicit_platform_selects_identically_to_dense() {
        use crate::topology::MetricMode;
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let implicit = plat.clone().with_metric(MetricMode::Implicit);
        let mut outage = vec![0.0; 64];
        outage[5] = 0.3;
        let free: Vec<usize> = (0..64).step_by(2).collect();
        let fans = FansPlugin::default();
        for policy in PlacementPolicy::all() {
            for mask in [None, Some(free.as_slice())] {
                let mut rng_a = Rng::new(47);
                let mut rng_b = Rng::new(47);
                let a = fans
                    .select(policy, &comm, &plat, &outage, mask, &mut rng_a)
                    .unwrap();
                let b = fans
                    .select(policy, &comm, &implicit, &outage, mask, &mut rng_b)
                    .unwrap();
                assert_eq!(a, b, "{policy} masked={}", mask.is_some());
            }
        }
    }

    #[test]
    fn block_over_candidates_is_sequential_over_free_nodes() {
        let app = LammpsProxy::tiny(4, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let free = vec![3usize, 5, 9, 10, 40, 41];
        let fans = FansPlugin::default();
        let mut rng = Rng::new(1);
        let p = fans
            .select(
                PlacementPolicy::DefaultSlurm,
                &comm,
                &plat,
                &vec![0.0; 64],
                Some(&free),
                &mut rng,
            )
            .unwrap();
        assert_eq!(p.assignment, vec![3, 5, 9, 10]);
    }

    #[test]
    fn multilevel_selects_identically_dense_and_implicit_with_mask() {
        use crate::topology::MetricMode;
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let implicit = plat.clone().with_metric(MetricMode::Implicit);
        let outage = vec![0.0; 64];
        let free: Vec<usize> = (0..64).step_by(2).collect();
        let fans = FansPlugin::default();
        let ml = PlacementPolicy::Multilevel;
        for mask in [None, Some(free.as_slice())] {
            let mut rng_a = Rng::new(3);
            let mut rng_b = Rng::new(3);
            let a = fans.select(ml, &comm, &plat, &outage, mask, &mut rng_a);
            let b = fans.select(ml, &comm, &implicit, &outage, mask, &mut rng_b);
            let (a, b) = (a.unwrap(), b.unwrap());
            assert_eq!(a, b, "masked={}", mask.is_some());
            a.validate(64).unwrap();
            assert_eq!(a.num_ranks(), 8);
            if let Some(f) = mask {
                for &n in &a.assignment {
                    assert!(f.contains(&n), "used busy node {n}");
                }
            }
        }
    }

    #[test]
    fn multilevel_fails_cleanly_when_candidates_are_too_few() {
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let outage = vec![0.0; 64];
        let free = vec![0usize, 1, 2];
        let fans = FansPlugin::default();
        let ml = PlacementPolicy::Multilevel;
        let mut rng = Rng::new(2);
        let r = fans.select(ml, &comm, &plat, &outage, Some(&free), &mut rng);
        assert!(r.is_err(), "multilevel placed 8 ranks on 3 free nodes");
    }

    #[test]
    fn selection_fails_cleanly_when_candidates_are_too_few() {
        let app = LammpsProxy::tiny(8, 2);
        let comm = profile_app(&app).volume;
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let free = vec![0usize, 1, 2];
        let fans = FansPlugin::default();
        let mut rng = Rng::new(2);
        for policy in PlacementPolicy::all() {
            let r = fans.select(policy, &comm, &plat, &vec![0.0; 64], Some(&free), &mut rng);
            assert!(r.is_err(), "{policy} placed 8 ranks on 3 free nodes");
        }
    }
}
