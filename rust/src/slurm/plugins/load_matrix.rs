//! LoadMatrix SPANK plugin.
//!
//! "Used to send the communication graph G from any compute node to the
//! controller node ... enables srun to have an extra argument which can be
//! used to provide the file containing a representation of G."
//!
//! Two paths are supported, matching how the real plugin can be fed:
//! reading the graph from an srun-provided file, and fetching it from a
//! node daemon over the protocol channel.

use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Duration;

use crate::commgraph::{io, CommMatrix};
use crate::error::{Error, Result};
use crate::slurm::noded::NodeHandle;
use crate::slurm::protocol::ToNode;

/// Load a communication graph from the file named on the srun command
/// line (`--load-matrix=<path>`).
pub fn from_file(path: &Path) -> Result<CommMatrix> {
    io::load(path)
}

/// Fetch the staged communication graph from a compute node's daemon.
pub fn from_node(node: &NodeHandle) -> Result<CommMatrix> {
    let (tx, rx) = channel();
    node.tx
        .send(ToNode::FetchLoadMatrix { reply: tx })
        .map_err(|_| Error::Slurm(format!("node {} daemon gone", node.id)))?;
    rx.recv_timeout(Duration::from_secs(1))
        .map_err(|_| Error::Slurm(format!("node {} dropped reply", node.id)))?
        .ok_or_else(|| Error::Slurm(format!("node {} has no staged comm graph", node.id)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::noded;
    use crate::slurm::plugins::node_state::NodeStatePlugin;

    #[test]
    fn fetch_roundtrip() {
        let mut m = CommMatrix::new(3);
        m.add_sym(0, 2, 9.0);
        let h = noded::spawn(5, NodeStatePlugin::healthy(), Some(m.clone()));
        let got = from_node(&h).unwrap();
        assert_eq!(got, m);
        h.shutdown();
    }

    #[test]
    fn missing_matrix_errors() {
        let h = noded::spawn(6, NodeStatePlugin::healthy(), None);
        assert!(from_node(&h).is_err());
        h.shutdown();
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("tofa-lm-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        let mut m = CommMatrix::new(2);
        m.add_sym(0, 1, 3.0);
        io::save(&m, &p).unwrap();
        assert_eq!(from_file(&p).unwrap(), m);
    }
}
