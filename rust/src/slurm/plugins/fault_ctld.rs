//! Fault-Aware Slurmctld plugin: heartbeat collection + outage inference.
//!
//! "Responsible for periodic polling of each node through a heartbeat ...
//! Absence of a reply to a heartbeat is translated as node outage.
//! Slurmctld maintains a record of heartbeats for each node i, HB(i)."

use std::sync::mpsc::channel;
use std::time::Duration;

use crate::slurm::heartbeat::{HeartbeatHistory, OutagePolicy};
use crate::slurm::noded::NodeHandle;
use crate::slurm::protocol::ToNode;

/// Controller-side heartbeat state.
#[derive(Debug)]
pub struct FaultCtldPlugin {
    histories: Vec<HeartbeatHistory>,
    policy: OutagePolicy,
    seq: u64,
    /// How long to wait for a reply before declaring a miss.
    pub timeout: Duration,
}

impl FaultCtldPlugin {
    /// New collector for `n` nodes.
    pub fn new(n: usize, policy: OutagePolicy) -> Self {
        FaultCtldPlugin {
            histories: vec![HeartbeatHistory::default(); n],
            policy,
            seq: 0,
            timeout: Duration::from_millis(200),
        }
    }

    /// Probe every node once (fan out, then collect) and record outcomes.
    pub fn poll_all(&mut self, nodes: &[NodeHandle]) {
        self.seq += 1;
        let seq = self.seq;
        let mut pending = Vec::with_capacity(nodes.len());
        for h in nodes {
            let (tx, rx) = channel();
            // a dead daemon is a miss
            let sent = h.tx.send(ToNode::Heartbeat { seq, reply: tx }).is_ok();
            pending.push((h.id, sent, rx));
        }
        for (id, sent, rx) in pending {
            let replied = sent
                && matches!(rx.recv_timeout(self.timeout), Ok(r) if r.seq == seq);
            self.histories[id].record(replied);
        }
    }

    /// Run `rounds` heartbeat cycles.
    pub fn collect(&mut self, nodes: &[NodeHandle], rounds: usize) {
        for _ in 0..rounds {
            self.poll_all(nodes);
        }
    }

    /// Current outage-probability estimates, one per node.
    pub fn outage_estimates(&self) -> Vec<f64> {
        self.histories
            .iter()
            .map(|h| self.policy.estimate(h))
            .collect()
    }

    /// Heartbeat record for one node (`HB(i)`).
    pub fn history(&self, node: usize) -> &HeartbeatHistory {
        &self.histories[node]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::noded::spawn;
    use crate::slurm::plugins::node_state::NodeStatePlugin;

    #[test]
    fn estimates_track_ground_truth() {
        // 4 healthy nodes, 2 flaky at 50% (high p so few rounds suffice)
        let mut nodes = Vec::new();
        for i in 0..4 {
            nodes.push(spawn(i, NodeStatePlugin::healthy(), None));
        }
        nodes.push(spawn(4, NodeStatePlugin::flaky(0.5, 1), None));
        nodes.push(spawn(5, NodeStatePlugin::flaky(0.5, 2), None));

        let mut ctld = FaultCtldPlugin::new(6, OutagePolicy::Empirical);
        ctld.collect(&nodes, 60);
        let est = ctld.outage_estimates();
        for e in &est[..4] {
            assert_eq!(*e, 0.0);
        }
        for e in &est[4..] {
            assert!((*e - 0.5).abs() < 0.25, "estimate {e}");
        }
        assert_eq!(ctld.history(0).len(), 60);
    }

    #[test]
    fn dead_daemon_counts_as_miss() {
        let h = spawn(0, NodeStatePlugin::healthy(), None);
        h.tx.send(ToNode::Shutdown).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let mut ctld = FaultCtldPlugin::new(1, OutagePolicy::Empirical);
        ctld.poll_all(std::slice::from_ref(&h));
        assert_eq!(ctld.outage_estimates()[0], 1.0);
    }
}
