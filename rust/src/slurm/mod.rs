//! Slurm-lite: the resource-manager substrate TOFA integrates into.
//!
//! Mirrors the paper's Section 4 architecture (Fig. 2):
//!
//! * [`controller`] — `slurmctld`: resource allocation, job launch, and
//!   the controller-side plugins.
//! * [`noded`] — `slurmd`: the per-node daemon (one tokio task per node)
//!   hosting the node-side SPANK plugins.
//! * [`plugins`] — the five paper plugins: *Fault-Aware Slurmctld*
//!   (heartbeats), *NodeState* (heartbeat replies), *LoadMatrix* (ships the
//!   communication graph), *FATT* (torus topology + routing function), and
//!   *FANS* (fault-aware node selection = TOFA).
//! * [`srun`] — the user front-end (`--distribution=tofa --load-matrix=G`).
//! * [`protocol`] / [`jobs`] / [`queue`] — messages, job records, FIFO.

pub mod controller;
pub mod heartbeat;
pub mod jobs;
pub mod noded;
pub mod plugins;
pub mod protocol;
pub mod queue;
pub mod srun;

use crate::sim::failure::FaultScenario;

/// Ground-truth fault model used to *emulate* node behaviour (the node
/// side of the heartbeat protocol and the per-instance down sampling).
/// The controller never reads this directly — it only sees heartbeat
/// outcomes, from which it estimates outage probabilities.
#[derive(Debug, Clone)]
pub struct FaultModel {
    /// The batch-level fault scenario.
    pub scenario: FaultScenario,
}

impl FaultModel {
    /// Fault-free model.
    pub fn none(num_nodes: usize) -> Self {
        FaultModel {
            scenario: FaultScenario::none(num_nodes),
        }
    }

    /// Wrap a scenario.
    pub fn new(scenario: FaultScenario) -> Self {
        FaultModel { scenario }
    }

    /// The *true* outage probabilities (oracle; tests and upper-bound
    /// experiments only — production code estimates via heartbeats).
    pub fn outage_estimates(&self) -> Vec<f64> {
        self.scenario.true_outage()
    }
}
