//! Slurm-lite: the resource-manager substrate TOFA integrates into.
//!
//! Mirrors the paper's Section 4 architecture (Fig. 2):
//!
//! * [`controller`] — `slurmctld`: resource allocation, job launch, and
//!   the controller-side plugins.
//! * [`noded`] — `slurmd`: the per-node daemon (one tokio task per node)
//!   hosting the node-side SPANK plugins.
//! * [`plugins`] — the five paper plugins: *Fault-Aware Slurmctld*
//!   (heartbeats), *NodeState* (heartbeat replies), *LoadMatrix* (ships the
//!   communication graph), *FATT* (torus topology + routing function), and
//!   *FANS* (fault-aware node selection = TOFA).
//! * [`srun`] — the user front-end (`--distribution=tofa --load-matrix=G`).
//! * [`protocol`] / [`jobs`] / [`queue`] — messages, job records, FIFO.
//! * [`sched`] — the cluster-level discrete-event scheduler: concurrent
//!   jobs on the shared [`sched::NodeLedger`] occupancy state, FIFO +
//!   conservative backfill, abort -> resubmit, heartbeat health epochs.

//! Ground-truth fault behaviour (which nodes are down, when) lives in
//! [`crate::sim::fault`]: a [`crate::sim::fault::FaultScenario`] *emulates*
//! node behaviour — the node side of the heartbeat protocol and the
//! per-instance down sampling. The controller never reads it directly; it
//! only sees heartbeat outcomes ([`heartbeat`]), from which it estimates
//! the per-node outage vector the FANS plugin consumes.

pub mod controller;
pub mod heartbeat;
pub mod jobs;
pub mod noded;
pub mod plugins;
pub mod protocol;
pub mod queue;
pub mod sched;
pub mod srun;
