//! Job descriptions and records.

use crate::commgraph::CommMatrix;
use crate::mapping::PlacementPolicy;

/// A job submission (what srun hands to the controller).
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// Job name (application id).
    pub name: String,
    /// Number of MPI processes.
    pub ranks: usize,
    /// srun `--distribution` value.
    pub distribution: PlacementPolicy,
    /// Communication graph, if supplied via `--load-matrix`.
    pub comm_graph: Option<CommMatrix>,
}

/// Lifecycle state of a job in the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Aborted,
}

/// A job record tracked by the controller.
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Controller-assigned id.
    pub id: u64,
    /// The request.
    pub request: JobRequest,
    /// Current state.
    pub state: JobState,
    /// Node assignment once allocated (`T` in the paper).
    pub assignment: Option<Vec<usize>>,
    /// Simulated completion time, once finished.
    pub completion_s: Option<f64>,
    /// Abort count (restarts performed).
    pub aborts: u32,
}

impl JobRecord {
    /// New pending record.
    pub fn new(id: u64, request: JobRequest) -> Self {
        JobRecord {
            id,
            request,
            state: JobState::Pending,
            assignment: None,
            completion_s: None,
            aborts: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_lifecycle_defaults() {
        let r = JobRecord::new(
            1,
            JobRequest {
                name: "x".into(),
                ranks: 4,
                distribution: PlacementPolicy::Tofa,
                comm_graph: None,
            },
        );
        assert_eq!(r.state, JobState::Pending);
        assert!(r.assignment.is_none());
        assert_eq!(r.aborts, 0);
    }
}
