//! Job descriptions and records.

use crate::commgraph::CommMatrix;
use crate::mapping::PlacementPolicy;

/// A job submission (what srun hands to the controller).
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// Job name (application id).
    pub name: String,
    /// Number of MPI processes.
    pub ranks: usize,
    /// srun `--distribution` value.
    pub distribution: PlacementPolicy,
    /// Communication graph, if supplied via `--load-matrix`.
    pub comm_graph: Option<CommMatrix>,
}

/// Lifecycle state of a job in the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Aborted,
    /// Terminal failure: the job could never be placed (resource-selection
    /// error) or exhausted its restart budget. Unlike `Aborted` — which a
    /// scheduler resubmits — a `Failed` job leaves the system.
    Failed,
}

impl JobState {
    /// True for states a job can be parked in `finished` under. The queue
    /// asserts this, so a record can never be retired mid-lifecycle.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Completed | JobState::Aborted | JobState::Failed
        )
    }
}

/// A job record tracked by the controller.
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Controller-assigned id.
    pub id: u64,
    /// The request.
    pub request: JobRequest,
    /// Current state.
    pub state: JobState,
    /// Node assignment once allocated (`T` in the paper).
    pub assignment: Option<Vec<usize>>,
    /// Simulated completion time, once finished.
    pub completion_s: Option<f64>,
    /// Abort count (restarts performed).
    pub aborts: u32,
    /// Simulated submission (arrival) time.
    pub submit_s: f64,
    /// Simulated time of the job's **first** launch (queue wait ends).
    pub start_s: Option<f64>,
    /// Simulated time the job reached a terminal state.
    pub end_s: Option<f64>,
    /// Why the job failed, for `Failed` records.
    pub error: Option<String>,
    /// Durable fraction of the job's work completed (survives abort →
    /// resubmit under checkpoint/restart; stays 0 under abort-resubmit).
    pub progress: f64,
    /// Useful-work seconds credited across all attempts (work that
    /// counted toward completion, excluding rolled-back intervals and
    /// checkpoint write costs).
    pub useful_s: f64,
    /// Node-seconds held without useful progress (rollback intervals,
    /// checkpoint writes, shrink degradation overhead).
    pub lost_node_s: f64,
    /// Checkpoints this job committed.
    pub ckpts: u32,
    /// Shrink-replace recoveries this job performed.
    pub shrinks: u32,
    /// Per-job fault-stream draws consumed (the attempt index of the
    /// next `Rng::stream` draw; equals `aborts` under abort-resubmit).
    pub fault_draws: u32,
}

impl JobRecord {
    /// New pending record.
    pub fn new(id: u64, request: JobRequest) -> Self {
        JobRecord {
            id,
            request,
            state: JobState::Pending,
            assignment: None,
            completion_s: None,
            aborts: 0,
            submit_s: 0.0,
            start_s: None,
            end_s: None,
            error: None,
            progress: 0.0,
            useful_s: 0.0,
            lost_node_s: 0.0,
            ckpts: 0,
            shrinks: 0,
            fault_draws: 0,
        }
    }

    /// Queue wait: first launch minus arrival (`None` until launched).
    pub fn wait_s(&self) -> Option<f64> {
        self.start_s.map(|s| s - self.submit_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_lifecycle_defaults() {
        let r = JobRecord::new(
            1,
            JobRequest {
                name: "x".into(),
                ranks: 4,
                distribution: PlacementPolicy::Tofa,
                comm_graph: None,
            },
        );
        assert_eq!(r.state, JobState::Pending);
        assert!(r.assignment.is_none());
        assert_eq!(r.aborts, 0);
        assert_eq!(r.submit_s, 0.0);
        assert!(r.start_s.is_none() && r.end_s.is_none() && r.error.is_none());
        assert!(r.wait_s().is_none());
        assert_eq!(r.progress, 0.0);
        assert_eq!(r.useful_s, 0.0);
        assert_eq!(r.lost_node_s, 0.0);
        assert_eq!((r.ckpts, r.shrinks, r.fault_draws), (0, 0, 0));
    }

    #[test]
    fn terminal_states() {
        assert!(!JobState::Pending.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Completed.is_terminal());
        assert!(JobState::Aborted.is_terminal());
        assert!(JobState::Failed.is_terminal());
    }

    #[test]
    fn wait_is_start_minus_submit() {
        let mut r = JobRecord::new(
            0,
            JobRequest {
                name: "x".into(),
                ranks: 1,
                distribution: PlacementPolicy::DefaultSlurm,
                comm_graph: None,
            },
        );
        r.submit_s = 2.0;
        r.start_s = Some(5.5);
        assert!((r.wait_s().unwrap() - 3.5).abs() < 1e-12);
    }
}
