//! Dense node-to-node distance matrices.
//!
//! The mapper consumes an `M x M` matrix of path costs between host nodes.
//! For the fault-free case this is plain torus hop counts; [`crate::tofa`]
//! produces the Eq. 1 fault-inflated variant.

use super::torus::Torus;
use super::Topology;

/// Dense symmetric matrix of inter-node path costs (f32 to match the
/// PJRT artifact's dtype).
#[derive(Debug, Clone)]
pub struct DistanceMatrix {
    n: usize,
    data: Vec<f32>,
}

impl DistanceMatrix {
    /// Zero matrix of size `n x n`.
    pub fn zeros(n: usize) -> Self {
        DistanceMatrix {
            n,
            data: vec![0.0; n * n],
        }
    }

    /// Hop-count matrix of a torus.
    pub fn from_torus_hops(t: &Torus) -> Self {
        Self::from_topology(t)
    }

    /// Hop-count matrix over the compute nodes of any [`Topology`].
    pub fn from_topology(t: &dyn Topology) -> Self {
        let n = t.num_nodes();
        let mut m = DistanceMatrix::zeros(n);
        for u in 0..n {
            for v in (u + 1)..n {
                let h = t.hops(u, v) as f32;
                m.set(u, v, h);
                m.set(v, u, h);
            }
        }
        m
    }

    /// Matrix restricted to a subset of nodes (the `ScotchExtract` step of
    /// Listing 1.1). `subset[i]` is the original node id of new index `i`.
    pub fn extract(&self, subset: &[usize]) -> DistanceMatrix {
        let k = subset.len();
        let mut m = DistanceMatrix::zeros(k);
        for (i, &u) in subset.iter().enumerate() {
            for (j, &v) in subset.iter().enumerate() {
                m.set(i, j, self.get(u, v));
            }
        }
        m
    }

    /// Dimension.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Read entry.
    #[inline]
    pub fn get(&self, u: usize, v: usize) -> f32 {
        self.data[u * self.n + v]
    }

    /// Write entry.
    #[inline]
    pub fn set(&mut self, u: usize, v: usize, w: f32) {
        self.data[u * self.n + v] = w;
    }

    /// Raw row-major data.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Row `u`.
    #[inline]
    pub fn row(&self, u: usize) -> &[f32] {
        &self.data[u * self.n..(u + 1) * self.n]
    }

    /// Maximum entry (e.g. diameter for a hop matrix).
    pub fn max(&self) -> f32 {
        self.data.iter().cloned().fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::torus::TorusDims;

    #[test]
    fn torus_hop_matrix_diagonal_zero_symmetric() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let m = DistanceMatrix::from_torus_hops(&t);
        for u in 0..m.len() {
            assert_eq!(m.get(u, u), 0.0);
            for v in 0..m.len() {
                assert_eq!(m.get(u, v), m.get(v, u));
                assert_eq!(m.get(u, v), t.hops(u, v) as f32);
            }
        }
    }

    #[test]
    fn diameter_of_8x8x8() {
        let t = Torus::new(TorusDims::new(8, 8, 8));
        let m = DistanceMatrix::from_torus_hops(&t);
        assert_eq!(m.max(), 12.0);
    }

    #[test]
    fn extract_preserves_pairwise_costs() {
        let t = Torus::new(TorusDims::new(4, 4, 2));
        let m = DistanceMatrix::from_torus_hops(&t);
        let subset = vec![3, 7, 12, 30];
        let s = m.extract(&subset);
        assert_eq!(s.len(), 4);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(s.get(i, j), m.get(subset[i], subset[j]));
            }
        }
    }
}
