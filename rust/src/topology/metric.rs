//! Implicit hop metrics — on-demand distances without the O(n²) wall.
//!
//! Every placement-side consumer used to reach distances through dense
//! per-platform state: the [`DistanceMatrix`] (n² f32 entries) and the
//! [`TopoIndex`] (the same matrix plus a transit-incidence CSR built by a
//! full O(n²) route sweep). That caps platforms at a few thousand nodes —
//! a 100k-node fabric would need ~40 GB for the hop matrix alone — even
//! though all three in-tree topology families answer `hops(u, v)` and
//! "does `R(u, v)` touch node `w`?" in closed form
//! ([`Topology::hops`], [`Topology::route_touches`]).
//!
//! This module makes the metric *implicit*:
//!
//! * [`MetricMode`] selects per platform how distances are served:
//!   `Dense` (the [`TopoIndex`] reference path), `Implicit` (closed
//!   forms, no O(n²) state ever built), or `Auto` (dense up to
//!   [`DENSE_NODE_LIMIT`] nodes, implicit beyond — the PR-4 pattern of
//!   keeping the dense path as the bit-identity reference under a size
//!   threshold).
//! * [`HopOracle`] is the uniform façade the Eq. 1 engine, the window
//!   search, and [`FansPlugin::select`](crate::slurm::plugins::fans) see:
//!   `hops(u, v)` on demand, plus [`HopOracle::extract`] for the sparse
//!   per-job views — only the candidate-set submatrix (sized by the job,
//!   not the cluster) is ever materialized under the implicit mode.
//!
//! Both modes are **bit-identical** where both run: a clean entry is the
//! exact `|R(u, v)| as f32` either way (a sum of `1.0f32` per hop is
//! exact), asserted across all topology families and fault models in
//! `tests/proptests.rs`.
//!
//! ```
//! use tofa::topology::{MetricMode, Platform, TorusDims};
//!
//! let dense = Platform::paper_default(TorusDims::new(4, 4, 2));
//! let implicit = dense.clone().with_metric(MetricMode::Implicit);
//! assert!(dense.resolved_metric().is_dense());
//! assert!(!implicit.resolved_metric().is_dense());
//! // same hops, bit for bit — one from the TopoIndex, one on demand
//! let (a, b) = (dense.hop_oracle(), implicit.hop_oracle());
//! for u in 0..32 {
//!     for v in 0..32 {
//!         assert_eq!(a.hops(u, v).to_bits(), b.hops(u, v).to_bits());
//!     }
//! }
//! // the implicit platform refuses to build the dense index
//! assert!(implicit.try_topo_index().is_err());
//! ```

use super::distance::DistanceMatrix;
use super::index::TopoIndex;
use super::Topology;
use crate::error::{Error, Result};

/// Largest platform (in compute nodes) for which [`MetricMode::Auto`]
/// still builds the dense [`TopoIndex`]. At this size the hop matrix is
/// 64 MB — comfortably cached and the fastest option; beyond it the
/// implicit path wins on memory by construction (it allocates O(n)).
pub const DENSE_NODE_LIMIT: usize = 4096;

/// How a [`Platform`](super::Platform) serves hop distances.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricMode {
    /// Dense up to [`DENSE_NODE_LIMIT`] nodes, implicit beyond.
    #[default]
    Auto,
    /// Always build and use the dense [`TopoIndex`] (reference path).
    Dense,
    /// Never build O(n²) state; serve every query from closed forms.
    Implicit,
}

impl MetricMode {
    /// Parse the CLI form (`--metric=auto|dense|implicit`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(MetricMode::Auto),
            "dense" => Ok(MetricMode::Dense),
            "implicit" => Ok(MetricMode::Implicit),
            other => Err(Error::Topology(format!(
                "unknown metric mode: {other} (expected auto|dense|implicit)"
            ))),
        }
    }

    /// Resolve the mode for a platform of `num_nodes` compute nodes.
    pub fn resolve(self, num_nodes: usize) -> ResolvedMetric {
        match self {
            MetricMode::Dense => ResolvedMetric::Dense,
            MetricMode::Implicit => ResolvedMetric::Implicit,
            MetricMode::Auto => {
                if num_nodes <= DENSE_NODE_LIMIT {
                    ResolvedMetric::Dense
                } else {
                    ResolvedMetric::Implicit
                }
            }
        }
    }
}

impl std::fmt::Display for MetricMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MetricMode::Auto => "auto",
            MetricMode::Dense => "dense",
            MetricMode::Implicit => "implicit",
        })
    }
}

/// A [`MetricMode`] resolved against a concrete platform size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolvedMetric {
    /// The dense [`TopoIndex`] path is in effect.
    Dense,
    /// The implicit closed-form path is in effect.
    Implicit,
}

impl ResolvedMetric {
    /// True for the dense [`TopoIndex`] path.
    pub fn is_dense(self) -> bool {
        matches!(self, ResolvedMetric::Dense)
    }
}

/// Guard for the few implicit-mode operations that must still materialize
/// a `k x k` matrix (the fault-weighted full-cluster fallback, the
/// standard policies' candidate extract): a typed error instead of a
/// multi-gigabyte allocation. Window extracts are job-sized and never hit
/// this.
pub fn check_materialize(k: usize) -> Result<()> {
    if k > DENSE_NODE_LIMIT {
        return Err(Error::Placement(format!(
            "refusing to materialize a {k}x{k} distance matrix under the implicit metric \
             (limit {DENSE_NODE_LIMIT} nodes); restrict the candidate set"
        )));
    }
    Ok(())
}

/// The distance source placement consumers see: either a borrowed dense
/// [`TopoIndex`] or the topology's closed forms, behind one API. Obtain
/// one from [`Platform::hop_oracle`](super::Platform::hop_oracle).
///
/// Dense and implicit answers are bit-identical (the clean hop matrix
/// stores exactly `|R(u, v)| as f32`, which equals `hops(u, v) as f32` by
/// the [`Topology`] contract); the difference is purely memory — O(n²)
/// once vs O(1) per query.
#[derive(Debug, Clone, Copy)]
pub struct HopOracle<'a> {
    topo: &'a dyn Topology,
    index: Option<&'a TopoIndex>,
}

impl<'a> HopOracle<'a> {
    /// Dense oracle over a prebuilt index.
    pub fn dense(topo: &'a dyn Topology, index: &'a TopoIndex) -> Self {
        debug_assert_eq!(index.num_nodes(), topo.num_nodes());
        HopOracle {
            topo,
            index: Some(index),
        }
    }

    /// Implicit oracle: every query goes to the topology's closed forms.
    pub fn implicit(topo: &'a dyn Topology) -> Self {
        HopOracle { topo, index: None }
    }

    /// True when backed by the dense [`TopoIndex`].
    pub fn is_dense(&self) -> bool {
        self.index.is_some()
    }

    /// The dense index, when this oracle is dense — the incremental
    /// engines ([`fault_aware_distance_indexed`], the indexed window
    /// search) take it directly.
    ///
    /// [`fault_aware_distance_indexed`]: crate::tofa::eq1::fault_aware_distance_indexed
    pub fn index(&self) -> Option<&'a TopoIndex> {
        self.index
    }

    /// The underlying topology.
    pub fn topology(&self) -> &'a dyn Topology {
        self.topo
    }

    /// Compute-node count.
    pub fn num_nodes(&self) -> usize {
        self.topo.num_nodes()
    }

    /// Clean hop distance between two compute nodes, as the f32 the
    /// Eq. 1 engine works in.
    #[inline]
    pub fn hops(&self, u: usize, v: usize) -> f32 {
        match self.index {
            Some(ix) => ix.clean_hops().get(u, v),
            None => self.topo.hops(u, v) as f32,
        }
    }

    /// The sparse per-job view: the clean hop submatrix over `subset`
    /// (entry `(i, j)` is the distance between `subset[i]` and
    /// `subset[j]`). Sized by the job's candidate set — under the
    /// implicit mode this is the *only* matrix ever materialized.
    pub fn extract(&self, subset: &[usize]) -> DistanceMatrix {
        match self.index {
            Some(ix) => ix.clean_hops().extract(subset),
            None => {
                let k = subset.len();
                let mut m = DistanceMatrix::zeros(k);
                for i in 0..k {
                    for j in (i + 1)..k {
                        let h = self.topo.hops(subset[i], subset[j]) as f32;
                        m.set(i, j, h);
                        m.set(j, i, h);
                    }
                }
                m
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Dragonfly, DragonflyParams, FatTree, Torus, TorusDims};

    fn families() -> Vec<Box<dyn Topology>> {
        vec![
            Box::new(Torus::new(TorusDims::new(4, 4, 2))),
            Box::new(FatTree::new(4).unwrap()),
            Box::new(Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap()),
        ]
    }

    #[test]
    fn mode_parsing_and_display_round_trip() {
        for mode in [MetricMode::Auto, MetricMode::Dense, MetricMode::Implicit] {
            assert_eq!(MetricMode::parse(&mode.to_string()).unwrap(), mode);
        }
        assert!(MetricMode::parse("sparse").is_err());
        assert_eq!(MetricMode::default(), MetricMode::Auto);
    }

    #[test]
    fn auto_resolves_on_the_size_threshold() {
        assert!(MetricMode::Auto.resolve(DENSE_NODE_LIMIT).is_dense());
        assert!(!MetricMode::Auto.resolve(DENSE_NODE_LIMIT + 1).is_dense());
        assert!(MetricMode::Dense.resolve(1_000_000).is_dense());
        assert!(!MetricMode::Implicit.resolve(2).is_dense());
    }

    #[test]
    fn materialize_guard_trips_beyond_the_limit() {
        assert!(check_materialize(DENSE_NODE_LIMIT).is_ok());
        let err = check_materialize(DENSE_NODE_LIMIT + 1).unwrap_err();
        assert!(err.to_string().contains("implicit metric"), "{err}");
    }

    #[test]
    fn implicit_oracle_matches_dense_bit_for_bit() {
        for t in families() {
            let what = t.describe();
            let index = TopoIndex::build(t.as_ref());
            let dense = HopOracle::dense(t.as_ref(), &index);
            let implicit = HopOracle::implicit(t.as_ref());
            assert!(dense.is_dense() && !implicit.is_dense());
            let n = t.num_nodes();
            for u in 0..n {
                for v in 0..n {
                    assert_eq!(
                        dense.hops(u, v).to_bits(),
                        implicit.hops(u, v).to_bits(),
                        "{what} ({u},{v})"
                    );
                }
            }
        }
    }

    #[test]
    fn extract_agrees_across_modes_on_arbitrary_subsets() {
        let mut rng = crate::rng::Rng::new(7);
        for t in families() {
            let what = t.describe();
            let index = TopoIndex::build(t.as_ref());
            let dense = HopOracle::dense(t.as_ref(), &index);
            let implicit = HopOracle::implicit(t.as_ref());
            let n = t.num_nodes();
            for case in 0..20 {
                let k = 1 + rng.below_usize(n);
                let subset = rng.sample_distinct(n, k);
                let a = dense.extract(&subset);
                let b = implicit.extract(&subset);
                assert_eq!(a.len(), b.len());
                for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{what} case {case}");
                }
            }
        }
    }
}
