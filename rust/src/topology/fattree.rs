//! k-ary fat-tree topology (Al-Fares/Leiserson construction).
//!
//! A k-ary fat-tree has `k` pods; each pod holds `k/2` edge switches and
//! `k/2` aggregation switches; `(k/2)^2` core switches join the pods.
//! Every edge switch hosts `k/2` compute nodes, so the fabric serves
//! `k^3/4` nodes at full bisection bandwidth with uniform link capacity.
//!
//! Node ids enumerate pod-major then edge-major, so consecutive ids share
//! an edge switch / pod — the same locality contract the torus gives the
//! TOFA window search. Distance is `2 * level(LCA)`: 2 within an edge
//! switch, 4 within a pod, 6 across pods.
//!
//! Routing is deterministic destination-based up/down: the uplink
//! (aggregation switch, then core switch) is chosen by a fixed function of
//! the destination id — the usual static-ECMP hash, pinned so `R(u, v)` is
//! a pure function, as the simulator and Eq. 1 require.

use super::torus::Link;
use super::Topology;
use crate::error::{Error, Result};

/// k-ary fat-tree over `k^3/4` compute nodes (`k` even, >= 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FatTree {
    k: usize,
}

impl FatTree {
    /// Build a k-ary fat-tree. `k` must be even and >= 2.
    pub fn new(k: usize) -> Result<Self> {
        if k < 2 || k % 2 != 0 {
            return Err(Error::Topology(format!(
                "fat-tree arity must be even and >= 2, got {k}"
            )));
        }
        Ok(FatTree { k })
    }

    /// Parse the CLI form: the arity `k` (e.g. `"8"`).
    pub fn parse(s: &str) -> Result<Self> {
        let k = s
            .parse()
            .map_err(|_| Error::Topology(format!("bad fat-tree arity: {s}")))?;
        FatTree::new(k)
    }

    /// The arity `k`.
    pub fn arity(&self) -> usize {
        self.k
    }

    /// Half-arity `k/2`: nodes per edge switch, edge/agg switches per pod.
    #[inline]
    fn h(&self) -> usize {
        self.k / 2
    }

    /// Compute nodes per pod (`k^2/4`).
    #[inline]
    fn nodes_per_pod(&self) -> usize {
        self.h() * self.h()
    }

    /// Pod of a compute node.
    #[inline]
    pub fn pod_of(&self, node: usize) -> usize {
        node / self.nodes_per_pod()
    }

    /// Vertex id of the edge switch serving `node`.
    #[inline]
    fn edge_vertex(&self, node: usize) -> usize {
        let pod = self.pod_of(node);
        let edge_in_pod = (node % self.nodes_per_pod()) / self.h();
        self.num_nodes() + pod * self.h() + edge_in_pod
    }

    /// Vertex id of aggregation switch `a` (0..k/2) in `pod`.
    #[inline]
    fn agg_vertex(&self, pod: usize, a: usize) -> usize {
        self.num_nodes() + self.k * self.h() + pod * self.h() + a
    }

    /// Vertex id of core switch `(a, j)`: core group `a` (reachable from
    /// aggregation switch `a` of every pod), member `j` (0..k/2).
    #[inline]
    fn core_vertex(&self, a: usize, j: usize) -> usize {
        self.num_nodes() + 2 * self.k * self.h() + a * self.h() + j
    }

    /// The deterministic uplink choice for destination `v`: aggregation
    /// index and core member (the pinned static-ECMP hash).
    #[inline]
    fn uplink_for(&self, v: usize) -> (usize, usize) {
        (v % self.h(), (v / self.h()) % self.h())
    }

    fn num_nodes(&self) -> usize {
        self.k * self.k * self.k / 4
    }
}

impl Topology for FatTree {
    fn kind(&self) -> &'static str {
        "fattree"
    }

    fn describe(&self) -> String {
        format!("fat-tree k={} ({} nodes)", self.k, FatTree::num_nodes(self))
    }

    fn num_nodes(&self) -> usize {
        FatTree::num_nodes(self)
    }

    fn num_vertices(&self) -> usize {
        // nodes + k*(k/2) edge + k*(k/2) agg + (k/2)^2 core
        FatTree::num_nodes(self) + 2 * self.k * self.h() + self.h() * self.h()
    }

    fn hops(&self, u: usize, v: usize) -> usize {
        // 2 * tree-level of the lowest common ancestor
        if u == v {
            0
        } else if self.edge_vertex(u) == self.edge_vertex(v) {
            2
        } else if self.pod_of(u) == self.pod_of(v) {
            4
        } else {
            6
        }
    }

    fn route_into(&self, u: usize, v: usize, links: &mut Vec<Link>) {
        links.clear();
        if u == v {
            return;
        }
        // waypoint vertices of the up/down path (at most 7)
        let mut way = [0usize; 7];
        let mut k = 0;
        let at = |way: &mut [usize; 7], k: &mut usize, w: usize| {
            way[*k] = w;
            *k += 1;
        };
        let (eu, ev) = (self.edge_vertex(u), self.edge_vertex(v));
        at(&mut way, &mut k, u);
        at(&mut way, &mut k, eu);
        if eu != ev {
            let (a, j) = self.uplink_for(v);
            at(&mut way, &mut k, self.agg_vertex(self.pod_of(u), a));
            if self.pod_of(u) != self.pod_of(v) {
                at(&mut way, &mut k, self.core_vertex(a, j));
                at(&mut way, &mut k, self.agg_vertex(self.pod_of(v), a));
            }
            at(&mut way, &mut k, ev);
        }
        at(&mut way, &mut k, v);
        for w in way[..k].windows(2) {
            links.push(Link { src: w[0], dst: w[1] });
        }
        debug_assert_eq!(links.len(), self.hops(u, v));
    }

    fn all_links(&self) -> Vec<Link> {
        let mut links = Vec::new();
        let both = |a: usize, b: usize, links: &mut Vec<Link>| {
            links.push(Link { src: a, dst: b });
            links.push(Link { src: b, dst: a });
        };
        for n in 0..FatTree::num_nodes(self) {
            both(n, self.edge_vertex(n), &mut links);
        }
        for pod in 0..self.k {
            for e in 0..self.h() {
                let edge = FatTree::num_nodes(self) + pod * self.h() + e;
                for a in 0..self.h() {
                    both(edge, self.agg_vertex(pod, a), &mut links);
                }
            }
            for a in 0..self.h() {
                for j in 0..self.h() {
                    both(self.agg_vertex(pod, a), self.core_vertex(a, j), &mut links);
                }
            }
        }
        links
    }

    fn bisection_links(&self) -> usize {
        // splitting the pods in half cuts half the core downlinks:
        // (k/2)^2 cores x k/2 pod links each, both directions
        2 * self.h() * self.h() * self.h()
    }

    fn num_racks(&self) -> usize {
        self.k
    }

    fn rack_of(&self, node: usize) -> usize {
        self.pod_of(node)
    }

    fn rack_members(&self, rack: usize) -> Vec<usize> {
        let npp = self.nodes_per_pod();
        (rack * npp..(rack + 1) * npp).collect()
    }

    fn salt(&self) -> u64 {
        super::fnv_salt("fattree", &[self.k as u64])
    }

    fn route_touches(&self, u: usize, v: usize, node: usize) -> bool {
        debug_assert!(node < FatTree::num_nodes(self));
        // up/down routes transit switches only (asserted in
        // routes_match_hops_and_are_connected), so a compute node is on
        // R(u, v) iff it is an endpoint of a non-empty route
        u != v && (node == u || node == v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_counts() {
        let f = FatTree::new(4).unwrap();
        assert_eq!(Topology::num_nodes(&f), 16);
        assert_eq!(f.num_vertices(), 16 + 8 + 8 + 4);
        assert_eq!(f.num_racks(), 4);
        let f8 = FatTree::parse("8").unwrap();
        assert_eq!(Topology::num_nodes(&f8), 128);
        assert!(FatTree::new(3).is_err());
        assert!(FatTree::new(0).is_err());
        assert!(FatTree::parse("x").is_err());
    }

    #[test]
    fn distance_is_twice_lca_level() {
        let f = FatTree::new(4).unwrap();
        // nodes 0,1 share edge switch; 0,2 share only the pod; 0,4 differ
        assert_eq!(f.hops(0, 0), 0);
        assert_eq!(f.hops(0, 1), 2);
        assert_eq!(f.hops(0, 2), 4);
        assert_eq!(f.hops(0, 4), 6);
    }

    #[test]
    fn routes_match_hops_and_are_connected() {
        let f = FatTree::new(4).unwrap();
        let n = Topology::num_nodes(&f);
        for u in 0..n {
            for v in 0..n {
                let r = f.route(u, v);
                assert_eq!(r.len(), f.hops(u, v), "{u}->{v}");
                if u != v {
                    assert_eq!(r.first().unwrap().src, u);
                    assert_eq!(r.last().unwrap().dst, v);
                    for w in r.windows(2) {
                        assert_eq!(w[0].dst, w[1].src);
                    }
                    // interior hops are switches, never compute nodes
                    for l in &r[..r.len() - 1] {
                        assert!(l.dst >= n, "{u}->{v} transits node {}", l.dst);
                    }
                }
            }
        }
    }

    #[test]
    fn routes_use_physical_links_only() {
        let f = FatTree::new(6).unwrap();
        let n = Topology::num_nodes(&f);
        let mut physical = std::collections::HashSet::new();
        for l in f.all_links() {
            physical.insert((l.src, l.dst));
        }
        for u in (0..n).step_by(5) {
            for v in (0..n).step_by(7) {
                for l in f.route(u, v) {
                    assert!(physical.contains(&(l.src, l.dst)), "{u}->{v}: {l:?}");
                }
            }
        }
    }

    #[test]
    fn route_touches_matches_routed_scan() {
        let f = FatTree::new(4).unwrap();
        let n = Topology::num_nodes(&f);
        for u in 0..n {
            for v in 0..n {
                let route = f.route(u, v);
                for node in 0..n {
                    let scanned = route.iter().any(|l| l.src == node || l.dst == node);
                    assert_eq!(f.route_touches(u, v, node), scanned, "({u},{v}) node {node}");
                }
            }
        }
    }

    #[test]
    fn pods_are_contiguous_racks() {
        let f = FatTree::new(4).unwrap();
        assert_eq!(f.rack_members(0), vec![0, 1, 2, 3]);
        assert_eq!(f.rack_members(3), vec![12, 13, 14, 15]);
        for node in 0..16 {
            assert_eq!(f.rack_of(node), node / 4);
        }
    }

    #[test]
    fn link_index_is_dense() {
        let f = FatTree::new(4).unwrap();
        let (index, count) = f.link_index();
        assert_eq!(count, f.all_links().len());
        let mut seen = vec![false; count];
        for slot in index.iter().filter(|&&s| s != u32::MAX) {
            seen[*slot as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
