//! Generic weighted architecture graph (adjacency list).
//!
//! Used where an explicit sparse graph is more natural than the dense
//! [`super::DistanceMatrix`]: host-side recursive bisection and the FATT
//! plugin's exported platform representation.

/// Undirected weighted graph over `n` vertices.
#[derive(Debug, Clone)]
pub struct ArchGraph {
    n: usize,
    adj: Vec<Vec<(usize, f32)>>,
}

impl ArchGraph {
    /// Empty graph with `n` vertices.
    pub fn new(n: usize) -> Self {
        ArchGraph {
            n,
            adj: vec![Vec::new(); n],
        }
    }

    /// Build the physical-link graph of a torus (unit edge weights).
    pub fn from_torus(t: &super::torus::Torus) -> Self {
        let mut g = ArchGraph::new(t.num_nodes());
        for u in 0..t.num_nodes() {
            for v in t.neighbors(u) {
                if u < v {
                    g.add_edge(u, v, 1.0);
                }
            }
        }
        g
    }

    /// Build the physical-link graph of any [`super::Topology`] over its
    /// full vertex set (compute nodes + switches), unit edge weights.
    pub fn from_topology(t: &dyn super::Topology) -> Self {
        let mut g = ArchGraph::new(t.num_vertices());
        for l in t.all_links() {
            if l.src < l.dst {
                g.add_edge(l.src, l.dst, 1.0);
            }
        }
        g
    }

    /// Add an undirected edge.
    pub fn add_edge(&mut self, u: usize, v: usize, w: f32) {
        assert!(u < self.n && v < self.n && u != v);
        self.adj[u].push((v, w));
        self.adj[v].push((u, w));
    }

    /// Vertex count.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if no vertices.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Neighbours of `u` with weights.
    pub fn neighbors(&self, u: usize) -> &[(usize, f32)] {
        &self.adj[u]
    }

    /// Degree of `u`.
    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    /// Unweighted BFS hop distances from `src` (usize::MAX = unreachable).
    pub fn bfs_hops(&self, src: usize) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.n];
        let mut queue = std::collections::VecDeque::new();
        dist[src] = 0;
        queue.push_back(src);
        while let Some(u) = queue.pop_front() {
            for &(v, _) in &self.adj[u] {
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// A pseudo-peripheral vertex: repeated BFS from the farthest vertex.
    pub fn pseudo_peripheral(&self, start: usize) -> usize {
        let mut cur = start;
        let mut ecc = 0usize;
        for _ in 0..4 {
            let d = self.bfs_hops(cur);
            let (far, far_d) = d
                .iter()
                .enumerate()
                .filter(|(_, &x)| x != usize::MAX)
                .max_by_key(|(_, &x)| x)
                .map(|(i, &x)| (i, x))
                .unwrap_or((cur, 0));
            if far_d <= ecc {
                break;
            }
            ecc = far_d;
            cur = far;
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::torus::{Torus, TorusDims};

    #[test]
    fn torus_graph_degrees() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let g = ArchGraph::from_torus(&t);
        for u in 0..g.len() {
            assert_eq!(g.degree(u), 6);
        }
    }

    #[test]
    fn bfs_matches_torus_hops() {
        let t = Torus::new(TorusDims::new(4, 4, 2));
        let g = ArchGraph::from_torus(&t);
        let d = g.bfs_hops(0);
        for v in 0..g.len() {
            assert_eq!(d[v], t.hops(0, v), "v={v}");
        }
    }

    #[test]
    fn topology_graph_spans_switch_vertices() {
        use crate::topology::{FatTree, Topology};
        let f = FatTree::new(4).unwrap();
        let g = ArchGraph::from_topology(&f);
        assert_eq!(g.len(), f.num_vertices());
        // BFS over the physical graph agrees with the fat-tree metric
        let d = g.bfs_hops(0);
        assert_eq!(d[1], 2); // same edge switch
        assert_eq!(d[4], 6); // cross-pod
        // every vertex is reachable
        assert!(d.iter().all(|&x| x != usize::MAX));
    }

    #[test]
    fn pseudo_peripheral_is_far() {
        let t = Torus::new(TorusDims::new(8, 8, 1));
        let g = ArchGraph::from_torus(&t);
        let p = g.pseudo_peripheral(0);
        // Eccentricity of any vertex in an 8x8 torus is 8; the pseudo
        // peripheral vertex must achieve it.
        let d = g.bfs_hops(p);
        assert_eq!(*d.iter().max().unwrap(), 8);
    }
}
