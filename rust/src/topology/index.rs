//! `TopoIndex` — per-topology precompute shared across the placement and
//! simulation layers.
//!
//! Every batch cell used to pay three hot paths from scratch: Eq. 1
//! re-routed all `O(n^2)` node pairs per outage vector, the route-clean
//! window search re-routed `O(len^2)` pairs per candidate start, and the
//! max-min solver rescanned the whole link array per bottleneck round. The
//! paper's regime — *few* nodes with low outage probability — means almost
//! all of that work recomputes the clean answer. `TopoIndex` precomputes
//! the structure that lets the hot paths touch only what faults actually
//! perturb:
//!
//! * **clean hop matrix** — `|R(u, v)|` for every pair, built from the
//!   routes themselves (so it is bit-identical to what Eq. 1 produces with
//!   no faults: a sum of `1.0f32` per hop is exact for any realistic hop
//!   count);
//! * **transit-incidence index** — for every compute node `n`, the ordered
//!   list of pairs `(u, v)` whose fixed route `R(u, v)` has `n` as a link
//!   endpoint. This is the inverse of the routing function: the set of
//!   matrix entries a flaky `n` can perturb. It is also exactly the
//!   registry the paper's FATT plugin exports (vertex -> paths it serves).
//!
//! The index is built once per platform ([`super::Platform::topo_index`])
//! and shared `Arc`-style across batch instances and worker threads, the
//! same ownership model as [`crate::sim::cache::PhaseCache`]. Consumers:
//! [`crate::tofa::eq1::fault_aware_distance_indexed`],
//! [`crate::tofa::window::find_route_clean_window_indexed`], the TOFA
//! placer, and the FATT plugin's transit registry.

use super::distance::DistanceMatrix;
use super::torus::Link;
use super::Topology;

/// Pack a node pair `(u, v)` with `u < v` into one word.
#[inline]
fn pack(u: usize, v: usize) -> u64 {
    ((u as u64) << 32) | v as u64
}

/// Inverse of [`pack`].
#[inline]
fn unpack(p: u64) -> (usize, usize) {
    ((p >> 32) as usize, (p & 0xffff_ffff) as usize)
}

/// Immutable per-topology precompute: clean hop matrix + transit-incidence
/// CSR. Build once (one full route sweep, the cost of a single dense
/// Eq. 1 evaluation) and share.
pub struct TopoIndex {
    num_nodes: usize,
    /// Clean route-length matrix: entry `(u, v)` is `|R(u, v)|` as f32.
    clean: DistanceMatrix,
    /// CSR offsets into [`Self::inc_pairs`], one slice per compute node.
    inc_off: Vec<u32>,
    /// Packed `(u, v)` pairs (`u < v`, lexicographic per node) whose route
    /// touches the node as a link endpoint. Endpoints count: `u` and `v`
    /// are themselves endpoints of the first/last link of `R(u, v)`.
    inc_pairs: Vec<u64>,
}

impl TopoIndex {
    /// Build the index with one sweep over all `(u, v)` routes. Transit
    /// vertices `>= num_nodes()` (switches/routers of indirect fabrics)
    /// never fail and are not indexed.
    pub fn build(topo: &dyn Topology) -> Self {
        let n = topo.num_nodes();
        let mut clean = DistanceMatrix::zeros(n);
        let mut per_node: Vec<Vec<u64>> = vec![Vec::new(); n];
        // last pair that touched each node: routes revisit a node as the
        // dst of one link and the src of the next, so this collapses the
        // duplicate without a per-pair set
        let mut last_pair = vec![u64::MAX; n];
        let mut route: Vec<Link> = Vec::new();
        for u in 0..n {
            for v in (u + 1)..n {
                topo.route_into(u, v, &mut route);
                let h = route.len() as f32;
                clean.set(u, v, h);
                clean.set(v, u, h);
                let p = pack(u, v);
                for l in &route {
                    for e in [l.src, l.dst] {
                        if e < n && last_pair[e] != p {
                            last_pair[e] = p;
                            per_node[e].push(p);
                        }
                    }
                }
            }
        }
        #[cfg(debug_assertions)]
        for u in 0..n {
            for v in 0..n {
                debug_assert_eq!(
                    clean.get(u, v),
                    topo.hops(u, v) as f32,
                    "route length disagrees with the hop metric for ({u},{v})"
                );
            }
        }
        let mut inc_off = Vec::with_capacity(n + 1);
        let mut inc_pairs = Vec::with_capacity(per_node.iter().map(Vec::len).sum());
        inc_off.push(0u32);
        for pairs in &per_node {
            inc_pairs.extend_from_slice(pairs);
            inc_off.push(inc_pairs.len() as u32);
        }
        TopoIndex {
            num_nodes: n,
            clean,
            inc_off,
            inc_pairs,
        }
    }

    /// Compute-node count the index covers.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// The clean (fault-free) hop matrix, `|R(u, v)|` per entry.
    #[inline]
    pub fn clean_hops(&self) -> &DistanceMatrix {
        &self.clean
    }

    /// Packed pairs whose route touches `node` (see [`pair_of`] to
    /// unpack). Lexicographically sorted, `u < v`.
    #[inline]
    pub fn pairs_through_packed(&self, node: usize) -> &[u64] {
        &self.inc_pairs[self.inc_off[node] as usize..self.inc_off[node + 1] as usize]
    }

    /// The pairs `(u, v)` (with `u < v`) whose route `R(u, v)` touches
    /// `node` as a link endpoint — the transit registry entry for `node`.
    pub fn pairs_through(&self, node: usize) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.pairs_through_packed(node).iter().map(|&p| unpack(p))
    }

    /// Total incidence entries across all nodes (index memory figure of
    /// merit, reported by `benches/cost_engine.rs`).
    pub fn incidence_len(&self) -> usize {
        self.inc_pairs.len()
    }
}

/// Unpack a packed pair from [`TopoIndex::pairs_through_packed`].
#[inline]
pub fn pair_of(packed: u64) -> (usize, usize) {
    unpack(packed)
}

/// The epoch-mark protocol of [`CostWorkspace::mark_pair`], usable on a
/// destructured `pair_mark` cell under split borrows (the incremental
/// engines iterate one workspace field while marking another): returns
/// true iff `cell` had not been stamped with `epoch` yet.
#[inline]
pub(crate) fn mark_cell(cell: &mut u32, epoch: u32) -> bool {
    if *cell == epoch {
        false
    } else {
        *cell = epoch;
        true
    }
}

impl std::fmt::Debug for TopoIndex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TopoIndex")
            .field("num_nodes", &self.num_nodes)
            .field("incidence_pairs", &self.inc_pairs.len())
            .finish()
    }
}

/// Reusable scratch for the incremental cost engines — one per worker
/// thread (the TOFA placer owns one), so the hot paths allocate nothing
/// after warm-up. Holds the flaky-node view of the current outage vector
/// (built **once** per `place()` call and shared by the window search and
/// Eq. 1, which used to rebuild it back-to-back) plus epoch-stamped pair
/// marks for de-duplicating incidence lists without clearing.
pub struct CostWorkspace {
    /// `flaky[n]` = `outage[n] > 0`, for the last prepared outage vector.
    pub(crate) flaky: Vec<bool>,
    /// Indices of the flaky nodes, ascending.
    pub(crate) flaky_nodes: Vec<u32>,
    /// `flaky_prefix[i]` = flaky nodes among ids `0..i` (window check).
    pub(crate) flaky_prefix: Vec<u32>,
    /// Epoch-stamped marks over the dense pair space (`u * n + v`).
    pub(crate) pair_mark: Vec<u32>,
    pub(crate) pair_epoch: u32,
    /// Route scratch for Eq. 1 recomputation.
    pub(crate) route: Vec<Link>,
    /// Per-node dirty-partner lists for the sliding window search.
    pub(crate) partners: Vec<Vec<u32>>,
    /// Nodes whose partner list is non-empty (cleared lazily next call).
    pub(crate) partner_touched: Vec<u32>,
    /// Prefix sums of blocked (flaky or candidate-masked) nodes for the
    /// masked window search; rebuilt per call, buffer reused.
    pub(crate) blocked_prefix: Vec<u32>,
    /// Matrix entries recomputed by the last incremental Eq. 1 call
    /// (index effectiveness stat: compare against `n * (n - 1) / 2`).
    pub(crate) pairs_patched: usize,
}

impl Default for CostWorkspace {
    fn default() -> Self {
        CostWorkspace {
            flaky: Vec::new(),
            flaky_nodes: Vec::new(),
            flaky_prefix: Vec::new(),
            pair_mark: Vec::new(),
            pair_epoch: 0,
            route: Vec::new(),
            partners: Vec::new(),
            partner_touched: Vec::new(),
            blocked_prefix: Vec::new(),
            pairs_patched: 0,
        }
    }
}

impl CostWorkspace {
    /// Fresh workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// (Re)fill the flaky view from an outage vector. O(n), allocation-
    /// free once the buffers have grown to the platform size. Idempotent:
    /// callers that invoke several engines back-to-back with the same
    /// vector pay two cheap passes, never a rebuild of the index.
    pub fn prepare(&mut self, outage: &[f64]) {
        let n = outage.len();
        self.flaky.clear();
        self.flaky.extend(outage.iter().map(|&p| p > 0.0));
        self.flaky_nodes.clear();
        self.flaky_prefix.clear();
        self.flaky_prefix.reserve(n + 1);
        self.flaky_prefix.push(0);
        let mut acc = 0u32;
        for (i, &f) in self.flaky.iter().enumerate() {
            if f {
                self.flaky_nodes.push(i as u32);
                acc += 1;
            }
            self.flaky_prefix.push(acc);
        }
    }

    /// True if node `n` is flaky under the prepared outage vector
    /// (vertices beyond the node range — switches — are never flaky).
    #[inline]
    pub fn is_flaky(&self, n: usize) -> bool {
        n < self.flaky.len() && self.flaky[n]
    }

    /// Flaky nodes among ids `lo..hi` under the prepared outage vector.
    #[inline]
    pub fn flaky_in(&self, lo: usize, hi: usize) -> u32 {
        self.flaky_prefix[hi] - self.flaky_prefix[lo]
    }

    /// Start a pair-dedup pass over an `n x n` pair space (see
    /// [`Self::mark_pair`]; custom engines walking incidence lists use
    /// this to visit each pair once even when flaky lists overlap).
    pub fn begin_pairs(&mut self, n: usize) {
        if self.pair_mark.len() < n * n {
            // growing re-lays the pair space out (`u * n + v` changes
            // meaning), so zero everything — old marks kept by a plain
            // resize() would alias other pairs once the epoch recycles
            self.pair_mark.clear();
            self.pair_mark.resize(n * n, 0);
            self.pair_epoch = 0;
        }
        self.pair_epoch = self.pair_epoch.wrapping_add(1);
        if self.pair_epoch == 0 {
            // u32 wrapped (once per ~4e9 passes): stale marks could alias
            self.pair_mark.fill(0);
            self.pair_epoch = 1;
        }
    }

    /// Mark pair `(u, v)`; true the first time this pass sees it.
    #[inline]
    pub fn mark_pair(&mut self, n: usize, u: usize, v: usize) -> bool {
        mark_cell(&mut self.pair_mark[u * n + v], self.pair_epoch)
    }

    /// Matrix entries the last incremental Eq. 1 call actually recomputed.
    pub fn pairs_patched(&self) -> usize {
        self.pairs_patched
    }
}

impl std::fmt::Debug for CostWorkspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CostWorkspace")
            .field("nodes", &self.flaky.len())
            .field("flaky", &self.flaky_nodes.len())
            .field("pairs_patched", &self.pairs_patched)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Dragonfly, DragonflyParams, FatTree, Torus, TorusDims};

    fn families() -> Vec<Box<dyn Topology>> {
        vec![
            Box::new(Torus::new(TorusDims::new(4, 4, 2))),
            Box::new(FatTree::new(4).unwrap()),
            Box::new(Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap()),
        ]
    }

    #[test]
    fn clean_matrix_matches_hop_matrix_exactly() {
        for t in families() {
            let idx = TopoIndex::build(t.as_ref());
            let hops = DistanceMatrix::from_topology(t.as_ref());
            let what = t.describe();
            assert_eq!(idx.num_nodes(), t.num_nodes(), "{what}");
            for u in 0..t.num_nodes() {
                for v in 0..t.num_nodes() {
                    assert_eq!(
                        idx.clean_hops().get(u, v).to_bits(),
                        hops.get(u, v).to_bits(),
                        "{what} ({u},{v})"
                    );
                }
            }
        }
    }

    #[test]
    fn incidence_matches_brute_force_route_sweep() {
        for t in families() {
            let n = t.num_nodes();
            let what = t.describe();
            let idx = TopoIndex::build(t.as_ref());
            for node in 0..n {
                let mut want = Vec::new();
                for u in 0..n {
                    for v in (u + 1)..n {
                        let r = t.route(u, v);
                        if r.iter().any(|l| l.src == node || l.dst == node) {
                            want.push((u, v));
                        }
                    }
                }
                let got: Vec<(usize, usize)> = idx.pairs_through(node).collect();
                assert_eq!(got, want, "{what} node {node}");
                // lists are lexicographically sorted and duplicate-free
                let packed = idx.pairs_through_packed(node);
                assert!(packed.windows(2).all(|w| w[0] < w[1]), "{what} node {node}");
            }
        }
    }

    #[test]
    fn endpoints_are_in_their_own_incidence_lists() {
        let t = Torus::new(TorusDims::new(4, 4, 1));
        let idx = TopoIndex::build(&t);
        // every pair (u, v) must appear in both u's and v's list
        for u in 0..16 {
            for v in (u + 1)..16 {
                for node in [u, v] {
                    assert!(
                        idx.pairs_through(node).any(|p| p == (u, v)),
                        "pair ({u},{v}) missing from node {node}"
                    );
                }
            }
        }
    }

    #[test]
    fn switches_are_not_indexed() {
        let f = FatTree::new(4).unwrap();
        let idx = TopoIndex::build(&f);
        assert_eq!(idx.num_nodes(), 16);
        // all pairs reference compute nodes only
        for node in 0..16 {
            for (u, v) in idx.pairs_through(node) {
                assert!(u < 16 && v < 16 && u < v);
            }
        }
    }

    #[test]
    fn workspace_prepare_is_reusable_and_consistent() {
        let mut ws = CostWorkspace::new();
        let mut outage = vec![0.0; 10];
        outage[3] = 0.1;
        outage[7] = 0.2;
        ws.prepare(&outage);
        assert_eq!(ws.flaky_nodes, vec![3, 7]);
        assert_eq!(ws.flaky_in(0, 10), 2);
        assert_eq!(ws.flaky_in(4, 7), 0);
        assert!(ws.is_flaky(3) && !ws.is_flaky(4));
        // switches beyond the node range never count as flaky
        assert!(!ws.is_flaky(10_000));
        // re-prepare with a different vector reuses the buffers
        ws.prepare(&vec![0.0; 10]);
        assert!(ws.flaky_nodes.is_empty());
        assert_eq!(ws.flaky_in(0, 10), 0);
    }

    #[test]
    fn pair_marks_dedup_per_pass() {
        let mut ws = CostWorkspace::new();
        ws.begin_pairs(8);
        assert!(ws.mark_pair(8, 1, 2));
        assert!(!ws.mark_pair(8, 1, 2));
        assert!(ws.mark_pair(8, 2, 3));
        ws.begin_pairs(8);
        assert!(ws.mark_pair(8, 1, 2), "new pass must reset marks");
    }

    #[test]
    fn pair_marks_survive_workspace_growth() {
        // growing the pair space re-lays it out; a stale mark written
        // under the small layout must never read as current once the
        // epoch restarts (regression: resize() used to keep old cells)
        let mut ws = CostWorkspace::new();
        ws.begin_pairs(4);
        assert!(ws.mark_pair(4, 1, 2)); // cell 1*4+2 = 6 under n=4
        ws.begin_pairs(8);
        assert!(ws.mark_pair(8, 0, 6), "stale small-layout mark aliased"); // cell 6 under n=8
        // shrinking back keeps monotonic epochs: nothing stale survives
        ws.begin_pairs(4);
        assert!(ws.mark_pair(4, 1, 2));
    }
}
