//! SimGrid-style platform description.
//!
//! Mirrors the components the paper feeds to SimGrid: nodes with a fixed
//! compute capability, links with bandwidth + latency, and a static route
//! for every node pair (provided by the torus DOR routing function). The
//! paper's values: 6 Gflops per node, 10 Gbps and 1 us per link.

use super::distance::DistanceMatrix;
use super::torus::{Torus, TorusDims};

/// Immutable platform description shared by the placement and simulation
/// layers. Fault *state* (which nodes are down in a given scenario) is kept
/// separate — see [`crate::sim::fault::FaultScenario`] — so one platform
/// can be reused across thousands of simulated instances.
#[derive(Debug, Clone)]
pub struct Platform {
    torus: Torus,
    /// Node compute capability in FLOPS.
    pub flops: f64,
    /// Link bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Per-link latency in seconds.
    pub latency: f64,
}

impl Platform {
    /// Platform with the paper's simulation parameters:
    /// 6 Gflops nodes, 10 Gbps links, 1 us latency.
    pub fn paper_default(dims: TorusDims) -> Self {
        Platform {
            torus: Torus::new(dims),
            flops: 6e9,
            bandwidth: 10e9 / 8.0, // 10 Gbps in bytes/s
            latency: 1e-6,
        }
    }

    /// Custom parameters.
    pub fn new(dims: TorusDims, flops: f64, bandwidth_bps: f64, latency_s: f64) -> Self {
        Platform {
            torus: Torus::new(dims),
            flops,
            bandwidth: bandwidth_bps / 8.0,
            latency: latency_s,
        }
    }

    /// Underlying torus (routing function provider).
    pub fn torus(&self) -> &Torus {
        &self.torus
    }

    /// Node count.
    pub fn num_nodes(&self) -> usize {
        self.torus.num_nodes()
    }

    /// Fault-free hop-count distance matrix.
    pub fn hop_matrix(&self) -> DistanceMatrix {
        DistanceMatrix::from_torus_hops(&self.torus)
    }

    /// Failure-domain count (racks = X-lines; the definition lives in
    /// [`Torus::num_racks`]). Correlated fault models
    /// ([`crate::sim::fault::CorrelatedDomains`]) use these as their
    /// default domains.
    pub fn num_racks(&self) -> usize {
        self.torus.num_racks()
    }

    /// The rack (failure domain) a node belongs to.
    pub fn rack_of(&self, node: usize) -> usize {
        self.torus.rack_of(node)
    }

    /// Member node ids of one rack, in ascending order.
    pub fn rack_members(&self, rack: usize) -> Vec<usize> {
        self.torus.rack_members(rack)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_parameters() {
        let p = Platform::paper_default(TorusDims::new(8, 8, 8));
        assert_eq!(p.num_nodes(), 512);
        assert_eq!(p.flops, 6e9);
        assert!((p.bandwidth - 1.25e9).abs() < 1.0);
        assert_eq!(p.latency, 1e-6);
    }

    #[test]
    fn hop_matrix_consistent_with_torus() {
        let p = Platform::paper_default(TorusDims::new(4, 4, 4));
        let m = p.hop_matrix();
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.len(), 64);
    }

    #[test]
    fn racks_partition_the_platform() {
        let p = Platform::paper_default(TorusDims::new(8, 4, 2));
        assert_eq!(p.num_racks(), 8);
        let mut seen = vec![false; p.num_nodes()];
        for r in 0..p.num_racks() {
            for n in p.rack_members(r) {
                assert_eq!(p.rack_of(n), r);
                assert!(!seen[n], "node {n} in two racks");
                seen[n] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // members are consecutive ids (X-lines)
        assert_eq!(p.rack_members(1), vec![8, 9, 10, 11, 12, 13, 14, 15]);
    }
}
