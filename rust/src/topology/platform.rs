//! SimGrid-style platform description.
//!
//! Mirrors the components the paper feeds to SimGrid: nodes with a fixed
//! compute capability, links with bandwidth + latency, and a static route
//! for every node pair (provided by the pluggable [`Topology`]'s routing
//! function). The paper's values: 6 Gflops per node, 10 Gbps and 1 us per
//! link.

use std::sync::{Arc, OnceLock};

use super::distance::DistanceMatrix;
use super::index::TopoIndex;
use super::metric::{HopOracle, MetricMode, ResolvedMetric};
use super::torus::{Torus, TorusDims};
use super::Topology;
use crate::error::{Error, Result};

/// Immutable platform description shared by the placement and simulation
/// layers. Fault *state* (which nodes are down in a given scenario) is kept
/// separate — see [`crate::sim::fault::FaultScenario`] — so one platform
/// can be reused across thousands of simulated instances.
///
/// The interconnect is any [`Topology`] (torus, fat-tree, dragonfly);
/// cloning a platform shares it.
#[derive(Debug, Clone)]
pub struct Platform {
    topo: Arc<dyn Topology>,
    /// Lazily-built [`TopoIndex`] (clean hop matrix + transit-incidence
    /// index). Behind `Arc` so every clone of the platform — including the
    /// per-worker runner clones of the parallel batch engine — shares the
    /// one index, exactly like the phase cache.
    index: Arc<OnceLock<TopoIndex>>,
    /// How distances are served: dense [`TopoIndex`] or on-demand closed
    /// forms. Defaults to [`MetricMode::Auto`] (dense up to
    /// [`DENSE_NODE_LIMIT`](super::metric::DENSE_NODE_LIMIT) nodes).
    metric: MetricMode,
    /// Node compute capability in FLOPS.
    pub flops: f64,
    /// Link bandwidth in bytes/second (scaled per link by
    /// [`Topology::link_capacity_scale`]).
    pub bandwidth: f64,
    /// Per-link latency in seconds.
    pub latency: f64,
}

impl Platform {
    /// Torus platform with the paper's simulation parameters:
    /// 6 Gflops nodes, 10 Gbps links, 1 us latency.
    pub fn paper_default(dims: TorusDims) -> Self {
        Self::paper_default_on(Arc::new(Torus::new(dims)))
    }

    /// Any topology with the paper's simulation parameters.
    pub fn paper_default_on(topo: Arc<dyn Topology>) -> Self {
        Platform {
            topo,
            index: Arc::new(OnceLock::new()),
            metric: MetricMode::Auto,
            flops: 6e9,
            bandwidth: 10e9 / 8.0, // 10 Gbps in bytes/s
            latency: 1e-6,
        }
    }

    /// Torus platform with custom parameters.
    pub fn new(dims: TorusDims, flops: f64, bandwidth_bps: f64, latency_s: f64) -> Self {
        Self::with_topology(Arc::new(Torus::new(dims)), flops, bandwidth_bps, latency_s)
    }

    /// Any topology with custom parameters.
    pub fn with_topology(
        topo: Arc<dyn Topology>,
        flops: f64,
        bandwidth_bps: f64,
        latency_s: f64,
    ) -> Self {
        Platform {
            topo,
            index: Arc::new(OnceLock::new()),
            metric: MetricMode::Auto,
            flops,
            bandwidth: bandwidth_bps / 8.0,
            latency: latency_s,
        }
    }

    /// Select the [`MetricMode`] (builder style; the default is `Auto`).
    pub fn with_metric(mut self, metric: MetricMode) -> Self {
        self.metric = metric;
        self
    }

    /// The configured (unresolved) metric mode.
    pub fn metric_mode(&self) -> MetricMode {
        self.metric
    }

    /// The metric mode resolved against this platform's size.
    pub fn resolved_metric(&self) -> ResolvedMetric {
        self.metric.resolve(self.num_nodes())
    }

    /// The [`HopOracle`] placement consumers should query: dense (backed
    /// by [`Platform::topo_index`]) or implicit, per
    /// [`Platform::resolved_metric`].
    pub fn hop_oracle(&self) -> HopOracle<'_> {
        match self.resolved_metric() {
            ResolvedMetric::Dense => HopOracle::dense(self.topo.as_ref(), self.topo_index()),
            ResolvedMetric::Implicit => HopOracle::implicit(self.topo.as_ref()),
        }
    }

    /// The dense [`TopoIndex`], or a typed error when the implicit metric
    /// is in effect (the index is the O(n²) state the implicit mode exists
    /// to avoid). Callers that can serve their query on demand should use
    /// [`Platform::hop_oracle`] instead.
    pub fn try_topo_index(&self) -> Result<&TopoIndex> {
        match self.resolved_metric() {
            ResolvedMetric::Dense => Ok(self.topo_index_dense()),
            ResolvedMetric::Implicit => Err(Error::Topology(format!(
                "dense TopoIndex refused: {} nodes under the implicit metric (mode {}); \
                 use Platform::hop_oracle",
                self.num_nodes(),
                self.metric
            ))),
        }
    }

    /// The interconnect (routing function provider).
    pub fn topology(&self) -> &dyn Topology {
        self.topo.as_ref()
    }

    /// Shared handle to the interconnect.
    pub fn topology_arc(&self) -> Arc<dyn Topology> {
        Arc::clone(&self.topo)
    }

    /// Compute-node count.
    pub fn num_nodes(&self) -> usize {
        self.topo.num_nodes()
    }

    /// Fault-free hop-count distance matrix over the compute nodes.
    ///
    /// Allocates a fresh matrix per call; hot paths should prefer
    /// [`Platform::topo_index`] and borrow
    /// [`TopoIndex::clean_hops`] instead (same values bit-for-bit).
    pub fn hop_matrix(&self) -> DistanceMatrix {
        DistanceMatrix::from_topology(self.topo.as_ref())
    }

    /// The shared [`TopoIndex`] for this platform, built on first use and
    /// reused by every clone (worker threads included — `OnceLock` makes
    /// the one-time build race-free).
    ///
    /// # Panics
    ///
    /// When the implicit metric is in effect (the dense index must never
    /// be built then) — use [`Platform::try_topo_index`] or
    /// [`Platform::hop_oracle`] on code paths that can see implicit
    /// platforms.
    pub fn topo_index(&self) -> &TopoIndex {
        self.try_topo_index()
            // invariant: documented panic contract above -- callers that
            // can see implicit platforms must use try_topo_index()
            .expect("dense TopoIndex requested under the implicit metric mode")
    }

    /// The index build itself, sans the metric-mode guard.
    // detlint: allow(dense-reference-pairing, `_dense` here names the index mode, not an oracle)
    fn topo_index_dense(&self) -> &TopoIndex {
        self.index.get_or_init(|| TopoIndex::build(self.topo.as_ref()))
    }

    /// Shared handle to the lazily-built index cell, so consumers that
    /// outlive a `&Platform` borrow (the FATT plugin's transit registry)
    /// can reuse the same one-time build instead of duplicating it.
    pub(crate) fn index_cell(&self) -> Arc<OnceLock<TopoIndex>> {
        Arc::clone(&self.index)
    }

    /// Failure-domain count (torus X-lines / fat-tree pods / dragonfly
    /// groups; the definition lives with each [`Topology`]). Correlated
    /// fault models ([`crate::sim::fault::CorrelatedDomains`]) use these
    /// as their default domains.
    pub fn num_racks(&self) -> usize {
        self.topo.num_racks()
    }

    /// The rack (failure domain) a node belongs to.
    pub fn rack_of(&self, node: usize) -> usize {
        self.topo.rack_of(node)
    }

    /// Member node ids of one rack, in ascending order.
    pub fn rack_members(&self, rack: usize) -> Vec<usize> {
        self.topo.rack_members(rack)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Dragonfly, DragonflyParams, FatTree};

    #[test]
    fn paper_default_parameters() {
        let p = Platform::paper_default(TorusDims::new(8, 8, 8));
        assert_eq!(p.num_nodes(), 512);
        assert_eq!(p.flops, 6e9);
        assert!((p.bandwidth - 1.25e9).abs() < 1.0);
        assert_eq!(p.latency, 1e-6);
        assert_eq!(p.topology().kind(), "torus");
    }

    #[test]
    fn hop_matrix_consistent_with_torus() {
        let p = Platform::paper_default(TorusDims::new(4, 4, 4));
        let m = p.hop_matrix();
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.len(), 64);
    }

    #[test]
    fn racks_partition_the_platform() {
        let p = Platform::paper_default(TorusDims::new(8, 4, 2));
        assert_eq!(p.num_racks(), 8);
        let mut seen = vec![false; p.num_nodes()];
        for r in 0..p.num_racks() {
            for n in p.rack_members(r) {
                assert_eq!(p.rack_of(n), r);
                assert!(!seen[n], "node {n} in two racks");
                seen[n] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // members are consecutive ids (X-lines)
        assert_eq!(p.rack_members(1), vec![8, 9, 10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn non_torus_platforms_carry_their_topology() {
        let ft = Platform::paper_default_on(Arc::new(FatTree::new(4).unwrap()));
        assert_eq!(ft.num_nodes(), 16);
        assert_eq!(ft.num_racks(), 4);
        assert_eq!(ft.topology().kind(), "fattree");
        assert_eq!(ft.hop_matrix().max(), 6.0);

        let df = Platform::paper_default_on(Arc::new(
            Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap(),
        ));
        assert_eq!(df.num_nodes(), 12);
        assert_eq!(df.num_racks(), 3);
        assert_eq!(df.topology().kind(), "dragonfly");
        // cloning shares the topology
        let clone = df.clone();
        assert_eq!(clone.num_nodes(), 12);
    }

    #[test]
    fn metric_mode_defaults_to_auto_and_is_selectable() {
        let p = Platform::paper_default(TorusDims::new(4, 4, 2));
        assert_eq!(p.metric_mode(), MetricMode::Auto);
        assert!(p.resolved_metric().is_dense(), "32 nodes resolve dense");
        assert!(p.hop_oracle().is_dense());
        assert!(p.try_topo_index().is_ok());

        let imp = p.clone().with_metric(MetricMode::Implicit);
        assert!(!imp.resolved_metric().is_dense());
        assert!(!imp.hop_oracle().is_dense());
        let err = imp.try_topo_index().unwrap_err();
        assert!(err.to_string().contains("implicit metric"), "{err}");
        // the oracle still answers, from closed forms
        assert_eq!(imp.hop_oracle().hops(0, 1), 1.0);
    }

    #[test]
    fn topo_index_is_built_once_and_shared_by_clones() {
        let p = Platform::paper_default(TorusDims::new(4, 4, 2));
        let clone = p.clone();
        assert!(
            std::ptr::eq(p.topo_index(), clone.topo_index()),
            "clones must share one index"
        );
        // index agrees with the allocating hop matrix bit-for-bit
        let hops = p.hop_matrix();
        let clean = p.topo_index().clean_hops();
        for u in 0..p.num_nodes() {
            for v in 0..p.num_nodes() {
                assert_eq!(clean.get(u, v).to_bits(), hops.get(u, v).to_bits());
            }
        }
    }
}
