//! SimGrid-style platform description.
//!
//! Mirrors the components the paper feeds to SimGrid: nodes with a fixed
//! compute capability, links with bandwidth + latency, and a static route
//! for every node pair (provided by the torus DOR routing function). The
//! paper's values: 6 Gflops per node, 10 Gbps and 1 us per link.

use super::distance::DistanceMatrix;
use super::torus::{Torus, TorusDims};

/// Immutable platform description shared by the placement and simulation
/// layers. Fault *state* (which nodes are down in a given scenario) is kept
/// separate — see [`crate::slurm::FaultModel`] — so one platform can be
/// reused across thousands of simulated instances.
#[derive(Debug, Clone)]
pub struct Platform {
    torus: Torus,
    /// Node compute capability in FLOPS.
    pub flops: f64,
    /// Link bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Per-link latency in seconds.
    pub latency: f64,
}

impl Platform {
    /// Platform with the paper's simulation parameters:
    /// 6 Gflops nodes, 10 Gbps links, 1 us latency.
    pub fn paper_default(dims: TorusDims) -> Self {
        Platform {
            torus: Torus::new(dims),
            flops: 6e9,
            bandwidth: 10e9 / 8.0, // 10 Gbps in bytes/s
            latency: 1e-6,
        }
    }

    /// Custom parameters.
    pub fn new(dims: TorusDims, flops: f64, bandwidth_bps: f64, latency_s: f64) -> Self {
        Platform {
            torus: Torus::new(dims),
            flops,
            bandwidth: bandwidth_bps / 8.0,
            latency: latency_s,
        }
    }

    /// Underlying torus (routing function provider).
    pub fn torus(&self) -> &Torus {
        &self.torus
    }

    /// Node count.
    pub fn num_nodes(&self) -> usize {
        self.torus.num_nodes()
    }

    /// Fault-free hop-count distance matrix.
    pub fn hop_matrix(&self) -> DistanceMatrix {
        DistanceMatrix::from_torus_hops(&self.torus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_parameters() {
        let p = Platform::paper_default(TorusDims::new(8, 8, 8));
        assert_eq!(p.num_nodes(), 512);
        assert_eq!(p.flops, 6e9);
        assert!((p.bandwidth - 1.25e9).abs() < 1.0);
        assert_eq!(p.latency, 1e-6);
    }

    #[test]
    fn hop_matrix_consistent_with_torus() {
        let p = Platform::paper_default(TorusDims::new(4, 4, 4));
        let m = p.hop_matrix();
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.len(), 64);
    }
}
