//! 3-D torus topology with dimension-ordered routing (DOR).
//!
//! Node ids are row-major: `id = x + X*(y + Y*z)` so "consecutive node ids"
//! (the window TOFA searches for) are lines along the X dimension, matching
//! how Slurm enumerates nodes sequentially.

use crate::error::{Error, Result};

/// Dimensions of a 3-D torus (each >= 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TorusDims {
    pub x: usize,
    pub y: usize,
    pub z: usize,
}

impl TorusDims {
    /// New dimension triple.
    pub const fn new(x: usize, y: usize, z: usize) -> Self {
        TorusDims { x, y, z }
    }

    /// Total node count.
    pub const fn nodes(&self) -> usize {
        self.x * self.y * self.z
    }

    /// Parse `"8x8x8"` style strings.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<_> = s.split('x').collect();
        if parts.len() != 3 {
            return Err(Error::Topology(format!("bad torus dims: {s}")));
        }
        let mut v = [0usize; 3];
        for (i, p) in parts.iter().enumerate() {
            v[i] = p
                .parse()
                .map_err(|_| Error::Topology(format!("bad torus dims: {s}")))?;
            if v[i] == 0 {
                return Err(Error::Topology(format!("zero dimension in: {s}")));
            }
        }
        Ok(TorusDims::new(v[0], v[1], v[2]))
    }
}

impl std::fmt::Display for TorusDims {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.x, self.y, self.z)
    }
}

/// A directed physical link between two adjacent torus nodes.
///
/// The flow-level simulator treats each direction as an independent
/// capacity (full-duplex links), matching SimGrid's default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Link {
    pub src: usize,
    pub dst: usize,
}

/// 3-D torus with dimension-ordered (X then Y then Z), shortest-wrap
/// routing — the fixed routing function `R(u, v)` of the paper's Section 3.
#[derive(Debug, Clone)]
pub struct Torus {
    dims: TorusDims,
}

impl Torus {
    /// Build a torus.
    pub fn new(dims: TorusDims) -> Self {
        Torus { dims }
    }

    /// Dimensions.
    pub fn dims(&self) -> TorusDims {
        self.dims
    }

    /// Node count.
    pub fn num_nodes(&self) -> usize {
        self.dims.nodes()
    }

    /// Row-major id from coordinates.
    #[inline]
    pub fn id(&self, x: usize, y: usize, z: usize) -> usize {
        debug_assert!(x < self.dims.x && y < self.dims.y && z < self.dims.z);
        x + self.dims.x * (y + self.dims.y * z)
    }

    /// Coordinates from id.
    #[inline]
    pub fn coords(&self, id: usize) -> (usize, usize, usize) {
        debug_assert!(id < self.num_nodes());
        let x = id % self.dims.x;
        let y = (id / self.dims.x) % self.dims.y;
        let z = id / (self.dims.x * self.dims.y);
        (x, y, z)
    }

    /// Failure-domain (rack) count: one rack per X-line. The `dims.x`
    /// nodes sharing a `(y, z)` coordinate have consecutive row-major ids
    /// — matching both how Slurm enumerates a cabinet and how nodes share
    /// power/switch infrastructure. This is the single definition of the
    /// rack grouping; `Platform` and the FATT plugin both delegate here.
    pub fn num_racks(&self) -> usize {
        self.num_nodes() / self.dims.x
    }

    /// The rack (failure domain) a node belongs to.
    #[inline]
    pub fn rack_of(&self, node: usize) -> usize {
        debug_assert!(node < self.num_nodes());
        node / self.dims.x
    }

    /// Member node ids of one rack, in ascending order.
    pub fn rack_members(&self, rack: usize) -> Vec<usize> {
        debug_assert!(rack < self.num_racks());
        (rack * self.dims.x..(rack + 1) * self.dims.x).collect()
    }

    /// Signed shortest displacement from `a` to `b` along a ring of size
    /// `n`: the per-step direction (+1/-1) and the hop count.
    #[inline]
    fn ring_step(a: usize, b: usize, n: usize) -> (i64, usize) {
        if a == b {
            return (0, 0);
        }
        let fwd = (b + n - a) % n; // hops going +1
        let bwd = (a + n - b) % n; // hops going -1
        if fwd <= bwd {
            (1, fwd)
        } else {
            (-1, bwd)
        }
    }

    /// Hop distance between two ring coordinates.
    #[inline]
    fn ring_dist(a: usize, b: usize, n: usize) -> usize {
        let fwd = (b + n - a) % n;
        let bwd = (a + n - b) % n;
        fwd.min(bwd)
    }

    /// Number of hops of the DOR route from `u` to `v` (torus metric).
    #[inline]
    pub fn hops(&self, u: usize, v: usize) -> usize {
        let (ux, uy, uz) = self.coords(u);
        let (vx, vy, vz) = self.coords(v);
        Self::ring_dist(ux, vx, self.dims.x)
            + Self::ring_dist(uy, vy, self.dims.y)
            + Self::ring_dist(uz, vz, self.dims.z)
    }

    /// The routing function `R(u, v)`: ordered list of directed links the
    /// message traverses, correcting X, then Y, then Z, taking the shorter
    /// wrap direction per dimension (fixed & deterministic).
    pub fn route(&self, u: usize, v: usize) -> Vec<Link> {
        let mut links = Vec::with_capacity(self.hops(u, v));
        self.route_into(u, v, &mut links);
        links
    }

    /// Allocation-free variant of [`Torus::route`] for hot loops.
    pub fn route_into(&self, u: usize, v: usize, links: &mut Vec<Link>) {
        links.clear();
        if u == v {
            return;
        }
        let (mut cx, mut cy, mut cz) = self.coords(u);
        let (vx, vy, vz) = self.coords(v);
        let mut cur = u;

        let (dx, nx) = Self::ring_step(cx, vx, self.dims.x);
        for _ in 0..nx {
            cx = Self::step(cx, dx, self.dims.x);
            let nxt = self.id(cx, cy, cz);
            links.push(Link { src: cur, dst: nxt });
            cur = nxt;
        }
        let (dy, ny) = Self::ring_step(cy, vy, self.dims.y);
        for _ in 0..ny {
            cy = Self::step(cy, dy, self.dims.y);
            let nxt = self.id(cx, cy, cz);
            links.push(Link { src: cur, dst: nxt });
            cur = nxt;
        }
        let (dz, nz) = Self::ring_step(cz, vz, self.dims.z);
        for _ in 0..nz {
            cz = Self::step(cz, dz, self.dims.z);
            let nxt = self.id(cx, cy, cz);
            links.push(Link { src: cur, dst: nxt });
            cur = nxt;
        }
        debug_assert_eq!(cur, v);
    }

    #[inline]
    fn step(c: usize, dir: i64, n: usize) -> usize {
        if dir > 0 {
            (c + 1) % n
        } else {
            (c + n - 1) % n
        }
    }

    /// Is coordinate `x` visited walking `from -> to` the shortest-wrap
    /// way around a ring of size `n`? Ties break toward +1, exactly as
    /// [`Torus::ring_step`] does, so the arc is the set of coordinates the
    /// DOR route actually steps through (both endpoints included).
    #[inline]
    fn on_ring_arc(from: usize, to: usize, x: usize, n: usize) -> bool {
        let (dir, hops) = Self::ring_step(from, to, n);
        if dir >= 0 {
            (x + n - from) % n <= hops
        } else {
            (from + n - x) % n <= hops
        }
    }

    /// Closed-form membership test for the DOR route: does `R(u, v)` touch
    /// `node` as a link endpoint? O(1), no route materialization — the
    /// primitive of the implicit metric. Equivalent to scanning
    /// [`Torus::route`] (asserted in tests here and in
    /// `tests/proptests.rs`).
    ///
    /// The DOR route corrects X at `(., y_u, z_u)`, then Y at
    /// `(x_v, ., z_u)`, then Z at `(x_v, y_v, .)`; segment endpoints
    /// overlap at the turn vertices, matching the link-endpoint scan.
    pub fn route_touches(&self, u: usize, v: usize, node: usize) -> bool {
        debug_assert!(node < self.num_nodes());
        if u == v {
            return false;
        }
        let (ux, uy, uz) = self.coords(u);
        let (vx, vy, vz) = self.coords(v);
        let (nx, ny, nz) = self.coords(node);
        (ny == uy && nz == uz && Self::on_ring_arc(ux, vx, nx, self.dims.x))
            || (nx == vx && nz == uz && Self::on_ring_arc(uy, vy, ny, self.dims.y))
            || (nx == vx && ny == vy && Self::on_ring_arc(uz, vz, nz, self.dims.z))
    }

    /// Intermediate nodes (excluding endpoints) on the route `u -> v`.
    /// This is the registry the FATT plugin exports: which nodes serve as
    /// transit hops for a pair.
    pub fn intermediates(&self, u: usize, v: usize) -> Vec<usize> {
        let route = self.route(u, v);
        route
            .iter()
            .map(|l| l.dst)
            .filter(|&n| n != v)
            .collect()
    }

    /// The 6 neighbours of a node (±x, ±y, ±z). For dimensions of size 1
    /// or 2 duplicates are removed.
    pub fn neighbors(&self, id: usize) -> Vec<usize> {
        let (x, y, z) = self.coords(id);
        let mut out = Vec::with_capacity(6);
        let d = self.dims;
        let candidates = [
            self.id((x + 1) % d.x, y, z),
            self.id((x + d.x - 1) % d.x, y, z),
            self.id(x, (y + 1) % d.y, z),
            self.id(x, (y + d.y - 1) % d.y, z),
            self.id(x, y, (z + 1) % d.z),
            self.id(x, y, (z + d.z - 1) % d.z),
        ];
        for c in candidates {
            if c != id && !out.contains(&c) {
                out.push(c);
            }
        }
        out
    }

    /// All directed links in the torus.
    pub fn all_links(&self) -> Vec<Link> {
        let mut links = Vec::new();
        for u in 0..self.num_nodes() {
            for n in self.neighbors(u) {
                links.push(Link { src: u, dst: n });
            }
        }
        links
    }

    /// Dense per-node index of directed links, used by the simulator to
    /// map a `Link` to a contiguous capacity slot. Returns (index map,
    /// number of links) where slot = `index[src * num_nodes + dst]`.
    pub fn link_index(&self) -> (Vec<u32>, usize) {
        let n = self.num_nodes();
        let mut index = vec![u32::MAX; n * n];
        let mut count = 0u32;
        for l in self.all_links() {
            let slot = l.src * n + l.dst;
            if index[slot] == u32::MAX {
                index[slot] = count;
                count += 1;
            }
        }
        (index, count as usize)
    }
}

impl super::Topology for Torus {
    fn kind(&self) -> &'static str {
        "torus"
    }

    fn describe(&self) -> String {
        format!("torus {}", self.dims)
    }

    fn num_nodes(&self) -> usize {
        Torus::num_nodes(self)
    }

    fn hops(&self, u: usize, v: usize) -> usize {
        Torus::hops(self, u, v)
    }

    fn route_into(&self, u: usize, v: usize, links: &mut Vec<Link>) {
        Torus::route_into(self, u, v, links)
    }

    fn route(&self, u: usize, v: usize) -> Vec<Link> {
        Torus::route(self, u, v)
    }

    fn intermediates(&self, u: usize, v: usize) -> Vec<usize> {
        Torus::intermediates(self, u, v)
    }

    fn all_links(&self) -> Vec<Link> {
        Torus::all_links(self)
    }

    fn link_index(&self) -> (Vec<u32>, usize) {
        Torus::link_index(self)
    }

    fn bisection_links(&self) -> usize {
        // halve across the largest ring: two cut planes (the ring wraps),
        // each severing nodes/max_dim full-duplex cables; on a 2-ring the
        // direct and wrap links are the same cable, so only one plane
        let d = self.dims;
        let dmax = d.x.max(d.y).max(d.z);
        let cut = match dmax {
            0 | 1 => 0,
            2 => 1,
            _ => 2,
        };
        2 * cut * (self.num_nodes() / dmax)
    }

    fn num_racks(&self) -> usize {
        Torus::num_racks(self)
    }

    fn rack_of(&self, node: usize) -> usize {
        Torus::rack_of(self, node)
    }

    fn rack_members(&self, rack: usize) -> Vec<usize> {
        Torus::rack_members(self, rack)
    }

    fn salt(&self) -> u64 {
        super::fnv_salt(
            "torus",
            &[self.dims.x as u64, self.dims.y as u64, self.dims.z as u64],
        )
    }

    fn route_touches(&self, u: usize, v: usize, node: usize) -> bool {
        Torus::route_touches(self, u, v, node)
    }

    fn as_torus(&self) -> Option<&Torus> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_dims() {
        assert_eq!(TorusDims::parse("8x8x8").unwrap(), TorusDims::new(8, 8, 8));
        assert_eq!(
            TorusDims::parse("4x32x4").unwrap(),
            TorusDims::new(4, 32, 4)
        );
        assert!(TorusDims::parse("8x8").is_err());
        assert!(TorusDims::parse("0x8x8").is_err());
        assert!(TorusDims::parse("axbxc").is_err());
    }

    #[test]
    fn route_touches_matches_routed_scan_exhaustively() {
        // even dims exercise the fwd == bwd tie-break, 1/2-sized dims the
        // degenerate rings
        for dims in [
            TorusDims::new(4, 4, 1),
            TorusDims::new(5, 3, 2),
            TorusDims::new(2, 2, 2),
            TorusDims::new(1, 1, 1),
            TorusDims::new(6, 1, 4),
        ] {
            let t = Torus::new(dims);
            let n = t.num_nodes();
            for u in 0..n {
                for v in 0..n {
                    let route = t.route(u, v);
                    for node in 0..n {
                        let scanned = route.iter().any(|l| l.src == node || l.dst == node);
                        assert_eq!(
                            t.route_touches(u, v, node),
                            scanned,
                            "{dims} ({u},{v}) node {node}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn id_coords_roundtrip() {
        let t = Torus::new(TorusDims::new(4, 8, 16));
        for id in 0..t.num_nodes() {
            let (x, y, z) = t.coords(id);
            assert_eq!(t.id(x, y, z), id);
        }
    }

    #[test]
    fn consecutive_ids_are_x_lines() {
        let t = Torus::new(TorusDims::new(8, 8, 8));
        // ids 0..8 share y=0,z=0
        for id in 0..8 {
            let (x, y, z) = t.coords(id);
            assert_eq!((x, y, z), (id, 0, 0));
        }
        assert_eq!(t.coords(8), (0, 1, 0));
    }

    #[test]
    fn hops_symmetric_and_triangle() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let n = t.num_nodes();
        for u in 0..n {
            assert_eq!(t.hops(u, u), 0);
            for v in 0..n {
                assert_eq!(t.hops(u, v), t.hops(v, u));
                for w in (0..n).step_by(7) {
                    assert!(t.hops(u, v) <= t.hops(u, w) + t.hops(w, v));
                }
            }
        }
    }

    #[test]
    fn max_hops_matches_torus_diameter() {
        let t = Torus::new(TorusDims::new(8, 8, 8));
        let max = (0..512)
            .flat_map(|u| (0..512).map(move |v| (u, v)))
            .map(|(u, v)| t.hops(u, v))
            .max()
            .unwrap();
        assert_eq!(max, 12); // 3 * floor(8/2)
    }

    #[test]
    fn route_length_equals_hops() {
        let t = Torus::new(TorusDims::new(4, 8, 2));
        for u in (0..t.num_nodes()).step_by(3) {
            for v in (0..t.num_nodes()).step_by(5) {
                let r = t.route(u, v);
                assert_eq!(r.len(), t.hops(u, v), "u={u} v={v}");
            }
        }
    }

    #[test]
    fn route_is_connected_path() {
        let t = Torus::new(TorusDims::new(8, 8, 8));
        let r = t.route(0, 511);
        assert_eq!(r.first().unwrap().src, 0);
        assert_eq!(r.last().unwrap().dst, 511);
        for w in r.windows(2) {
            assert_eq!(w[0].dst, w[1].src);
        }
        // every step is between physical neighbours
        for l in &r {
            assert!(t.neighbors(l.src).contains(&l.dst));
        }
    }

    #[test]
    fn route_uses_wraparound() {
        let t = Torus::new(TorusDims::new(8, 1, 1));
        // 0 -> 7 should wrap backwards: 1 hop.
        assert_eq!(t.hops(0, 7), 1);
        let r = t.route(0, 7);
        assert_eq!(r, vec![Link { src: 0, dst: 7 }]);
    }

    #[test]
    fn intermediates_exclude_endpoints() {
        let t = Torus::new(TorusDims::new(8, 8, 8));
        let inter = t.intermediates(0, 3);
        assert_eq!(inter, vec![1, 2]);
        assert!(t.intermediates(0, 1).is_empty());
        assert!(t.intermediates(5, 5).is_empty());
    }

    #[test]
    fn neighbor_counts() {
        let t = Torus::new(TorusDims::new(8, 8, 8));
        for id in 0..t.num_nodes() {
            assert_eq!(t.neighbors(id).len(), 6);
        }
        // size-2 dims collapse +/- into one neighbour
        let t2 = Torus::new(TorusDims::new(2, 2, 2));
        for id in 0..t2.num_nodes() {
            assert_eq!(t2.neighbors(id).len(), 3);
        }
    }

    #[test]
    fn link_index_is_dense() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let (index, count) = t.link_index();
        let mut seen = vec![false; count];
        for slot in index.iter().filter(|&&s| s != u32::MAX) {
            seen[*slot as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(count, t.all_links().len());
    }

    #[test]
    fn routes_stay_within_neighbors() {
        // DOR on asymmetric dims
        let t = Torus::new(TorusDims::new(4, 32, 4));
        let r = t.route(3, 400);
        for l in &r {
            assert!(t.neighbors(l.src).contains(&l.dst));
        }
        assert_eq!(r.len(), t.hops(3, 400));
    }
}
