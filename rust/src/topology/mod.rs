//! Platform topology: pluggable interconnect models, routing, distance
//! matrices, and SimGrid-style platform descriptions.
//!
//! The paper evaluates on a single 3-D torus, but its core claim — express
//! the system as a graph, minimize hop-bytes — is topology-generic. This
//! module therefore defines the [`Topology`] trait (routing function
//! `R(u, v)`, hop metric, link enumeration, failure-domain decomposition)
//! with three implementations:
//!
//! * [`Torus`] — the paper's 3-D torus with dimension-ordered routing;
//! * [`FatTree`] — k-ary fat-tree (pods → edge/aggregation/core layers);
//! * [`Dragonfly`] — router groups with all-to-all global links (Cray
//!   Aries parameterization).
//!
//! Everything above this module ([`crate::tofa`], [`crate::sim`],
//! [`crate::mapping`], the Slurm-lite plugins) consumes the trait, so the
//! whole pipeline — placement, flow simulation, correlated fault domains —
//! runs unchanged on any of the three.

pub mod distance;
pub mod dragonfly;
pub mod fattree;
pub mod graph;
pub mod index;
pub mod metric;
pub mod platform;
pub mod torus;

pub use distance::DistanceMatrix;
pub use dragonfly::{Dragonfly, DragonflyParams};
pub use fattree::FatTree;
pub use graph::ArchGraph;
pub use index::{CostWorkspace, TopoIndex};
pub use metric::{HopOracle, MetricMode, ResolvedMetric, DENSE_NODE_LIMIT};
pub use platform::Platform;
pub use torus::{Link, Torus, TorusDims};

/// A network topology: compute nodes (rank hosts, ids `0..num_nodes`)
/// plus, for indirect networks, switch/router vertices (ids
/// `num_nodes..num_vertices`) that carry transit traffic but never host
/// ranks and never fail.
///
/// Implementations must be pure and deterministic: the routing function is
/// fixed (`route_into(u, v)` always returns the same link sequence), which
/// is what lets the flow simulator, the Eq. 1 re-weighting, and the FATT
/// plugin's transit registry agree — and what preserves the batch engine's
/// bit-identical-for-any-worker-count contract on every topology.
pub trait Topology: std::fmt::Debug + Send + Sync {
    /// Topology family name (`"torus"`, `"fattree"`, `"dragonfly"`).
    fn kind(&self) -> &'static str;

    /// Human-readable parameter summary (e.g. `"torus 8x8x8"`).
    fn describe(&self) -> String;

    /// Compute-node count (rank hosts). Node ids are `0..num_nodes()` and
    /// enumerate the platform the way Slurm lists it, so "consecutive ids"
    /// (the TOFA window) are physically close under every implementation.
    fn num_nodes(&self) -> usize;

    /// Total vertex count including switches/routers. Direct networks
    /// (torus) have `num_vertices == num_nodes`.
    fn num_vertices(&self) -> usize {
        self.num_nodes()
    }

    /// Hop distance between two compute nodes: the length of
    /// `route_into(u, v)`. Must be a metric on the node set — symmetric,
    /// zero iff `u == v`, and triangle-inequality-consistent (asserted for
    /// all implementations in `tests/proptests.rs`).
    fn hops(&self, u: usize, v: usize) -> usize;

    /// The routing function `R(u, v)`: the ordered directed links a
    /// message traverses, over vertex ids (switch hops included).
    fn route_into(&self, u: usize, v: usize, links: &mut Vec<Link>);

    /// Allocating variant of [`Topology::route_into`].
    fn route(&self, u: usize, v: usize) -> Vec<Link> {
        let mut links = Vec::new();
        self.route_into(u, v, &mut links);
        links
    }

    /// Intermediate vertices (excluding endpoints) on the route `u -> v` —
    /// the transit registry the FATT plugin exports.
    fn intermediates(&self, u: usize, v: usize) -> Vec<usize> {
        self.route(u, v)
            .iter()
            .map(|l| l.dst)
            .filter(|&n| n != v)
            .collect()
    }

    /// All directed physical links (both directions of every cable).
    fn all_links(&self) -> Vec<Link>;

    /// Dense index of directed links: `(index, count)` with slot
    /// `index[src * num_vertices + dst]`, used by the flow simulator to
    /// map a [`Link`] to a contiguous capacity slot.
    fn link_index(&self) -> (Vec<u32>, usize) {
        let n = self.num_vertices();
        let mut index = vec![u32::MAX; n * n];
        let mut count = 0u32;
        for l in self.all_links() {
            let slot = l.src * n + l.dst;
            if index[slot] == u32::MAX {
                index[slot] = count;
                count += 1;
            }
        }
        (index, count as usize)
    }

    /// Relative capacity of the directed link `src -> dst` (contention
    /// weight): the flow simulator provisions `bandwidth * scale` on the
    /// link. 1.0 everywhere for uniform fabrics (torus, fat-tree); the
    /// dragonfly's global optical links report > 1.
    fn link_capacity_scale(&self, src: usize, dst: usize) -> f64 {
        let _ = (src, dst);
        1.0
    }

    /// Number of directed links crossing the topology's canonical halving
    /// — a contention figure of merit reported by `benches/topologies.rs`
    /// (not used by the simulator, which models every link individually).
    fn bisection_links(&self) -> usize;

    /// Failure-domain (rack) count. Racks are the shared-infrastructure
    /// groups correlated fault models take down as a unit: X-lines on the
    /// torus, pods on the fat-tree, groups on the dragonfly.
    fn num_racks(&self) -> usize;

    /// The rack (failure domain) a compute node belongs to.
    fn rack_of(&self, node: usize) -> usize;

    /// Member node ids of one rack, in ascending order. Racks partition
    /// the node set exactly (asserted in `tests/proptests.rs`).
    fn rack_members(&self, rack: usize) -> Vec<usize> {
        (0..self.num_nodes())
            .filter(|&n| self.rack_of(n) == rack)
            .collect()
    }

    /// FNV-1a hash over the topology family and its parameters — mixed
    /// into the shared phase-cache key so simulators on different
    /// platforms never collide.
    fn salt(&self) -> u64;

    /// Does the fixed route `R(u, v)` touch compute node `node` as a link
    /// endpoint? The pair endpoints count (`u` and `v` bound the first and
    /// last link), and `u == v` has an empty route touching nothing.
    ///
    /// `node` must be a compute node (`node < num_nodes()`): switches and
    /// routers never fail, so no fault-path consumer asks about them.
    ///
    /// This is the primitive of the implicit metric
    /// ([`metric::HopOracle`]): the default routes and scans, but the
    /// in-tree families override it with O(1) closed forms (equivalence
    /// with the routed ground truth is asserted per family in
    /// `tests/proptests.rs`).
    fn route_touches(&self, u: usize, v: usize, node: usize) -> bool {
        debug_assert!(node < self.num_nodes(), "route_touches asked about a switch");
        if u == v {
            return false;
        }
        if node == u || node == v {
            return true;
        }
        self.route(u, v).iter().any(|l| l.src == node || l.dst == node)
    }

    /// Downcast escape hatch for torus-only artifacts (the FATT topology
    /// file format stores torus coordinates).
    fn as_torus(&self) -> Option<&Torus> {
        None
    }
}

/// FNV-1a over a kind tag and parameter words (helper for
/// [`Topology::salt`] implementations).
pub(crate) fn fnv_salt(kind: &str, words: &[u64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut feed = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    };
    for b in kind.bytes() {
        feed(b as u64);
    }
    for &w in words {
        feed(w);
    }
    h
}

#[cfg(test)]
mod trait_tests {
    use super::*;

    /// Minimal direct topology (a path graph) relying on every default
    /// trait method: route, intermediates, link_index, rack_members.
    #[derive(Debug)]
    struct Line(usize);

    impl Topology for Line {
        fn kind(&self) -> &'static str {
            "line"
        }
        fn describe(&self) -> String {
            format!("line {}", self.0)
        }
        fn num_nodes(&self) -> usize {
            self.0
        }
        fn hops(&self, u: usize, v: usize) -> usize {
            u.abs_diff(v)
        }
        fn route_into(&self, u: usize, v: usize, links: &mut Vec<Link>) {
            links.clear();
            let step = |c: usize| if v > c { c + 1 } else { c - 1 };
            let mut cur = u;
            while cur != v {
                let nxt = step(cur);
                links.push(Link { src: cur, dst: nxt });
                cur = nxt;
            }
        }
        fn all_links(&self) -> Vec<Link> {
            (0..self.0 - 1)
                .flat_map(|i| {
                    [Link { src: i, dst: i + 1 }, Link { src: i + 1, dst: i }]
                })
                .collect()
        }
        fn bisection_links(&self) -> usize {
            2
        }
        fn num_racks(&self) -> usize {
            1
        }
        fn rack_of(&self, _node: usize) -> usize {
            0
        }
        fn salt(&self) -> u64 {
            fnv_salt("line", &[self.0 as u64])
        }
    }

    #[test]
    fn default_trait_methods_are_consistent() {
        let l = Line(6);
        assert_eq!(l.route(1, 4).len(), 3);
        assert_eq!(l.intermediates(1, 4), vec![2, 3]);
        assert!(l.intermediates(1, 2).is_empty());
        let (index, count) = l.link_index();
        assert_eq!(count, 10);
        let mut seen = vec![false; count];
        for slot in index.iter().filter(|&&s| s != u32::MAX) {
            seen[*slot as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(l.rack_members(0), (0..6).collect::<Vec<_>>());
        assert_eq!(l.num_vertices(), 6);
        assert_eq!(l.link_capacity_scale(0, 1), 1.0);
        assert!(l.as_torus().is_none());
        // default route_touches: route-and-scan over the path graph
        assert!(l.route_touches(1, 4, 2), "transit node");
        assert!(l.route_touches(1, 4, 1) && l.route_touches(1, 4, 4), "endpoints");
        assert!(!l.route_touches(1, 4, 5), "off-path node");
        assert!(!l.route_touches(3, 3, 3), "empty route touches nothing");
    }

    #[test]
    fn salts_differ_across_families_and_params() {
        let a: &dyn Topology = &Torus::new(TorusDims::new(8, 8, 8));
        let b: &dyn Topology = &FatTree::new(8).unwrap();
        let c: &dyn Topology = &Dragonfly::new(DragonflyParams::new(9, 4, 4, 2)).unwrap();
        let d: &dyn Topology = &Torus::new(TorusDims::new(4, 8, 16));
        let salts = [a.salt(), b.salt(), c.salt(), d.salt()];
        for i in 0..salts.len() {
            for j in (i + 1)..salts.len() {
                assert_ne!(salts[i], salts[j], "{i} vs {j}");
            }
        }
    }
}
