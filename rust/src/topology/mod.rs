//! Platform topology: 3-D torus model, dimension-ordered routing, distance
//! matrices, and SimGrid-style platform descriptions.
//!
//! This module is the substrate behind the paper's **FATT** (Fault-Aware
//! Torus Topology) plugin: it provides the routing function `R(u, v)` (the
//! exact list of links a message traverses) plus a graph representation of
//! the platform, which [`crate::tofa`] re-weights per Eq. 1.

pub mod distance;
pub mod graph;
pub mod platform;
pub mod torus;

pub use distance::DistanceMatrix;
pub use graph::ArchGraph;
pub use platform::Platform;
pub use torus::{Link, Torus, TorusDims};
