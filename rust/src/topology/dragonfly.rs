//! Dragonfly topology (Kim/Dally construction, Cray Aries parameterization).
//!
//! `g` groups of `a` routers; each router hosts `p` compute nodes and owns
//! `h` global links. Routers within a group are fully connected (the Aries
//! all-to-all local fabric); the groups themselves are fully connected by
//! the global links, so `g - 1 <= a * h` is required. Global optical links
//! carry a higher capacity than local electrical ones
//! ([`Topology::link_capacity_scale`] reports 2x, the Aries ratio).
//!
//! Node ids enumerate group-major then router-major, so consecutive ids
//! share a router / group — the locality contract the TOFA window search
//! relies on. Minimal routing: node → router, at most one local hop to the
//! gateway router, one global hop, at most one local hop, router → node;
//! hop distances are 0 / 2 (same router) / 3 (same group) / 3-5 (across
//! groups).

use super::torus::Link;
use super::Topology;
use crate::error::{Error, Result};

/// Dragonfly parameters: `g` groups x `a` routers x `p` nodes, `h` global
/// links per router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DragonflyParams {
    /// Group count.
    pub groups: usize,
    /// Routers per group.
    pub routers: usize,
    /// Compute nodes per router.
    pub hosts: usize,
    /// Global links per router.
    pub globals: usize,
}

impl DragonflyParams {
    /// New parameter tuple (validated by [`Dragonfly::new`]).
    pub const fn new(groups: usize, routers: usize, hosts: usize, globals: usize) -> Self {
        DragonflyParams {
            groups,
            routers,
            hosts,
            globals,
        }
    }

    /// Parse `"9x4x4x2"` (groups x routers x hosts x globals).
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<_> = s.split('x').collect();
        if parts.len() != 4 {
            return Err(Error::Topology(format!(
                "bad dragonfly spec (want GxAxPxH): {s}"
            )));
        }
        let mut v = [0usize; 4];
        for (i, p) in parts.iter().enumerate() {
            v[i] = p
                .parse()
                .map_err(|_| Error::Topology(format!("bad dragonfly spec: {s}")))?;
        }
        Ok(DragonflyParams::new(v[0], v[1], v[2], v[3]))
    }

    /// Total compute nodes `g * a * p`.
    pub const fn nodes(&self) -> usize {
        self.groups * self.routers * self.hosts
    }
}

impl std::fmt::Display for DragonflyParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}x{}x{}x{}",
            self.groups, self.routers, self.hosts, self.globals
        )
    }
}

/// Dragonfly network over `g * a * p` compute nodes and `g * a` routers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dragonfly {
    params: DragonflyParams,
}

impl Dragonfly {
    /// Build a dragonfly; every parameter must be >= 1 and the global
    /// links must suffice for the all-to-all group fabric
    /// (`g - 1 <= a * h`).
    pub fn new(params: DragonflyParams) -> Result<Self> {
        let DragonflyParams {
            groups,
            routers,
            hosts,
            globals,
        } = params;
        if groups == 0 || routers == 0 || hosts == 0 || globals == 0 {
            return Err(Error::Topology(format!(
                "dragonfly parameters must all be >= 1: {params}"
            )));
        }
        if groups > 1 && groups - 1 > routers * globals {
            return Err(Error::Topology(format!(
                "dragonfly {params}: {} groups need g-1 <= a*h = {} global slots",
                groups,
                routers * globals
            )));
        }
        Ok(Dragonfly { params })
    }

    /// The parameter tuple.
    pub fn params(&self) -> DragonflyParams {
        self.params
    }

    fn num_nodes(&self) -> usize {
        self.params.nodes()
    }

    /// Group of a compute node.
    #[inline]
    pub fn group_of(&self, node: usize) -> usize {
        node / (self.params.routers * self.params.hosts)
    }

    /// Global router index (0..g*a) of the router hosting `node`.
    #[inline]
    fn router_of(&self, node: usize) -> usize {
        node / self.params.hosts
    }

    /// Vertex id of global router index `r`.
    #[inline]
    fn router_vertex(&self, r: usize) -> usize {
        self.num_nodes() + r
    }

    /// The router in `from` group owning the global link toward `to`
    /// (its global router index). The link for group pair `(i, j)` uses
    /// slot `j - 1` on `i`'s side if `j > i` else slot `j`, and slots map
    /// to routers `slot / h` — the standard consecutive assignment, fixed
    /// so both directions name the same physical cable.
    #[inline]
    fn gateway(&self, from: usize, to: usize) -> usize {
        debug_assert_ne!(from, to);
        let slot = if to > from { to - 1 } else { to };
        from * self.params.routers + slot / self.params.globals
    }
}

impl Topology for Dragonfly {
    fn kind(&self) -> &'static str {
        "dragonfly"
    }

    fn describe(&self) -> String {
        format!("dragonfly {} ({} nodes)", self.params, self.num_nodes())
    }

    fn num_nodes(&self) -> usize {
        Dragonfly::num_nodes(self)
    }

    fn num_vertices(&self) -> usize {
        self.num_nodes() + self.params.groups * self.params.routers
    }

    fn hops(&self, u: usize, v: usize) -> usize {
        if u == v {
            return 0;
        }
        let (ru, rv) = (self.router_of(u), self.router_of(v));
        if ru == rv {
            return 2;
        }
        let (gu, gv) = (self.group_of(u), self.group_of(v));
        if gu == gv {
            return 3;
        }
        let (wu, wv) = (self.gateway(gu, gv), self.gateway(gv, gu));
        3 + usize::from(ru != wu) + usize::from(rv != wv)
    }

    fn route_into(&self, u: usize, v: usize, links: &mut Vec<Link>) {
        links.clear();
        if u == v {
            return;
        }
        // waypoint vertices of the minimal route (at most 6)
        let mut way = [0usize; 6];
        let mut k = 0;
        let at = |way: &mut [usize; 6], k: &mut usize, w: usize| {
            way[*k] = w;
            *k += 1;
        };
        let (ru, rv) = (self.router_of(u), self.router_of(v));
        at(&mut way, &mut k, u);
        at(&mut way, &mut k, self.router_vertex(ru));
        if ru != rv {
            let (gu, gv) = (self.group_of(u), self.group_of(v));
            if gu != gv {
                let (wu, wv) = (self.gateway(gu, gv), self.gateway(gv, gu));
                if ru != wu {
                    at(&mut way, &mut k, self.router_vertex(wu)); // local to gateway
                }
                at(&mut way, &mut k, self.router_vertex(wv)); // global hop
                if wv != rv {
                    at(&mut way, &mut k, self.router_vertex(rv)); // local to dest
                }
            } else {
                at(&mut way, &mut k, self.router_vertex(rv)); // local all-to-all
            }
        }
        at(&mut way, &mut k, v);
        for w in way[..k].windows(2) {
            links.push(Link { src: w[0], dst: w[1] });
        }
        debug_assert_eq!(links.len(), self.hops(u, v));
    }

    fn all_links(&self) -> Vec<Link> {
        let p = self.params;
        let mut links = Vec::new();
        let both = |a: usize, b: usize, links: &mut Vec<Link>| {
            links.push(Link { src: a, dst: b });
            links.push(Link { src: b, dst: a });
        };
        for n in 0..self.num_nodes() {
            both(n, self.router_vertex(self.router_of(n)), &mut links);
        }
        for g in 0..p.groups {
            for r1 in 0..p.routers {
                for r2 in (r1 + 1)..p.routers {
                    both(
                        self.router_vertex(g * p.routers + r1),
                        self.router_vertex(g * p.routers + r2),
                        &mut links,
                    );
                }
            }
        }
        for g1 in 0..p.groups {
            for g2 in (g1 + 1)..p.groups {
                both(
                    self.router_vertex(self.gateway(g1, g2)),
                    self.router_vertex(self.gateway(g2, g1)),
                    &mut links,
                );
            }
        }
        links
    }

    fn link_capacity_scale(&self, src: usize, dst: usize) -> f64 {
        // global (inter-group) router-router links are the fat optical
        // pipes of the Aries fabric: 2x the local electrical capacity
        let n = self.num_nodes();
        if src >= n && dst >= n {
            let per_group = self.params.routers;
            if (src - n) / per_group != (dst - n) / per_group {
                return 2.0;
            }
        }
        1.0
    }

    fn bisection_links(&self) -> usize {
        // halving the groups cuts ceil(g/2)*floor(g/2) global cables
        let g = self.params.groups;
        2 * (g / 2) * g.div_ceil(2)
    }

    fn num_racks(&self) -> usize {
        self.params.groups
    }

    fn rack_of(&self, node: usize) -> usize {
        self.group_of(node)
    }

    fn rack_members(&self, rack: usize) -> Vec<usize> {
        let per_group = self.params.routers * self.params.hosts;
        (rack * per_group..(rack + 1) * per_group).collect()
    }

    fn route_touches(&self, u: usize, v: usize, node: usize) -> bool {
        debug_assert!(node < Dragonfly::num_nodes(self));
        // minimal routes transit routers only (asserted in
        // routes_match_hops_and_are_connected), so a compute node is on
        // R(u, v) iff it is an endpoint of a non-empty route
        u != v && (node == u || node == v)
    }

    fn salt(&self) -> u64 {
        super::fnv_salt(
            "dragonfly",
            &[
                self.params.groups as u64,
                self.params.routers as u64,
                self.params.hosts as u64,
                self.params.globals as u64,
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Dragonfly {
        // 3 groups x 2 routers x 2 hosts, 1 global link per router
        Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap()
    }

    #[test]
    fn construction_and_counts() {
        let d = small();
        assert_eq!(Topology::num_nodes(&d), 12);
        assert_eq!(d.num_vertices(), 12 + 6);
        assert_eq!(d.num_racks(), 3);
        assert!(Dragonfly::new(DragonflyParams::new(9, 2, 2, 1)).is_err()); // 8 > 2*1
        assert!(Dragonfly::new(DragonflyParams::new(0, 2, 2, 1)).is_err());
        assert_eq!(
            DragonflyParams::parse("9x4x4x2").unwrap(),
            DragonflyParams::new(9, 4, 4, 2)
        );
        assert!(DragonflyParams::parse("9x4x4").is_err());
    }

    #[test]
    fn hop_tiers() {
        let d = small();
        assert_eq!(d.hops(0, 0), 0);
        assert_eq!(d.hops(0, 1), 2); // same router
        assert_eq!(d.hops(0, 2), 3); // same group, other router
        let cross = d.hops(0, 4); // other group
        assert!((3..=5).contains(&cross), "cross-group hops {cross}");
    }

    #[test]
    fn routes_match_hops_and_are_connected() {
        let d = Dragonfly::new(DragonflyParams::new(5, 4, 2, 1)).unwrap();
        let n = Topology::num_nodes(&d);
        for u in 0..n {
            for v in 0..n {
                let r = d.route(u, v);
                assert_eq!(r.len(), d.hops(u, v), "{u}->{v}");
                if u != v {
                    assert_eq!(r.first().unwrap().src, u);
                    assert_eq!(r.last().unwrap().dst, v);
                    for w in r.windows(2) {
                        assert_eq!(w[0].dst, w[1].src);
                    }
                    for l in &r[..r.len() - 1] {
                        assert!(l.dst >= n, "{u}->{v} transits compute node {}", l.dst);
                    }
                }
            }
        }
    }

    #[test]
    fn routes_use_physical_links_only() {
        let d = Dragonfly::new(DragonflyParams::new(4, 2, 3, 2)).unwrap();
        let n = Topology::num_nodes(&d);
        let mut physical = std::collections::HashSet::new();
        for l in d.all_links() {
            physical.insert((l.src, l.dst));
        }
        for u in 0..n {
            for v in 0..n {
                for l in d.route(u, v) {
                    assert!(physical.contains(&(l.src, l.dst)), "{u}->{v}: {l:?}");
                }
            }
        }
    }

    #[test]
    fn route_touches_matches_routed_scan() {
        let d = Dragonfly::new(DragonflyParams::new(5, 4, 2, 1)).unwrap();
        let n = Topology::num_nodes(&d);
        for u in 0..n {
            for v in 0..n {
                let route = d.route(u, v);
                for node in 0..n {
                    let scanned = route.iter().any(|l| l.src == node || l.dst == node);
                    assert_eq!(d.route_touches(u, v, node), scanned, "({u},{v}) node {node}");
                }
            }
        }
    }

    #[test]
    fn global_links_are_fatter() {
        let d = small();
        // group 0 -> group 1 cable: gateway(0,1) owns slot 0
        let a = d.router_vertex(d.gateway(0, 1));
        let b = d.router_vertex(d.gateway(1, 0));
        assert_eq!(d.link_capacity_scale(a, b), 2.0);
        // node-to-router and intra-group links stay at 1x
        assert_eq!(d.link_capacity_scale(0, d.router_vertex(0)), 1.0);
        assert_eq!(
            d.link_capacity_scale(d.router_vertex(0), d.router_vertex(1)),
            1.0
        );
    }

    #[test]
    fn groups_are_contiguous_racks() {
        let d = small();
        assert_eq!(d.rack_members(0), vec![0, 1, 2, 3]);
        assert_eq!(d.rack_members(2), vec![8, 9, 10, 11]);
        for node in 0..12 {
            assert_eq!(d.rack_of(node), node / 4);
        }
    }
}
