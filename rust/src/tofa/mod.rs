//! TOFA — the paper's TOpology and Fault-Aware placement approach.
//!
//! Three pieces, mirroring Section 3:
//! * [`eq1`] — fault-aware edge re-weighting of the topology graph
//!   (Equation 1): a path's cost counts 1 per hop, or 100 per hop for any
//!   link touching a node with non-zero outage probability.
//! * [`window`] — the search for `|V_G|` *consecutive* fault-free nodes
//!   (step 10 of Listing 1.1).
//! * [`placer`] — the TOFA procedure: extract the window sub-topology and
//!   map into it, or fall back to mapping over the fault-weighted full
//!   topology.
//!
//! Both cost kernels come in two flavors: a dense reference
//! ([`eq1::fault_aware_distance`], [`window::find_route_clean_window`])
//! that re-routes everything, and the incremental engines
//! ([`eq1::fault_aware_distance_indexed`],
//! [`window::find_route_clean_window_indexed`]) that run on the platform's
//! shared [`crate::topology::TopoIndex`] and touch only what faults
//! perturb. The placer uses the incremental engines; they are bit-
//! identical to the references (asserted in `tests/proptests.rs`).

pub mod eq1;
pub mod placer;
pub mod window;

pub use eq1::{fault_aware_distance, fault_aware_distance_indexed};
pub use placer::{TofaConfig, TofaPlacer};
pub use window::{find_fault_free_window, find_route_clean_window_indexed};
