//! TOFA — the paper's TOpology and Fault-Aware placement approach.
//!
//! Three pieces, mirroring Section 3:
//! * [`eq1`] — fault-aware edge re-weighting of the topology graph
//!   (Equation 1): a path's cost counts 1 per hop, or 100 per hop for any
//!   link touching a node with non-zero outage probability.
//! * [`window`] — the search for `|V_G|` *consecutive* fault-free nodes
//!   (step 10 of Listing 1.1).
//! * [`placer`] — the TOFA procedure: extract the window sub-topology and
//!   map into it, or fall back to mapping over the fault-weighted full
//!   topology.

pub mod eq1;
pub mod placer;
pub mod window;

pub use eq1::fault_aware_distance;
pub use placer::{TofaConfig, TofaPlacer};
pub use window::find_fault_free_window;
