//! The TOFA placement procedure (Listing 1.1 of the paper).
//!
//! ```text
//! procedure TOFA(G, H):
//!   S = find |V_G| consecutive nodes s.t. p_f(n) = 0
//!   if S == {}:  T = ScotchMap(G, H)          # fault-weighted full map
//!   else:        H_s = ScotchExtract(H, S)
//!                T = ScotchMap(G, H_s)         # map inside the window
//! ```

use std::sync::Mutex;

use super::eq1::{fault_aware_distance_indexed, fault_aware_submatrix};
use super::window::{
    find_fault_free_window, find_fault_free_window_masked, find_route_clean_window_implicit,
    find_route_clean_window_indexed, find_route_clean_window_masked,
    find_route_clean_window_masked_implicit,
};
use crate::error::Error;
use crate::commgraph::CommMatrix;
use crate::error::Result;
use crate::mapping::recmap::RecursiveMapper;
use crate::mapping::Placement;
use crate::topology::metric::check_materialize;
use crate::topology::{CostWorkspace, DistanceMatrix, Platform};

/// Tunables of the TOFA pipeline.
#[derive(Debug, Clone)]
pub struct TofaConfig {
    /// Underlying graph mapper configuration.
    pub mapper: RecursiveMapper,
}

impl Default for TofaConfig {
    fn default() -> Self {
        TofaConfig {
            mapper: RecursiveMapper::default(),
        }
    }
}

/// How a placement was derived — reported in experiment logs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TofaPath {
    /// A consecutive fault-free window was found; mapped inside it.
    Window,
    /// No window; mapped over the Eq. 1 fault-weighted full topology.
    FaultWeighted,
    /// No outage information at all (all zero): plain topology mapping.
    FaultFree,
}

/// Result of a TOFA placement.
#[derive(Debug, Clone)]
pub struct TofaPlacement {
    /// rank -> node assignment.
    pub assignment: Vec<usize>,
    /// Which path of Listing 1.1 produced it.
    pub path: TofaPath,
}

/// The TOFA placer.
///
/// Runs on whichever distance source the platform's metric mode resolves
/// to ([`Platform::hop_oracle`]): the shared dense
/// [`crate::topology::TopoIndex`] (clean hop matrix + transit-incidence
/// lists) or the implicit closed-form metric, which serves the same
/// values with O(n) memory. A per-placer [`CostWorkspace`] (behind a
/// `Mutex` so the placer stays `Sync` for the parallel batch engine; each
/// worker's runner clone owns its own placer, so the lock is never
/// contended) makes the window search and Eq. 1 allocation-free: the
/// flaky-node buffers the two engines used to allocate per call are
/// hoisted here and reused across every `place()` of this placer.
#[derive(Debug, Default)]
pub struct TofaPlacer {
    config: TofaConfig,
    ws: Mutex<CostWorkspace>,
}

impl Clone for TofaPlacer {
    fn clone(&self) -> Self {
        // scratch is per-instance; clones start with fresh buffers
        TofaPlacer {
            config: self.config.clone(),
            ws: Mutex::new(CostWorkspace::new()),
        }
    }
}

impl TofaPlacer {
    /// Build with a config.
    pub fn new(config: TofaConfig) -> Self {
        TofaPlacer {
            config,
            ws: Mutex::new(CostWorkspace::new()),
        }
    }

    /// Place `comm` on `platform` given per-node outage probability
    /// estimates (from the Fault-Aware Slurmctld heartbeat history).
    pub fn place(
        &self,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
    ) -> Result<TofaPlacement> {
        let n = comm.len();
        let topo = platform.topology();
        // the platform's distance source: a shared dense TopoIndex (built
        // once, like the phase cache) or the on-demand implicit metric
        let oracle = platform.hop_oracle();

        if outage.iter().all(|&p| p <= 0.0) {
            // Nothing flaky: Listing 1.1 still finds S (trivially the
            // first |V_G| node ids) and maps inside that window.
            let window: Vec<usize> = (0..n).collect();
            let sub = oracle.extract(&window);
            let local = self.config.mapper.map(comm, &sub)?;
            let assignment = local.assignment.iter().map(|&li| window[li]).collect();
            return Ok(TofaPlacement {
                assignment,
                path: TofaPath::FaultFree,
            });
        }

        // one workspace for both engines: the flaky view of `outage` is
        // built once here instead of once per callee. A poisoned lock is
        // recovered: the workspace is pure scratch, fully rebuilt by each
        // user, so a panic mid-fill on another thread leaves nothing to
        // protect against.
        let mut ws = self.ws.lock().unwrap_or_else(|poisoned| poisoned.into_inner());

        // Prefer a window whose route closure is flaky-free (zero abort
        // guarantee); fall back to any endpoint-clean window.
        let window = match oracle.index() {
            Some(index) => find_route_clean_window_indexed(index, outage, n, &mut ws),
            None => find_route_clean_window_implicit(topo, outage, n, &mut ws),
        }
        .or_else(|| find_fault_free_window(outage, n));
        if let Some(window) = window {
            // ScotchExtract: sub-topology restricted to the window, with
            // plain hop distances (window is fault-free by construction).
            let sub: DistanceMatrix = oracle.extract(&window);
            let local = self.config.mapper.map(comm, &sub)?;
            let assignment = local
                .assignment
                .iter()
                .map(|&li| window[li])
                .collect::<Vec<_>>();
            Ok(TofaPlacement {
                assignment,
                path: TofaPath::Window,
            })
        } else {
            // no window: map over the Eq. 1 fault-weighted topology. The
            // full matrix is cluster-sized, so the implicit path refuses
            // it beyond the dense limit instead of allocating O(n²).
            let dist = match oracle.index() {
                Some(index) => fault_aware_distance_indexed(index, topo, outage, &mut ws),
                None => {
                    check_materialize(topo.num_nodes())?;
                    let all: Vec<usize> = (0..topo.num_nodes()).collect();
                    fault_aware_submatrix(topo, outage, &all, &mut ws)
                }
            };
            let p = self.config.mapper.map(comm, &dist)?;
            Ok(TofaPlacement {
                assignment: p.assignment,
                path: TofaPath::FaultWeighted,
            })
        }
    }

    /// TOFA placement restricted to a candidate node set (Listing 1.1 on
    /// a shared cluster): `free[n]` marks the nodes the scheduler's
    /// [`crate::slurm::sched::NodeLedger`] currently has available. The
    /// window search only accepts windows of free nodes (a busy node
    /// fragments a window like a flaky one, though busy *transits* stay
    /// acceptable — allocated nodes keep forwarding traffic), and the
    /// fault-weighted fallback maps over the Eq. 1 matrix extracted to the
    /// candidates — served by the platform's [`Platform::hop_oracle`]
    /// (dense [`crate::topology::TopoIndex`] or implicit closed forms).
    pub fn place_within(
        &self,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
        free: &[bool],
    ) -> Result<TofaPlacement> {
        let n = comm.len();
        let topo = platform.topology();
        let oracle = platform.hop_oracle();
        assert_eq!(free.len(), platform.num_nodes());
        let candidates: Vec<usize> = (0..free.len()).filter(|&i| free[i]).collect();
        if candidates.len() < n {
            return Err(Error::Placement(format!(
                "{n} ranks > {} free nodes",
                candidates.len()
            )));
        }
        let clean = outage.iter().all(|&p| p <= 0.0);
        // poisoned-lock recovery: scratch workspace, see place()
        let mut ws = self.ws.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        let window = match oracle.index() {
            Some(index) => find_route_clean_window_masked(index, outage, n, free, &mut ws),
            None => find_route_clean_window_masked_implicit(topo, outage, n, free, &mut ws),
        }
        .or_else(|| find_fault_free_window_masked(outage, free, n));
        if let Some(window) = window {
            let sub: DistanceMatrix = oracle.extract(&window);
            let local = self.config.mapper.map(comm, &sub)?;
            let assignment = local.assignment.iter().map(|&li| window[li]).collect();
            return Ok(TofaPlacement {
                assignment,
                path: if clean {
                    TofaPath::FaultFree
                } else {
                    TofaPath::Window
                },
            });
        }
        // no window inside the free set (fragmentation or faults): map
        // over the fault-weighted matrix restricted to the candidates —
        // candidate-sized, but an implicit platform still refuses a
        // cluster-scale candidate set rather than allocate O(n²)
        let dist = if clean {
            if !oracle.is_dense() {
                check_materialize(candidates.len())?;
            }
            oracle.extract(&candidates)
        } else {
            match oracle.index() {
                Some(index) => {
                    fault_aware_distance_indexed(index, topo, outage, &mut ws).extract(&candidates)
                }
                None => {
                    check_materialize(candidates.len())?;
                    fault_aware_submatrix(topo, outage, &candidates, &mut ws)
                }
            }
        };
        let local = self.config.mapper.map(comm, &dist)?;
        let assignment = local.assignment.iter().map(|&li| candidates[li]).collect();
        Ok(TofaPlacement {
            assignment,
            path: TofaPath::FaultWeighted,
        })
    }

    /// Place and wrap as a [`Placement`].
    pub fn placement(
        &self,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
    ) -> Result<Placement> {
        Ok(Placement::new(self.place(comm, platform, outage)?.assignment))
    }

    /// [`TofaPlacer::place_within`] wrapped as a [`Placement`].
    pub fn placement_within(
        &self,
        comm: &CommMatrix,
        platform: &Platform,
        outage: &[f64],
        free: &[bool],
    ) -> Result<Placement> {
        Ok(Placement::new(
            self.place_within(comm, platform, outage, free)?.assignment,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lammps_proxy::LammpsProxy, MpiApp};
    use crate::profiler::profile_app;
    use crate::topology::TorusDims;

    fn setup(n_ranks: usize) -> (CommMatrix, Platform) {
        let app = LammpsProxy::tiny(n_ranks, 2);
        let profile = profile_app(&app);
        let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
        (profile.volume, platform)
    }

    #[test]
    fn fault_free_path_when_no_outage() {
        let (c, plat) = setup(32);
        let p = TofaPlacer::default()
            .place(&c, &plat, &vec![0.0; 512])
            .unwrap();
        assert_eq!(p.path, TofaPath::FaultFree);
        Placement::new(p.assignment).validate(512).unwrap();
    }

    #[test]
    fn window_path_avoids_flaky_nodes_entirely() {
        let (c, plat) = setup(32);
        let mut outage = vec![0.0; 512];
        // 16 flaky nodes spread out but leaving a 32-window
        for i in 0..16 {
            outage[64 + i * 28] = 0.02;
        }
        let p = TofaPlacer::default().place(&c, &plat, &outage).unwrap();
        assert_eq!(p.path, TofaPath::Window);
        for &node in &p.assignment {
            assert_eq!(outage[node], 0.0, "flaky node {node} used");
        }
        Placement::new(p.assignment).validate(512).unwrap();
    }

    #[test]
    fn fault_weighted_path_when_no_window() {
        let (c, plat) = setup(32);
        // flaky node every 16 ids: no 32-run exists
        let mut outage = vec![0.0; 512];
        for i in (0..512).step_by(16) {
            outage[i] = 0.02;
        }
        let p = TofaPlacer::default().place(&c, &plat, &outage).unwrap();
        assert_eq!(p.path, TofaPath::FaultWeighted);
        // fault weighting should still avoid most flaky nodes
        let flaky_used = p
            .assignment
            .iter()
            .filter(|&&n| outage[n] > 0.0)
            .count();
        assert!(
            flaky_used <= 4,
            "fault-weighted map used {flaky_used} flaky nodes"
        );
    }

    #[test]
    fn tofa_runs_on_every_topology_family() {
        use crate::topology::{Dragonfly, DragonflyParams, FatTree};
        use std::sync::Arc;
        let app = LammpsProxy::tiny(8, 2);
        let profile = profile_app(&app);
        let platforms = [
            Platform::paper_default_on(Arc::new(FatTree::new(4).unwrap())),
            Platform::paper_default_on(Arc::new(
                Dragonfly::new(DragonflyParams::new(5, 4, 2, 1)).unwrap(),
            )),
        ];
        for plat in &platforms {
            let n = plat.num_nodes();
            let kind = plat.topology().kind();
            // window path dodges a flaky node in the middle
            let mut outage = vec![0.0; n];
            outage[2] = 0.1;
            let p = TofaPlacer::default()
                .place(&profile.volume, plat, &outage)
                .unwrap();
            assert_eq!(p.path, TofaPath::Window, "{kind}");
            assert!(!p.assignment.contains(&2), "{kind}");
            Placement::new(p.assignment).validate(n).unwrap();
            // fault-weighted path when no 8-window survives
            let mut dense = vec![0.0; n];
            for i in (0..n).step_by(4) {
                dense[i] = 0.1;
            }
            let p = TofaPlacer::default()
                .place(&profile.volume, plat, &dense)
                .unwrap();
            assert_eq!(p.path, TofaPath::FaultWeighted, "{kind}");
            Placement::new(p.assignment).validate(n).unwrap();
        }
    }

    #[test]
    fn candidate_mask_excludes_busy_nodes_entirely() {
        let (c, plat) = setup(32);
        let mut outage = vec![0.0; 512];
        outage[40] = 0.05;
        // nodes 0..64 busy: neither the window nor the fallback may use
        // them, flaky or not
        let mut free = vec![true; 512];
        for f in free.iter_mut().take(64) {
            *f = false;
        }
        let p = TofaPlacer::default()
            .place_within(&c, &plat, &outage, &free)
            .unwrap();
        for &node in &p.assignment {
            assert!(free[node], "busy node {node} used");
        }
        assert_eq!(p.path, TofaPath::Window);
        Placement::new(p.assignment).validate(512).unwrap();
    }

    #[test]
    fn fragmented_free_set_forces_fault_weighted_path() {
        let (c, plat) = setup(32);
        let mut outage = vec![0.0; 512];
        outage[9] = 0.05;
        // every second 16-run busy: no 32-window of free ids exists
        let mut free = vec![true; 512];
        for start in (0..512).step_by(32) {
            for n in start + 16..start + 32 {
                free[n] = false;
            }
        }
        let p = TofaPlacer::default()
            .place_within(&c, &plat, &outage, &free)
            .unwrap();
        assert_eq!(p.path, TofaPath::FaultWeighted);
        for &node in &p.assignment {
            assert!(free[node], "busy node {node} used");
        }
        Placement::new(p.assignment).validate(512).unwrap();
    }

    #[test]
    fn all_free_mask_matches_unrestricted_placement() {
        let (c, plat) = setup(32);
        let mut outage = vec![0.0; 512];
        outage[100] = 0.02;
        let placer = TofaPlacer::default();
        let unrestricted = placer.place(&c, &plat, &outage).unwrap();
        let masked = placer
            .place_within(&c, &plat, &outage, &vec![true; 512])
            .unwrap();
        assert_eq!(masked.path, unrestricted.path);
        assert_eq!(masked.assignment, unrestricted.assignment);
    }

    #[test]
    fn too_few_free_nodes_is_a_placement_error() {
        let (c, plat) = setup(32);
        let mut free = vec![false; 512];
        for f in free.iter_mut().take(16) {
            *f = true;
        }
        let err = TofaPlacer::default()
            .place_within(&c, &plat, &vec![0.0; 512], &free)
            .unwrap_err();
        assert!(err.to_string().contains("free nodes"), "{err}");
    }

    #[test]
    fn implicit_platform_places_identically_to_dense() {
        use crate::topology::MetricMode;
        let (c, plat) = setup(32);
        let implicit = plat.clone().with_metric(MetricMode::Implicit);
        let placer = TofaPlacer::default();
        // the three Listing 1.1 paths plus a candidate mask
        let mut window_outage = vec![0.0; 512];
        window_outage[40] = 0.05;
        let mut dense_outage = vec![0.0; 512];
        for i in (0..512).step_by(16) {
            dense_outage[i] = 0.02;
        }
        for outage in [vec![0.0; 512], window_outage, dense_outage] {
            let a = placer.place(&c, &plat, &outage).unwrap();
            let b = placer.place(&c, &implicit, &outage).unwrap();
            assert_eq!(a.path, b.path);
            assert_eq!(a.assignment, b.assignment);
            let mut free = vec![true; 512];
            for f in free.iter_mut().take(64) {
                *f = false;
            }
            let a = placer.place_within(&c, &plat, &outage, &free).unwrap();
            let b = placer.place_within(&c, &implicit, &outage, &free).unwrap();
            assert_eq!(a.path, b.path);
            assert_eq!(a.assignment, b.assignment);
        }
    }

    #[test]
    fn window_placement_is_compact() {
        // a window map should not be worse than ~2x the unconstrained map
        use crate::mapping::cost::hop_bytes_cost;
        let (c, plat) = setup(64);
        let hop = plat.hop_matrix();
        let clean = TofaPlacer::default()
            .place(&c, &plat, &vec![0.0; 512])
            .unwrap();
        let mut outage = vec![0.0; 512];
        outage[300] = 0.02; // window exists at the front
        let windowed = TofaPlacer::default().place(&c, &plat, &outage).unwrap();
        let cost_clean = hop_bytes_cost(&c, &hop, &clean.assignment);
        let cost_win = hop_bytes_cost(&c, &hop, &windowed.assignment);
        assert!(
            cost_win <= 2.0 * cost_clean,
            "window map cost {cost_win} vs clean {cost_clean}"
        );
    }
}
