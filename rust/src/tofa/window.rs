//! Consecutive fault-free node search (step 10 of Listing 1.1).
//!
//! TOFA first tries to find `|V_G|` nodes with **consecutive ids** all of
//! which have zero (estimated) outage probability. Node ids enumerate the
//! torus row-major, so a window is a contiguous run in Slurm's node list.

/// Find the first run of `len` consecutive node ids whose outage
/// probability is zero. Returns the node ids, or `None`.
pub fn find_fault_free_window(outage: &[f64], len: usize) -> Option<Vec<usize>> {
    if len == 0 || len > outage.len() {
        return None;
    }
    let mut run_start = 0usize;
    let mut run_len = 0usize;
    for (i, &p) in outage.iter().enumerate() {
        if p <= 0.0 {
            if run_len == 0 {
                run_start = i;
            }
            run_len += 1;
            if run_len == len {
                return Some((run_start..run_start + len).collect());
            }
        } else {
            run_len = 0;
        }
    }
    None
}

/// Find a fault-free window whose **route closure** is also fault-free:
/// no fixed route between any two window nodes transits a node with
/// `outage > 0`. This is the check the FANS plugin can make because FATT
/// exports the intermediate nodes of `R(u, v)` (Section 4 of the paper) —
/// a window passing it guarantees a zero abort ratio for jobs mapped
/// inside. Transit vertices beyond `outage.len()` are switches/routers,
/// which never fail. Falls back to `None` if no such window exists.
pub fn find_route_clean_window(
    outage: &[f64],
    len: usize,
    topo: &dyn crate::topology::Topology,
) -> Option<Vec<usize>> {
    if len == 0 || len > outage.len() {
        return None;
    }
    let flaky: Vec<bool> = outage.iter().map(|&p| p > 0.0).collect();
    let is_flaky = |n: usize| n < flaky.len() && flaky[n];
    let mut route = Vec::new();
    'starts: for start in 0..=(outage.len() - len) {
        // endpoint check first (cheap)
        if flaky[start..start + len].iter().any(|&f| f) {
            continue;
        }
        // route-closure check against flaky transits
        for u in start..start + len {
            for v in (u + 1)..start + len {
                topo.route_into(u, v, &mut route);
                for l in &route {
                    if is_flaky(l.src) || is_flaky(l.dst) {
                        continue 'starts;
                    }
                }
            }
        }
        return Some((start..start + len).collect());
    }
    None
}

/// All maximal fault-free runs as `(start, len)` — used by diagnostics and
/// the ablation bench exploring window availability vs faulty-node count.
pub fn fault_free_runs(outage: &[f64]) -> Vec<(usize, usize)> {
    let mut runs = Vec::new();
    let mut start = None;
    for (i, &p) in outage.iter().enumerate() {
        if p <= 0.0 {
            if start.is_none() {
                start = Some(i);
            }
        } else if let Some(s) = start.take() {
            runs.push((s, i - s));
        }
    }
    if let Some(s) = start {
        runs.push((s, outage.len() - s));
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_first_window() {
        let mut outage = vec![0.0; 20];
        outage[3] = 0.1;
        let w = find_fault_free_window(&outage, 5).unwrap();
        assert_eq!(w, vec![4, 5, 6, 7, 8]);
    }

    #[test]
    fn none_when_no_window() {
        let mut outage = vec![0.0; 10];
        outage[2] = 0.1;
        outage[5] = 0.1;
        outage[8] = 0.1;
        assert!(find_fault_free_window(&outage, 4).is_none());
        assert!(find_fault_free_window(&outage, 2).is_some());
    }

    #[test]
    fn full_array_when_clean() {
        let outage = vec![0.0; 8];
        assert_eq!(
            find_fault_free_window(&outage, 8).unwrap(),
            (0..8).collect::<Vec<_>>()
        );
        assert!(find_fault_free_window(&outage, 9).is_none());
        assert!(find_fault_free_window(&outage, 0).is_none());
    }

    #[test]
    fn runs_enumeration() {
        let outage = vec![0.0, 0.0, 0.5, 0.0, 0.0, 0.0, 0.5, 0.0];
        assert_eq!(fault_free_runs(&outage), vec![(0, 2), (3, 3), (7, 1)]);
    }

    #[test]
    fn probability_of_window_shrinks_with_faults() {
        // statistical sanity for the paper's Fig. 5a observation: with 8
        // faulty nodes out of 512 a 64-window almost always exists; with
        // many more it often does not.
        let mut rng = crate::rng::Rng::new(11);
        let trials = 200;
        let count_with = |n_faulty: usize, rng: &mut crate::rng::Rng| {
            (0..trials)
                .filter(|_| {
                    let mut outage = vec![0.0; 512];
                    for f in rng.sample_distinct(512, n_faulty) {
                        outage[f] = 0.02;
                    }
                    find_fault_free_window(&outage, 64).is_some()
                })
                .count()
        };
        let with_8 = count_with(8, &mut rng);
        let with_64 = count_with(64, &mut rng);
        assert!(with_8 > trials * 7 / 10, "8 faulty: {with_8}/{trials}");
        assert!(with_64 < with_8);
    }
}
