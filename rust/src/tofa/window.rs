//! Consecutive fault-free node search (step 10 of Listing 1.1).
//!
//! TOFA first tries to find `|V_G|` nodes with **consecutive ids** all of
//! which have zero (estimated) outage probability. Node ids enumerate the
//! torus row-major, so a window is a contiguous run in Slurm's node list.
//!
//! Three route-clean searches return the **same** window:
//! [`find_route_clean_window`] (dense reference, re-routes every closure),
//! [`find_route_clean_window_indexed`] (slides over a precomputed
//! [`TopoIndex`](crate::topology::TopoIndex)), and
//! [`find_route_clean_window_implicit`] (slides with on-demand
//! [`route_touches`](crate::topology::Topology::route_touches) queries —
//! O(n) memory, the 100k-node path).
//!
//! ```
//! use tofa::tofa::window::find_fault_free_window;
//!
//! let mut outage = vec![0.0; 16];
//! outage[3] = 0.1; // node 3 is flaky: the first clean 4-run starts at 4
//! assert_eq!(find_fault_free_window(&outage, 4), Some(vec![4, 5, 6, 7]));
//! assert_eq!(find_fault_free_window(&outage, 13), None);
//! ```

use crate::topology::{CostWorkspace, Topology};

/// Find the first run of `len` consecutive node ids whose outage
/// probability is zero. Returns the node ids, or `None`.
pub fn find_fault_free_window(outage: &[f64], len: usize) -> Option<Vec<usize>> {
    fault_free_window_core(outage, None, len)
}

/// Shared core of the plain and candidate-masked endpoint-clean window
/// searches: one run scanner, with eligibility as an optional extra
/// condition (so the two public entry points cannot drift apart).
fn fault_free_window_core(
    outage: &[f64],
    eligible: Option<&[bool]>,
    len: usize,
) -> Option<Vec<usize>> {
    if len == 0 || len > outage.len() {
        return None;
    }
    // map_or (not is_none_or): the crate's MSRV is 1.74
    let ok = |i: usize| eligible.map_or(true, |e| e[i]);
    let mut run_start = 0usize;
    let mut run_len = 0usize;
    for (i, &p) in outage.iter().enumerate() {
        if p <= 0.0 && ok(i) {
            if run_len == 0 {
                run_start = i;
            }
            run_len += 1;
            if run_len == len {
                return Some((run_start..run_start + len).collect());
            }
        } else {
            run_len = 0;
        }
    }
    None
}

/// Find a fault-free window whose **route closure** is also fault-free:
/// no fixed route between any two window nodes transits a node with
/// `outage > 0`. This is the check the FANS plugin can make because FATT
/// exports the intermediate nodes of `R(u, v)` (Section 4 of the paper) —
/// a window passing it guarantees a zero abort ratio for jobs mapped
/// inside. Transit vertices beyond `outage.len()` are switches/routers,
/// which never fail. Falls back to `None` if no such window exists.
///
/// This is the **dense reference implementation**: every candidate start
/// re-routes `O(len^2)` pairs. The hot path —
/// [`find_route_clean_window_indexed`] — slides the window with per-window
/// dirty-pair counts instead; it returns the *same* window (asserted in
/// `tests/proptests.rs`), and this function stays the ground truth for
/// those equivalence tests and the `cost_engine` bench.
pub fn find_route_clean_window(
    outage: &[f64],
    len: usize,
    topo: &dyn crate::topology::Topology,
) -> Option<Vec<usize>> {
    if len == 0 || len > outage.len() {
        return None;
    }
    let flaky: Vec<bool> = outage.iter().map(|&p| p > 0.0).collect();
    let is_flaky = |n: usize| n < flaky.len() && flaky[n];
    let mut route = Vec::new();
    'starts: for start in 0..=(outage.len() - len) {
        // endpoint check first (cheap)
        if flaky[start..start + len].iter().any(|&f| f) {
            continue;
        }
        // route-closure check against flaky transits
        for u in start..start + len {
            for v in (u + 1)..start + len {
                topo.route_into(u, v, &mut route);
                for l in &route {
                    if is_flaky(l.src) || is_flaky(l.dst) {
                        continue 'starts;
                    }
                }
            }
        }
        return Some((start..start + len).collect());
    }
    None
}

/// Incremental route-clean window search over a precomputed
/// [`TopoIndex`](crate::topology::TopoIndex).
///
/// A pair `(u, v)` is *dirty* when some link of `R(u, v)` has a flaky
/// endpoint — exactly the pairs in the union of the flaky nodes' transit-
/// incidence lists. A window is valid iff it contains no flaky node and no
/// dirty pair. Instead of re-routing the `O(len^2)` closure at every
/// candidate start, this builds per-node sorted dirty-partner lists once
/// per outage vector and then *slides*: moving the window from `s` to
/// `s + 1` subtracts the dirty pairs `(s, .)` leaving on the left and adds
/// the dirty pairs `(., s + len)` entering on the right (two binary
/// searches), with flaky-node membership answered by a prefix sum.
///
/// Returns the **same** window as [`find_route_clean_window`] — the first
/// valid start — or `None` (equivalence asserted in `tests/proptests.rs`).
pub fn find_route_clean_window_indexed(
    index: &crate::topology::TopoIndex,
    outage: &[f64],
    len: usize,
    ws: &mut CostWorkspace,
) -> Option<Vec<usize>> {
    route_clean_window_core(index, outage, len, None, ws)
}

/// [`find_fault_free_window`] restricted to a candidate set: every window
/// node must additionally be `eligible` (free in the scheduler's
/// [`crate::slurm::sched::NodeLedger`]). A busy node breaks a run exactly
/// like a flaky one — windows are consecutive *ids*, and an occupied node
/// in the middle fragments them.
pub fn find_fault_free_window_masked(
    outage: &[f64],
    eligible: &[bool],
    len: usize,
) -> Option<Vec<usize>> {
    assert_eq!(outage.len(), eligible.len());
    fault_free_window_core(outage, Some(eligible), len)
}

/// [`find_route_clean_window_indexed`] restricted to a candidate set.
///
/// Window *endpoints* must be eligible (free) and zero-outage; the route
/// closure must avoid flaky transits only — a **busy** transit node is
/// fine, because an allocated node keeps forwarding traffic (links keep
/// their capacity; only failures abort). The dirty-pair machinery is the
/// same slide as the unmasked search; eligibility enters solely through
/// the per-window membership check, via a blocked-node prefix sum.
pub fn find_route_clean_window_masked(
    index: &crate::topology::TopoIndex,
    outage: &[f64],
    len: usize,
    eligible: &[bool],
    ws: &mut CostWorkspace,
) -> Option<Vec<usize>> {
    assert_eq!(eligible.len(), index.num_nodes());
    route_clean_window_core(index, outage, len, Some(eligible), ws)
}

/// Shared core of the plain and candidate-masked route-clean window
/// searches: the dirty-pair build + slide is written exactly once, and
/// eligibility enters solely through the membership prefix (the prepared
/// flaky prefix, or a blocked = flaky-or-ineligible prefix rebuilt into
/// workspace scratch). With `eligible == None` this is bit-identical to
/// the pre-mask search.
fn route_clean_window_core(
    index: &crate::topology::TopoIndex,
    outage: &[f64],
    len: usize,
    eligible: Option<&[bool]>,
    ws: &mut CostWorkspace,
) -> Option<Vec<usize>> {
    let n = index.num_nodes();
    assert_eq!(outage.len(), n, "index built for a different platform");
    if len == 0 || len > n {
        return None;
    }
    ws.prepare(outage);
    ws.begin_pairs(n);
    // reset only the partner lists the previous call populated
    let CostWorkspace {
        flaky,
        flaky_nodes,
        flaky_prefix,
        pair_mark,
        pair_epoch,
        partners,
        partner_touched,
        blocked_prefix,
        ..
    } = ws;
    if partners.len() < n {
        partners.resize_with(n, Vec::new);
    }
    for &t in partner_touched.iter() {
        partners[t as usize].clear();
    }
    partner_touched.clear();
    let epoch = *pair_epoch;
    for &f in flaky_nodes.iter() {
        for &packed in index.pairs_through_packed(f as usize) {
            let (u, v) = crate::topology::index::pair_of(packed);
            if !crate::topology::index::mark_cell(&mut pair_mark[u * n + v], epoch) {
                continue;
            }
            if partners[u].is_empty() {
                partner_touched.push(u as u32);
            }
            partners[u].push(v as u32);
            if partners[v].is_empty() {
                partner_touched.push(v as u32);
            }
            partners[v].push(u as u32);
        }
    }
    for &t in partner_touched.iter() {
        partners[t as usize].sort_unstable();
    }
    // dirty partners of `x` with ids in [lo, hi)
    let count_in = |x: usize, lo: usize, hi: usize| -> i64 {
        let p = &partners[x];
        let a = p.partition_point(|&y| (y as usize) < lo);
        let b = p.partition_point(|&y| (y as usize) < hi);
        (b - a) as i64
    };
    // window-membership prefix: flaky nodes alone (unmasked — the
    // prepared prefix), or flaky-or-ineligible (masked, rebuilt into the
    // reusable workspace buffer)
    let prefix: &[u32] = match eligible {
        None => flaky_prefix.as_slice(),
        Some(elig) => {
            blocked_prefix.clear();
            blocked_prefix.reserve(n + 1);
            blocked_prefix.push(0u32);
            let mut acc = 0u32;
            for i in 0..n {
                if flaky[i] || !elig[i] {
                    acc += 1;
                }
                blocked_prefix.push(acc);
            }
            blocked_prefix.as_slice()
        }
    };
    let blocked_in = |lo: usize, hi: usize| prefix[hi] - prefix[lo];
    // dirty pairs fully inside the initial window [0, len)
    let mut dirty: i64 = (0..len).map(|u| count_in(u, u + 1, len)).sum();
    for s in 0..=(n - len) {
        debug_assert!(dirty >= 0, "dirty-pair count went negative at {s}");
        if blocked_in(s, s + len) == 0 && dirty == 0 {
            return Some((s..s + len).collect());
        }
        if s + len < n {
            // shared core [s+1, s+len): drop pairs (s, .), add (., s+len)
            dirty -= count_in(s, s + 1, s + len);
            dirty += count_in(s + len, s + 1, s + len);
        }
    }
    None
}

/// Implicit-metric route-clean window search: the counterpart of
/// [`find_route_clean_window_indexed`] for platforms where the
/// [`TopoIndex`](crate::topology::TopoIndex) is never built. Dirty pairs
/// are discovered on demand with
/// [`Topology::route_touches`] (closed-form for the in-tree families)
/// instead of precomputed transit-incidence lists, so the search allocates
/// O(n) — never O(n²) — and still returns the **same** first valid window
/// as the dense and indexed paths (asserted in `tests/proptests.rs`).
pub fn find_route_clean_window_implicit(
    topo: &dyn Topology,
    outage: &[f64],
    len: usize,
    ws: &mut CostWorkspace,
) -> Option<Vec<usize>> {
    route_clean_window_lazy_core(topo, outage, len, None, ws)
}

/// [`find_route_clean_window_implicit`] restricted to a candidate set —
/// the implicit counterpart of [`find_route_clean_window_masked`], with
/// identical mask semantics (endpoints must be eligible and clean; busy
/// transits are fine).
pub fn find_route_clean_window_masked_implicit(
    topo: &dyn Topology,
    outage: &[f64],
    len: usize,
    eligible: &[bool],
    ws: &mut CostWorkspace,
) -> Option<Vec<usize>> {
    assert_eq!(eligible.len(), topo.num_nodes());
    route_clean_window_lazy_core(topo, outage, len, Some(eligible), ws)
}

/// Shared core of the implicit window searches: the same slide as
/// [`route_clean_window_core`], but each pair's dirtiness is answered
/// lazily by [`Topology::route_touches`] the moment the pair enters the
/// window. Every in-window pair is recorded (once, on its *lower* node's
/// partner list, when its higher node enters — the lower node is the one
/// that exits first as windows slide right) and discharged wholesale when
/// that node leaves, so the running dirty count is exact without any
/// per-pair marks. Memory: the partner lists hold at most the dirty pairs
/// of one window — O(n) overall.
fn route_clean_window_lazy_core(
    topo: &dyn Topology,
    outage: &[f64],
    len: usize,
    eligible: Option<&[bool]>,
    ws: &mut CostWorkspace,
) -> Option<Vec<usize>> {
    let n = topo.num_nodes();
    assert_eq!(outage.len(), n);
    if len == 0 || len > n {
        return None;
    }
    ws.prepare(outage);
    let CostWorkspace {
        flaky,
        flaky_nodes,
        flaky_prefix,
        partners,
        partner_touched,
        blocked_prefix,
        ..
    } = ws;
    // window-membership prefix, exactly as in the indexed core
    let prefix: &[u32] = match eligible {
        None => flaky_prefix.as_slice(),
        Some(elig) => {
            blocked_prefix.clear();
            blocked_prefix.reserve(n + 1);
            blocked_prefix.push(0u32);
            let mut acc = 0u32;
            for i in 0..n {
                if flaky[i] || !elig[i] {
                    acc += 1;
                }
                blocked_prefix.push(acc);
            }
            blocked_prefix.as_slice()
        }
    };
    let blocked_in = |lo: usize, hi: usize| prefix[hi] - prefix[lo];
    if flaky_nodes.is_empty() {
        // no flaky node, no dirty pair anywhere: scan membership only
        return (0..=(n - len))
            .find(|&s| blocked_in(s, s + len) == 0)
            .map(|s| (s..s + len).collect());
    }
    let dirty_pair = |u: usize, v: usize| {
        flaky_nodes
            .iter()
            .any(|&f| topo.route_touches(u, v, f as usize))
    };
    // reset only the partner lists the previous call populated
    if partners.len() < n {
        partners.resize_with(n, Vec::new);
    }
    for &t in partner_touched.iter() {
        partners[t as usize].clear();
    }
    partner_touched.clear();
    // seed the initial window [0, len)
    let mut dirty: i64 = 0;
    for w in 1..len {
        for u in 0..w {
            if dirty_pair(u, w) {
                if partners[u].is_empty() {
                    partner_touched.push(u as u32);
                }
                partners[u].push(w as u32);
                dirty += 1;
            }
        }
    }
    for s in 0..=(n - len) {
        debug_assert!(dirty >= 0, "dirty-pair count went negative at {s}");
        if blocked_in(s, s + len) == 0 && dirty == 0 {
            return Some((s..s + len).collect());
        }
        if s + len < n {
            // node s leaves: every pair it still holds was (s, x), x > s
            dirty -= partners[s].len() as i64;
            partners[s].clear();
            // node w = s + len enters: admit its pairs against [s+1, w)
            let w = s + len;
            for u in (s + 1)..w {
                if dirty_pair(u, w) {
                    if partners[u].is_empty() {
                        partner_touched.push(u as u32);
                    }
                    partners[u].push(w as u32);
                    dirty += 1;
                }
            }
        }
    }
    None
}

/// All maximal fault-free runs as `(start, len)` — used by diagnostics and
/// the ablation bench exploring window availability vs faulty-node count.
pub fn fault_free_runs(outage: &[f64]) -> Vec<(usize, usize)> {
    let mut runs = Vec::new();
    let mut start = None;
    for (i, &p) in outage.iter().enumerate() {
        if p <= 0.0 {
            if start.is_none() {
                start = Some(i);
            }
        } else if let Some(s) = start.take() {
            runs.push((s, i - s));
        }
    }
    if let Some(s) = start {
        runs.push((s, outage.len() - s));
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_first_window() {
        let mut outage = vec![0.0; 20];
        outage[3] = 0.1;
        let w = find_fault_free_window(&outage, 5).unwrap();
        assert_eq!(w, vec![4, 5, 6, 7, 8]);
    }

    #[test]
    fn none_when_no_window() {
        let mut outage = vec![0.0; 10];
        outage[2] = 0.1;
        outage[5] = 0.1;
        outage[8] = 0.1;
        assert!(find_fault_free_window(&outage, 4).is_none());
        assert!(find_fault_free_window(&outage, 2).is_some());
    }

    #[test]
    fn full_array_when_clean() {
        let outage = vec![0.0; 8];
        assert_eq!(
            find_fault_free_window(&outage, 8).unwrap(),
            (0..8).collect::<Vec<_>>()
        );
        assert!(find_fault_free_window(&outage, 9).is_none());
        assert!(find_fault_free_window(&outage, 0).is_none());
    }

    #[test]
    fn runs_enumeration() {
        let outage = vec![0.0, 0.0, 0.5, 0.0, 0.0, 0.0, 0.5, 0.0];
        assert_eq!(fault_free_runs(&outage), vec![(0, 2), (3, 3), (7, 1)]);
    }

    #[test]
    fn indexed_search_returns_the_same_window_as_dense() {
        use crate::topology::{Dragonfly, DragonflyParams, FatTree, TopoIndex, Torus, TorusDims};
        // ascending node counts: the shared workspace must survive
        // growing to a larger platform mid-life
        let topos: Vec<Box<dyn crate::topology::Topology>> = vec![
            Box::new(Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap()),
            Box::new(FatTree::new(4).unwrap()),
            Box::new(Torus::new(TorusDims::new(4, 4, 2))),
        ];
        let mut rng = crate::rng::Rng::new(23);
        let mut ws = CostWorkspace::new();
        for t in &topos {
            let n = t.num_nodes();
            let index = TopoIndex::build(t.as_ref());
            for case in 0..40 {
                let mut outage = vec![0.0; n];
                let n_flaky = rng.below_usize(n / 2 + 1);
                for f in rng.sample_distinct(n, n_flaky) {
                    outage[f] = 0.02;
                }
                let len = rng.below_usize(n + 2); // includes 0 and > n
                let dense = find_route_clean_window(&outage, len, t.as_ref());
                let fast = find_route_clean_window_indexed(&index, &outage, len, &mut ws);
                assert_eq!(fast, dense, "{} case {case} len {len}", t.describe());
            }
        }
    }

    #[test]
    fn implicit_search_returns_the_same_window_as_indexed() {
        use crate::topology::{Dragonfly, DragonflyParams, FatTree, TopoIndex, Torus, TorusDims};
        let topos: Vec<Box<dyn Topology>> = vec![
            Box::new(Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap()),
            Box::new(FatTree::new(4).unwrap()),
            Box::new(Torus::new(TorusDims::new(4, 4, 2))),
        ];
        let mut rng = crate::rng::Rng::new(37);
        let mut ws_a = CostWorkspace::new();
        let mut ws_b = CostWorkspace::new();
        for t in &topos {
            let n = t.num_nodes();
            let index = TopoIndex::build(t.as_ref());
            for case in 0..40 {
                let mut outage = vec![0.0; n];
                let n_flaky = rng.below_usize(n / 2 + 1);
                for f in rng.sample_distinct(n, n_flaky) {
                    outage[f] = 0.02;
                }
                let len = rng.below_usize(n + 2); // includes 0 and > n
                let indexed = find_route_clean_window_indexed(&index, &outage, len, &mut ws_a);
                let implicit =
                    find_route_clean_window_implicit(t.as_ref(), &outage, len, &mut ws_b);
                assert_eq!(implicit, indexed, "{} case {case} len {len}", t.describe());
            }
        }
    }

    #[test]
    fn masked_implicit_search_matches_the_masked_indexed_search() {
        use crate::topology::{TopoIndex, Torus, TorusDims};
        let t = Torus::new(TorusDims::new(4, 4, 2));
        let index = TopoIndex::build(&t);
        let n = crate::topology::Topology::num_nodes(&t);
        let mut rng = crate::rng::Rng::new(53);
        let mut ws_a = CostWorkspace::new();
        let mut ws_b = CostWorkspace::new();
        for case in 0..60 {
            let mut outage = vec![0.0; n];
            for f in rng.sample_distinct(n, rng.below_usize(n / 3 + 1)) {
                outage[f] = 0.02;
            }
            let mut eligible = vec![true; n];
            for b in rng.sample_distinct(n, rng.below_usize(n / 2 + 1)) {
                eligible[b] = false;
            }
            let len = rng.below_usize(n + 2);
            let indexed = find_route_clean_window_masked(&index, &outage, len, &eligible, &mut ws_a);
            let implicit =
                find_route_clean_window_masked_implicit(&t, &outage, len, &eligible, &mut ws_b);
            assert_eq!(implicit, indexed, "case {case} len {len}");
        }
    }

    #[test]
    fn masked_window_skips_busy_and_flaky_nodes() {
        let mut outage = vec![0.0; 16];
        outage[1] = 0.1;
        let mut eligible = vec![true; 16];
        eligible[6] = false; // busy node fragments the run 2..16
        let w = find_fault_free_window_masked(&outage, &eligible, 4).unwrap();
        assert_eq!(w, vec![2, 3, 4, 5]);
        let w = find_fault_free_window_masked(&outage, &eligible, 8).unwrap();
        assert_eq!(w, (7..15).collect::<Vec<_>>());
        // all-eligible mask reduces to the unmasked search
        assert_eq!(
            find_fault_free_window_masked(&outage, &vec![true; 16], 5),
            find_fault_free_window(&outage, 5)
        );
    }

    #[test]
    fn masked_route_clean_window_matches_dense_reference() {
        use crate::topology::{TopoIndex, Torus, TorusDims};
        // dense reference: endpoints eligible + clean, transits clean
        fn dense(
            outage: &[f64],
            eligible: &[bool],
            len: usize,
            topo: &dyn crate::topology::Topology,
        ) -> Option<Vec<usize>> {
            if len == 0 || len > outage.len() {
                return None;
            }
            let flaky: Vec<bool> = outage.iter().map(|&p| p > 0.0).collect();
            let mut route = Vec::new();
            'starts: for start in 0..=(outage.len() - len) {
                for i in start..start + len {
                    if flaky[i] || !eligible[i] {
                        continue 'starts;
                    }
                }
                for u in start..start + len {
                    for v in (u + 1)..start + len {
                        topo.route_into(u, v, &mut route);
                        for l in &route {
                            let f = |n: usize| n < flaky.len() && flaky[n];
                            if f(l.src) || f(l.dst) {
                                continue 'starts;
                            }
                        }
                    }
                }
                return Some((start..start + len).collect());
            }
            None
        }
        let t = Torus::new(TorusDims::new(4, 4, 2));
        let index = TopoIndex::build(&t);
        let n = t.num_nodes();
        let mut rng = crate::rng::Rng::new(91);
        let mut ws = CostWorkspace::new();
        for case in 0..60 {
            let mut outage = vec![0.0; n];
            for f in rng.sample_distinct(n, rng.below_usize(n / 3 + 1)) {
                outage[f] = 0.02;
            }
            let mut eligible = vec![true; n];
            for b in rng.sample_distinct(n, rng.below_usize(n / 2 + 1)) {
                eligible[b] = false;
            }
            let len = rng.below_usize(n + 2);
            let want = dense(&outage, &eligible, len, &t);
            let got = find_route_clean_window_masked(&index, &outage, len, &eligible, &mut ws);
            assert_eq!(got, want, "case {case} len {len}");
            // with everything eligible the masked search must equal the
            // unmasked indexed search
            let all = vec![true; n];
            assert_eq!(
                find_route_clean_window_masked(&index, &outage, len, &all, &mut ws),
                find_route_clean_window_indexed(&index, &outage, len, &mut ws),
                "case {case} all-eligible"
            );
        }
    }

    #[test]
    fn probability_of_window_shrinks_with_faults() {
        // statistical sanity for the paper's Fig. 5a observation: with 8
        // faulty nodes out of 512 a 64-window almost always exists; with
        // many more it often does not.
        let mut rng = crate::rng::Rng::new(11);
        let trials = 200;
        let count_with = |n_faulty: usize, rng: &mut crate::rng::Rng| {
            (0..trials)
                .filter(|_| {
                    let mut outage = vec![0.0; 512];
                    for f in rng.sample_distinct(512, n_faulty) {
                        outage[f] = 0.02;
                    }
                    find_fault_free_window(&outage, 64).is_some()
                })
                .count()
        };
        let with_8 = count_with(8, &mut rng);
        let with_64 = count_with(64, &mut rng);
        assert!(with_8 > trials * 7 / 10, "8 faulty: {with_8}/{trials}");
        assert!(with_64 < with_8);
    }
}
