//! Equation 1: fault-aware topology edge weights.
//!
//! For each node pair `(u, v)` the routing function `R(u, v)` yields the
//! links a message traverses. The weight of the topology edge `(u, v)` is
//!
//! ```text
//! w(e_uv) = sum_{l in R(u,v)}  c  +  c * 100 * 1[p_f(l.src) > 0 or p_f(l.dst) > 0]
//! ```
//!
//! with `c = 1` hop. A link with a flaky endpoint therefore costs 101
//! instead of 1, making any failed path far costlier than the longest
//! fault-free path on the platform (the paper found small increments gave
//! only marginal abort-rate reductions — hence the x100).
//!
//! Three evaluators share these semantics bit-for-bit:
//! [`fault_aware_distance`] (dense reference, re-routes all pairs),
//! [`fault_aware_distance_indexed`] (patches a precomputed clean matrix),
//! and [`fault_aware_submatrix`] (job-sized view for the implicit metric,
//! never materializing cluster-sized state).
//!
//! ```
//! use tofa::tofa::eq1::fault_aware_distance;
//! use tofa::topology::{Torus, TorusDims};
//!
//! // an 8-node ring with node 1 flaky
//! let ring = Torus::new(TorusDims::new(8, 1, 1));
//! let mut outage = vec![0.0; 8];
//! outage[1] = 0.05;
//! let d = fault_aware_distance(&ring, &outage);
//! assert_eq!(d.get(0, 1), 101.0); // one link, flaky endpoint: 1 + 100
//! assert_eq!(d.get(0, 7), 1.0); // wraps the other way, fault-free
//! ```

use crate::topology::{CostWorkspace, DistanceMatrix, TopoIndex, Topology};

/// The hop cost constant `c` of Equation 1.
pub const HOP_COST: f32 = 1.0;
/// The fault inflation factor of Equation 1.
pub const FAULT_FACTOR: f32 = 100.0;

/// Build the full fault-aware distance matrix: entry `(u, v)` is Eq. 1
/// evaluated over `R(u, v)`. `outage[n] > 0` marks node `n` as flaky.
/// Route vertices beyond `outage.len()` are switches/routers (indirect
/// topologies), which never fail and so never inflate a link.
///
/// This is the **dense reference implementation**: it re-routes all
/// `O(n^2)` pairs regardless of how many nodes are flaky. The hot path —
/// [`fault_aware_distance_indexed`] — copies the precomputed clean matrix
/// and patches only the entries the flaky nodes can perturb; it is
/// bit-identical to this function (asserted for every topology family and
/// fault model in `tests/proptests.rs`), which stays the ground truth for
/// those equivalence tests and the `cost_engine` bench.
pub fn fault_aware_distance(topo: &dyn Topology, outage: &[f64]) -> DistanceMatrix {
    let m = topo.num_nodes();
    assert_eq!(outage.len(), m);
    let flaky: Vec<bool> = outage.iter().map(|&p| p > 0.0).collect();
    let is_flaky = |n: usize| n < flaky.len() && flaky[n];
    let mut dist = DistanceMatrix::zeros(m);
    let mut route = Vec::new();
    for u in 0..m {
        for v in (u + 1)..m {
            topo.route_into(u, v, &mut route);
            let mut w = 0.0f32;
            for l in &route {
                w += HOP_COST;
                if is_flaky(l.src) || is_flaky(l.dst) {
                    w += HOP_COST * FAULT_FACTOR;
                }
            }
            dist.set(u, v, w);
            dist.set(v, u, w);
        }
    }
    dist
}

/// Incremental Eq. 1 over a precomputed [`TopoIndex`]: start from the
/// clean hop matrix (a memcpy) and re-evaluate only the pairs whose route
/// touches a flaky node — the union of the flaky nodes' transit-incidence
/// lists. In the paper's regime (few flaky nodes) that is a small fraction
/// of the `n * (n - 1) / 2` pairs the dense path re-routes, turning
/// `O(n^2 * route_len)` into `O(faulty * incidence * route_len)`.
///
/// Bit-identical to [`fault_aware_distance`]: untouched entries are the
/// exact `|R(u, v)| as f32` the dense path produces with no flaky link
/// (a sum of `1.0f32` per hop is exact), and touched entries are
/// recomputed with the same accumulation loop in the same order.
///
/// `ws` is reusable scratch (see [`CostWorkspace`]); nothing is allocated
/// after the buffers have grown to the platform size, except the returned
/// matrix itself.
pub fn fault_aware_distance_indexed(
    index: &TopoIndex,
    topo: &dyn Topology,
    outage: &[f64],
    ws: &mut CostWorkspace,
) -> DistanceMatrix {
    let m = topo.num_nodes();
    assert_eq!(outage.len(), m);
    assert_eq!(index.num_nodes(), m, "index built for a different platform");
    ws.prepare(outage);
    ws.begin_pairs(m);
    let mut dist = index.clean_hops().clone();
    // split borrows: the flaky list is iterated while the route buffer and
    // pair marks are mutated
    let CostWorkspace {
        flaky,
        flaky_nodes,
        route,
        pair_mark,
        pair_epoch,
        pairs_patched,
        ..
    } = ws;
    let epoch = *pair_epoch;
    let is_flaky = |n: usize| n < flaky.len() && flaky[n];
    let mut patched = 0usize;
    for &f in flaky_nodes.iter() {
        for &packed in index.pairs_through_packed(f as usize) {
            let (u, v) = crate::topology::index::pair_of(packed);
            if !crate::topology::index::mark_cell(&mut pair_mark[u * m + v], epoch) {
                continue; // another flaky node already patched this pair
            }
            topo.route_into(u, v, route);
            let mut w = 0.0f32;
            for l in route.iter() {
                w += HOP_COST;
                if is_flaky(l.src) || is_flaky(l.dst) {
                    w += HOP_COST * FAULT_FACTOR;
                }
            }
            dist.set(u, v, w);
            dist.set(v, u, w);
            patched += 1;
        }
    }
    *pairs_patched = patched;
    dist
}

/// Eq. 1 over a candidate subset only — the implicit-metric counterpart of
/// [`fault_aware_distance_indexed`]. Entry `(i, j)` is the fault-aware
/// weight of the pair `(subset[i], subset[j])`; the returned matrix is
/// `k x k` for `k = subset.len()`, sized by the job's candidate set rather
/// than the cluster, and nothing O(n²) is ever built.
///
/// Pair screening uses [`Topology::route_touches`] (closed-form for the
/// in-tree families): a pair no flaky node's route membership can perturb
/// is served as the exact `hops as f32` without routing; perturbed pairs
/// are routed and accumulated with the very loop of
/// [`fault_aware_distance`], keeping bit-identity with the dense reference
/// on the extracted entries (asserted in `tests/proptests.rs`).
pub fn fault_aware_submatrix(
    topo: &dyn Topology,
    outage: &[f64],
    subset: &[usize],
    ws: &mut CostWorkspace,
) -> DistanceMatrix {
    let m = topo.num_nodes();
    assert_eq!(outage.len(), m);
    debug_assert!(subset.iter().all(|&n| n < m));
    ws.prepare(outage);
    let CostWorkspace {
        flaky,
        flaky_nodes,
        route,
        ..
    } = ws;
    let is_flaky = |n: usize| n < flaky.len() && flaky[n];
    let k = subset.len();
    let mut dist = DistanceMatrix::zeros(k);
    for i in 0..k {
        for j in (i + 1)..k {
            // route the (lo, hi) orientation the dense reference uses
            let (lo, hi) = (subset[i].min(subset[j]), subset[i].max(subset[j]));
            if lo == hi {
                continue; // duplicate candidate: weight 0, as dense extract gives
            }
            let touched = flaky_nodes
                .iter()
                .any(|&f| topo.route_touches(lo, hi, f as usize));
            let w = if touched {
                topo.route_into(lo, hi, route);
                let mut w = 0.0f32;
                for l in route.iter() {
                    w += HOP_COST;
                    if is_flaky(l.src) || is_flaky(l.dst) {
                        w += HOP_COST * FAULT_FACTOR;
                    }
                }
                w
            } else {
                topo.hops(lo, hi) as f32
            };
            dist.set(i, j, w);
            dist.set(j, i, w);
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Torus, TorusDims};

    #[test]
    fn indirect_topologies_inflate_on_flaky_endpoints_only() {
        // fat-tree routes transit switches, never compute nodes: Eq. 1
        // inflates exactly the pairs with a flaky endpoint
        let f = crate::topology::FatTree::new(4).unwrap();
        let mut outage = vec![0.0; 16];
        outage[1] = 0.05;
        let d = fault_aware_distance(&f, &outage);
        // exactly one link of each route touches the flaky node (its
        // access link); every switch-to-switch hop stays at cost 1
        assert_eq!(d.get(0, 1), 2.0 + 100.0);
        assert_eq!(d.get(0, 2), 4.0); // same pod, clean endpoints
        assert_eq!(d.get(0, 4), 6.0); // cross pod, clean endpoints
        assert_eq!(d.get(1, 4), 6.0 + 100.0);
    }

    #[test]
    fn no_faults_reduces_to_hops() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let d = fault_aware_distance(&t, &vec![0.0; 64]);
        let hops = DistanceMatrix::from_torus_hops(&t);
        for u in 0..64 {
            for v in 0..64 {
                assert_eq!(d.get(u, v), hops.get(u, v));
            }
        }
    }

    #[test]
    fn flaky_endpoint_inflates_links() {
        let t = Torus::new(TorusDims::new(8, 1, 1));
        let mut outage = vec![0.0; 8];
        outage[1] = 0.05;
        let d = fault_aware_distance(&t, &outage);
        // 0 -> 1: one link touching node 1 -> 1 + 100
        assert_eq!(d.get(0, 1), 101.0);
        // 0 -> 2 routes 0->1->2: both links touch node 1 -> 2 + 200
        assert_eq!(d.get(0, 2), 202.0);
        // 0 -> 7 wraps the other way, fault-free
        assert_eq!(d.get(0, 7), 1.0);
        // 4 -> 6: fault-free segment
        assert_eq!(d.get(4, 6), 2.0);
    }

    #[test]
    fn failed_path_costs_more_than_any_clean_path() {
        // the paper's rationale: one flaky link (101) > diameter (12) of
        // the 8x8x8 torus.
        let t = Torus::new(TorusDims::new(8, 8, 8));
        let mut outage = vec![0.0; 512];
        outage[100] = 0.02;
        let d = fault_aware_distance(&t, &outage);
        let clean_max = DistanceMatrix::from_torus_hops(&t).max();
        // any pair whose route touches node 100 costs > clean_max
        let neighbors = t.neighbors(100);
        for &nb in &neighbors {
            assert!(d.get(nb, 100) > clean_max);
        }
    }

    #[test]
    fn indexed_engine_is_bit_identical_to_dense() {
        use crate::topology::{Dragonfly, DragonflyParams, FatTree, TopoIndex};
        // ascending node counts (12, 16, 32): the shared workspace must
        // survive growing to a larger platform mid-life
        let topos: Vec<Box<dyn Topology>> = vec![
            Box::new(Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap()),
            Box::new(FatTree::new(4).unwrap()),
            Box::new(Torus::new(TorusDims::new(4, 4, 2))),
        ];
        let mut rng = crate::rng::Rng::new(17);
        let mut ws = crate::topology::CostWorkspace::new();
        for t in &topos {
            let n = t.num_nodes();
            let index = TopoIndex::build(t.as_ref());
            for n_flaky in [0usize, 1, 3, n / 2, n] {
                let mut outage = vec![0.0; n];
                for f in rng.sample_distinct(n, n_flaky) {
                    outage[f] = 0.01 + rng.f64() * 0.5;
                }
                let dense = fault_aware_distance(t.as_ref(), &outage);
                let fast = fault_aware_distance_indexed(&index, t.as_ref(), &outage, &mut ws);
                for (a, b) in dense.as_slice().iter().zip(fast.as_slice()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} flaky={n_flaky}", t.describe());
                }
                if n_flaky == 0 {
                    assert_eq!(ws.pairs_patched(), 0);
                }
            }
        }
    }

    #[test]
    fn submatrix_matches_the_dense_extract_bit_for_bit() {
        use crate::topology::{Dragonfly, DragonflyParams, FatTree};
        let topos: Vec<Box<dyn Topology>> = vec![
            Box::new(Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap()),
            Box::new(FatTree::new(4).unwrap()),
            Box::new(Torus::new(TorusDims::new(4, 4, 2))),
        ];
        let mut rng = crate::rng::Rng::new(23);
        let mut ws = crate::topology::CostWorkspace::new();
        for t in &topos {
            let n = t.num_nodes();
            for n_flaky in [0usize, 2, n / 3] {
                let mut outage = vec![0.0; n];
                for f in rng.sample_distinct(n, n_flaky) {
                    outage[f] = 0.01 + rng.f64() * 0.5;
                }
                let dense = fault_aware_distance(t.as_ref(), &outage);
                // the full set and a few random subsets
                let full: Vec<usize> = (0..n).collect();
                let mut subsets = vec![full];
                for _ in 0..4 {
                    let k = 1 + rng.below_usize(n);
                    subsets.push(rng.sample_distinct(n, k));
                }
                for subset in &subsets {
                    let sub = fault_aware_submatrix(t.as_ref(), &outage, subset, &mut ws);
                    let reference = dense.extract(subset);
                    for (a, b) in reference.as_slice().iter().zip(sub.as_slice()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{}", t.describe());
                    }
                }
            }
        }
    }

    #[test]
    fn symmetric() {
        let t = Torus::new(TorusDims::new(4, 4, 2));
        let mut outage = vec![0.0; 32];
        outage[5] = 0.1;
        outage[20] = 0.3;
        let d = fault_aware_distance(&t, &outage);
        for u in 0..32 {
            for v in 0..32 {
                assert_eq!(d.get(u, v), d.get(v, u));
            }
        }
    }
}
