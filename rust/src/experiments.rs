//! Experiment drivers behind the `repro` CLI: one function per paper
//! artifact (Figures 1, 3a, 3b, 4, 5a/5b and Table 1) plus utilities.
//!
//! Every driver prints the table the paper reports and saves a CSV under
//! the results directory. Seeds make all of them bit-reproducible.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use tofa::apps::npb_dt::NpbDt;
use tofa::apps::{lammps_proxy::LammpsProxy, ring::RingApp, stencil::Stencil2D, MpiApp};
use tofa::batch::{run_grid, BatchConfig, BatchRunner, Parallelism};
use tofa::commgraph::{heatmap, SparseComm};
use tofa::error::Error;
use tofa::mapping::multilevel::MultilevelMapper;
use tofa::mapping::{cost, place as place_policy, PlacementPolicy};
use tofa::profiler::profile_app;
use tofa::report::bench::{write_bench_json, JsonValue};
use tofa::report::{fmt_secs, improvement_pct, Table};
use tofa::rng::Rng;
use tofa::sim::executor::Simulator;
use tofa::sim::fault::{FaultSpec, FaultTrace};
use tofa::slurm::sched::workload::{self, Arrivals, CampaignWorkload, TraceConfig};
use tofa::slurm::sched::{run_campaign, run_sweep, RecoveryPolicy, SchedConfig, WorkloadSpec};
use tofa::topology::{Dragonfly, DragonflyParams, FatTree, MetricMode, Platform, TorusDims};

type Result<T> = std::result::Result<T, Error>;

/// Platform-topology selection from the `repro` CLI (`--topology=` plus
/// the per-family size flags). The paper's platform — the 8x8x8 torus —
/// stays the default, so `repro` without flags reproduces the figures
/// bit-for-bit.
#[derive(Debug, Clone)]
pub struct TopoCliOpts {
    /// `torus` | `fattree` | `dragonfly`.
    pub topology: String,
    /// Torus dimensions (`--torus=XxYxZ`).
    pub torus: String,
    /// Fat-tree arity (`--fattree-k=<k>`, k even; k^3/4 nodes).
    pub fattree_k: usize,
    /// Dragonfly parameters (`--dragonfly=GxAxPxH`: groups x routers x
    /// hosts-per-router x global-links-per-router).
    pub dragonfly: String,
    /// Distance metric (`--metric=auto|dense|implicit`).
    pub metric: String,
}

impl Default for TopoCliOpts {
    fn default() -> Self {
        TopoCliOpts {
            topology: "torus".to_string(),
            torus: "8x8x8".to_string(),
            fattree_k: 8, // 128 nodes
            dragonfly: "9x4x4x2".to_string(), // 144 nodes
            metric: "auto".to_string(),
        }
    }
}

impl TopoCliOpts {
    /// Build the platform (paper simulation parameters) for the selected
    /// topology and size.
    pub fn platform(&self) -> Result<Platform> {
        let metric = MetricMode::parse(&self.metric)?;
        Ok(match self.topology.as_str() {
            "torus" => Platform::paper_default(TorusDims::parse(&self.torus)?),
            "fattree" => {
                Platform::paper_default_on(Arc::new(FatTree::new(self.fattree_k)?))
            }
            "dragonfly" => Platform::paper_default_on(Arc::new(Dragonfly::new(
                DragonflyParams::parse(&self.dragonfly)?,
            )?)),
            other => {
                return Err(Error::Topology(format!(
                    "unknown topology: {other} (expected torus|fattree|dragonfly)"
                )))
            }
        }
        .with_metric(metric))
    }
}

/// Fault-model selection from the `repro` CLI (`--fault-model=` plus the
/// model-specific knobs). The figures' per-experiment faulty-node counts
/// (`n_f` = 16 for Fig. 4, 8/16 for Fig. 5) stay with the figure; these
/// options choose *how* those nodes fail.
#[derive(Debug, Clone)]
pub struct FaultCliOpts {
    /// `iid` | `correlated` | `weibull` | `trace`.
    pub model: String,
    /// Outage probability: per node (`iid`), or at the horizon (`weibull`).
    pub p_f: f64,
    /// Faulty racks for `correlated` (0 = one rack per 8 faulty nodes).
    pub domains: usize,
    /// Whole-rack outage probability for `correlated`.
    pub p_domain: f64,
    /// Weibull shape `k`.
    pub weibull_shape: f64,
    /// Planning horizon in simulated seconds (`weibull`).
    pub horizon_s: f64,
    /// Down-interval trace file (`trace`).
    pub trace_path: Option<PathBuf>,
}

impl Default for FaultCliOpts {
    fn default() -> Self {
        FaultCliOpts {
            model: "iid".to_string(),
            p_f: 0.02,
            domains: 0,
            p_domain: 0.05,
            weibull_shape: 0.7,
            horizon_s: 1.0,
            trace_path: None,
        }
    }
}

impl FaultCliOpts {
    /// Build the concrete [`FaultSpec`] for an experiment that faults
    /// `n_faulty` nodes on `platform`.
    pub fn spec(&self, platform: &Platform, n_faulty: usize) -> Result<FaultSpec> {
        // validate probabilities here, at the CLI boundary: the model
        // constructors only debug_assert, so a release binary would
        // otherwise run a degenerate experiment instead of erroring
        let check_prob = |flag: &str, p: f64| -> Result<()> {
            if (0.0..=1.0).contains(&p) {
                Ok(())
            } else {
                Err(Error::Fault(format!("{flag} must be in [0, 1], got {p}")))
            }
        };
        match self.model.as_str() {
            "iid" => {
                check_prob("--p-f", self.p_f)?;
                Ok(FaultSpec::Iid {
                    n_faulty,
                    p_f: self.p_f,
                })
            }
            "correlated" => {
                check_prob("--p-domain", self.p_domain)?;
                let rack = platform.num_nodes() / platform.num_racks();
                let domains = if self.domains > 0 {
                    self.domains
                } else {
                    (n_faulty / rack).max(1)
                };
                Ok(FaultSpec::CorrelatedRacks {
                    domains,
                    p_domain: self.p_domain,
                })
            }
            "weibull" => Ok(FaultSpec::Weibull {
                n_faulty,
                shape: self.weibull_shape,
                p_horizon: self.p_f,
                horizon_s: self.horizon_s,
            }),
            "trace" => {
                let path = self.trace_path.as_ref().ok_or_else(|| {
                    Error::Fault("--fault-trace=<path> is required with --fault-model=trace".into())
                })?;
                Ok(FaultSpec::Trace {
                    trace: Arc::new(FaultTrace::from_file(path)?),
                })
            }
            other => Err(Error::Fault(format!(
                "unknown fault model: {other} (expected iid|correlated|weibull|trace)"
            ))),
        }
    }
}

/// `repro sched` options (cluster-level event-driven scheduler).
#[derive(Debug, Clone)]
pub struct SchedCliOpts {
    /// Jobs in the workload (`--jobs`).
    pub jobs: usize,
    /// Mean interarrival gap in simulated seconds; 0 = batch dump
    /// (`--arrival`).
    pub arrival_s: f64,
    /// Queueing policy: `fifo` | `backfill` (`--policy`, `--backfill`).
    pub policy: String,
    /// Job-size mix `ranks:weight,...`; empty = platform-scaled default
    /// (`--mix`).
    pub mix: String,
    /// Faulty-node count for the fault spec (`--n-faulty`).
    pub n_faulty: usize,
    /// Heartbeat health-epoch period, seconds; 0 = off (`--hb-period`).
    pub hb_period_s: f64,
    /// Restart budget per job (`--max-restarts`).
    pub max_restarts: u32,
    /// In-job recovery policy: `abort` | `ckpt:<interval>` | `shrink`
    /// (`--recovery`).
    pub recovery: String,
    /// Wall-clock cost of one checkpoint write (`--ckpt-cost`).
    pub ckpt_cost_s: f64,
    /// Reduced-size smoke run for CI (`--smoke`).
    pub smoke: bool,
}

impl Default for SchedCliOpts {
    fn default() -> Self {
        SchedCliOpts {
            jobs: 100,
            arrival_s: 0.0,
            policy: "fifo".to_string(),
            mix: String::new(),
            n_faulty: 16,
            hb_period_s: 0.0,
            max_restarts: 100,
            recovery: "abort".to_string(),
            ckpt_cost_s: 0.05,
            smoke: false,
        }
    }
}

/// Parse a `ranks:weight,...` job-size mix (shared by `repro sched` and
/// `repro campaign`).
fn parse_mix(mix: &str) -> Result<Vec<(usize, f64)>> {
    let mk_err = |s: &str| Error::Slurm(format!("bad --mix entry: {s} (want ranks:weight)"));
    let mix: Vec<(usize, f64)> = mix
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|entry| {
            let (r, w) = entry.split_once(':').ok_or_else(|| mk_err(entry))?;
            let ranks: usize = r.parse().map_err(|_| mk_err(entry))?;
            let weight: f64 = w.parse().map_err(|_| mk_err(entry))?;
            // reject degenerate entries here, at the CLI boundary —
            // the workload generator would otherwise assert/panic
            if ranks == 0 || !weight.is_finite() || weight <= 0.0 {
                return Err(Error::Slurm(format!(
                    "bad --mix entry: {entry} (ranks must be > 0, weight > 0)"
                )));
            }
            Ok((ranks, weight))
        })
        .collect::<Result<_>>()?;
    if mix.is_empty() {
        return Err(Error::Slurm("--mix has no entries".into()));
    }
    Ok(mix)
}

/// `repro sched`: push a workload of concurrent MPI jobs through the
/// cluster-level event-driven scheduler (shared `NodeLedger` allocation
/// state, FIFO or conservative backfill) and report makespan / queue wait
/// / utilization per placement policy, next to the Fig. 4/5-style abort
/// statistics.
pub fn sched(
    results: &Path,
    seed: u64,
    workers: usize,
    topo_cli: &TopoCliOpts,
    fault_cli: &FaultCliOpts,
    opts: &SchedCliOpts,
) -> Result<()> {
    let platform = topo_cli.platform()?;
    let n = platform.num_nodes();
    let backfill = match opts.policy.as_str() {
        "fifo" => false,
        "backfill" => true,
        other => {
            return Err(Error::Slurm(format!(
                "unknown --policy: {other} (expected fifo|backfill)"
            )))
        }
    };
    let mut workload = WorkloadSpec::paper_like(n);
    workload.seed = seed ^ 0x5eed;
    workload.jobs = opts.jobs;
    workload.mean_interarrival_s = opts.arrival_s;
    if !opts.mix.is_empty() {
        workload.mix = parse_mix(&opts.mix)?;
    }
    if opts.smoke {
        workload.jobs = workload.jobs.min(12);
        workload.steps = 2;
    }
    let n_faulty = opts.n_faulty.min(n / 2);
    let fault = fault_cli.spec(&platform, n_faulty)?;
    let recovery = RecoveryPolicy::parse(&opts.recovery)?;
    let config = SchedConfig {
        placement: PlacementPolicy::Tofa, // overridden per cell
        backfill,
        max_restarts: opts.max_restarts,
        heartbeat_period_s: opts.hb_period_s,
        recovery,
        ckpt_cost_s: opts.ckpt_cost_s,
        seed,
    };
    let cells = [
        (PlacementPolicy::DefaultSlurm, backfill),
        (PlacementPolicy::Tofa, backfill),
    ];
    let policy_name = if backfill { "backfill" } else { "fifo" };
    let title = format!(
        "Cluster scheduler: {} jobs, {} queue, {} recovery, {}; {}",
        workload.jobs,
        policy_name,
        recovery,
        platform.topology().describe(),
        fault.describe()
    );
    let wall = std::time::Instant::now();
    let sweep = run_sweep(&platform, &workload, &fault, &cells, &config, workers)?;
    let wall = wall.elapsed();
    let mut t = Table::new(
        &title,
        &[
            "placement",
            "makespan (s)",
            "mean wait (s)",
            "max wait (s)",
            "util (%)",
            "aborts",
            "exhausted",
            "failed",
            "backfills",
            "lost node-s",
            "ckpts",
            "shrinks",
        ],
    );
    for cell in &sweep {
        let r = &cell.result;
        t.row(vec![
            cell.placement.to_string(),
            fmt_secs(r.makespan_s),
            fmt_secs(r.mean_wait_s),
            fmt_secs(r.max_wait_s),
            format!("{:.1}", 100.0 * r.utilization),
            r.total_aborts.to_string(),
            r.exhausted.to_string(),
            r.failed.to_string(),
            r.backfills.to_string(),
            format!("{:.1}", r.lost_node_s),
            r.ckpts.to_string(),
            r.shrinks.to_string(),
        ]);
    }
    print!("{}", t.render());
    let (d, tf) = (&sweep[0].result, &sweep[1].result);
    println!(
        "batch completion (makespan): default {} vs tofa {} ({:.1}% improvement)  \
         mean wait: default {} vs tofa {}",
        fmt_secs(d.makespan_s),
        fmt_secs(tf.makespan_s),
        improvement_pct(d.makespan_s, tf.makespan_s),
        fmt_secs(d.mean_wait_s),
        fmt_secs(tf.mean_wait_s),
    );
    println!(
        "[sched] {} jobs x 2 placements, wall-clock {:.3} s\n",
        workload.jobs,
        wall.as_secs_f64()
    );
    t.save_csv(results)?;
    Ok(())
}

/// `repro campaign` options (trace-driven heavy-traffic campaigns).
#[derive(Debug, Clone)]
pub struct CampaignCliOpts {
    /// Jobs to generate; ignored when `--trace` is given (`--jobs`).
    pub jobs: usize,
    /// Arrival process: `batch` | `poisson` | `diurnal` | `flash`
    /// (`--arrivals`).
    pub arrivals: String,
    /// Mean interarrival gap in simulated seconds (`--arrival`).
    pub mean_gap_s: f64,
    /// Diurnal cycle length in simulated seconds (`--day`).
    pub day_s: f64,
    /// Diurnal peak-to-trough arrival-rate ratio (`--peak-trough`).
    pub peak_to_trough: f64,
    /// Flash-crowd burst count (`--bursts`).
    pub bursts: usize,
    /// Jobs dumped per flash-crowd burst (`--burst-jobs`).
    pub burst_jobs: usize,
    /// Seconds each flash-crowd burst spans (`--burst-span`).
    pub burst_span_s: f64,
    /// Job-size mix `ranks:weight,...`; empty = platform-scaled default
    /// (`--mix`).
    pub mix: String,
    /// Workload trace to replay instead of generating: `.swf` or `.tsv`
    /// (`--trace`).
    pub trace_path: Option<PathBuf>,
    /// Compress (< 1) or stretch (> 1) trace arrival gaps
    /// (`--arrival-scale`).
    pub arrival_scale: f64,
    /// Faulty-node count for the fault spec (`--n-faulty`).
    pub n_faulty: usize,
    /// Heartbeat health-epoch period, seconds; 0 = off (`--hb-period`).
    pub hb_period_s: f64,
    /// Restart budget per job (`--max-restarts`).
    pub max_restarts: u32,
    /// In-job recovery policy: `abort` | `ckpt:<interval>` | `shrink`
    /// (`--recovery`).
    pub recovery: String,
    /// Wall-clock cost of one checkpoint write (`--ckpt-cost`).
    pub ckpt_cost_s: f64,
    /// Write `BENCH_campaign.json` next to the CSV tables (`--emit-json`).
    pub emit_json: bool,
    /// Reduced-size smoke run for CI: at most 200 jobs, 2 cells
    /// (`--smoke`).
    pub smoke: bool,
}

impl Default for CampaignCliOpts {
    fn default() -> Self {
        CampaignCliOpts {
            jobs: 2000,
            arrivals: "poisson".to_string(),
            mean_gap_s: 0.05,
            day_s: 240.0,
            peak_to_trough: 4.0,
            bursts: 4,
            burst_jobs: 50,
            burst_span_s: 1.0,
            mix: String::new(),
            trace_path: None,
            arrival_scale: 1.0,
            n_faulty: 16,
            hb_period_s: 0.0,
            max_restarts: 100,
            recovery: "abort".to_string(),
            ckpt_cost_s: 0.05,
            emit_json: false,
            smoke: false,
        }
    }
}

impl CampaignCliOpts {
    fn arrivals(&self) -> Result<Arrivals> {
        match self.arrivals.as_str() {
            "batch" => Ok(Arrivals::Batch),
            "poisson" => Ok(Arrivals::Poisson {
                mean_gap_s: self.mean_gap_s,
            }),
            "diurnal" => Ok(Arrivals::Diurnal {
                mean_gap_s: self.mean_gap_s,
                day_s: self.day_s,
                peak_to_trough: self.peak_to_trough,
            }),
            "flash" => Ok(Arrivals::FlashCrowd {
                mean_gap_s: self.mean_gap_s,
                bursts: self.bursts,
                burst_jobs: self.burst_jobs,
                burst_span_s: self.burst_span_s,
            }),
            other => Err(Error::Workload(format!(
                "unknown --arrivals: {other} (expected batch|poisson|diurnal|flash)"
            ))),
        }
    }
}

/// `repro campaign`: push a day-long workload (trace replay or bursty
/// synthetic arrivals) through the cluster scheduler per
/// (placement x queue) cell and report queueing-theory metrics — wait and
/// slowdown percentiles, utilization, fragmentation — next to the
/// events-per-second throughput of each cell's event loop.
pub fn campaign(
    results: &Path,
    seed: u64,
    workers: usize,
    topo_cli: &TopoCliOpts,
    fault_cli: &FaultCliOpts,
    opts: &CampaignCliOpts,
) -> Result<()> {
    let platform = topo_cli.platform()?;
    let n = platform.num_nodes();
    let mut jobs = match &opts.trace_path {
        Some(path) => {
            let cfg = TraceConfig::default();
            let mut jobs = workload::load_trace(path, &cfg)?;
            workload::rebase_arrivals(&mut jobs);
            // detlint: allow(float-discipline, 1.0 is the CLI default sentinel meaning "no scaling")
            if opts.arrival_scale != 1.0 {
                workload::scale_arrivals(&mut jobs, opts.arrival_scale);
            }
            workload::clamp_ranks(&mut jobs, n);
            jobs
        }
        None => {
            let mut spec = CampaignWorkload::paper_like(n);
            spec.seed = seed ^ 0xca3b;
            spec.jobs = opts.jobs;
            spec.arrivals = opts.arrivals()?;
            if !opts.mix.is_empty() {
                spec.mix = parse_mix(&opts.mix)?;
            }
            if opts.smoke {
                spec.jobs = spec.jobs.min(200);
                spec.steps_max = spec.steps_min;
            }
            spec.generate()?
        }
    };
    if opts.smoke {
        jobs.truncate(200);
    }
    let n_faulty = opts.n_faulty.min(n / 2);
    let fault = fault_cli.spec(&platform, n_faulty)?;
    let recovery = RecoveryPolicy::parse(&opts.recovery)?;
    let config = SchedConfig {
        placement: PlacementPolicy::Tofa, // overridden per cell
        backfill: false, // overridden per cell
        max_restarts: opts.max_restarts,
        heartbeat_period_s: opts.hb_period_s,
        recovery,
        ckpt_cost_s: opts.ckpt_cost_s,
        seed,
    };
    let cells: &[(PlacementPolicy, bool)] = if opts.smoke {
        &[
            (PlacementPolicy::DefaultSlurm, false),
            (PlacementPolicy::Tofa, true),
        ]
    } else {
        &[
            (PlacementPolicy::DefaultSlurm, false),
            (PlacementPolicy::Tofa, false),
            (PlacementPolicy::DefaultSlurm, true),
            (PlacementPolicy::Tofa, true),
        ]
    };
    let title = format!(
        "Workload campaign: {} jobs, {} recovery, {}; {}",
        jobs.len(),
        recovery,
        platform.topology().describe(),
        fault.describe()
    );
    let campaign = run_campaign(&platform, &jobs, &fault, cells, &config, workers)?;
    let mut t = Table::new(
        &title,
        &[
            "placement",
            "queue",
            "completed",
            "p50 wait (s)",
            "p95 wait (s)",
            "p99 wait (s)",
            "p50 slowdown",
            "p99 slowdown",
            "util (%)",
            "events/s",
        ],
    );
    for cell in &campaign {
        let m = &cell.metrics;
        t.row(vec![
            cell.placement.to_string(),
            if cell.backfill { "backfill" } else { "fifo" }.to_string(),
            format!("{}/{}", m.completed, m.total_jobs),
            fmt_secs(m.wait.p50),
            fmt_secs(m.wait.p95),
            fmt_secs(m.wait.p99),
            format!("{:.2}", m.slowdown.p50),
            format!("{:.2}", m.slowdown.p99),
            format!("{:.1}", 100.0 * m.utilization),
            format!("{:.0}", cell.events_per_s()),
        ]);
    }
    print!("{}", t.render());
    let base = &campaign[0].metrics;
    let best = &campaign[campaign.len() - 1];
    let best_queue = if best.backfill { "backfill" } else { "fifo" };
    println!(
        "p95 wait: default/fifo {} vs tofa/{} {} ({:.1}% improvement)",
        fmt_secs(base.wait.p95),
        best_queue,
        fmt_secs(best.metrics.wait.p95),
        improvement_pct(base.wait.p95, best.metrics.wait.p95),
    );
    t.save_csv(results)?;
    if opts.emit_json {
        let payload = JsonValue::obj()
            .set("topology", JsonValue::Str(platform.topology().describe()))
            .set("nodes", JsonValue::Int(n as u64))
            .set("jobs", JsonValue::Int(jobs.len() as u64))
            .set("fault", JsonValue::Str(fault.describe()))
            .set("recovery", JsonValue::Str(recovery.to_string()))
            .set("cells", JsonValue::Arr(campaign.iter().map(|c| c.json()).collect()));
        let path = write_bench_json("campaign", payload)?;
        println!("[campaign] wrote {}", path.display());
    }
    Ok(())
}

/// Parse an app spec: `lammps:<ranks>` | `npb-dt` | `stencil:<px>x<py>` |
/// `ring:<ranks>`.
pub fn parse_app(spec: &str) -> Result<Box<dyn MpiApp>> {
    let mk_err = || Error::Placement(format!("unknown app spec: {spec}"));
    if let Some(r) = spec.strip_prefix("lammps:") {
        let ranks: usize = r.parse().map_err(|_| mk_err())?;
        return Ok(Box::new(LammpsProxy::rhodopsin(ranks)));
    }
    if spec == "npb-dt" {
        return Ok(Box::new(NpbDt::class_c()));
    }
    if let Some(r) = spec.strip_prefix("stencil:") {
        let (px, py) = r.split_once('x').ok_or_else(mk_err)?;
        return Ok(Box::new(Stencil2D::new(
            px.parse().map_err(|_| mk_err())?,
            py.parse().map_err(|_| mk_err())?,
            128,
            50,
        )));
    }
    if let Some(r) = spec.strip_prefix("ring:") {
        let ranks: usize = r.parse().map_err(|_| mk_err())?;
        return Ok(Box::new(RingApp::new(ranks, 64.0 * 1024.0, 50)));
    }
    Err(mk_err())
}

/// Figure 1: traffic heatmaps for LAMMPS (128p) and NPB-DT class C (85p).
pub fn fig1(results: &Path) -> Result<()> {
    for (label, app) in [
        ("fig1a_lammps_128", Box::new(LammpsProxy::rhodopsin(128)) as Box<dyn MpiApp>),
        ("fig1b_npb_dt_85", Box::new(NpbDt::class_c())),
    ] {
        let profile = profile_app(app.as_ref());
        println!(
            "== Figure 1 ({label}): {} ranks, total {:.1} MB, diagonal mass(k=8) {:.2} ==",
            profile.num_ranks(),
            profile.volume.total() / 2.0 / 1e6,
            profile.volume.diagonal_mass(8)
        );
        println!("{}", heatmap::ascii(&profile.volume, 64));
        let pgm = heatmap::pgm(&profile.volume);
        std::fs::create_dir_all(results)?;
        std::fs::write(results.join(format!("{label}.pgm")), pgm)?;
    }
    println!("heatmaps written under {}", results.display());
    Ok(())
}

/// Simulate the report metric for one app under each policy.
fn metric_per_policy(
    app: &dyn MpiApp,
    platform: &Platform,
    policies: &[PlacementPolicy],
    seed: u64,
) -> Result<Vec<(PlacementPolicy, f64)>> {
    let comm = profile_app(app).volume;
    let dist = platform.hop_matrix();
    let mut sim = Simulator::new(app, platform);
    let mut out = Vec::new();
    for &policy in policies {
        let mut rng = Rng::new(seed);
        let placement = place_policy(policy, &comm, &dist, &mut rng)?;
        out.push((policy, sim.metric_value(&placement.assignment)));
    }
    Ok(out)
}

/// Figure 3a: NPB-DT execution time under scotch / default / greedy /
/// random on the 8x8x8 torus (no faults).
pub fn fig3a(results: &Path, seed: u64) -> Result<()> {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let app = NpbDt::class_c();
    let policies = [
        PlacementPolicy::DefaultSlurm,
        PlacementPolicy::Random,
        PlacementPolicy::Greedy,
        PlacementPolicy::Scotch,
    ];
    let rows = metric_per_policy(&app, &platform, &policies, seed)?;
    let scotch = rows
        .iter()
        .find(|(p, _)| *p == PlacementPolicy::Scotch)
        // invariant: Scotch is in the `policies` list built right above
        .unwrap()
        .1;
    let mut t = Table::new(
        "Figure 3a: NPB-DT class C (85p) execution time",
        &["policy", "exec time (s)", "scotch improvement (%)"],
    );
    for (p, secs) in &rows {
        t.row(vec![
            p.to_string(),
            fmt_secs(*secs),
            format!("{:.1}", improvement_pct(*secs, scotch)),
        ]);
    }
    print!("{}", t.render());
    t.save_csv(results)?;
    Ok(())
}

/// Figure 3b: LAMMPS timesteps/s for 32..256 processes per policy.
pub fn fig3b(results: &Path, seed: u64) -> Result<()> {
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let policies = [
        PlacementPolicy::DefaultSlurm,
        PlacementPolicy::Random,
        PlacementPolicy::Greedy,
        PlacementPolicy::Scotch,
    ];
    let mut t = Table::new(
        "Figure 3b: LAMMPS timesteps/s",
        &["ranks", "default-slurm", "random", "greedy", "scotch"],
    );
    for ranks in [32usize, 64, 128, 256] {
        let app = LammpsProxy::rhodopsin(ranks);
        let rows = metric_per_policy(&app, &platform, &policies, seed)?;
        let mut cells = vec![ranks.to_string()];
        cells.extend(rows.iter().map(|(_, v)| format!("{v:.1}")));
        t.row(cells);
    }
    print!("{}", t.render());
    t.save_csv(results)?;
    Ok(())
}

/// Table 1: LAMMPS 256p timesteps/s across torus arrangements,
/// Default-Slurm vs TOFA (fault-free, so TOFA = Scotch path).
pub fn table1(results: &Path, seed: u64) -> Result<()> {
    let arrangements = ["8x8x8", "4x8x16", "8x4x16", "4x4x32", "4x32x4"];
    let mut t = Table::new(
        "Table 1: LAMMPS 256p timesteps/s by torus arrangement",
        &["arrangement", "default-slurm", "tofa"],
    );
    let app = LammpsProxy::rhodopsin(256);
    for arr in arrangements {
        let dims = TorusDims::parse(arr)?;
        let platform = Platform::paper_default(dims);
        let rows = metric_per_policy(
            &app,
            &platform,
            &[PlacementPolicy::DefaultSlurm, PlacementPolicy::Scotch],
            seed,
        )?;
        t.row(vec![
            arr.to_string(),
            format!("{:.1}", rows[0].1),
            format!("{:.1}", rows[1].1),
        ]);
    }
    print!("{}", t.render());
    t.save_csv(results)?;
    Ok(())
}

/// Shared driver for the batch experiments (Figures 4, 5a, 5b).
///
/// Runs the `(batch, policy)` grid on the sharded parallel engine
/// (`workers` threads; 0 = one per core) with one shared phase-solve
/// cache. Results are independent of the worker count.
#[allow(clippy::too_many_arguments)]
fn batch_experiment(
    results: &Path,
    base_title: &str,
    app: &dyn MpiApp,
    n_faulty: usize,
    topo_cli: &TopoCliOpts,
    fault_cli: &FaultCliOpts,
    batches: usize,
    instances: usize,
    seed: u64,
    workers: usize,
) -> Result<()> {
    let platform = topo_cli.platform()?;
    let runner = BatchRunner::new(app, &platform);
    let fault = fault_cli.spec(&platform, n_faulty)?;
    // compose the fault clause from the actual spec so tables and CSVs
    // are never mislabeled; the paper's exact regime (8x8x8 torus, iid at
    // 2%) keeps its canonical "(N faulty @ 2%)" wording
    let paper_topology = platform
        .topology()
        .as_torus()
        .is_some_and(|t| t.dims() == TorusDims::new(8, 8, 8));
    let paper_regime =
        // detlint: allow(float-discipline, 0.02 is the paper's exact literal regime tag)
        paper_topology && matches!(&fault, FaultSpec::Iid { p_f, .. } if *p_f == 0.02);
    let title = if paper_regime {
        format!("{base_title} ({n_faulty} faulty @ 2%)")
    } else {
        format!(
            "{base_title} ({}; {})",
            platform.topology().describe(),
            fault.describe()
        )
    };
    let config = BatchConfig {
        instances,
        fault,
        parallelism: Parallelism::fixed(workers),
        ..Default::default()
    };
    let mut t = Table::new(
        &title,
        &[
            "batch",
            "default (s)",
            "tofa (s)",
            "improvement (%)",
            "default aborts",
            "tofa aborts",
        ],
    );
    let policies = [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa];
    let wall = std::time::Instant::now();
    let grid = run_grid(&runner, &policies, &config, batches, seed)?;
    let wall = wall.elapsed();
    let (mut sum_d, mut sum_t) = (0.0, 0.0);
    let (mut ab_d, mut ab_t) = (0usize, 0usize);
    for pair in grid.cells.chunks(2) {
        let (d, tt) = (&pair[0].result, &pair[1].result);
        sum_d += d.completion_s;
        sum_t += tt.completion_s;
        ab_d += d.aborted_instances;
        ab_t += tt.aborted_instances;
        t.row(vec![
            pair[0].batch_index.to_string(),
            fmt_secs(d.completion_s),
            fmt_secs(tt.completion_s),
            format!("{:.1}", improvement_pct(d.completion_s, tt.completion_s)),
            d.aborted_instances.to_string(),
            tt.aborted_instances.to_string(),
        ]);
    }
    print!("{}", t.render());
    let total = (batches * instances) as f64;
    println!(
        "avg improvement: {:.1}%   abort ratio: default {:.1}% vs tofa {:.1}%",
        improvement_pct(sum_d, sum_t),
        100.0 * ab_d as f64 / total,
        100.0 * ab_t as f64 / total,
    );
    println!(
        "[parallel] {} grid workers, wall-clock {:.3} s (slowest shard {:.3} s), \
         phase-cache {} entries, hit-rate {:.1}%\n",
        grid.telemetry.shards.len(),
        wall.as_secs_f64(),
        grid.telemetry.slowest_shard().as_secs_f64(),
        runner.cache().len(),
        100.0 * grid.telemetry.hit_rate(),
    );
    t.save_csv(results)?;
    Ok(())
}

/// Figure 4: NPB-DT batches with 16 faulty nodes (topology and model from
/// the CLI; the paper's regime is the 8x8x8 torus, `--fault-model=iid` at
/// 2%).
#[allow(clippy::too_many_arguments)]
pub fn fig4(
    results: &Path,
    seed: u64,
    batches: usize,
    instances: usize,
    workers: usize,
    topo: &TopoCliOpts,
    fault: &FaultCliOpts,
) -> Result<()> {
    let app = NpbDt::class_c();
    batch_experiment(
        results,
        "Figure 4: NPB-DT batch completion",
        &app,
        16,
        topo,
        fault,
        batches,
        instances,
        seed,
        workers,
    )
}

/// Figures 5a / 5b: LAMMPS 64p batches with 8 or 16 faulty nodes.
#[allow(clippy::too_many_arguments)]
pub fn fig5(
    results: &Path,
    seed: u64,
    n_faulty: usize,
    batches: usize,
    instances: usize,
    tag: &str,
    workers: usize,
    topo: &TopoCliOpts,
    fault: &FaultCliOpts,
) -> Result<()> {
    let app = LammpsProxy::rhodopsin(64);
    batch_experiment(
        results,
        &format!("Figure {tag}: LAMMPS 64p batch completion"),
        &app,
        n_faulty,
        topo,
        fault,
        batches,
        instances,
        seed,
        workers,
    )
}

/// `repro profile`: communication-graph stats and heatmap for an app.
pub fn profile(app_spec: &str) -> Result<()> {
    let app = parse_app(app_spec)?;
    let p = profile_app(app.as_ref());
    println!(
        "app {} ranks {}  G_v total {:.2} MB  G_m msgs {}  diag-mass(8) {:.2}",
        app.name(),
        p.num_ranks(),
        p.volume.total() / 2.0 / 1e6,
        p.messages.total() as u64 / 2,
        p.volume.diagonal_mass(8),
    );
    println!("{}", heatmap::ascii(&p.volume, 48));
    Ok(())
}

/// `repro place`: mapping-quality comparison across policies.
/// `policy` (from `--policy=`) restricts the table to one parsed policy;
/// `None` compares the paper's fault-unaware baselines plus the
/// multilevel mapper.
pub fn place(
    app_spec: &str,
    topo_cli: &TopoCliOpts,
    seed: u64,
    policy: Option<&str>,
) -> Result<()> {
    let app = parse_app(app_spec)?;
    let platform = topo_cli.platform()?;
    let comm = profile_app(app.as_ref()).volume;
    let dist = platform.hop_matrix();
    let mut sim = Simulator::new(app.as_ref(), &platform);
    let policies: Vec<PlacementPolicy> = match policy {
        Some(p) => {
            let parsed = PlacementPolicy::parse(p).ok_or_else(|| {
                Error::Placement(format!("unknown placement policy {p:?}"))
            })?;
            vec![parsed]
        }
        None => vec![
            PlacementPolicy::DefaultSlurm,
            PlacementPolicy::Random,
            PlacementPolicy::Greedy,
            PlacementPolicy::Scotch,
            PlacementPolicy::Multilevel,
        ],
    };
    let mut t = Table::new(
        &format!(
            "Placement quality: {} on {}",
            app.name(),
            platform.topology().describe()
        ),
        &["policy", "hop-bytes (MB*hop)", "avg dilation", "max congestion (MB)", "metric"],
    );
    for policy in policies {
        let mut rng = Rng::new(seed);
        let pl = if policy == PlacementPolicy::Multilevel {
            // the sparse path — same one the scheduler uses on implicit
            // platforms, so the CLI smoke-tests exactly that code
            let g = SparseComm::from_matrix(&comm);
            let oracle = platform.hop_oracle();
            let hosts: Vec<usize> = (0..platform.num_nodes()).collect();
            MultilevelMapper::default().map_sparse(&g, &oracle, &hosts)?
        } else {
            place_policy(policy, &comm, &dist, &mut rng)?
        };
        let hb = cost::hop_bytes_cost(&comm, &dist, &pl.assignment);
        let (avg_dil, _) = cost::dilation(&comm, &dist, &pl.assignment);
        let (max_cong, _) = cost::congestion(&comm, platform.topology(), &pl.assignment);
        let metric = sim.metric_value(&pl.assignment);
        t.row(vec![
            policy.to_string(),
            format!("{:.1}", hb / 1e6),
            format!("{avg_dil:.2}"),
            format!("{:.1}", max_cong / 1e6),
            format!("{metric:.2}"),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `repro runtime`: PJRT artifact smoke check + cross-validation.
pub fn runtime_check() -> Result<()> {
    use tofa::runtime::{default_artifacts_dir, CostEvaluator};
    let dir = default_artifacts_dir();
    let mut eval = CostEvaluator::load(&dir)?;
    println!(
        "PJRT platform: {}  shapes: {:?}",
        eval.platform_name(),
        eval.shapes()
    );
    let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
    let dist = platform.hop_matrix();
    let app = LammpsProxy::tiny(64, 2);
    let comm = profile_app(&app).volume;
    let mut rng = Rng::new(7);
    let candidates: Vec<Vec<usize>> = (0..eval.shapes().k_batch)
        .map(|_| rng.sample_distinct(512, 64))
        .collect();
    let t0 = std::time::Instant::now();
    let pjrt = eval.batch_costs(&comm, &dist, &candidates)?;
    let t_pjrt = t0.elapsed();
    let t1 = std::time::Instant::now();
    let rust: Vec<f64> = candidates
        .iter()
        .map(|c| cost::hop_bytes_cost(&comm, &dist, c))
        .collect();
    let t_rust = t1.elapsed();
    let max_rel = pjrt
        .iter()
        .zip(&rust)
        .map(|(a, b)| (a - b).abs() / b.max(1.0))
        .fold(0.0, f64::max);
    println!(
        "{} candidates: pjrt {:?} rust {:?} max rel err {:.2e}",
        candidates.len(),
        t_pjrt,
        t_rust,
        max_rel
    );
    assert!(max_rel < 1e-4, "PJRT/rust mismatch");
    println!("runtime check OK");
    Ok(())
}


