//! Result tables and series shared by the CLI and the benches.

pub mod bench;

use std::fmt::Write as _;

/// A simple named table: header row + data rows, printed with aligned
/// columns and dumped as CSV next to the binary output.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (e.g. `Figure 3a`).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Render aligned text.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let _ = writeln!(
            out,
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r, &widths));
        }
        let _ = ncols;
        out
    }

    /// Render CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV under `results/<slug>.csv`.
    pub fn save_csv(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let slug: String = self
            .title
            .to_ascii_lowercase()
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        let path = dir.join(format!("{slug}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format seconds for display.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.3}")
    }
}

/// Percentage improvement of `new` over `base` (positive = better/lower).
pub fn improvement_pct(base: f64, new: f64) -> f64 {
    // detlint: allow(float-discipline, exact 0.0 guard against division, not a comparison)
    if base == 0.0 {
        0.0
    } else {
        (base - new) / base * 100.0
    }
}

/// Nearest-rank percentile of an **already sorted** sample vector:
/// the smallest element with at least `p`% of the samples at or below it
/// (`p` in `[0, 100]`). Empty samples yield 0.0 — campaign aggregates
/// must report zeros, not NaNs, when every job failed.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "unsorted samples");
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let txt = t.render();
        assert!(txt.contains("== Demo =="));
        assert!(txt.contains("a"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    fn improvement_math() {
        assert_eq!(improvement_pct(100.0, 69.0), 31.0);
        assert_eq!(improvement_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn nearest_rank_percentiles() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 95.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&[3.5], 99.0), 3.5);
        // empty samples are 0.0, never NaN
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
