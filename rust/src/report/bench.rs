//! Minimal benchmark harness (criterion is unavailable in the offline
//! build environment; this provides the same warmup + multi-sample
//! median/mean discipline with zero dependencies), plus a tiny JSON
//! emitter so benches can drop machine-readable `BENCH_*.json` trajectory
//! files at the repo root (consumed by CI artifacts and perf tracking).

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id.
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Min / max per-iteration time.
    pub min: Duration,
    /// Max sample.
    pub max: Duration,
    /// Samples collected.
    pub samples: usize,
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12?}  mean {:>12?}  min {:>12?}  max {:>12?}  ({} samples)",
            self.name, self.median, self.mean, self.min, self.max, self.samples
        )
    }
}

/// Run `f` with warmup then timed samples; prints and returns the stats.
///
/// `samples` individual timings of one call each; use closures that do a
/// meaningful unit of work. Results are printed immediately so a crashed
/// bench still reports earlier rows.
pub fn bench<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> Measurement {
    // warmup: 2 calls or 200 ms, whichever first
    let warm_start = Instant::now();
    for _ in 0..2 {
        std::hint::black_box(f());
        if warm_start.elapsed() > Duration::from_millis(200) {
            break;
        }
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let m = Measurement {
        name: name.to_string(),
        median,
        mean,
        min: times[0],
        // invariant: the sampling loop above runs samples.max(1) >= 1
        // iterations, so `times` is never empty
        max: *times.last().unwrap(),
        samples: times.len(),
    };
    println!("{m}");
    m
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n### {title}");
}

/// A JSON value for the bench trajectory files. Hand-rolled (no serde in
/// the offline build environment); covers exactly what bench reports
/// need: numbers, strings, bools, arrays, objects.
#[derive(Debug, Clone)]
pub enum JsonValue {
    /// Float (non-finite values render as `null`).
    Num(f64),
    /// Integer (kept separate so counters render without a decimal).
    Int(u64),
    /// String (escaped on render).
    Str(String),
    /// Boolean.
    Bool(bool),
    /// Array.
    Arr(Vec<JsonValue>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Empty object builder.
    pub fn obj() -> Self {
        JsonValue::Obj(Vec::new())
    }

    /// Insert a key (objects only; panics otherwise — builder misuse).
    pub fn set(mut self, key: &str, value: JsonValue) -> Self {
        match &mut self {
            JsonValue::Obj(pairs) => pairs.push((key.to_string(), value)),
            // invariant: `set` is only chained onto `JsonValue::obj()`;
            // a non-object receiver is a compile-site builder bug, not a
            // runtime condition
            _ => panic!("JsonValue::set on a non-object"),
        }
        self
    }

    /// Render as compact JSON.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            JsonValue::Num(x) if x.is_finite() => out.push_str(&format!("{x}")),
            // JSON has no NaN/Infinity literal: serialize explicitly as
            // null so the field is present (and obviously degenerate)
            // downstream instead of producing a malformed document
            JsonValue::Num(_) => out.push_str("null"),
            JsonValue::Int(x) => out.push_str(&format!("{x}")),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            JsonValue::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            JsonValue::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    JsonValue::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

impl Measurement {
    /// This measurement as a JSON object (durations in nanoseconds).
    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj()
            .set("name", JsonValue::Str(self.name.clone()))
            .set("median_ns", JsonValue::Int(self.median.as_nanos() as u64))
            .set("mean_ns", JsonValue::Int(self.mean.as_nanos() as u64))
            .set("min_ns", JsonValue::Int(self.min.as_nanos() as u64))
            .set("max_ns", JsonValue::Int(self.max.as_nanos() as u64))
            .set("samples", JsonValue::Int(self.samples as u64))
    }
}

/// Repository root: the parent of this crate's manifest directory (the
/// workspace layout is fixed — `rust/` inside the repo). Bench JSON
/// trajectory files land here so CI can glob `BENCH_*.json`. Falls back
/// to the manifest directory itself in the degenerate case where it has
/// no parent (a crate checked out at a filesystem root).
pub fn repo_root() -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).to_path_buf()
}

/// Write a bench trajectory file `BENCH_<name>.json` at the repo root and
/// echo where it went. Content is wrapped with the bench name so files
/// are self-describing.
pub fn write_bench_json(name: &str, payload: JsonValue) -> std::io::Result<PathBuf> {
    let doc = JsonValue::obj()
        .set("bench", JsonValue::Str(name.to_string()))
        .set("payload", payload);
    let path = repo_root().join(format!("BENCH_{name}.json"));
    let mut f = std::fs::File::create(&path)?;
    f.write_all(doc.render().as_bytes())?;
    f.write_all(b"\n")?;
    println!("wrote {}", path.display());
    Ok(path)
}

/// Wall-clock of one worker shard of a parallel region
/// (see [`crate::batch::parallel`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardTiming {
    /// Shard (worker) index.
    pub shard: usize,
    /// Items the shard processed.
    pub items: usize,
    /// Wall-clock the shard spent on them.
    pub wall: Duration,
}

/// Telemetry for one parallel region: per-shard timings plus phase-cache
/// counters for the region. Batch-level reports attribute cache traffic
/// exactly (from simulator-local stats); grid-level reports cover the
/// whole sweep's shared cache.
#[derive(Debug, Clone, Default)]
pub struct ParallelReport {
    /// One entry per worker shard, in shard order.
    pub shards: Vec<ShardTiming>,
    /// Phase-cache lookups attributed to this region.
    pub cache_lookups: u64,
    /// Lookups served from the cache.
    pub cache_hits: u64,
}

impl ParallelReport {
    /// Fraction of cache lookups that hit (0.0 when there were none).
    pub fn hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.cache_lookups as f64
        }
    }

    /// The critical-path shard time (parallel wall-clock lower bound).
    pub fn slowest_shard(&self) -> Duration {
        self.shards.iter().map(|s| s.wall).max().unwrap_or_default()
    }

    /// Items processed across all shards.
    pub fn total_items(&self) -> usize {
        self.shards.iter().map(|s| s.items).sum()
    }
}

impl std::fmt::Display for ParallelReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for s in &self.shards {
            writeln!(f, "shard {:>3}: {:>6} items in {:?}", s.shard, s.items, s.wall)?;
        }
        write!(
            f,
            "phase-cache: {} lookups, {} hits ({:.1}%)",
            self.cache_lookups,
            self.cache_hits,
            100.0 * self.hit_rate()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let m = bench("noop", 5, || 1 + 1);
        assert_eq!(m.samples, 5);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn parallel_report_aggregates() {
        let r = ParallelReport {
            shards: vec![
                ShardTiming {
                    shard: 0,
                    items: 10,
                    wall: Duration::from_millis(4),
                },
                ShardTiming {
                    shard: 1,
                    items: 12,
                    wall: Duration::from_millis(9),
                },
            ],
            cache_lookups: 40,
            cache_hits: 30,
        };
        assert_eq!(r.total_items(), 22);
        assert_eq!(r.slowest_shard(), Duration::from_millis(9));
        assert!((r.hit_rate() - 0.75).abs() < 1e-12);
        let text = r.to_string();
        assert!(text.contains("shard"));
        assert!(text.contains("75.0%"));
    }

    #[test]
    fn json_renders_escaped_and_ordered() {
        let v = JsonValue::obj()
            .set("name", JsonValue::Str("a\"b\\c\nd".into()))
            .set("x", JsonValue::Num(1.5))
            .set("n", JsonValue::Int(7))
            .set("ok", JsonValue::Bool(true))
            .set("bad", JsonValue::Num(f64::NAN))
            .set(
                "arr",
                JsonValue::Arr(vec![JsonValue::Int(1), JsonValue::Int(2)]),
            );
        assert_eq!(
            v.render(),
            r#"{"name":"a\"b\\c\nd","x":1.5,"n":7,"ok":true,"bad":null,"arr":[1,2]}"#
        );
    }

    #[test]
    fn non_finite_floats_serialize_as_explicit_null() {
        // every non-finite flavor, at top level and nested: the document
        // must stay valid JSON with the key present
        let v = JsonValue::obj()
            .set("nan", JsonValue::Num(f64::NAN))
            .set("inf", JsonValue::Num(f64::INFINITY))
            .set("ninf", JsonValue::Num(f64::NEG_INFINITY))
            .set(
                "arr",
                JsonValue::Arr(vec![JsonValue::Num(1.0), JsonValue::Num(f64::NAN)]),
            );
        assert_eq!(
            v.render(),
            r#"{"nan":null,"inf":null,"ninf":null,"arr":[1,null]}"#
        );
    }

    #[test]
    fn measurement_json_has_all_fields() {
        let m = bench("unit", 3, || 0);
        let j = m.to_json().render();
        for key in ["median_ns", "mean_ns", "min_ns", "max_ns", "samples"] {
            assert!(j.contains(key), "{j}");
        }
    }

    #[test]
    fn repo_root_is_the_workspace_root() {
        // the crate lives at <root>/rust, so the root holds the workspace
        // manifest
        assert!(repo_root().join("Cargo.toml").exists());
    }

    #[test]
    fn empty_report_is_harmless() {
        let r = ParallelReport::default();
        assert_eq!(r.hit_rate(), 0.0);
        assert_eq!(r.slowest_shard(), Duration::ZERO);
        assert_eq!(r.total_items(), 0);
    }
}
