//! Minimal benchmark harness (criterion is unavailable in the offline
//! build environment; this provides the same warmup + multi-sample
//! median/mean discipline with zero dependencies).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id.
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Min / max per-iteration time.
    pub min: Duration,
    /// Max sample.
    pub max: Duration,
    /// Samples collected.
    pub samples: usize,
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12?}  mean {:>12?}  min {:>12?}  max {:>12?}  ({} samples)",
            self.name, self.median, self.mean, self.min, self.max, self.samples
        )
    }
}

/// Run `f` with warmup then timed samples; prints and returns the stats.
///
/// `samples` individual timings of one call each; use closures that do a
/// meaningful unit of work. Results are printed immediately so a crashed
/// bench still reports earlier rows.
pub fn bench<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> Measurement {
    // warmup: 2 calls or 200 ms, whichever first
    let warm_start = Instant::now();
    for _ in 0..2 {
        std::hint::black_box(f());
        if warm_start.elapsed() > Duration::from_millis(200) {
            break;
        }
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let m = Measurement {
        name: name.to_string(),
        median,
        mean,
        min: times[0],
        max: *times.last().unwrap(),
        samples: times.len(),
    };
    println!("{m}");
    m
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n### {title}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let m = bench("noop", 5, || 1 + 1);
        assert_eq!(m.samples, 5);
        assert!(m.min <= m.median && m.median <= m.max);
    }
}
