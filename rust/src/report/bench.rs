//! Minimal benchmark harness (criterion is unavailable in the offline
//! build environment; this provides the same warmup + multi-sample
//! median/mean discipline with zero dependencies).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id.
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Min / max per-iteration time.
    pub min: Duration,
    /// Max sample.
    pub max: Duration,
    /// Samples collected.
    pub samples: usize,
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12?}  mean {:>12?}  min {:>12?}  max {:>12?}  ({} samples)",
            self.name, self.median, self.mean, self.min, self.max, self.samples
        )
    }
}

/// Run `f` with warmup then timed samples; prints and returns the stats.
///
/// `samples` individual timings of one call each; use closures that do a
/// meaningful unit of work. Results are printed immediately so a crashed
/// bench still reports earlier rows.
pub fn bench<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> Measurement {
    // warmup: 2 calls or 200 ms, whichever first
    let warm_start = Instant::now();
    for _ in 0..2 {
        std::hint::black_box(f());
        if warm_start.elapsed() > Duration::from_millis(200) {
            break;
        }
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let m = Measurement {
        name: name.to_string(),
        median,
        mean,
        min: times[0],
        max: *times.last().unwrap(),
        samples: times.len(),
    };
    println!("{m}");
    m
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n### {title}");
}

/// Wall-clock of one worker shard of a parallel region
/// (see [`crate::batch::parallel`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardTiming {
    /// Shard (worker) index.
    pub shard: usize,
    /// Items the shard processed.
    pub items: usize,
    /// Wall-clock the shard spent on them.
    pub wall: Duration,
}

/// Telemetry for one parallel region: per-shard timings plus phase-cache
/// counters for the region. Batch-level reports attribute cache traffic
/// exactly (from simulator-local stats); grid-level reports cover the
/// whole sweep's shared cache.
#[derive(Debug, Clone, Default)]
pub struct ParallelReport {
    /// One entry per worker shard, in shard order.
    pub shards: Vec<ShardTiming>,
    /// Phase-cache lookups attributed to this region.
    pub cache_lookups: u64,
    /// Lookups served from the cache.
    pub cache_hits: u64,
}

impl ParallelReport {
    /// Fraction of cache lookups that hit (0.0 when there were none).
    pub fn hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.cache_lookups as f64
        }
    }

    /// The critical-path shard time (parallel wall-clock lower bound).
    pub fn slowest_shard(&self) -> Duration {
        self.shards.iter().map(|s| s.wall).max().unwrap_or_default()
    }

    /// Items processed across all shards.
    pub fn total_items(&self) -> usize {
        self.shards.iter().map(|s| s.items).sum()
    }
}

impl std::fmt::Display for ParallelReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for s in &self.shards {
            writeln!(f, "shard {:>3}: {:>6} items in {:?}", s.shard, s.items, s.wall)?;
        }
        write!(
            f,
            "phase-cache: {} lookups, {} hits ({:.1}%)",
            self.cache_lookups,
            self.cache_hits,
            100.0 * self.hit_rate()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let m = bench("noop", 5, || 1 + 1);
        assert_eq!(m.samples, 5);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn parallel_report_aggregates() {
        let r = ParallelReport {
            shards: vec![
                ShardTiming {
                    shard: 0,
                    items: 10,
                    wall: Duration::from_millis(4),
                },
                ShardTiming {
                    shard: 1,
                    items: 12,
                    wall: Duration::from_millis(9),
                },
            ],
            cache_lookups: 40,
            cache_hits: 30,
        };
        assert_eq!(r.total_items(), 22);
        assert_eq!(r.slowest_shard(), Duration::from_millis(9));
        assert!((r.hit_rate() - 0.75).abs() < 1e-12);
        let text = r.to_string();
        assert!(text.contains("shard"));
        assert!(text.contains("75.0%"));
    }

    #[test]
    fn empty_report_is_harmless() {
        let r = ParallelReport::default();
        assert_eq!(r.hit_rate(), 0.0);
        assert_eq!(r.slowest_shard(), Duration::ZERO);
        assert_eq!(r.total_items(), 0);
    }
}
