//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` lowers the L2 mapping-cost model (which wraps the L1
//! Pallas kernel) to HLO *text*; this module loads the text with the `xla`
//! crate, compiles it once on the PJRT CPU client, and exposes a batched
//! mapping-cost evaluator to the placement hot path. Python never runs at
//! request time.

use std::path::{Path, PathBuf};

use crate::commgraph::CommMatrix;
use crate::error::{Error, Result};
use crate::topology::DistanceMatrix;

/// Shape bucket the artifacts were lowered at (kept in sync with
/// `python/compile/model.py`; validated against the manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactShapes {
    /// Max ranks per job.
    pub n_pad: usize,
    /// Max platform nodes.
    pub m_pad: usize,
    /// Candidates scored per executable call.
    pub k_batch: usize,
}

impl Default for ArtifactShapes {
    fn default() -> Self {
        ArtifactShapes {
            n_pad: 256,
            m_pad: 512,
            k_batch: 32,
        }
    }
}

#[derive(Debug)]
#[cfg_attr(not(any(feature = "pjrt", test)), allow(dead_code))]
struct Manifest {
    n_pad: usize,
    m_pad: usize,
    k_batch: usize,
    mapping_cost: String,
}

/// Minimal parser for the fixed-schema manifest JSON emitted by
/// `python/compile/aot.py` (avoids a serde dependency in the offline
/// build environment). Tolerates whitespace and key order.
#[cfg_attr(not(any(feature = "pjrt", test)), allow(dead_code))]
fn parse_manifest(text: &str) -> Result<Manifest> {
    fn grab_usize(text: &str, key: &str) -> Result<usize> {
        let pat = format!("\"{key}\"");
        let at = text
            .find(&pat)
            .ok_or_else(|| Error::Runtime(format!("manifest missing {key}")))?;
        let rest = &text[at + pat.len()..];
        let rest = rest.trim_start().strip_prefix(':').ok_or_else(|| {
            Error::Runtime(format!("manifest: no value for {key}"))
        })?;
        let digits: String = rest
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        digits
            .parse()
            .map_err(|_| Error::Runtime(format!("manifest: bad value for {key}")))
    }
    fn grab_string(text: &str, key: &str) -> Result<String> {
        let pat = format!("\"{key}\"");
        let at = text
            .find(&pat)
            .ok_or_else(|| Error::Runtime(format!("manifest missing {key}")))?;
        let rest = &text[at + pat.len()..];
        let rest = rest.trim_start().strip_prefix(':').ok_or_else(|| {
            Error::Runtime(format!("manifest: no value for {key}"))
        })?;
        let rest = rest.trim_start().strip_prefix('\"').ok_or_else(|| {
            Error::Runtime(format!("manifest: {key} is not a string"))
        })?;
        let end = rest
            .find('\"')
            .ok_or_else(|| Error::Runtime(format!("manifest: unterminated {key}")))?;
        Ok(rest[..end].to_string())
    }
    Ok(Manifest {
        n_pad: grab_usize(text, "n_pad")?,
        m_pad: grab_usize(text, "m_pad")?,
        k_batch: grab_usize(text, "k_batch")?,
        mapping_cost: grab_string(text, "mapping_cost")?,
    })
}

#[cfg(feature = "pjrt")]
fn xerr(e: impl std::fmt::Display) -> Error {
    Error::Runtime(e.to_string())
}

/// Batched mapping-cost evaluator backed by the PJRT CPU client.
///
/// Reuses padded staging buffers across calls; the only per-call
/// allocations are inside the XLA runtime.
///
/// Gated behind the `pjrt` feature: the `xla` crate that provides the
/// PJRT bindings is not available in the offline build environment (and
/// deliberately not declared in Cargo.toml — see the `[features]` note
/// there; enabling `pjrt` also requires adding a vendored `xla` path
/// dependency). Default builds get the stub below, whose `load` explains
/// the situation. Everything else in the crate (the placement pipeline,
/// the simulator, all figures) is independent of this evaluator.
#[cfg(feature = "pjrt")]
pub struct CostEvaluator {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    shapes: ArtifactShapes,
    // staging
    c_buf: Vec<f32>,
    d_buf: Vec<f32>,
    p_buf: Vec<i32>,
}

#[cfg(feature = "pjrt")]
impl CostEvaluator {
    /// Load from an artifacts directory (expects `model.manifest.json`
    /// and `model.hlo.txt` as produced by `make artifacts`).
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest_path = artifacts_dir.join("model.manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "missing {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = parse_manifest(&text)?;
        let shapes = ArtifactShapes {
            n_pad: manifest.n_pad,
            m_pad: manifest.m_pad,
            k_batch: manifest.k_batch,
        };
        Self::load_hlo(&artifacts_dir.join(&manifest.mapping_cost), shapes)
    }

    /// Load a specific HLO text file with explicit shapes.
    pub fn load_hlo(hlo_path: &Path, shapes: ArtifactShapes) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(xerr)?;
        Ok(CostEvaluator {
            client,
            exe,
            shapes,
            c_buf: vec![0.0; shapes.n_pad * shapes.n_pad],
            d_buf: vec![0.0; shapes.m_pad * shapes.m_pad],
            p_buf: vec![0; shapes.k_batch * shapes.n_pad],
        })
    }

    /// The artifact's shape bucket.
    pub fn shapes(&self) -> ArtifactShapes {
        self.shapes
    }

    /// PJRT platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Score a batch of candidate assignments:
    /// `costs[k] = 1/2 sum_ij C[i,j] * D[cand_k[i], cand_k[j]]`.
    ///
    /// `comm` is NxN with N <= n_pad, `dist` MxM with M <= m_pad; any
    /// number of candidates (chunked internally by `k_batch`).
    pub fn batch_costs(
        &mut self,
        comm: &CommMatrix,
        dist: &DistanceMatrix,
        candidates: &[Vec<usize>],
    ) -> Result<Vec<f64>> {
        let n = comm.len();
        let m = dist.len();
        let sh = self.shapes;
        if n > sh.n_pad {
            return Err(Error::Runtime(format!(
                "{n} ranks exceed artifact n_pad {}",
                sh.n_pad
            )));
        }
        if m > sh.m_pad {
            return Err(Error::Runtime(format!(
                "{m} nodes exceed artifact m_pad {}",
                sh.m_pad
            )));
        }
        // stage C (zero-pad)
        self.c_buf.fill(0.0);
        for i in 0..n {
            let row = comm.row(i);
            for j in 0..n {
                self.c_buf[i * sh.n_pad + j] = row[j] as f32;
            }
        }
        // stage D
        self.d_buf.fill(0.0);
        for u in 0..m {
            let row = dist.row(u);
            self.d_buf[u * sh.m_pad..u * sh.m_pad + m].copy_from_slice(row);
        }
        let c_lit = xla::Literal::vec1(&self.c_buf)
            .reshape(&[sh.n_pad as i64, sh.n_pad as i64])
            .map_err(xerr)?;
        let d_lit = xla::Literal::vec1(&self.d_buf)
            .reshape(&[sh.m_pad as i64, sh.m_pad as i64])
            .map_err(xerr)?;

        let mut out = Vec::with_capacity(candidates.len());
        for chunk in candidates.chunks(sh.k_batch) {
            self.p_buf.fill(0);
            for (k, cand) in chunk.iter().enumerate() {
                debug_assert_eq!(cand.len(), n);
                for (i, &node) in cand.iter().enumerate() {
                    self.p_buf[k * sh.n_pad + i] = node as i32;
                }
            }
            let p_lit = xla::Literal::vec1(&self.p_buf)
                .reshape(&[sh.k_batch as i64, sh.n_pad as i64])
                .map_err(xerr)?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[c_lit.clone(), d_lit.clone(), p_lit])
                .map_err(xerr)?[0][0]
                .to_literal_sync()
                .map_err(xerr)?;
            let tuple = result.to_tuple1().map_err(xerr)?;
            let costs: Vec<f32> = tuple.to_vec().map_err(xerr)?;
            out.extend(costs[..chunk.len()].iter().map(|&c| c as f64));
        }
        Ok(out)
    }
}

/// Stub evaluator for builds without the `pjrt` feature (the offline
/// default). It can never be constructed — `load`/`load_hlo` always
/// return a [`Error::Runtime`] explaining the situation — so the other
/// methods are statically unreachable.
#[cfg(not(feature = "pjrt"))]
pub struct CostEvaluator {
    never: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl CostEvaluator {
    fn unavailable<T>() -> Result<T> {
        Err(Error::Runtime(
            "tofa was built without the `pjrt` feature; the XLA/PJRT runtime \
             (and its `xla` crate dependency) is unavailable in this build. \
             To enable the batched cost evaluator, add a vendored `xla` path \
             dependency to rust/Cargo.toml and rebuild with `--features pjrt`."
                .to_string(),
        ))
    }

    /// Always fails in non-`pjrt` builds; see [`CostEvaluator`].
    pub fn load(_artifacts_dir: &Path) -> Result<Self> {
        Self::unavailable()
    }

    /// Always fails in non-`pjrt` builds; see [`CostEvaluator`].
    pub fn load_hlo(_hlo_path: &Path, _shapes: ArtifactShapes) -> Result<Self> {
        Self::unavailable()
    }

    /// Statically unreachable (no stub evaluator can exist).
    pub fn shapes(&self) -> ArtifactShapes {
        match self.never {}
    }

    /// Statically unreachable (no stub evaluator can exist).
    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    /// Statically unreachable (no stub evaluator can exist).
    pub fn batch_costs(
        &mut self,
        _comm: &CommMatrix,
        _dist: &DistanceMatrix,
        _candidates: &[Vec<usize>],
    ) -> Result<Vec<f64>> {
        match self.never {}
    }
}

/// Locate the artifacts directory: `$TOFA_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("TOFA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::cost::hop_bytes_cost;
    use crate::rng::Rng;
    use crate::topology::{Torus, TorusDims};

    fn artifacts_available() -> Option<PathBuf> {
        if cfg!(not(feature = "pjrt")) {
            return None; // stub build: CostEvaluator::load always errors
        }
        let dir = default_artifacts_dir();
        dir.join("model.manifest.json").exists().then_some(dir)
    }

    #[test]
    fn manifest_parser_handles_whitespace_and_order() {
        let text = r#"{
            "mapping_cost" :  "model.hlo.txt",
            "k_batch": 32, "n_pad":256,
            "m_pad" : 512
        }"#;
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.n_pad, 256);
        assert_eq!(m.m_pad, 512);
        assert_eq!(m.k_batch, 32);
        assert_eq!(m.mapping_cost, "model.hlo.txt");
        assert!(parse_manifest("{}").is_err());
    }

    #[test]
    fn stub_build_reports_unavailable() {
        if cfg!(feature = "pjrt") {
            return;
        }
        match CostEvaluator::load(std::path::Path::new("/nonexistent")) {
            Err(e) => assert!(e.to_string().contains("pjrt")),
            Ok(_) => panic!("stub load must fail"),
        }
    }

    #[test]
    fn pjrt_costs_match_rust_reference() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut eval = CostEvaluator::load(&dir).unwrap();
        let torus = Torus::new(TorusDims::new(8, 8, 8));
        let dist = DistanceMatrix::from_torus_hops(&torus);
        let mut comm = CommMatrix::new(24);
        let mut rng = Rng::new(7);
        for _ in 0..60 {
            let i = rng.below_usize(24);
            let j = rng.below_usize(24);
            if i != j {
                comm.add_sym(i, j, (rng.below(1000) + 1) as f64);
            }
        }
        let candidates: Vec<Vec<usize>> =
            (0..5).map(|_| rng.sample_distinct(512, 24)).collect();
        let got = eval.batch_costs(&comm, &dist, &candidates).unwrap();
        for (k, cand) in candidates.iter().enumerate() {
            let want = hop_bytes_cost(&comm, &dist, cand);
            let rel = (got[k] - want).abs() / want.max(1.0);
            assert!(rel < 1e-4, "cand {k}: pjrt {} vs rust {want}", got[k]);
        }
    }

    #[test]
    fn chunking_handles_more_than_k_batch() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut eval = CostEvaluator::load(&dir).unwrap();
        let kb = eval.shapes().k_batch;
        let torus = Torus::new(TorusDims::new(4, 4, 4));
        let dist = DistanceMatrix::from_torus_hops(&torus);
        let mut comm = CommMatrix::new(8);
        comm.add_sym(0, 7, 100.0);
        let mut rng = Rng::new(3);
        let candidates: Vec<Vec<usize>> =
            (0..kb + 3).map(|_| rng.sample_distinct(64, 8)).collect();
        let got = eval.batch_costs(&comm, &dist, &candidates).unwrap();
        assert_eq!(got.len(), kb + 3);
        for (k, cand) in candidates.iter().enumerate() {
            let want = hop_bytes_cost(&comm, &dist, cand);
            assert!((got[k] - want).abs() / want.max(1.0) < 1e-4);
        }
    }
}
