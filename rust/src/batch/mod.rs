//! Batch experiments (Section 5.2 of the paper).
//!
//! A *batch* is 100 instances of the same MPI application submitted as a
//! queue. Per batch, `n_f` faulty nodes are drawn and keep the same outage
//! probability `p_f` for all instances; per instance, each faulty node is
//! independently emulated as down. An aborted instance is restarted from
//! scratch and the batch completion time is augmented by one
//! successful-run interval per abort (the paper's exact accounting).

use crate::apps::MpiApp;
use crate::commgraph::CommMatrix;
use crate::error::Result;
use crate::mapping::PlacementPolicy;
use crate::profiler::profile_app;
use crate::rng::Rng;
use crate::sim::executor::{JobOutcome, Simulator};
use crate::sim::failure::{sample_down_nodes, FaultScenario};
use crate::slurm::plugins::fans::FansPlugin;
use crate::topology::Platform;

/// Batch experiment configuration.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Instances per batch (paper: 100).
    pub instances: usize,
    /// Number of faulty nodes `n_f`.
    pub n_faulty: usize,
    /// Outage probability `p_f`.
    pub p_f: f64,
    /// Heartbeat rounds used to estimate outage (0 = oracle estimates).
    pub heartbeat_rounds: usize,
    /// Give up on an instance after this many consecutive aborts
    /// (safety net; effectively unreachable at the paper's p_f).
    pub max_restarts: u32,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            instances: 100,
            n_faulty: 16,
            p_f: 0.02,
            heartbeat_rounds: 0,
            max_restarts: 1000,
        }
    }
}

/// Result of one batch run.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Total simulated completion time of the queue.
    pub completion_s: f64,
    /// Instances that aborted at least once.
    pub aborted_instances: usize,
    /// Total aborts (restarts).
    pub total_aborts: usize,
    /// Instances in the batch.
    pub instances: usize,
    /// Fault-free single-run duration under this placement.
    pub success_run_s: f64,
}

impl BatchResult {
    /// Fraction of instances that aborted at least once.
    pub fn abort_ratio(&self) -> f64 {
        self.aborted_instances as f64 / self.instances as f64
    }
}

/// Runs batches of one application on one platform.
pub struct BatchRunner {
    platform: Platform,
    comm: CommMatrix,
    sim: Simulator,
    fans: FansPlugin,
}

impl BatchRunner {
    /// Profile the app and prepare the simulator.
    pub fn new(app: &dyn MpiApp, platform: &Platform) -> Self {
        let comm = profile_app(app).volume;
        BatchRunner {
            platform: platform.clone(),
            comm,
            sim: Simulator::new(app, platform),
            fans: FansPlugin::default(),
        }
    }

    /// The profiled communication graph.
    pub fn comm(&self) -> &CommMatrix {
        &self.comm
    }

    /// Estimate outage probabilities the way the controller would: either
    /// the oracle values (heartbeat_rounds == 0) or `rounds` Bernoulli
    /// probes per node.
    fn estimate_outage(
        &self,
        scenario: &FaultScenario,
        rounds: usize,
        rng: &mut Rng,
    ) -> Vec<f64> {
        let truth = scenario.true_outage();
        if rounds == 0 {
            return truth;
        }
        truth
            .iter()
            .map(|&p| {
                if p <= 0.0 {
                    0.0
                } else {
                    let misses = (0..rounds).filter(|_| rng.bernoulli(p)).count();
                    misses as f64 / rounds as f64
                }
            })
            .collect()
    }

    /// Run one batch under `policy` with the batch-level fault `scenario`.
    ///
    /// The placement is computed **once per batch** (the paper re-derives
    /// it per job, but within a batch the inputs — comm graph and outage
    /// estimates — are identical, so the mapping is too).
    pub fn run_batch(
        &mut self,
        policy: PlacementPolicy,
        scenario: &FaultScenario,
        config: &BatchConfig,
        rng: &mut Rng,
    ) -> Result<BatchResult> {
        let outage = self.estimate_outage(scenario, config.heartbeat_rounds, rng);
        let placement =
            self.fans
                .select(policy, &self.comm, &self.platform, &outage, rng)?;
        let assignment = placement.assignment;
        // one fault-free simulation + touched-node sweep; every instance
        // then resolves with an intersection test (see JobProfile).
        let profile = self.sim.prepare(&assignment);
        let success_run_s = profile.success_s;

        let mut completion = 0.0f64;
        let mut aborted_instances = 0usize;
        let mut total_aborts = 0usize;
        for _ in 0..config.instances {
            let mut aborted_this = false;
            let mut restarts = 0u32;
            loop {
                let down = sample_down_nodes(scenario, rng);
                match profile.outcome(&down) {
                    JobOutcome::Completed { seconds } => {
                        completion += seconds;
                        break;
                    }
                    JobOutcome::Aborted { .. } => {
                        // paper accounting: each abort costs one
                        // successful-run interval, then restart
                        completion += success_run_s;
                        total_aborts += 1;
                        aborted_this = true;
                        restarts += 1;
                        if restarts >= config.max_restarts {
                            break;
                        }
                    }
                }
            }
            if aborted_this {
                aborted_instances += 1;
            }
        }
        Ok(BatchResult {
            completion_s: completion,
            aborted_instances,
            total_aborts,
            instances: config.instances,
            success_run_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::lammps_proxy::LammpsProxy;
    use crate::topology::TorusDims;

    fn runner(ranks: usize) -> (BatchRunner, Platform) {
        let plat = Platform::paper_default(TorusDims::new(8, 8, 8));
        let app = LammpsProxy::tiny(ranks, 3);
        (BatchRunner::new(&app, &plat), plat)
    }

    #[test]
    fn fault_free_batch_has_no_aborts() {
        let (mut r, plat) = runner(16);
        let scenario = FaultScenario::none(plat.num_nodes());
        let cfg = BatchConfig {
            instances: 5,
            n_faulty: 0,
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let res = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(res.aborted_instances, 0);
        assert!((res.completion_s - 5.0 * res.success_run_s).abs() < 1e-6);
    }

    #[test]
    fn tofa_beats_default_with_faults_in_front() {
        // faulty nodes right where block placement lands
        let (mut r, plat) = runner(16);
        let scenario = FaultScenario {
            faulty_nodes: (0..8).collect(),
            p_f: 0.3,
            num_nodes: plat.num_nodes(),
        };
        let cfg = BatchConfig {
            instances: 10,
            n_faulty: 8,
            p_f: 0.3,
            ..Default::default()
        };
        let mut rng = Rng::new(2);
        let d = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        let mut rng = Rng::new(2);
        let t = r
            .run_batch(PlacementPolicy::Tofa, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(t.aborted_instances, 0, "TOFA should dodge all faults");
        assert!(d.aborted_instances > 0, "default should hit faults");
        assert!(t.completion_s < d.completion_s);
    }

    #[test]
    fn abort_accounting_adds_success_intervals() {
        let (mut r, plat) = runner(8);
        let scenario = FaultScenario {
            faulty_nodes: vec![0],
            p_f: 1.0, // node 0 always down
            num_nodes: plat.num_nodes(),
        };
        let cfg = BatchConfig {
            instances: 2,
            n_faulty: 1,
            p_f: 1.0,
            max_restarts: 3,
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        // block placement uses node 0 -> aborts forever until max_restarts
        let res = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(res.aborted_instances, 2);
        assert_eq!(res.total_aborts, 6);
        assert!((res.completion_s - 6.0 * res.success_run_s).abs() < 1e-9);
    }

    #[test]
    fn heartbeat_estimation_still_avoids_faults() {
        let (mut r, plat) = runner(16);
        let scenario = FaultScenario {
            faulty_nodes: (0..8).collect(),
            p_f: 0.5,
            num_nodes: plat.num_nodes(),
        };
        let cfg = BatchConfig {
            instances: 5,
            n_faulty: 8,
            p_f: 0.5,
            heartbeat_rounds: 50,
            ..Default::default()
        };
        let mut rng = Rng::new(4);
        let t = r
            .run_batch(PlacementPolicy::Tofa, &scenario, &cfg, &mut rng)
            .unwrap();
        // with 50 rounds at p=0.5 every faulty node is detected w.h.p.
        assert_eq!(t.aborted_instances, 0);
    }
}
