//! Batch experiments (Section 5.2 of the paper).
//!
//! A *batch* is 100 instances of the same MPI application submitted as a
//! queue. Per batch, a [`FaultScenario`] is derived from the configured
//! [`FaultSpec`] (the paper's regime — `n_f` faulty nodes at a shared
//! outage probability `p_f` — is the default; correlated-domain, Weibull-
//! lifetime, and trace-replay models plug in behind the same trait, see
//! [`crate::sim::fault`]); per instance, the scenario samples a down-state
//! vector. An aborted instance is restarted from scratch and the batch
//! completion time is augmented by one successful-run interval per abort
//! (the paper's exact accounting).

pub mod parallel;

pub use parallel::{run_grid, GridCell, GridRun, Parallelism};

use std::sync::Arc;

use crate::apps::MpiApp;
use crate::commgraph::CommMatrix;
use crate::error::Result;
use crate::mapping::PlacementPolicy;
use crate::profiler::profile_app;
use crate::report::bench::ParallelReport;
use crate::rng::Rng;
use crate::sim::cache::PhaseCache;
use crate::sim::executor::{JobOutcome, Simulator};
use crate::sim::fault::{FaultScenario, FaultSpec};
use crate::slurm::heartbeat::{probe_histories, OutagePolicy};
use crate::slurm::plugins::fans::FansPlugin;
use crate::topology::Platform;

/// Batch experiment configuration.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Instances per batch (paper: 100).
    pub instances: usize,
    /// Fault-model recipe grid sweeps realize per batch (paper default:
    /// 16 i.i.d. faulty nodes at 2%). Ignored by [`BatchRunner::run_batch`],
    /// which takes an explicit scenario.
    pub fault: FaultSpec,
    /// Heartbeat rounds used to estimate outage (0 = oracle estimates).
    pub heartbeat_rounds: usize,
    /// Give up on an instance after this many consecutive aborts
    /// (safety net; effectively unreachable at the paper's parameters).
    pub max_restarts: u32,
    /// Worker-pool sizing for instance shards / grid cells. Changing it
    /// never changes results (see [`parallel`]), only wall-clock.
    pub parallelism: Parallelism,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            instances: 100,
            fault: FaultSpec::Iid {
                n_faulty: 16,
                p_f: 0.02,
            },
            heartbeat_rounds: 0,
            max_restarts: 1000,
            parallelism: Parallelism::serial(),
        }
    }
}

/// How one batch instance resolved.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceOutcome {
    /// Simulated time the instance contributed to the queue (its final
    /// successful run plus one success-interval per abort).
    pub completion_s: f64,
    /// Aborts (restarts) the instance went through.
    pub aborts: u32,
    /// True if the instance hit `max_restarts` and gave up: its
    /// `completion_s` holds only abort penalties and **no** successful
    /// run. These used to be silently counted like successes.
    pub exhausted: bool,
}

/// Result of one batch run.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Total simulated completion time of the queue.
    pub completion_s: f64,
    /// Instances that aborted at least once.
    pub aborted_instances: usize,
    /// Total aborts (restarts).
    pub total_aborts: usize,
    /// Instances that hit `max_restarts` and never completed (0 at the
    /// paper's parameters; nonzero values flag that `completion_s`
    /// under-reports the batch).
    pub exhausted_instances: usize,
    /// Instances in the batch.
    pub instances: usize,
    /// Fault-free single-run duration under this placement.
    pub success_run_s: f64,
    /// Per-instance outcomes, in instance order (identical for every
    /// worker count — the determinism contract).
    pub outcomes: Vec<InstanceOutcome>,
    /// Per-shard wall-clock and phase-cache counters for this run.
    pub telemetry: ParallelReport,
}

impl BatchResult {
    /// Fraction of instances that aborted at least once. An empty batch
    /// has ratio 0.0 (used to be NaN, which the JSON emitter then turned
    /// into a missing/`null` field downstream).
    pub fn abort_ratio(&self) -> f64 {
        if self.instances == 0 {
            0.0
        } else {
            self.aborted_instances as f64 / self.instances as f64
        }
    }
}

/// Runs batches of one application on one platform.
///
/// Cloning a runner is cheap relative to a batch and **shares the phase
/// cache** — the grid engine ([`parallel::run_grid`]) clones one runner
/// per worker so all cells reuse each other's network solves.
#[derive(Clone)]
pub struct BatchRunner {
    platform: Platform,
    comm: CommMatrix,
    sim: Simulator,
    fans: FansPlugin,
}

impl BatchRunner {
    /// Profile the app and prepare the simulator.
    ///
    /// Also forces the platform's shared
    /// [`crate::topology::TopoIndex`] to be built here, once, so the
    /// per-worker runner clones of [`parallel::run_grid`] all reuse the
    /// same precompute (like the phase cache) instead of each paying the
    /// one-time route sweep inside their first cell.
    pub fn new(app: &dyn MpiApp, platform: &Platform) -> Self {
        let comm = profile_app(app).volume;
        // only the dense metric has an index to warm; implicit platforms
        // serve every query on demand
        if platform.resolved_metric().is_dense() {
            platform.topo_index();
        }
        BatchRunner {
            platform: platform.clone(),
            comm,
            sim: Simulator::new(app, platform),
            fans: FansPlugin::default(),
        }
    }

    /// The profiled communication graph.
    pub fn comm(&self) -> &CommMatrix {
        &self.comm
    }

    /// The platform the runner simulates on.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// The shared phase-duration cache (shared further by every clone).
    pub fn cache(&self) -> Arc<PhaseCache> {
        self.sim.cache()
    }

    /// Estimate outage probabilities the way the controller would: either
    /// the oracle values (heartbeat_rounds == 0) or empirical-frequency
    /// estimates over `rounds` simulated probes against the scenario's
    /// generalized per-node outage vector (any fault model, not just a
    /// uniform `p_f` — see [`probe_histories`]).
    fn estimate_outage(
        &self,
        scenario: &FaultScenario,
        rounds: usize,
        rng: &mut Rng,
    ) -> Vec<f64> {
        let truth = scenario.true_outage();
        if rounds == 0 {
            return truth;
        }
        OutagePolicy::Empirical.estimate_all(&probe_histories(&truth, rounds, rng))
    }

    /// Run one batch under `policy` with the batch-level fault `scenario`.
    ///
    /// The placement is computed **once per batch** (the paper re-derives
    /// it per job, but within a batch the inputs — comm graph and outage
    /// estimates — are identical, so the mapping is too). Instances then
    /// execute on `config.parallelism` workers; each instance derives its
    /// RNG stream from one base draw plus its index, and the per-instance
    /// results are reduced in instance order, so the batch is
    /// bit-identical for every worker count.
    pub fn run_batch(
        &mut self,
        policy: PlacementPolicy,
        scenario: &FaultScenario,
        config: &BatchConfig,
        rng: &mut Rng,
    ) -> Result<BatchResult> {
        let outage = self.estimate_outage(scenario, config.heartbeat_rounds, rng);
        let placement =
            self.fans
                .select(policy, &self.comm, &self.platform, &outage, None, rng)?;
        let assignment = placement.assignment;
        // simulator-local stats give *exact* per-run cache attribution
        // even when other grid cells hammer the shared cache concurrently
        let stats0 = self.sim.stats().clone();
        // one fault-free simulation + touched-node sweep; every instance
        // then resolves with an intersection test (see JobProfile).
        let profile = self.sim.prepare(&assignment);
        let success_run_s = profile.success_s;

        let stream_base = rng.next_u64();
        let workers = config.parallelism.for_items(config.instances);
        let profile = &profile;
        let (outcomes, shards) = parallel::run_sharded(config.instances, workers, |i| {
            let mut irng = Rng::stream(stream_base, i as u64);
            // temporal fault models condition on the fault-free makespan;
            // each retry bumps `attempt` so trace replay re-runs the job
            // in the next trace window (a real resubmission)
            let mut ctx = profile.fault_ctx(i as u64);
            let mut completion = 0.0f64;
            let mut aborts = 0u32;
            let mut exhausted = false;
            loop {
                let down = scenario.sample_down(&ctx, &mut irng);
                match profile.outcome(&down) {
                    JobOutcome::Completed { seconds } => {
                        completion += seconds;
                        break;
                    }
                    JobOutcome::Aborted { .. } => {
                        // paper accounting: each abort costs one
                        // successful-run interval, then restart
                        completion += success_run_s;
                        aborts += 1;
                        ctx.attempt = aborts;
                        if aborts >= config.max_restarts {
                            // give-up is flagged, not silently counted
                            // like a success
                            exhausted = true;
                            break;
                        }
                    }
                }
            }
            InstanceOutcome {
                completion_s: completion,
                aborts,
                exhausted,
            }
        });

        // reduce in instance order: the f64 sum is worker-count invariant
        let mut completion = 0.0f64;
        let mut aborted_instances = 0usize;
        let mut total_aborts = 0usize;
        let mut exhausted_instances = 0usize;
        for o in &outcomes {
            completion += o.completion_s;
            total_aborts += o.aborts as usize;
            if o.aborts > 0 {
                aborted_instances += 1;
            }
            if o.exhausted {
                exhausted_instances += 1;
            }
        }
        let stats1 = self.sim.stats();
        let telemetry = ParallelReport {
            shards,
            cache_lookups: stats1.comm_phases - stats0.comm_phases,
            cache_hits: stats1.cache_hits - stats0.cache_hits,
        };
        Ok(BatchResult {
            completion_s: completion,
            aborted_instances,
            total_aborts,
            exhausted_instances,
            instances: config.instances,
            success_run_s,
            outcomes,
            telemetry,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::lammps_proxy::LammpsProxy;
    use crate::topology::TorusDims;

    fn runner(ranks: usize) -> (BatchRunner, Platform) {
        let plat = Platform::paper_default(TorusDims::new(8, 8, 8));
        let app = LammpsProxy::tiny(ranks, 3);
        (BatchRunner::new(&app, &plat), plat)
    }

    #[test]
    fn fault_free_batch_has_no_aborts() {
        let (mut r, plat) = runner(16);
        let scenario = FaultScenario::none(plat.num_nodes());
        let cfg = BatchConfig {
            instances: 5,
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let res = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(res.aborted_instances, 0);
        assert_eq!(res.exhausted_instances, 0);
        assert!((res.completion_s - 5.0 * res.success_run_s).abs() < 1e-6);
    }

    #[test]
    fn empty_batch_has_zero_abort_ratio() {
        // regression: 0 instances used to yield NaN, which then emitted a
        // null/malformed field in BENCH_*.json payloads
        let (mut r, plat) = runner(8);
        let scenario = FaultScenario::none(plat.num_nodes());
        let cfg = BatchConfig {
            instances: 0,
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let res = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(res.instances, 0);
        assert_eq!(res.abort_ratio(), 0.0);
        assert!(res.abort_ratio().is_finite());
    }

    #[test]
    fn tofa_beats_default_with_faults_in_front() {
        // faulty nodes right where block placement lands
        let (mut r, plat) = runner(16);
        let scenario = FaultScenario::iid((0..8).collect(), 0.3, plat.num_nodes());
        let cfg = BatchConfig {
            instances: 10,
            ..Default::default()
        };
        let mut rng = Rng::new(2);
        let d = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        let mut rng = Rng::new(2);
        let t = r
            .run_batch(PlacementPolicy::Tofa, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(t.aborted_instances, 0, "TOFA should dodge all faults");
        assert!(d.aborted_instances > 0, "default should hit faults");
        assert!(t.completion_s < d.completion_s);
    }

    #[test]
    fn abort_accounting_adds_success_intervals() {
        let (mut r, plat) = runner(8);
        // node 0 always down
        let scenario = FaultScenario::iid(vec![0], 1.0, plat.num_nodes());
        let cfg = BatchConfig {
            instances: 2,
            max_restarts: 3,
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        // block placement uses node 0 -> aborts forever until max_restarts
        let res = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(res.aborted_instances, 2);
        assert_eq!(res.total_aborts, 6);
        // silent-exhaustion regression: both instances gave up and are
        // flagged as such — completion_s holds only abort penalties
        assert_eq!(res.exhausted_instances, 2);
        assert!(res.outcomes.iter().all(|o| o.exhausted));
        assert!((res.completion_s - 6.0 * res.success_run_s).abs() < 1e-9);
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let (_, plat) = runner(16);
        let scenario = FaultScenario::iid((0..12).collect(), 0.3, plat.num_nodes());
        let run = |workers: usize| {
            let app = LammpsProxy::tiny(16, 3);
            let mut r = BatchRunner::new(&app, &plat);
            let cfg = BatchConfig {
                instances: 40,
                parallelism: Parallelism::fixed(workers),
                ..Default::default()
            };
            let mut rng = Rng::new(9);
            r.run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
                .unwrap()
        };
        let serial = run(1);
        assert_eq!(serial.outcomes.len(), 40);
        for workers in [2usize, 4, 7] {
            let par = run(workers);
            assert_eq!(par.outcomes, serial.outcomes, "{workers} workers");
            assert_eq!(
                par.completion_s.to_bits(),
                serial.completion_s.to_bits(),
                "{workers} workers"
            );
            assert_eq!(par.aborted_instances, serial.aborted_instances);
            assert_eq!(par.total_aborts, serial.total_aborts);
            assert_eq!(par.exhausted_instances, serial.exhausted_instances);
        }
    }

    #[test]
    fn grid_results_independent_of_worker_count() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let app = LammpsProxy::tiny(16, 2);
        let policies = [PlacementPolicy::DefaultSlurm, PlacementPolicy::Tofa];
        let run = |workers: usize| {
            let r = BatchRunner::new(&app, &plat);
            let cfg = BatchConfig {
                instances: 10,
                fault: FaultSpec::Iid {
                    n_faulty: 6,
                    p_f: 0.4,
                },
                parallelism: Parallelism::fixed(workers),
                ..Default::default()
            };
            run_grid(&r, &policies, &cfg, 4, 11).unwrap().cells
        };
        let serial = run(1);
        let par = run(4);
        assert_eq!(serial.len(), 8);
        assert_eq!(par.len(), 8);
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.batch_index, b.batch_index);
            assert_eq!(a.policy, b.policy);
            assert_eq!(a.result.outcomes, b.result.outcomes);
            assert_eq!(
                a.result.completion_s.to_bits(),
                b.result.completion_s.to_bits()
            );
        }
    }

    #[test]
    fn batch_telemetry_covers_all_instances() {
        let (mut r, plat) = runner(16);
        let scenario = FaultScenario::none(plat.num_nodes());
        let cfg = BatchConfig {
            instances: 12,
            parallelism: Parallelism::fixed(3),
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let res = r
            .run_batch(PlacementPolicy::DefaultSlurm, &scenario, &cfg, &mut rng)
            .unwrap();
        assert_eq!(res.telemetry.total_items(), 12);
        assert_eq!(res.telemetry.shards.len(), 3);
        // prepare() ran phases through the shared cache
        assert!(res.telemetry.cache_lookups > 0);
    }

    #[test]
    fn heartbeat_estimation_still_avoids_faults() {
        let (mut r, plat) = runner(16);
        let scenario = FaultScenario::iid((0..8).collect(), 0.5, plat.num_nodes());
        let cfg = BatchConfig {
            instances: 5,
            heartbeat_rounds: 50,
            ..Default::default()
        };
        let mut rng = Rng::new(4);
        let t = r
            .run_batch(PlacementPolicy::Tofa, &scenario, &cfg, &mut rng)
            .unwrap();
        // with 50 rounds at p=0.5 every faulty node is detected w.h.p.
        assert_eq!(t.aborted_instances, 0);
    }
}
