//! Sharded parallel execution for batch experiments.
//!
//! Two levels of parallelism, both deterministic:
//!
//! * **instances** — [`run_sharded`] splits a batch's instances into
//!   contiguous shards executed on a scoped-thread worker pool; each
//!   instance derives its RNG stream from `(base draw, instance index)`
//!   via [`Rng::stream`], so results are bit-identical for any worker
//!   count (including 1).
//! * **scenario grid** — [`run_grid`] fans the `(batch, policy)` grid of
//!   the Fig. 4/5 sweeps out over the pool. Every cell derives its fault
//!   scenario and RNG from `(seed, batch index)`, clones the runner, and
//!   shares one [`crate::sim::PhaseCache`], so all cells with the same
//!   placement reuse each other's network solves across threads. Cells
//!   also share the platform's [`crate::topology::TopoIndex`] (clean hop
//!   matrix + transit incidence, built once in
//!   [`super::BatchRunner::new`]), which the TOFA placer's incremental
//!   Eq. 1 and window engines read concurrently, lock-free.
//!
//! The pool is hand-rolled on `std::thread::scope` — the offline build
//! environment has no rayon — and shards report per-worker wall-clock
//! through [`ShardTiming`] for the telemetry in [`super::BatchResult`].

use std::time::Instant;

use crate::error::Result;
use crate::mapping::PlacementPolicy;
use crate::report::bench::{ParallelReport, ShardTiming};
use crate::rng::Rng;

use super::{BatchConfig, BatchResult, BatchRunner};

/// Worker-pool sizing for batch/grid execution.
///
/// `workers == 0` means "auto": use every core
/// (`std::thread::available_parallelism`). The determinism contract holds
/// for every value — changing `workers` never changes results, only
/// wall-clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Worker threads; 0 = one per available core.
    pub workers: usize,
}

impl Parallelism {
    /// Single-threaded execution (the default).
    pub fn serial() -> Self {
        Parallelism { workers: 1 }
    }

    /// One worker per available core.
    pub fn auto() -> Self {
        Parallelism { workers: 0 }
    }

    /// Exactly `workers` threads (0 = auto).
    pub fn fixed(workers: usize) -> Self {
        Parallelism { workers }
    }

    /// The concrete worker count this configuration resolves to.
    pub fn effective(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.workers
        }
    }

    /// Workers to actually spawn for `items` work items.
    pub fn for_items(&self, items: usize) -> usize {
        if items == 0 {
            1
        } else {
            self.effective().min(items)
        }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::serial()
    }
}

/// Map `f` over `0..items` on `workers` scoped threads.
///
/// Items are partitioned into *balanced* contiguous shards — the first
/// `items % workers` shards take one extra item, so no worker ever sits
/// idle (naive ceil-chunking would leave trailing shards empty). Results
/// are returned **in item order**, with per-shard wall-clock reported
/// alongside. Because `f` receives only the item index, results cannot
/// depend on scheduling — callers keep determinism by deriving all
/// randomness from the index.
pub fn run_sharded<T, F>(items: usize, workers: usize, f: F) -> (Vec<T>, Vec<ShardTiming>)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, items.max(1));
    if workers <= 1 {
        let t0 = Instant::now();
        let out: Vec<T> = (0..items).map(&f).collect();
        let timing = ShardTiming {
            shard: 0,
            items,
            wall: t0.elapsed(),
        };
        return (out, vec![timing]);
    }
    let base = items / workers;
    let extra = items % workers;
    let mut results: Vec<Option<T>> = (0..items).map(|_| None).collect();
    let mut timings: Vec<ShardTiming> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let lo = w * base + w.min(extra);
                let len = base + usize::from(w < extra);
                scope.spawn(move || {
                    let t0 = Instant::now();
                    let vals: Vec<T> = (lo..lo + len).map(f).collect();
                    (w, lo, vals, t0.elapsed())
                })
            })
            .collect();
        for handle in handles {
            // invariant: a worker panic is already fatal; join only
            // propagates it onto the coordinating thread
            let (w, lo, vals, wall) = handle.join().expect("batch worker panicked");
            timings.push(ShardTiming {
                shard: w,
                items: vals.len(),
                wall,
            });
            for (k, v) in vals.into_iter().enumerate() {
                results[lo + k] = Some(v);
            }
        }
    });
    let out = results
        .into_iter()
        // invariant: the shard ranges [lo, lo + len) partition 0..n
        // exactly, so every slot was filled above
        .map(|r| r.expect("shard left a hole"))
        .collect();
    (out, timings)
}

/// One cell of a batch sweep: `(batch index, policy)` with its result.
#[derive(Debug, Clone)]
pub struct GridCell {
    /// Batch index within the sweep.
    pub batch_index: usize,
    /// Placement policy the cell ran under.
    pub policy: PlacementPolicy,
    /// The batch result.
    pub result: BatchResult,
}

/// A completed `batches x policies` sweep: the cells plus the sweep-level
/// telemetry (per-shard wall-clock of the grid pool, and the phase-cache
/// counters accumulated across the whole sweep — exact, since the sweep
/// owns the cache for its duration).
#[derive(Debug, Clone)]
pub struct GridRun {
    /// Batch-major cells: `cells[b * policies.len() + p]`.
    pub cells: Vec<GridCell>,
    /// Grid-pool shard timings + whole-sweep cache counters.
    pub telemetry: ParallelReport,
}

/// Run a `batches x policies` sweep in parallel.
///
/// Cell layout is batch-major: `cells[b * policies.len() + p]`. Every
/// policy within batch `b` sees the **same** fault scenario — realized
/// from `config.fault` with the `(seed, b)` RNG stream, for any
/// [`crate::sim::fault::FaultSpec`] — matching the paper's paired
/// comparison. Each cell clones
/// `runner` — sharing its [`crate::sim::PhaseCache`] — so all cells reuse
/// each other's network solves. The worker budget splits across levels:
/// with at least as many cells as workers each cell runs its instances
/// serially; with fewer cells the whole budget is distributed (remainder
/// included) over instance-level shards inside the cells, so small grids
/// still use the whole machine. Either way results are independent of
/// the worker count.
pub fn run_grid(
    runner: &BatchRunner,
    policies: &[PlacementPolicy],
    config: &BatchConfig,
    batches: usize,
    seed: u64,
) -> Result<GridRun> {
    let npol = policies.len();
    let cells = batches * npol;
    if cells == 0 {
        return Ok(GridRun {
            cells: Vec::new(),
            telemetry: Default::default(),
        });
    }
    let workers = config.parallelism.for_items(cells);
    // split the worker budget exactly: with fewer cells than cores, each
    // cell gets floor(effective/cells) inner workers and the first
    // (effective % cells) cells one extra, so the totals always sum to
    // the machine (inner counts never change results, only wall-clock)
    let effective = config.parallelism.effective();
    let (inner_base, inner_extra) = if cells >= effective {
        (1, 0)
    } else {
        (effective / cells, effective % cells)
    };
    let cache = runner.cache();
    let (lookups0, hits0) = (cache.lookups(), cache.hits());
    let (results, shards) = run_sharded(cells, workers, |c| {
        let b = c / npol;
        let p = c % npol;
        let policy = policies[p];
        // identical scenario for every policy of batch `b`
        let mut scen_rng = Rng::stream(seed, b as u64);
        let scenario = config.fault.realize(runner.platform(), &mut scen_rng)?;
        let mut cell_rng = scen_rng.fork(1 + p as u64);
        let mut local = runner.clone();
        let mut my_cfg = config.clone();
        my_cfg.parallelism = Parallelism::fixed(inner_base + usize::from(c < inner_extra));
        local
            .run_batch(policy, &scenario, &my_cfg, &mut cell_rng)
            .map(|result| GridCell {
                batch_index: b,
                policy,
                result,
            })
    });
    let cells = results.into_iter().collect::<Result<Vec<_>>>()?;
    Ok(GridRun {
        cells,
        telemetry: ParallelReport {
            shards,
            cache_lookups: cache.lookups() - lookups0,
            cache_hits: cache.hits() - hits0,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_results_are_in_item_order() {
        for workers in [1usize, 2, 3, 8] {
            let (out, timings) = run_sharded(17, workers, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
            assert_eq!(timings.iter().map(|t| t.items).sum::<usize>(), 17);
        }
    }

    #[test]
    fn zero_items_is_fine() {
        let (out, timings) = run_sharded(0, 4, |i| i);
        assert!(out.is_empty());
        assert_eq!(timings.len(), 1);
    }

    #[test]
    fn more_workers_than_items_clamps() {
        let (out, timings) = run_sharded(3, 64, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
        assert!(timings.len() <= 3);
    }

    #[test]
    fn shards_are_balanced_with_no_idle_workers() {
        let (out, timings) = run_sharded(10, 7, |i| i);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
        assert_eq!(timings.len(), 7);
        assert!(timings.iter().all(|t| t.items >= 1), "idle worker: {timings:?}");
        let most = timings.iter().map(|t| t.items).max().unwrap();
        let least = timings.iter().map(|t| t.items).min().unwrap();
        assert!(most - least <= 1, "unbalanced: {most} vs {least}");
    }

    #[test]
    fn parallelism_resolution() {
        assert_eq!(Parallelism::serial().effective(), 1);
        assert_eq!(Parallelism::fixed(6).effective(), 6);
        assert!(Parallelism::auto().effective() >= 1);
        assert_eq!(Parallelism::fixed(8).for_items(3), 3);
        assert_eq!(Parallelism::fixed(2).for_items(0), 1);
        assert_eq!(Parallelism::default(), Parallelism::serial());
    }
}
