//! Failure scenario sampling (Section 5.2 of the paper).
//!
//! Per batch, a set `N_f` of nodes gets a fixed outage probability `p_f`;
//! per job instance ("scenario"), each node of `N_f` is independently
//! emulated as *down* with probability `p_f`. A down node cannot compute
//! or forward traffic (its links get zero capacity in SimGrid terms).

use crate::rng::Rng;

/// The per-batch fault configuration.
#[derive(Debug, Clone)]
pub struct FaultScenario {
    /// Node ids with non-zero outage probability (`N_f`).
    pub faulty_nodes: Vec<usize>,
    /// The shared outage probability (`p_f`).
    pub p_f: f64,
    /// Platform size.
    pub num_nodes: usize,
}

impl FaultScenario {
    /// No faults.
    pub fn none(num_nodes: usize) -> Self {
        FaultScenario {
            faulty_nodes: Vec::new(),
            p_f: 0.0,
            num_nodes,
        }
    }

    /// Randomly select `n_f` faulty nodes with probability `p_f` each.
    pub fn random(num_nodes: usize, n_f: usize, p_f: f64, rng: &mut Rng) -> Self {
        FaultScenario {
            faulty_nodes: rng.sample_distinct(num_nodes, n_f),
            p_f,
            num_nodes,
        }
    }

    /// The true per-node outage probability vector (what heartbeat
    /// estimation tries to recover).
    pub fn true_outage(&self) -> Vec<f64> {
        let mut p = vec![0.0; self.num_nodes];
        for &n in &self.faulty_nodes {
            p[n] = self.p_f;
        }
        p
    }
}

/// Sample the down-state for one job instance: each faulty node is down
/// with probability `p_f`, independently.
pub fn sample_down_nodes(scenario: &FaultScenario, rng: &mut Rng) -> Vec<bool> {
    let mut down = vec![false; scenario.num_nodes];
    for &n in &scenario.faulty_nodes {
        if rng.bernoulli(scenario.p_f) {
            down[n] = true;
        }
    }
    down
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_has_no_down_nodes() {
        let s = FaultScenario::none(16);
        let mut rng = Rng::new(0);
        assert!(sample_down_nodes(&s, &mut rng).iter().all(|&d| !d));
    }

    #[test]
    fn down_rate_matches_p_f() {
        let mut rng = Rng::new(1);
        let s = FaultScenario::random(512, 16, 0.02, &mut rng);
        assert_eq!(s.faulty_nodes.len(), 16);
        let mut downs = 0usize;
        let trials = 10_000;
        for _ in 0..trials {
            downs += sample_down_nodes(&s, &mut rng)
                .iter()
                .filter(|&&d| d)
                .count();
        }
        let rate = downs as f64 / (trials * 16) as f64;
        assert!((rate - 0.02).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn only_faulty_nodes_go_down() {
        let mut rng = Rng::new(2);
        let s = FaultScenario::random(64, 4, 1.0, &mut rng);
        let down = sample_down_nodes(&s, &mut rng);
        for (n, &d) in down.iter().enumerate() {
            assert_eq!(d, s.faulty_nodes.contains(&n));
        }
    }

    #[test]
    fn true_outage_vector() {
        let s = FaultScenario {
            faulty_nodes: vec![3, 7],
            p_f: 0.02,
            num_nodes: 10,
        };
        let p = s.true_outage();
        assert_eq!(p[3], 0.02);
        assert_eq!(p[7], 0.02);
        assert_eq!(p.iter().filter(|&&x| x > 0.0).count(), 2);
    }
}
