//! Shared, concurrency-safe phase-duration cache.
//!
//! The executor memoizes comm-phase durations by a content hash of the
//! node-level flow set (see [`crate::sim::executor`]). Historically that
//! memo was a private `HashMap` per [`crate::sim::executor::Simulator`],
//! rebuilt from scratch for every batch instance. `PhaseCache` lifts it
//! into a shared structure behind `Arc`, so every simulator running the
//! same app/platform/placement — including simulators on different worker
//! threads of the parallel batch engine — solves each distinct phase once.
//!
//! Concurrency model: the key space is split across `2^k` shards, each a
//! `RwLock<HashMap>`, selected by high key bits; readers never contend
//! with writers on other shards. Cached values are pure functions of the
//! key (the flow-level solve is deterministic), so racing threads that
//! both miss compute and insert the *same* value — sharing the cache can
//! never change a simulation result, only its wall-clock cost. That
//! value-determinism is what makes the parallel engine bit-reproducible.
//!
//! An aborted phase (a flow crossing a down node) is stored as `NaN`, the
//! same sentinel the private memo used.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Sharded concurrent map from phase content-hash to phase duration.
pub struct PhaseCache {
    shards: Vec<RwLock<HashMap<u64, f64>>>,
    mask: u64,
    lookups: AtomicU64,
    hits: AtomicU64,
}

impl PhaseCache {
    /// Default shard count (16): enough to keep a handful of worker
    /// threads off each other's locks without bloating tiny runs.
    pub fn new() -> Self {
        Self::with_shards(16)
    }

    /// Cache with `shards` shards (rounded up to a power of two).
    pub fn with_shards(shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        PhaseCache {
            shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
            mask: (n - 1) as u64,
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
        }
    }

    /// Shard holding `key`. High bits pick the shard so the map's own
    /// bucketing (low bits) stays well distributed within each shard.
    #[inline]
    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, f64>> {
        &self.shards[((key >> 48) & self.mask) as usize]
    }

    /// Cached duration for `key`, if any (`NaN` = memoized abort).
    ///
    /// A poisoned shard (a panic on another worker while its lock was
    /// held) is recovered rather than propagated: cached values are pure
    /// functions of the key, so the map's contents are valid regardless
    /// of where the panicking thread stopped — worst case a partial
    /// insert is simply recomputed.
    #[inline]
    pub fn get(&self, key: u64) -> Option<f64> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let got = self
            .shard(key)
            .read()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .get(&key)
            .copied();
        if got.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// Store the duration for `key`. Last writer wins; all writers of a
    /// given key store the same value (see module docs). Poisoned shards
    /// are recovered, as in [`Self::get`].
    #[inline]
    pub fn insert(&self, key: u64, duration: f64) {
        self.shard(key)
            .write()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .insert(key, duration);
    }

    /// Distinct phases cached.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap_or_else(|poisoned| poisoned.into_inner()).len())
            .sum()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total lookups since construction (or the last [`Self::clear`]).
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Lookups answered from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Fraction of lookups served from the cache (0.0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.lookups();
        if lookups == 0 {
            0.0
        } else {
            self.hits() as f64 / lookups as f64
        }
    }

    /// Drop all entries and reset the counters.
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap_or_else(|poisoned| poisoned.into_inner()).clear();
        }
        self.lookups.store(0, Ordering::Relaxed);
        self.hits.store(0, Ordering::Relaxed);
    }
}

impl Default for PhaseCache {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for PhaseCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PhaseCache")
            .field("shards", &self.shards.len())
            .field("entries", &self.len())
            .field("lookups", &self.lookups())
            .field("hits", &self.hits())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn get_insert_roundtrip() {
        let c = PhaseCache::new();
        assert_eq!(c.get(42), None);
        c.insert(42, 1.5);
        assert_eq!(c.get(42), Some(1.5));
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookups(), 2);
        assert_eq!(c.hits(), 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nan_sentinel_survives() {
        let c = PhaseCache::new();
        c.insert(7, f64::NAN);
        assert!(c.get(7).unwrap().is_nan());
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        let c = PhaseCache::with_shards(5);
        assert_eq!(c.shards.len(), 8);
        let c = PhaseCache::with_shards(0);
        assert_eq!(c.shards.len(), 1);
    }

    #[test]
    fn clear_resets_entries_and_counters() {
        let c = PhaseCache::new();
        c.insert(1, 2.0);
        let _ = c.get(1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.lookups(), 0);
        assert_eq!(c.hits(), 0);
    }

    #[test]
    fn concurrent_inserts_all_visible() {
        let c = Arc::new(PhaseCache::with_shards(4));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..256u64 {
                        let key = t * 1000 + i;
                        c.insert(key.wrapping_mul(0x9E3779B97F4A7C15), key as f64);
                    }
                });
            }
        });
        assert_eq!(c.len(), 4 * 256);
    }
}
