//! Correlated failure domains: a rack or switch group fails as a unit.
//!
//! Motivated by the topology-regime sensitivity in "Mapping Matters"
//! (Korndörfer et al.) and the grid/torus failure-domain structure in
//! Glantz et al.: real outages hit shared infrastructure (PDU, top-of-rack
//! switch), taking every node of the domain down together — a regime the
//! paper's i.i.d. model cannot express.

use crate::rng::Rng;
use crate::sim::fault::{FaultCtx, FaultModel};
use crate::topology::Platform;

/// One failure domain: a node group that goes down together.
#[derive(Debug, Clone)]
pub struct Domain {
    /// Member node ids.
    pub nodes: Vec<usize>,
    /// Per-instance probability the whole domain is down.
    pub p_d: f64,
}

/// Topology-aware correlated outages: each domain fails independently of
/// the others, but its members fail *together*. Per-domain probabilities
/// may differ, so the true outage vector is non-uniform in general.
#[derive(Debug, Clone)]
pub struct CorrelatedDomains {
    domains: Vec<Domain>,
    num_nodes: usize,
}

impl CorrelatedDomains {
    /// Explicit domain list. Domains may overlap (a node in several
    /// domains is down if any of them is).
    pub fn new(domains: Vec<Domain>, num_nodes: usize) -> Self {
        debug_assert!(domains.iter().all(|d| d.nodes.iter().all(|&n| n < num_nodes)));
        debug_assert!(domains.iter().all(|d| (0.0..=1.0).contains(&d.p_d)));
        CorrelatedDomains { domains, num_nodes }
    }

    /// One domain per listed rack of the platform (rack = X-line, see
    /// [`Platform::rack_members`]), all with probability `p_d`.
    pub fn racks(platform: &Platform, rack_ids: &[usize], p_d: f64) -> Self {
        let domains = rack_ids
            .iter()
            .map(|&r| Domain {
                nodes: platform.rack_members(r),
                p_d,
            })
            .collect();
        Self::new(domains, platform.num_nodes())
    }

    /// `n_domains` distinct racks drawn from `rng`, each failing with
    /// probability `p_d`.
    pub fn random_racks(platform: &Platform, n_domains: usize, p_d: f64, rng: &mut Rng) -> Self {
        let racks = rng.sample_distinct(platform.num_racks(), n_domains);
        Self::racks(platform, &racks, p_d)
    }

    /// The failure domains.
    pub fn domains(&self) -> &[Domain] {
        &self.domains
    }
}

impl FaultModel for CorrelatedDomains {
    fn name(&self) -> &'static str {
        "correlated"
    }

    fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    fn true_outage(&self) -> Vec<f64> {
        // node down iff any covering domain is down:
        // p = 1 - prod(1 - p_d) over the domains containing the node
        let mut up = vec![1.0f64; self.num_nodes];
        for d in &self.domains {
            for &n in &d.nodes {
                up[n] *= 1.0 - d.p_d;
            }
        }
        up.into_iter().map(|u| 1.0 - u).collect()
    }

    fn sample(&self, _ctx: &FaultCtx, rng: &mut Rng) -> Vec<bool> {
        // one Bernoulli draw per domain, in stored order
        let mut down = vec![false; self.num_nodes];
        for d in &self.domains {
            if rng.bernoulli(d.p_d) {
                for &n in &d.nodes {
                    down[n] = true;
                }
            }
        }
        down
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TorusDims;

    #[test]
    fn members_fail_together() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 2));
        let m = CorrelatedDomains::racks(&plat, &[0, 5], 0.5);
        let ctx = FaultCtx::new(0, 1.0);
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let down = m.sample(&ctx, &mut rng);
            for d in m.domains() {
                let states: Vec<bool> = d.nodes.iter().map(|&n| down[n]).collect();
                assert!(states.iter().all(|&s| s == states[0]), "split: {states:?}");
            }
            // nodes outside every domain never fail
            for (n, &dn) in down.iter().enumerate() {
                if dn {
                    assert!(m.domains().iter().any(|d| d.nodes.contains(&n)));
                }
            }
        }
    }

    #[test]
    fn true_outage_is_non_uniform_across_domains() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let m = CorrelatedDomains::new(
            vec![
                Domain {
                    nodes: plat.rack_members(0),
                    p_d: 0.6,
                },
                Domain {
                    nodes: plat.rack_members(2),
                    p_d: 0.2,
                },
            ],
            plat.num_nodes(),
        );
        let p = m.true_outage();
        assert!((p[0] - 0.6).abs() < 1e-12);
        assert!((p[8] - 0.2).abs() < 1e-12);
        assert_eq!(p[4], 0.0); // rack 1 untouched
    }

    #[test]
    fn overlapping_domains_compose_probabilities() {
        let m = CorrelatedDomains::new(
            vec![
                Domain {
                    nodes: vec![0, 1],
                    p_d: 0.5,
                },
                Domain {
                    nodes: vec![1, 2],
                    p_d: 0.5,
                },
            ],
            4,
        );
        let p = m.true_outage();
        assert_eq!(p[0], 0.5);
        assert!((p[1] - 0.75).abs() < 1e-12);
        assert_eq!(p[3], 0.0);
    }

    #[test]
    fn down_rate_matches_p_d() {
        let plat = Platform::paper_default(TorusDims::new(8, 4, 2));
        let m = CorrelatedDomains::racks(&plat, &[3], 0.3);
        let ctx = FaultCtx::new(0, 1.0);
        let mut rng = Rng::new(6);
        let trials = 10_000;
        let mut hits = 0usize;
        for _ in 0..trials {
            if m.sample(&ctx, &mut rng)[plat.rack_members(3)[0]] {
                hits += 1;
            }
        }
        let rate = hits as f64 / trials as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }
}
