//! Deterministic replay of recorded node down-intervals.
//!
//! The trace format is a minimal LANL-failure-data-style text file:
//!
//! ```text
//! # comments and blank lines are ignored
//! nodes 512
//! 17 0.0 2.5      # node 17 down during [0.0 s, 2.5 s)
//! 17 10.0 11.0
//! 203 4.0 6.25
//! ```
//!
//! Header `nodes N`, then one `node start end` down-interval per line
//! (seconds, `start < end`). Replay maps batch instance `i` (attempt `a`)
//! to the trace window `[i*d + a*d, i*d + a*d + d)` where `d` is the
//! job's fault-free makespan: instances run back-to-back in trace time,
//! and a restart re-runs the job in the *next* window, exactly like a
//! real resubmission. A node is down for an instance iff any of its
//! recorded intervals overlaps the instance's window. No randomness is
//! consumed — replay is fully deterministic.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::sim::fault::{FaultCtx, FaultModel};

/// A parsed down-interval trace.
#[derive(Debug, Clone)]
pub struct FaultTrace {
    /// Per-node down intervals `[start, end)`, sorted by start.
    intervals: Vec<Vec<(f64, f64)>>,
    /// Trace span: the largest interval end (0 for an empty trace).
    span_s: f64,
}

impl FaultTrace {
    /// Parse the text format described in the module docs.
    pub fn parse<R: Read>(r: R) -> Result<Self> {
        let mut lines = BufReader::new(r).lines();
        let header = loop {
            match lines.next() {
                None => return Err(Error::Fault("empty fault trace".into())),
                Some(line) => {
                    let line = line?;
                    let trimmed = strip_comment(&line);
                    if !trimmed.is_empty() {
                        break trimmed.to_string();
                    }
                }
            }
        };
        let hp: Vec<&str> = header.split_whitespace().collect();
        if hp.len() != 2 || hp[0] != "nodes" {
            return Err(Error::Fault(format!("bad trace header: {header}")));
        }
        let num_nodes: usize = hp[1]
            .parse()
            .map_err(|_| Error::Fault(format!("bad node count: {}", hp[1])))?;
        let mut intervals = vec![Vec::new(); num_nodes];
        let mut span_s = 0.0f64;
        for line in lines {
            let line = line?;
            let entry = strip_comment(&line);
            if entry.is_empty() {
                continue;
            }
            let p: Vec<&str> = entry.split_whitespace().collect();
            if p.len() != 3 {
                return Err(Error::Fault(format!("bad trace entry: {line}")));
            }
            let node: usize = p[0]
                .parse()
                .map_err(|_| Error::Fault(format!("bad node id: {line}")))?;
            let parse_s = |s: &str| {
                s.parse::<f64>()
                    .map_err(|_| Error::Fault(format!("bad time: {line}")))
            };
            let (start, end) = (parse_s(p[1])?, parse_s(p[2])?);
            if node >= num_nodes {
                return Err(Error::Fault(format!(
                    "node {node} out of range (trace has {num_nodes} nodes)"
                )));
            }
            let valid = start.is_finite() && end.is_finite() && start >= 0.0 && end > start;
            if !valid {
                return Err(Error::Fault(format!("bad interval: {line}")));
            }
            intervals[node].push((start, end));
            span_s = span_s.max(end);
        }
        for iv in &mut intervals {
            iv.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        Ok(FaultTrace { intervals, span_s })
    }

    /// Parse a trace from a file on disk.
    pub fn from_file(path: &Path) -> Result<Self> {
        Self::parse(std::fs::File::open(path)?)
    }

    /// Emit the trace back in its text format.
    pub fn to_text(&self) -> String {
        let mut out = format!("nodes {}\n", self.num_nodes());
        for (node, iv) in self.intervals.iter().enumerate() {
            for (start, end) in iv {
                out.push_str(&format!("{node} {start} {end}\n"));
            }
        }
        out
    }

    /// Node count the trace covers.
    pub fn num_nodes(&self) -> usize {
        self.intervals.len()
    }

    /// The largest recorded interval end.
    pub fn span_s(&self) -> f64 {
        self.span_s
    }

    /// Down intervals of one node, sorted by start.
    pub fn intervals(&self, node: usize) -> &[(f64, f64)] {
        &self.intervals[node]
    }

    /// True iff `node` has a down interval overlapping `[t0, t1)`.
    pub fn down_in(&self, node: usize, t0: f64, t1: f64) -> bool {
        self.intervals[node].iter().any(|&(s, e)| s < t1 && e > t0)
    }

    /// Per-node down-time fraction over the trace span (the availability
    /// statistic a heartbeat history would converge to).
    pub fn down_fraction(&self) -> Vec<f64> {
        if self.span_s <= 0.0 {
            return vec![0.0; self.num_nodes()];
        }
        self.intervals
            .iter()
            .map(|iv| {
                // intervals of one node may overlap; merge while summing
                let mut total = 0.0;
                let mut cur: Option<(f64, f64)> = None;
                for &(s, e) in iv {
                    match cur {
                        Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
                        Some((cs, ce)) => {
                            total += ce - cs;
                            cur = Some((s, e));
                        }
                        None => cur = Some((s, e)),
                    }
                }
                if let Some((cs, ce)) = cur {
                    total += ce - cs;
                }
                (total / self.span_s).min(1.0)
            })
            .collect()
    }
}

/// Deterministic trace replay (see the module docs for the instance →
/// trace-window mapping).
#[derive(Debug, Clone)]
pub struct TraceReplay {
    trace: Arc<FaultTrace>,
}

impl TraceReplay {
    /// Replay a shared trace.
    pub fn new(trace: Arc<FaultTrace>) -> Self {
        TraceReplay { trace }
    }

    /// The underlying trace.
    pub fn trace(&self) -> &FaultTrace {
        &self.trace
    }

    /// The trace window an instance/attempt occupies.
    pub fn window(&self, ctx: &FaultCtx) -> (f64, f64) {
        let d = ctx.job_duration_s;
        let t0 = (ctx.instance as f64 + ctx.attempt as f64) * d;
        (t0, t0 + d)
    }
}

impl FaultModel for TraceReplay {
    fn name(&self) -> &'static str {
        "trace"
    }

    fn num_nodes(&self) -> usize {
        self.trace.num_nodes()
    }

    fn true_outage(&self) -> Vec<f64> {
        self.trace.down_fraction()
    }

    fn sample(&self, ctx: &FaultCtx, _rng: &mut Rng) -> Vec<bool> {
        let (t0, t1) = self.window(ctx);
        let n = self.trace.num_nodes();
        if t1 <= t0 {
            return vec![false; n];
        }
        (0..n).map(|i| self.trace.down_in(i, t0, t1)).collect()
    }
}

fn strip_comment(line: &str) -> &str {
    match line.find('#') {
        Some(i) => line[..i].trim(),
        None => line.trim(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TRACE: &str = "\
# two flaky nodes on a 8-node platform
nodes 8
1 0.0 1.5
1 4.0 5.0
6 2.0 2.5   # trailing comment
";

    fn replay() -> TraceReplay {
        TraceReplay::new(Arc::new(FaultTrace::parse(TRACE.as_bytes()).unwrap()))
    }

    #[test]
    fn parse_roundtrip() {
        let t = FaultTrace::parse(TRACE.as_bytes()).unwrap();
        assert_eq!(t.num_nodes(), 8);
        assert_eq!(t.span_s(), 5.0);
        assert_eq!(t.intervals(1), &[(0.0, 1.5), (4.0, 5.0)]);
        let back = FaultTrace::parse(t.to_text().as_bytes()).unwrap();
        assert_eq!(back.intervals(1), t.intervals(1));
        assert_eq!(back.span_s(), t.span_s());
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "",
            "nodes\n",
            "racks 8\n",
            "nodes 8\n9 0.0 1.0\n",
            "nodes 8\n1 2.0 1.0\n",
            "nodes 8\n1 -1.0 1.0\n",
            "nodes 8\n1 0.0\n",
            "nodes 8\n1 0.0 x\n",
        ] {
            assert!(FaultTrace::parse(bad.as_bytes()).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn replay_is_deterministic_and_consumes_no_rng() {
        let m = replay();
        let ctx = FaultCtx::new(0, 1.0);
        let mut rng = Rng::new(3);
        let before = rng.clone().next_u64();
        let a = m.sample(&ctx, &mut rng);
        let b = m.sample(&ctx, &mut rng);
        assert_eq!(a, b);
        assert_eq!(rng.next_u64(), before, "trace replay consumed RNG draws");
    }

    #[test]
    fn windows_follow_instances_and_attempts() {
        let m = replay();
        let mut rng = Rng::new(0);
        // instance 0, d=1: window [0,1) overlaps node 1's [0,1.5)
        let d0 = m.sample(&FaultCtx::new(0, 1.0), &mut rng);
        assert!(d0[1] && !d0[6]);
        // instance 2, d=1: [2,3) overlaps node 6's [2,2.5)
        let d2 = m.sample(&FaultCtx::new(2, 1.0), &mut rng);
        assert!(!d2[1] && d2[6]);
        // instance 0 retry (attempt 1): window moves to [1,2) — clean
        let retry = m.sample(
            &FaultCtx {
                instance: 0,
                attempt: 1,
                job_duration_s: 1.0,
            },
            &mut rng,
        );
        assert!(retry.iter().all(|&x| !x));
        // beyond the trace span: nothing is down
        let far = m.sample(&FaultCtx::new(100, 1.0), &mut rng);
        assert!(far.iter().all(|&x| !x));
    }

    #[test]
    fn down_fraction_merges_overlaps() {
        let text = "nodes 4\n0 0.0 2.0\n0 1.0 3.0\n1 0.0 4.0\n";
        let t = FaultTrace::parse(text.as_bytes()).unwrap();
        let f = t.down_fraction();
        assert!((f[0] - 3.0 / 4.0).abs() < 1e-12);
        assert!((f[1] - 1.0).abs() < 1e-12);
        assert_eq!(f[2], 0.0);
    }

    #[test]
    fn empty_trace_is_fault_free() {
        let t = FaultTrace::parse("nodes 4\n".as_bytes()).unwrap();
        assert_eq!(t.down_fraction(), vec![0.0; 4]);
        let m = TraceReplay::new(Arc::new(t));
        let mut rng = Rng::new(0);
        assert!(m
            .sample(&FaultCtx::new(0, 1.0), &mut rng)
            .iter()
            .all(|&x| !x));
        assert!(m.true_outage().iter().all(|&p| p == 0.0));
    }
}
