//! Pluggable fault models (generalizing Section 5.2 of the paper).
//!
//! The paper's evaluation uses exactly one failure regime: a fixed set
//! `N_f` of nodes, each independently down with a shared probability
//! `p_f`. Real resilience studies need more — correlated rack/switch
//! outages, temporal failure processes, and replay of recorded downtime
//! traces — so down-state generation lives behind the [`FaultModel`]
//! trait with four implementations:
//!
//! * [`IidBernoulli`] — the paper's model and the back-compat default;
//! * [`CorrelatedDomains`] — topology-aware: a whole failure domain
//!   (rack = X-line of the torus, see
//!   [`crate::topology::Platform::rack_members`]) goes down together;
//! * [`WeibullLifetime`] — per-node time-to-failure with shape/scale, so
//!   a job with a longer makespan sees more failures (the sample is
//!   coupled to [`crate::sim::executor::JobProfile::success_s`] through
//!   [`FaultCtx::job_duration_s`]);
//! * [`TraceReplay`] — deterministic replay of a LANL-style down-interval
//!   trace ([`FaultTrace`]).
//!
//! ## Determinism contract
//!
//! Every model draws all of its randomness from the `&mut Rng` handed to
//! [`FaultModel::sample`]. The batch engine passes a per-instance
//! [`Rng::stream`], so results stay bit-identical for every worker count
//! — the same contract `batch::parallel` establishes for the paper's
//! model holds for all four (checked by `tests/parallel.rs`).
//!
//! ```
//! use tofa::rng::Rng;
//! use tofa::sim::fault::{FaultCtx, FaultModel, IidBernoulli};
//!
//! // the paper's model: nodes 3 and 7 flaky, each down with p_f = 0.5
//! let model = IidBernoulli::new(vec![3, 7], 0.5, 16);
//! assert_eq!(model.true_outage()[3], 0.5);
//! let down = model.sample(&FaultCtx::new(0, 1.0), &mut Rng::new(42));
//! for (node, &d) in down.iter().enumerate() {
//!     assert!(!d || node == 3 || node == 7, "only flaky nodes go down");
//! }
//! ```

pub mod correlated;
pub mod iid;
pub mod trace;
pub mod weibull;

pub use correlated::{CorrelatedDomains, Domain};
pub use iid::IidBernoulli;
pub use trace::{FaultTrace, TraceReplay};
pub use weibull::WeibullLifetime;

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::topology::Platform;

/// Per-instance context a model may condition on. Temporal models use the
/// job duration (Weibull: longer jobs fail more; trace replay: the
/// instance's window in trace time); memoryless models ignore it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultCtx {
    /// Index of the instance within its batch.
    pub instance: u64,
    /// Restart attempt for this instance (0 = first run). Trace replay
    /// advances its window by one job duration per attempt, modeling a
    /// restart that happens later in wall-clock time.
    pub attempt: u32,
    /// Fault-free makespan of the job under the batch's placement, in
    /// simulated seconds (see `Simulator::prepare`).
    pub job_duration_s: f64,
}

impl FaultCtx {
    /// Context for the first attempt of `instance`.
    pub fn new(instance: u64, job_duration_s: f64) -> Self {
        FaultCtx {
            instance,
            attempt: 0,
            job_duration_s,
        }
    }
}

/// A generative model of per-instance node down-states.
///
/// Implementations must be pure functions of `(self, ctx, rng)`: no
/// interior mutability, no global state — the parallel batch engine calls
/// [`FaultModel::sample`] concurrently from many worker threads and
/// requires bit-identical results for every worker count.
pub trait FaultModel: std::fmt::Debug + Send + Sync {
    /// Short model name (`"iid"`, `"correlated"`, `"weibull"`, `"trace"`).
    fn name(&self) -> &'static str;

    /// Platform size the model describes.
    fn num_nodes(&self) -> usize;

    /// The true per-node outage probability vector — what the heartbeat
    /// estimation path tries to recover. For temporal models this is the
    /// probability over the model's planning horizon; for trace replay,
    /// each node's down-time fraction over the trace span.
    fn true_outage(&self) -> Vec<f64>;

    /// Sample the down-state for one job instance, drawing all randomness
    /// from `rng` (a per-instance [`Rng::stream`] in batch runs).
    fn sample(&self, ctx: &FaultCtx, rng: &mut Rng) -> Vec<bool>;
}

/// The per-batch fault configuration: a shared handle to the model that
/// generates every instance's down-state. Cloning shares the model.
#[derive(Debug, Clone)]
pub struct FaultScenario {
    model: Arc<dyn FaultModel>,
}

impl FaultScenario {
    /// Wrap a concrete model.
    pub fn new(model: impl FaultModel + 'static) -> Self {
        FaultScenario {
            model: Arc::new(model),
        }
    }

    /// Wrap an already-shared model.
    pub fn from_arc(model: Arc<dyn FaultModel>) -> Self {
        FaultScenario { model }
    }

    /// No faults.
    pub fn none(num_nodes: usize) -> Self {
        Self::new(IidBernoulli::new(Vec::new(), 0.0, num_nodes))
    }

    /// The paper's model: `faulty_nodes` each independently down with
    /// probability `p_f`.
    pub fn iid(faulty_nodes: Vec<usize>, p_f: f64, num_nodes: usize) -> Self {
        Self::new(IidBernoulli::new(faulty_nodes, p_f, num_nodes))
    }

    /// Randomly select `n_f` i.i.d. faulty nodes with probability `p_f`
    /// each (the seed repo's `FaultScenario::random`, draw-for-draw).
    pub fn random(num_nodes: usize, n_f: usize, p_f: f64, rng: &mut Rng) -> Self {
        Self::new(IidBernoulli::random(num_nodes, n_f, p_f, rng))
    }

    /// The wrapped model.
    pub fn model(&self) -> &dyn FaultModel {
        self.model.as_ref()
    }

    /// Short model name.
    pub fn model_name(&self) -> &'static str {
        self.model.name()
    }

    /// Platform size.
    pub fn num_nodes(&self) -> usize {
        self.model.num_nodes()
    }

    /// The true per-node outage probability vector (what heartbeat
    /// estimation tries to recover).
    pub fn true_outage(&self) -> Vec<f64> {
        self.model.true_outage()
    }

    /// Node ids with non-zero outage probability.
    pub fn suspect_nodes(&self) -> Vec<usize> {
        self.true_outage()
            .iter()
            .enumerate()
            .filter(|(_, &p)| p > 0.0)
            .map(|(n, _)| n)
            .collect()
    }

    /// Sample the down-state for one job instance.
    pub fn sample_down(&self, ctx: &FaultCtx, rng: &mut Rng) -> Vec<bool> {
        self.model.sample(ctx, rng)
    }
}

/// Cloneable recipe for deriving one [`FaultScenario`] per batch of a
/// sweep. `run_grid` realizes the spec with a per-batch RNG stream, so
/// every policy within a batch sees the same scenario (the paper's paired
/// comparison) and results stay independent of the worker count.
#[derive(Debug, Clone)]
pub enum FaultSpec {
    /// No faults.
    None,
    /// The paper's model: `n_faulty` random nodes at probability `p_f`.
    Iid {
        /// Faulty-node count `N_f`.
        n_faulty: usize,
        /// Shared outage probability `p_f`.
        p_f: f64,
    },
    /// `domains` random racks (X-lines of the torus), each failing as a
    /// unit with probability `p_domain`.
    CorrelatedRacks {
        /// Faulty-rack count.
        domains: usize,
        /// Per-instance whole-rack outage probability.
        p_domain: f64,
    },
    /// `n_faulty` random nodes with Weibull time-to-failure, calibrated
    /// so a job of `horizon_s` seconds aborts with probability
    /// `p_horizon` per node.
    Weibull {
        /// Faulty-node count.
        n_faulty: usize,
        /// Weibull shape `k` (< 1 = infant mortality, 1 = exponential).
        shape: f64,
        /// Target per-node outage probability at the horizon.
        p_horizon: f64,
        /// Planning horizon in simulated seconds.
        horizon_s: f64,
    },
    /// Deterministic replay of a recorded down-interval trace.
    Trace {
        /// The shared, parsed trace.
        trace: Arc<FaultTrace>,
    },
}

impl FaultSpec {
    /// Short model name (matches `repro --fault-model=` values).
    pub fn model_name(&self) -> &'static str {
        match self {
            FaultSpec::None => "none",
            FaultSpec::Iid { .. } => "iid",
            FaultSpec::CorrelatedRacks { .. } => "correlated",
            FaultSpec::Weibull { .. } => "weibull",
            FaultSpec::Trace { .. } => "trace",
        }
    }

    /// Human-readable parameter summary for report titles and logs.
    pub fn describe(&self) -> String {
        match self {
            FaultSpec::None => "no faults".to_string(),
            FaultSpec::Iid { n_faulty, p_f } => {
                format!("iid: {n_faulty} faulty @ p={p_f}")
            }
            FaultSpec::CorrelatedRacks { domains, p_domain } => {
                format!("correlated: {domains} racks @ p={p_domain}")
            }
            FaultSpec::Weibull {
                n_faulty,
                shape,
                p_horizon,
                horizon_s,
            } => {
                format!("weibull: {n_faulty} faulty, k={shape}, p={p_horizon} @ {horizon_s}s")
            }
            FaultSpec::Trace { trace } => {
                format!("trace replay over {} nodes", trace.num_nodes())
            }
        }
    }

    /// Derive the concrete scenario for one batch. All randomness comes
    /// from `rng` (a per-batch [`Rng::stream`] in grid sweeps); for the
    /// `Iid` spec the draws match the seed repo's scenario derivation
    /// bit-for-bit (checked by `tests/golden.rs`).
    pub fn realize(&self, platform: &Platform, rng: &mut Rng) -> Result<FaultScenario> {
        let n = platform.num_nodes();
        match self {
            FaultSpec::None => Ok(FaultScenario::none(n)),
            FaultSpec::Iid { n_faulty, p_f } => {
                check_count(*n_faulty, n, "faulty nodes")?;
                Ok(FaultScenario::random(n, *n_faulty, *p_f, rng))
            }
            FaultSpec::CorrelatedRacks { domains, p_domain } => {
                check_count(*domains, platform.num_racks(), "faulty racks")?;
                Ok(FaultScenario::new(CorrelatedDomains::random_racks(
                    platform, *domains, *p_domain, rng,
                )))
            }
            FaultSpec::Weibull {
                n_faulty,
                shape,
                p_horizon,
                horizon_s,
            } => {
                check_count(*n_faulty, n, "faulty nodes")?;
                let nodes = rng.sample_distinct(n, *n_faulty);
                Ok(FaultScenario::new(WeibullLifetime::from_target(
                    nodes, *shape, *p_horizon, *horizon_s, n,
                )?))
            }
            FaultSpec::Trace { trace } => {
                if trace.num_nodes() != n {
                    return Err(Error::Fault(format!(
                        "trace covers {} nodes but the platform has {n}",
                        trace.num_nodes()
                    )));
                }
                Ok(FaultScenario::new(TraceReplay::new(Arc::clone(trace))))
            }
        }
    }
}

fn check_count(k: usize, n: usize, what: &str) -> Result<()> {
    if k > n {
        return Err(Error::Fault(format!("{k} {what} requested but only {n} exist")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TorusDims;

    #[test]
    fn none_scenario_never_samples_down() {
        let s = FaultScenario::none(16);
        let mut rng = Rng::new(0);
        let ctx = FaultCtx::new(0, 1.0);
        assert!(s.sample_down(&ctx, &mut rng).iter().all(|&d| !d));
        assert!(s.true_outage().iter().all(|&p| p == 0.0));
        assert!(s.suspect_nodes().is_empty());
    }

    #[test]
    fn scenario_clone_shares_model() {
        let s = FaultScenario::iid(vec![1, 2], 0.5, 8);
        let t = s.clone();
        assert_eq!(s.true_outage(), t.true_outage());
        assert_eq!(s.model_name(), "iid");
        assert_eq!(s.num_nodes(), 8);
        assert_eq!(s.suspect_nodes(), vec![1, 2]);
    }

    #[test]
    fn specs_realize_on_platform() {
        let plat = Platform::paper_default(TorusDims::new(4, 4, 4));
        let trace = Arc::new(FaultTrace::parse("nodes 64\n3 0.0 1.0\n".as_bytes()).unwrap());
        let specs = [
            FaultSpec::None,
            FaultSpec::Iid {
                n_faulty: 6,
                p_f: 0.1,
            },
            FaultSpec::CorrelatedRacks {
                domains: 2,
                p_domain: 0.2,
            },
            FaultSpec::Weibull {
                n_faulty: 6,
                shape: 0.7,
                p_horizon: 0.1,
                horizon_s: 1.0,
            },
            FaultSpec::Trace { trace },
        ];
        for spec in specs {
            let mut rng = Rng::new(3);
            let s = spec.realize(&plat, &mut rng).unwrap();
            assert_eq!(s.num_nodes(), 64, "{}", spec.model_name());
            if !matches!(spec, FaultSpec::None) {
                assert_eq!(s.model_name(), spec.model_name());
            }
            let p = s.true_outage();
            assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn specs_reject_oversized_requests() {
        let plat = Platform::paper_default(TorusDims::new(2, 2, 1));
        let mut rng = Rng::new(0);
        let iid = FaultSpec::Iid {
            n_faulty: 5,
            p_f: 0.1,
        };
        assert!(iid.realize(&plat, &mut rng).is_err());
        let racks = FaultSpec::CorrelatedRacks {
            domains: 3,
            p_domain: 0.1,
        };
        assert!(racks.realize(&plat, &mut rng).is_err());
        let trace = Arc::new(FaultTrace::parse("nodes 8\n".as_bytes()).unwrap());
        assert!(FaultSpec::Trace { trace }.realize(&plat, &mut rng).is_err());
    }

    #[test]
    fn iid_spec_realize_matches_seed_scenario_derivation() {
        // the exact draw order of the seed repo: one sample_distinct call
        let plat = Platform::paper_default(TorusDims::new(8, 8, 8));
        let spec = FaultSpec::Iid {
            n_faulty: 16,
            p_f: 0.02,
        };
        let mut a = Rng::new(42);
        let s = spec.realize(&plat, &mut a).unwrap();
        let mut b = Rng::new(42);
        let want = b.sample_distinct(512, 16);
        assert_eq!(s.suspect_nodes(), {
            let mut w = want.clone();
            w.sort_unstable();
            w
        });
        // both consumed the same number of draws
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
