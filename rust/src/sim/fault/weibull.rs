//! Weibull per-node lifetimes: temporal failures coupled to job length.
//!
//! Each faulty node draws a time-to-failure `T ~ Weibull(shape, scale)`
//! per instance; the node is down for the instance iff `T` falls inside
//! the job's makespan. A job running longer therefore sees more failures
//! — the coupling the paper's duration-blind Bernoulli model cannot
//! express. Shape < 1 models infant mortality (failure-prone right after
//! reboot, the empirically dominant HPC regime); shape = 1 is the
//! memoryless exponential; shape > 1 models wear-out.

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::sim::fault::{FaultCtx, FaultModel};

/// Per-node Weibull time-to-failure on a fixed faulty set.
#[derive(Debug, Clone)]
pub struct WeibullLifetime {
    /// Nodes with a finite lifetime, in the order draws are consumed.
    pub faulty_nodes: Vec<usize>,
    /// Weibull shape parameter `k`.
    pub shape: f64,
    /// Weibull scale parameter (characteristic life) in simulated seconds.
    pub scale_s: f64,
    /// Planning horizon for [`FaultModel::true_outage`]: the job duration
    /// the controller assumes when estimating outage probabilities before
    /// a placement (and thus a real makespan) exists.
    pub horizon_s: f64,
    /// Platform size.
    pub num_nodes: usize,
}

impl WeibullLifetime {
    /// Explicit parameters.
    pub fn new(
        faulty_nodes: Vec<usize>,
        shape: f64,
        scale_s: f64,
        horizon_s: f64,
        num_nodes: usize,
    ) -> Result<Self> {
        let positive = |x: f64| x.is_finite() && x > 0.0;
        if !positive(shape) || !positive(scale_s) || !positive(horizon_s) {
            return Err(Error::Fault(format!(
                "weibull parameters must be positive: shape {shape}, scale {scale_s}, \
                 horizon {horizon_s}"
            )));
        }
        debug_assert!(faulty_nodes.iter().all(|&n| n < num_nodes));
        Ok(WeibullLifetime {
            faulty_nodes,
            shape,
            scale_s,
            horizon_s,
            num_nodes,
        })
    }

    /// Calibrate the scale so that a job of exactly `horizon_s` seconds
    /// sees each faulty node down with probability `p_horizon` — the
    /// Weibull counterpart of the paper's `p_f`.
    pub fn from_target(
        faulty_nodes: Vec<usize>,
        shape: f64,
        p_horizon: f64,
        horizon_s: f64,
        num_nodes: usize,
    ) -> Result<Self> {
        let in_open_unit = p_horizon > 0.0 && p_horizon < 1.0;
        if !in_open_unit {
            return Err(Error::Fault(format!(
                "weibull target probability must be in (0, 1): {p_horizon}"
            )));
        }
        // p(t) = 1 - exp(-(t/scale)^k)  =>  scale = t / (-ln(1-p))^(1/k)
        let scale_s = horizon_s / (-(1.0 - p_horizon).ln()).powf(1.0 / shape);
        Self::new(faulty_nodes, shape, scale_s, horizon_s, num_nodes)
    }

    /// Probability a faulty node is down for a job of `t` seconds:
    /// the Weibull CDF `1 - exp(-(t/scale)^k)`.
    pub fn p_down_at(&self, t: f64) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        1.0 - (-(t / self.scale_s).powf(self.shape)).exp()
    }

    /// Outage probability vector for a job of `t` seconds (the horizon-
    /// free variant of [`FaultModel::true_outage`]).
    pub fn outage_at(&self, t: f64) -> Vec<f64> {
        let p = self.p_down_at(t);
        let mut out = vec![0.0; self.num_nodes];
        for &n in &self.faulty_nodes {
            out[n] = p;
        }
        out
    }
}

impl FaultModel for WeibullLifetime {
    fn name(&self) -> &'static str {
        "weibull"
    }

    fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    fn true_outage(&self) -> Vec<f64> {
        self.outage_at(self.horizon_s)
    }

    fn sample(&self, ctx: &FaultCtx, rng: &mut Rng) -> Vec<bool> {
        // inverse-CDF lifetime draw per faulty node, in stored order:
        // T = scale * (-ln(1-u))^(1/k); down iff T < job duration
        let mut down = vec![false; self.num_nodes];
        for &n in &self.faulty_nodes {
            let u = rng.f64();
            let lifetime = self.scale_s * (-(1.0 - u).ln()).powf(1.0 / self.shape);
            if lifetime < ctx.job_duration_s {
                down[n] = true;
            }
        }
        down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longer_jobs_see_more_failures() {
        let m = WeibullLifetime::from_target((0..32).collect(), 0.7, 0.1, 1.0, 64).unwrap();
        let mut rng = Rng::new(11);
        let rate = |dur: f64, rng: &mut Rng| {
            let trials = 4000;
            let mut downs = 0usize;
            for i in 0..trials {
                let ctx = FaultCtx::new(i, dur);
                downs += m.sample(&ctx, rng).iter().filter(|&&d| d).count();
            }
            downs as f64 / (trials as usize * 32) as f64
        };
        let short = rate(0.2, &mut rng);
        let nominal = rate(1.0, &mut rng);
        let long = rate(5.0, &mut rng);
        assert!(short < nominal && nominal < long, "{short} {nominal} {long}");
        // calibration: at the horizon the rate matches the target
        assert!((nominal - 0.1).abs() < 0.02, "nominal={nominal}");
        assert!((m.p_down_at(1.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn true_outage_uses_horizon() {
        let m = WeibullLifetime::from_target(vec![3], 1.0, 0.25, 2.0, 8).unwrap();
        let p = m.true_outage();
        assert!((p[3] - 0.25).abs() < 1e-12);
        assert_eq!(p[0], 0.0);
        // monotone in duration, bounded by 1
        assert!(m.p_down_at(0.0) == 0.0);
        assert!(m.p_down_at(1.0) < m.p_down_at(4.0));
        assert!(m.p_down_at(1e9) <= 1.0);
    }

    #[test]
    fn zero_duration_never_fails() {
        let m = WeibullLifetime::from_target(vec![0, 1], 0.5, 0.5, 1.0, 4).unwrap();
        let mut rng = Rng::new(2);
        let down = m.sample(&FaultCtx::new(0, 0.0), &mut rng);
        assert!(down.iter().all(|&d| !d));
    }

    #[test]
    fn invalid_parameters_rejected() {
        assert!(WeibullLifetime::new(vec![], 0.0, 1.0, 1.0, 4).is_err());
        assert!(WeibullLifetime::new(vec![], 1.0, -1.0, 1.0, 4).is_err());
        assert!(WeibullLifetime::from_target(vec![], 1.0, 0.0, 1.0, 4).is_err());
        assert!(WeibullLifetime::from_target(vec![], 1.0, 1.0, 1.0, 4).is_err());
    }
}
