//! The paper's failure model (Section 5.2): independent Bernoulli
//! outages on a fixed faulty set.

use crate::rng::Rng;
use crate::sim::fault::{FaultCtx, FaultModel};

/// A set `N_f` of nodes, each independently down with a shared
/// probability `p_f` per job instance — exactly the seed repo's
/// `FaultScenario`, draw-for-draw (the golden tests depend on this).
#[derive(Debug, Clone)]
pub struct IidBernoulli {
    /// Node ids with non-zero outage probability (`N_f`), in the order
    /// Bernoulli draws are consumed.
    pub faulty_nodes: Vec<usize>,
    /// The shared outage probability (`p_f`).
    pub p_f: f64,
    /// Platform size.
    pub num_nodes: usize,
}

impl IidBernoulli {
    /// Fixed faulty set.
    pub fn new(faulty_nodes: Vec<usize>, p_f: f64, num_nodes: usize) -> Self {
        debug_assert!((0.0..=1.0).contains(&p_f), "p_f out of range: {p_f}");
        debug_assert!(faulty_nodes.iter().all(|&n| n < num_nodes));
        IidBernoulli {
            faulty_nodes,
            p_f,
            num_nodes,
        }
    }

    /// Randomly select `n_f` faulty nodes with probability `p_f` each.
    pub fn random(num_nodes: usize, n_f: usize, p_f: f64, rng: &mut Rng) -> Self {
        Self::new(rng.sample_distinct(num_nodes, n_f), p_f, num_nodes)
    }
}

impl FaultModel for IidBernoulli {
    fn name(&self) -> &'static str {
        "iid"
    }

    fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    fn true_outage(&self) -> Vec<f64> {
        let mut p = vec![0.0; self.num_nodes];
        for &n in &self.faulty_nodes {
            p[n] = self.p_f;
        }
        p
    }

    fn sample(&self, _ctx: &FaultCtx, rng: &mut Rng) -> Vec<bool> {
        // one Bernoulli draw per faulty node, in stored order — the seed
        // repo's sample_down_nodes, bit-for-bit
        let mut down = vec![false; self.num_nodes];
        for &n in &self.faulty_nodes {
            if rng.bernoulli(self.p_f) {
                down[n] = true;
            }
        }
        down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn down_rate_matches_p_f() {
        let mut rng = Rng::new(1);
        let m = IidBernoulli::random(512, 16, 0.02, &mut rng);
        assert_eq!(m.faulty_nodes.len(), 16);
        let ctx = FaultCtx::new(0, 1.0);
        let mut downs = 0usize;
        let trials = 10_000;
        for _ in 0..trials {
            downs += m.sample(&ctx, &mut rng).iter().filter(|&&d| d).count();
        }
        let rate = downs as f64 / (trials * 16) as f64;
        assert!((rate - 0.02).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn only_faulty_nodes_go_down() {
        let mut rng = Rng::new(2);
        let m = IidBernoulli::random(64, 4, 1.0, &mut rng);
        let down = m.sample(&FaultCtx::new(0, 1.0), &mut rng);
        for (n, &d) in down.iter().enumerate() {
            assert_eq!(d, m.faulty_nodes.contains(&n));
        }
    }

    #[test]
    fn true_outage_vector() {
        let m = IidBernoulli::new(vec![3, 7], 0.02, 10);
        let p = m.true_outage();
        assert_eq!(p[3], 0.02);
        assert_eq!(p[7], 0.02);
        assert_eq!(p.iter().filter(|&&x| x > 0.0).count(), 2);
    }

    #[test]
    fn sample_ignores_ctx() {
        let m = IidBernoulli::new(vec![0, 5, 9], 0.5, 16);
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let x = m.sample(&FaultCtx::new(0, 1.0), &mut a);
        let y = m.sample(
            &FaultCtx {
                instance: 7,
                attempt: 3,
                job_duration_s: 99.0,
            },
            &mut b,
        );
        assert_eq!(x, y);
    }
}
