//! SMPI-lite: translate MPI op schedules into network flow phases.
//!
//! Under a placement, every message `src_rank -> dst_rank` becomes a flow
//! along the topology's fixed route between the hosting nodes. Collectives
//! expand through the same algorithm emulation the profiler uses
//! ([`crate::profiler::collectives`]), so simulated timing and profiled
//! traffic are consistent.

use crate::apps::MpiOp;
use crate::profiler::{expand, Msg};
use crate::sim::network::{Flow, NetSim};
use crate::topology::Topology;

/// A simulation phase: either local compute or a set of concurrent flows.
#[derive(Debug, Clone)]
pub enum Phase {
    /// All ranks compute `flops` (barrier-synchronized).
    Compute { flops: f64 },
    /// Concurrent messages between world ranks.
    Comm { msgs: Vec<Msg> },
}

/// Expand an op schedule into phases (collectives become per-round comm
/// phases).
pub fn phases_of(ops: &[MpiOp]) -> Vec<Phase> {
    let mut phases = Vec::with_capacity(ops.len());
    for op in ops {
        match op {
            MpiOp::Compute { flops } => phases.push(Phase::Compute { flops: *flops }),
            MpiOp::PointToPoint { msgs } => phases.push(Phase::Comm { msgs: msgs.clone() }),
            MpiOp::Collective { comm, kind, bytes } => {
                for round in expand(*kind, comm.size(), *bytes) {
                    let msgs = round
                        .into_iter()
                        .map(|m| Msg {
                            src: comm.to_world(m.src),
                            dst: comm.to_world(m.dst),
                            bytes: m.bytes,
                        })
                        .collect();
                    phases.push(Phase::Comm { msgs });
                }
            }
        }
    }
    phases
}

/// Convert a comm phase's messages into flows under a placement.
/// Returns `None` if any flow touches a down node (endpoint or transit) —
/// the SimGrid capacity-zero condition that aborts the job. Transit
/// vertices beyond `down.len()` are switches/routers, which never fail.
pub fn flows_for_phase(
    topo: &dyn Topology,
    net: &NetSim,
    assignment: &[usize],
    down: &[bool],
    msgs: &[Msg],
    route_buf: &mut Vec<crate::topology::Link>,
) -> Option<Vec<Flow>> {
    let node_down = |n: usize| n < down.len() && down[n];
    let mut flows = Vec::with_capacity(msgs.len());
    for m in msgs {
        let (u, v) = (assignment[m.src], assignment[m.dst]);
        if down[u] || down[v] {
            return None;
        }
        if u == v {
            flows.push(Flow {
                links: Vec::new(),
                bytes: m.bytes,
            });
            continue;
        }
        topo.route_into(u, v, route_buf);
        let mut links = Vec::with_capacity(route_buf.len());
        for l in route_buf.iter() {
            // transit through a down compute node fails the transmission
            if node_down(l.dst) || node_down(l.src) {
                return None;
            }
            links.push(net.slot(l.src, l.dst));
        }
        flows.push(Flow {
            links,
            bytes: m.bytes,
        });
    }
    Some(flows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::{CollectiveKind, Communicator};
    use crate::topology::{Torus, TorusDims};

    #[test]
    fn collective_ops_expand_to_rounds() {
        let ops = vec![MpiOp::Collective {
            comm: Communicator::world(8),
            kind: CollectiveKind::Allreduce,
            bytes: 64.0,
        }];
        let phases = phases_of(&ops);
        assert_eq!(phases.len(), 3); // log2(8) rounds
        assert!(matches!(phases[0], Phase::Comm { .. }));
    }

    #[test]
    fn down_transit_node_aborts() {
        let torus = Torus::new(TorusDims::new(8, 1, 1));
        let net = NetSim::new(&torus, 1e9, 1e-6);
        let mut down = vec![false; 8];
        down[1] = true; // transit node between 0 and 2
        let msgs = vec![Msg {
            src: 0,
            dst: 1,
            bytes: 100.0,
        }];
        // ranks on nodes 0 and 2: route 0->1->2 crosses down node 1
        let mut buf = Vec::new();
        let r = flows_for_phase(&torus, &net, &[0, 2], &down, &msgs, &mut buf);
        assert!(r.is_none());
    }

    #[test]
    fn down_endpoint_aborts() {
        let torus = Torus::new(TorusDims::new(4, 1, 1));
        let net = NetSim::new(&torus, 1e9, 1e-6);
        let mut down = vec![false; 4];
        down[3] = true;
        let msgs = vec![Msg {
            src: 0,
            dst: 1,
            bytes: 10.0,
        }];
        let mut buf = Vec::new();
        assert!(flows_for_phase(&torus, &net, &[0, 3], &down, &msgs, &mut buf).is_none());
    }

    #[test]
    fn same_node_message_is_local() {
        let torus = Torus::new(TorusDims::new(4, 1, 1));
        let net = NetSim::new(&torus, 1e9, 1e-6);
        let down = vec![false; 4];
        let msgs = vec![Msg {
            src: 0,
            dst: 1,
            bytes: 10.0,
        }];
        let mut buf = Vec::new();
        // both ranks on node 2 — valid here since we bypass Placement
        let flows =
            flows_for_phase(&torus, &net, &[2, 2], &down, &msgs, &mut buf).unwrap();
        assert!(flows[0].links.is_empty());
    }
}
