//! Flow-level network model with max-min fair bandwidth sharing.
//!
//! A *phase* is a set of concurrent flows with fixed routes. Within a
//! phase, link bandwidth is shared max-min fairly (SimGrid's default CM02
//! -style fluid model): we repeatedly find the bottleneck link (smallest
//! fair share), freeze its flows at that rate, remove their demand, and
//! continue. As flows finish, rates are recomputed event-by-event.
//!
//! The solver is event-driven around a per-phase **touched-link active
//! list** and a **CSR link -> flow adjacency**, both built once per phase:
//! bottleneck rounds scan only the links this phase's flows actually
//! cross (a handful, vs the platform's full link array — the gap is
//! widest on fat-tree/dragonfly fabrics whose link counts dwarf the
//! torus), and freezing a bottleneck walks exactly the flows on that link
//! instead of re-scanning the whole flow list. Results are bit-identical
//! to the dense reference solver (kept as
//! [`NetSim::phase_duration_reference`]; equivalence asserted in
//! `tests/proptests.rs`): the active list is sorted ascending so
//! bottleneck tie-breaking, freeze order, and every f64 operation happen
//! in the same order as the dense scan.

use crate::topology::Topology;

/// A flow: bytes to move along a fixed route of directed link slots.
#[derive(Debug, Clone)]
pub struct Flow {
    /// Link slot ids (see [`Topology::link_index`]); empty = same node.
    pub links: Vec<u32>,
    /// Payload bytes.
    pub bytes: f64,
}

/// Reusable flow-phase simulator for one platform.
///
/// Holds the link index and scratch buffers so per-phase simulation does
/// not allocate on the hot path. `Clone` gives each worker thread of the
/// parallel batch engine its own scratch space.
#[derive(Debug, Clone)]
pub struct NetSim {
    num_links: usize,
    link_slot: Vec<u32>,
    n_vertices: usize,
    /// Per-slot full capacity: `bandwidth * Topology::link_capacity_scale`
    /// (uniform fabrics keep every entry equal to `bandwidth`).
    cap_full: Vec<f64>,
    latency: f64,
    // --- per-phase index (built once per phase_duration call) ---
    /// Distinct link slots this phase's flows cross, sorted ascending
    /// (ascending order preserves the dense solver's bottleneck
    /// tie-breaking bit-for-bit).
    active_links: Vec<u32>,
    /// Slot -> dense active index, valid for slots stamped this epoch.
    link_pos: Vec<u32>,
    /// Per-slot epoch stamps (u64: never wraps in practice), so the
    /// dedup pass never has to clear the full link array.
    link_epoch: Vec<u64>,
    epoch: u64,
    /// CSR offsets into [`Self::csr_flows`], one slice per active link.
    csr_off: Vec<u32>,
    /// Flow ids per active link, ascending (freeze order of the dense
    /// solver's whole-flow-list scan).
    csr_flows: Vec<u32>,
    csr_cursor: Vec<u32>,
    // --- per-round scratch, dense over the active links ---
    cap: Vec<f64>,
    nflows_on: Vec<u32>,
    // --- per-flow scratch ---
    rate: Vec<f64>,
    remaining: Vec<f64>,
    alive: Vec<bool>,
    frozen: Vec<bool>,
}

impl NetSim {
    /// Build for a platform topology.
    pub fn new(topo: &dyn Topology, bandwidth: f64, latency: f64) -> Self {
        let (link_slot, num_links) = topo.link_index();
        let n_vertices = topo.num_vertices();
        let mut cap_full = vec![bandwidth; num_links];
        for l in topo.all_links() {
            let slot = link_slot[l.src * n_vertices + l.dst] as usize;
            cap_full[slot] = bandwidth * topo.link_capacity_scale(l.src, l.dst);
        }
        NetSim {
            num_links,
            link_slot,
            n_vertices,
            cap_full,
            latency,
            active_links: Vec::new(),
            link_pos: vec![0; num_links],
            link_epoch: vec![0; num_links],
            epoch: 0,
            csr_off: Vec::new(),
            csr_flows: Vec::new(),
            csr_cursor: Vec::new(),
            cap: Vec::new(),
            nflows_on: Vec::new(),
            rate: Vec::new(),
            remaining: Vec::new(),
            alive: Vec::new(),
            frozen: Vec::new(),
        }
    }

    /// Slot id of the directed link `src -> dst` (must be adjacent).
    #[inline]
    pub fn slot(&self, src: usize, dst: usize) -> u32 {
        let s = self.link_slot[src * self.n_vertices + dst];
        debug_assert_ne!(s, u32::MAX, "not a physical link: {src}->{dst}");
        s
    }

    /// Simulate one phase; returns its duration in seconds.
    ///
    /// Duration = max over flows of (per-flow completion under max-min
    /// sharing + route latency). Zero-link flows (same node) take zero
    /// network time.
    ///
    /// # Panics
    ///
    /// Panics — in every build profile — if the solver cannot assign a
    /// positive rate to some live flow (e.g. a flow whose links all ended
    /// up with zero capacity): without progress the event loop would
    /// otherwise spin forever on a zero-rate flow whose remaining bytes
    /// never shrink.
    pub fn phase_duration(&mut self, flows: &[Flow]) -> f64 {
        let nf = flows.len();
        if nf == 0 {
            return 0.0;
        }
        self.build_phase_index(flows);
        self.remaining.clear();
        self.remaining.extend(flows.iter().map(|f| f.bytes.max(0.0)));
        self.alive.clear();
        self.alive.resize(nf, true);
        self.rate.clear();
        self.rate.resize(nf, 0.0);
        self.frozen.clear();
        self.frozen.resize(nf, false);

        let mut n_alive = 0usize;
        for (i, f) in flows.iter().enumerate() {
            if f.links.is_empty() || f.bytes <= 0.0 {
                self.alive[i] = false; // local or empty: instantaneous
            } else {
                n_alive += 1;
            }
        }

        let mut t = 0.0f64;
        let mut dur = 0.0f64;
        // local flows still contribute latency 0; flows with links add
        // their latency at the end.
        while n_alive > 0 {
            self.compute_maxmin(flows);
            // earliest completion
            let mut dt = f64::INFINITY;
            for i in 0..nf {
                if self.alive[i] && self.rate[i] > 0.0 {
                    dt = dt.min(self.remaining[i] / self.rate[i]);
                }
            }
            assert!(
                dt.is_finite(),
                "max-min solver deadlock: {n_alive} live flow(s) were left at zero rate \
                 (every usable link saturated at zero capacity), so the phase can never \
                 finish — check link capacities and flow routes"
            );
            t += dt;
            for i in 0..nf {
                if self.alive[i] {
                    self.remaining[i] -= self.rate[i] * dt;
                    if self.remaining[i] <= 1e-9 * flows[i].bytes.max(1.0) {
                        self.alive[i] = false;
                        n_alive -= 1;
                        let total = t + flows[i].links.len() as f64 * self.latency;
                        dur = dur.max(total);
                    }
                }
            }
        }
        dur
    }

    /// Build the per-phase touched-link active list (sorted ascending)
    /// and the CSR link -> flow adjacency. Epoch stamps make the link
    /// dedup O(total route length) with no per-phase clearing of the full
    /// link array.
    fn build_phase_index(&mut self, flows: &[Flow]) {
        self.epoch += 1;
        self.active_links.clear();
        for f in flows {
            for &l in &f.links {
                if self.link_epoch[l as usize] != self.epoch {
                    self.link_epoch[l as usize] = self.epoch;
                    self.active_links.push(l);
                }
            }
        }
        self.active_links.sort_unstable();
        for (j, &l) in self.active_links.iter().enumerate() {
            self.link_pos[l as usize] = j as u32;
        }
        let na = self.active_links.len();
        self.cap.clear();
        self.cap.resize(na, 0.0);
        self.nflows_on.clear();
        self.nflows_on.resize(na, 0);
        // CSR: count, prefix-sum, fill (flow ids end up ascending per link)
        self.csr_off.clear();
        self.csr_off.resize(na + 1, 0);
        for f in flows {
            for &l in &f.links {
                let j = self.link_pos[l as usize] as usize;
                self.csr_off[j + 1] += 1;
            }
        }
        for j in 0..na {
            self.csr_off[j + 1] += self.csr_off[j];
        }
        self.csr_cursor.clear();
        self.csr_cursor.extend_from_slice(&self.csr_off[..na]);
        self.csr_flows.clear();
        self.csr_flows.resize(self.csr_off[na] as usize, 0);
        for (i, f) in flows.iter().enumerate() {
            for &l in &f.links {
                let j = self.link_pos[l as usize] as usize;
                let slot = self.csr_cursor[j] as usize;
                self.csr_flows[slot] = i as u32;
                self.csr_cursor[j] += 1;
            }
        }
    }

    /// Max-min progressive filling over the currently alive flows,
    /// event-driven on the per-phase index: rounds scan the active links
    /// only, and freezing walks the bottleneck's CSR flow list only.
    fn compute_maxmin(&mut self, flows: &[Flow]) {
        let na = self.active_links.len();
        for j in 0..na {
            self.cap[j] = self.cap_full[self.active_links[j] as usize];
            self.nflows_on[j] = 0;
        }
        self.frozen.fill(false);
        let mut unfrozen = 0usize;
        for (i, f) in flows.iter().enumerate() {
            if self.alive[i] {
                unfrozen += 1;
                for &l in &f.links {
                    self.nflows_on[self.link_pos[l as usize] as usize] += 1;
                }
            }
        }
        while unfrozen > 0 {
            // bottleneck link = min cap / nflows among links with live
            // flows; ascending scan keeps the dense solver's tie-breaking
            let mut best_fair = f64::INFINITY;
            let mut best = usize::MAX;
            for j in 0..na {
                if self.nflows_on[j] > 0 {
                    let fair = self.cap[j] / self.nflows_on[j] as f64;
                    if fair < best_fair {
                        best_fair = fair;
                        best = j;
                    }
                }
            }
            if best == usize::MAX {
                break;
            }
            // freeze all unfrozen alive flows crossing the bottleneck
            let (lo, hi) = (self.csr_off[best] as usize, self.csr_off[best + 1] as usize);
            for k in lo..hi {
                let i = self.csr_flows[k] as usize;
                if self.alive[i] && !self.frozen[i] {
                    self.frozen[i] = true;
                    self.rate[i] = best_fair;
                    unfrozen -= 1;
                    for &l in &flows[i].links {
                        let j = self.link_pos[l as usize] as usize;
                        self.cap[j] -= best_fair;
                        self.nflows_on[j] -= 1;
                    }
                }
            }
            // every alive flow on the bottleneck is now frozen and has
            // decremented it, so it can never be selected again
            debug_assert_eq!(self.nflows_on[best], 0);
        }
    }

    /// Dense reference solver: the pre-index implementation, kept verbatim
    /// (whole-link-array bottleneck scans, whole-flow-list freezes) as the
    /// ground truth for the bit-identity proptests and the `cost_engine`
    /// bench. Allocates its own scratch; do not use on hot paths.
    pub fn phase_duration_reference(&mut self, flows: &[Flow]) -> f64 {
        let nf = flows.len();
        if nf == 0 {
            return 0.0;
        }
        let mut remaining: Vec<f64> = flows.iter().map(|f| f.bytes.max(0.0)).collect();
        let mut alive = vec![true; nf];
        let mut rate = vec![0.0f64; nf];
        let mut cap = vec![0.0f64; self.num_links];
        let mut nflows_on = vec![0u32; self.num_links];
        let mut link_live = vec![false; self.num_links];

        let mut n_alive = 0usize;
        for (i, f) in flows.iter().enumerate() {
            if f.links.is_empty() || f.bytes <= 0.0 {
                alive[i] = false;
            } else {
                n_alive += 1;
            }
        }
        let mut t = 0.0f64;
        let mut dur = 0.0f64;
        while n_alive > 0 {
            // max-min progressive filling, dense
            let mut frozen = vec![false; nf];
            for (i, f) in flows.iter().enumerate() {
                if alive[i] {
                    for &l in &f.links {
                        cap[l as usize] = self.cap_full[l as usize];
                        nflows_on[l as usize] = 0;
                        link_live[l as usize] = true;
                    }
                }
            }
            for (i, f) in flows.iter().enumerate() {
                if alive[i] {
                    for &l in &f.links {
                        nflows_on[l as usize] += 1;
                    }
                }
            }
            let mut unfrozen: usize = (0..nf).filter(|&i| alive[i]).count();
            while unfrozen > 0 {
                let mut best_fair = f64::INFINITY;
                let mut best_link = usize::MAX;
                for l in 0..self.num_links {
                    if link_live[l] && nflows_on[l] > 0 {
                        let fair = cap[l] / nflows_on[l] as f64;
                        if fair < best_fair {
                            best_fair = fair;
                            best_link = l;
                        }
                    }
                }
                if best_link == usize::MAX {
                    break;
                }
                for (i, f) in flows.iter().enumerate() {
                    if alive[i] && !frozen[i] && f.links.iter().any(|&l| l as usize == best_link) {
                        frozen[i] = true;
                        rate[i] = best_fair;
                        unfrozen -= 1;
                        for &l in &f.links {
                            let l = l as usize;
                            cap[l] -= best_fair;
                            nflows_on[l] -= 1;
                            if nflows_on[l] == 0 {
                                link_live[l] = false;
                            }
                        }
                    }
                }
                link_live[best_link] = false;
            }
            for f in flows.iter() {
                for &l in &f.links {
                    link_live[l as usize] = false;
                }
            }
            // earliest completion
            let mut dt = f64::INFINITY;
            for i in 0..nf {
                if alive[i] && rate[i] > 0.0 {
                    dt = dt.min(remaining[i] / rate[i]);
                }
            }
            assert!(dt.is_finite(), "reference solver: live flow with zero rate");
            t += dt;
            for i in 0..nf {
                if alive[i] {
                    remaining[i] -= rate[i] * dt;
                    if remaining[i] <= 1e-9 * flows[i].bytes.max(1.0) {
                        alive[i] = false;
                        n_alive -= 1;
                        let total = t + flows[i].links.len() as f64 * self.latency;
                        dur = dur.max(total);
                    }
                }
            }
        }
        dur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Torus, TorusDims};

    fn sim() -> NetSim {
        let t = Torus::new(TorusDims::new(8, 1, 1));
        // 1 GB/s, 1 us
        NetSim::new(&t, 1e9, 1e-6)
    }

    #[test]
    fn per_link_capacity_scale_is_honored() {
        // dragonfly global links run at 2x: a flow crossing only the
        // global cable finishes twice as fast as a local-link flow
        use crate::topology::{Dragonfly, DragonflyParams};
        let d = Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap();
        let mut s = NetSim::new(&d, 1e9, 0.0);
        let route = d.route(0, 4); // crosses one global router-router link
        let global = route
            .iter()
            .find(|l| d.link_capacity_scale(l.src, l.dst) == 2.0)
            .expect("cross-group route must use a global link");
        let local = route.first().unwrap(); // node -> router, 1x
        let fast = s.phase_duration(&[Flow {
            links: vec![s.slot(global.src, global.dst)],
            bytes: 1e9,
        }]);
        let slow = s.phase_duration(&[Flow {
            links: vec![s.slot(local.src, local.dst)],
            bytes: 1e9,
        }]);
        assert!((fast - 0.5).abs() < 1e-6, "fast={fast}");
        assert!((slow - 1.0).abs() < 1e-6, "slow={slow}");
    }

    #[test]
    fn single_flow_bandwidth_bound() {
        let t = Torus::new(TorusDims::new(8, 1, 1));
        let mut s = sim();
        let f = Flow {
            links: vec![s.slot(0, 1)],
            bytes: 1e9,
        };
        let d = s.phase_duration(&[f]);
        assert!((d - (1.0 + 1e-6)).abs() < 1e-6, "d={d}");
        let _ = t;
    }

    #[test]
    fn two_flows_share_one_link() {
        let mut s = sim();
        let l = s.slot(0, 1);
        let flows = vec![
            Flow {
                links: vec![l],
                bytes: 1e9,
            },
            Flow {
                links: vec![l],
                bytes: 1e9,
            },
        ];
        let d = s.phase_duration(&flows);
        // both share 1 GB/s -> 2 s
        assert!((d - 2.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    fn disjoint_flows_run_in_parallel() {
        let mut s = sim();
        let flows = vec![
            Flow {
                links: vec![s.slot(0, 1)],
                bytes: 1e9,
            },
            Flow {
                links: vec![s.slot(4, 5)],
                bytes: 1e9,
            },
        ];
        let d = s.phase_duration(&flows);
        assert!((d - 1.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    fn short_flow_finishes_then_long_speeds_up() {
        let mut s = sim();
        let l = s.slot(0, 1);
        let flows = vec![
            Flow {
                links: vec![l],
                bytes: 0.5e9,
            },
            Flow {
                links: vec![l],
                bytes: 1.5e9,
            },
        ];
        // share until short done at t=1 (0.5 each); long has 1.0 left at
        // full rate -> total 2.0
        let d = s.phase_duration(&flows);
        assert!((d - 2.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    fn multi_hop_adds_latency_and_shares_each_link() {
        let mut s = sim();
        let f = Flow {
            links: vec![s.slot(0, 1), s.slot(1, 2), s.slot(2, 3)],
            bytes: 1e9,
        };
        let d = s.phase_duration(&[f]);
        assert!((d - (1.0 + 3e-6)).abs() < 1e-6, "d={d}");
    }

    #[test]
    fn local_flows_free() {
        let mut s = sim();
        assert_eq!(
            s.phase_duration(&[Flow {
                links: vec![],
                bytes: 1e12
            }]),
            0.0
        );
        assert_eq!(s.phase_duration(&[]), 0.0);
    }

    #[test]
    fn maxmin_bottleneck_distribution() {
        // flows A: link0 only; B: link0+link1; C: link1 only.
        // max-min: link0 splits .5/.5 between A,B; link1: B frozen at .5,
        // C gets remaining .5... then C could take 0.5 (cap 1 - 0.5).
        let mut s = sim();
        let l0 = s.slot(0, 1);
        let l1 = s.slot(1, 2);
        let flows = vec![
            Flow {
                links: vec![l0],
                bytes: 1e9,
            },
            Flow {
                links: vec![l0, l1],
                bytes: 1e9,
            },
            Flow {
                links: vec![l1],
                bytes: 1e9,
            },
        ];
        // All finish at t=2 (every flow gets 0.5 GB/s).
        let d = s.phase_duration(&flows);
        assert!((d - 2.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    #[should_panic(expected = "max-min solver deadlock")]
    fn zero_bandwidth_phase_panics_instead_of_spinning() {
        // a live flow that can never progress must abort the solve loudly
        // in every profile (in release builds the old code looped forever)
        let t = Torus::new(TorusDims::new(4, 1, 1));
        let mut s = NetSim::new(&t, 0.0, 0.0);
        let f = vec![Flow {
            links: vec![s.slot(0, 1)],
            bytes: 1e6,
        }];
        s.phase_duration(&f);
    }

    #[test]
    fn csr_solver_matches_dense_reference_bitwise() {
        use crate::rng::Rng;
        let t = Torus::new(TorusDims::new(4, 4, 2));
        let mut s = NetSim::new(&t, 1.25e9, 1e-6);
        let mut rng = Rng::new(77);
        for case in 0..200 {
            let nf = 1 + rng.below_usize(16);
            let mut flows = Vec::new();
            for _ in 0..nf {
                let u = rng.below_usize(32);
                let v = rng.below_usize(32);
                let route = t.route(u, v);
                let links = route.iter().map(|l| s.slot(l.src, l.dst)).collect();
                flows.push(Flow {
                    links,
                    bytes: (rng.below(1_000_000) + 1) as f64,
                });
            }
            let fast = s.phase_duration(&flows);
            let dense = s.phase_duration_reference(&flows);
            assert_eq!(fast.to_bits(), dense.to_bits(), "case {case}");
        }
    }

    #[test]
    fn scratch_reuse_is_clean_across_phases() {
        let mut s = sim();
        let l = s.slot(0, 1);
        let f1 = vec![Flow {
            links: vec![l],
            bytes: 1e9,
        }];
        let d1 = s.phase_duration(&f1);
        let d2 = s.phase_duration(&f1);
        assert!((d1 - d2).abs() < 1e-12);
    }
}
