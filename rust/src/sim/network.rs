//! Flow-level network model with max-min fair bandwidth sharing.
//!
//! A *phase* is a set of concurrent flows with fixed routes. Within a
//! phase, link bandwidth is shared max-min fairly (SimGrid's default CM02
//! -style fluid model): we repeatedly find the bottleneck link (smallest
//! fair share), freeze its flows at that rate, remove their demand, and
//! continue. As flows finish, rates are recomputed event-by-event.

use crate::topology::Topology;

/// A flow: bytes to move along a fixed route of directed link slots.
#[derive(Debug, Clone)]
pub struct Flow {
    /// Link slot ids (see [`Topology::link_index`]); empty = same node.
    pub links: Vec<u32>,
    /// Payload bytes.
    pub bytes: f64,
}

/// Reusable flow-phase simulator for one platform.
///
/// Holds the link index and scratch buffers so per-phase simulation does
/// not allocate on the hot path. `Clone` gives each worker thread of the
/// parallel batch engine its own scratch space.
#[derive(Debug, Clone)]
pub struct NetSim {
    num_links: usize,
    link_slot: Vec<u32>,
    n_vertices: usize,
    /// Per-slot full capacity: `bandwidth * Topology::link_capacity_scale`
    /// (uniform fabrics keep every entry equal to `bandwidth`).
    cap_full: Vec<f64>,
    latency: f64,
    // scratch
    cap: Vec<f64>,
    nflows_on: Vec<u32>,
    rate: Vec<f64>,
    remaining: Vec<f64>,
    alive: Vec<bool>,
    frozen: Vec<bool>,
    link_live: Vec<bool>,
}

impl NetSim {
    /// Build for a platform topology.
    pub fn new(topo: &dyn Topology, bandwidth: f64, latency: f64) -> Self {
        let (link_slot, num_links) = topo.link_index();
        let n_vertices = topo.num_vertices();
        let mut cap_full = vec![bandwidth; num_links];
        for l in topo.all_links() {
            let slot = link_slot[l.src * n_vertices + l.dst] as usize;
            cap_full[slot] = bandwidth * topo.link_capacity_scale(l.src, l.dst);
        }
        NetSim {
            num_links,
            link_slot,
            n_vertices,
            cap_full,
            latency,
            cap: vec![0.0; num_links],
            nflows_on: vec![0; num_links],
            rate: Vec::new(),
            remaining: Vec::new(),
            alive: Vec::new(),
            frozen: Vec::new(),
            link_live: vec![false; num_links],
        }
    }

    /// Slot id of the directed link `src -> dst` (must be adjacent).
    #[inline]
    pub fn slot(&self, src: usize, dst: usize) -> u32 {
        let s = self.link_slot[src * self.n_vertices + dst];
        debug_assert_ne!(s, u32::MAX, "not a physical link: {src}->{dst}");
        s
    }

    /// Simulate one phase; returns its duration in seconds.
    ///
    /// Duration = max over flows of (per-flow completion under max-min
    /// sharing + route latency). Zero-link flows (same node) take zero
    /// network time.
    pub fn phase_duration(&mut self, flows: &[Flow]) -> f64 {
        let nf = flows.len();
        if nf == 0 {
            return 0.0;
        }
        self.remaining.clear();
        self.remaining.extend(flows.iter().map(|f| f.bytes.max(0.0)));
        self.alive.clear();
        self.alive.resize(nf, true);
        self.rate.clear();
        self.rate.resize(nf, 0.0);

        let mut n_alive = 0usize;
        for (i, f) in flows.iter().enumerate() {
            if f.links.is_empty() || f.bytes <= 0.0 {
                self.alive[i] = false; // local or empty: instantaneous
            } else {
                n_alive += 1;
            }
        }

        let mut t = 0.0f64;
        let mut dur = 0.0f64;
        // local flows still contribute latency 0; flows with links add
        // their latency at the end.
        while n_alive > 0 {
            self.compute_maxmin(flows);
            // earliest completion
            let mut dt = f64::INFINITY;
            for i in 0..nf {
                if self.alive[i] && self.rate[i] > 0.0 {
                    dt = dt.min(self.remaining[i] / self.rate[i]);
                }
            }
            debug_assert!(dt.is_finite(), "live flow with zero rate");
            t += dt;
            for i in 0..nf {
                if self.alive[i] {
                    self.remaining[i] -= self.rate[i] * dt;
                    if self.remaining[i] <= 1e-9 * flows[i].bytes.max(1.0) {
                        self.alive[i] = false;
                        n_alive -= 1;
                        let total = t + flows[i].links.len() as f64 * self.latency;
                        dur = dur.max(total);
                    }
                }
            }
        }
        dur
    }

    /// Max-min progressive filling over the currently alive flows.
    fn compute_maxmin(&mut self, flows: &[Flow]) {
        let nf = flows.len();
        self.frozen.clear();
        self.frozen.resize(nf, false);
        // reset only links used by alive flows
        for (i, f) in flows.iter().enumerate() {
            if self.alive[i] {
                for &l in &f.links {
                    self.cap[l as usize] = self.cap_full[l as usize];
                    self.nflows_on[l as usize] = 0;
                    self.link_live[l as usize] = true;
                }
            }
        }
        for (i, f) in flows.iter().enumerate() {
            if self.alive[i] {
                for &l in &f.links {
                    self.nflows_on[l as usize] += 1;
                }
            }
        }
        let mut unfrozen: usize = (0..nf).filter(|&i| self.alive[i]).count();
        while unfrozen > 0 {
            // bottleneck link = min cap / nflows among live links
            let mut best_fair = f64::INFINITY;
            let mut best_link = usize::MAX;
            for l in 0..self.num_links {
                if self.link_live[l] && self.nflows_on[l] > 0 {
                    let fair = self.cap[l] / self.nflows_on[l] as f64;
                    if fair < best_fair {
                        best_fair = fair;
                        best_link = l;
                    }
                }
            }
            if best_link == usize::MAX {
                break;
            }
            // freeze all unfrozen alive flows crossing best_link
            for (i, f) in flows.iter().enumerate() {
                if self.alive[i]
                    && !self.frozen[i]
                    && f.links.iter().any(|&l| l as usize == best_link)
                {
                    self.frozen[i] = true;
                    self.rate[i] = best_fair;
                    unfrozen -= 1;
                    for &l in &f.links {
                        let l = l as usize;
                        self.cap[l] -= best_fair;
                        self.nflows_on[l] -= 1;
                        if self.nflows_on[l] == 0 {
                            self.link_live[l] = false;
                        }
                    }
                }
            }
            self.link_live[best_link] = false;
        }
        // clear live markers for reuse
        for f in flows.iter() {
            for &l in &f.links {
                self.link_live[l as usize] = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Torus, TorusDims};

    fn sim() -> NetSim {
        let t = Torus::new(TorusDims::new(8, 1, 1));
        // 1 GB/s, 1 us
        NetSim::new(&t, 1e9, 1e-6)
    }

    #[test]
    fn per_link_capacity_scale_is_honored() {
        // dragonfly global links run at 2x: a flow crossing only the
        // global cable finishes twice as fast as a local-link flow
        use crate::topology::{Dragonfly, DragonflyParams};
        let d = Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap();
        let mut s = NetSim::new(&d, 1e9, 0.0);
        let route = d.route(0, 4); // crosses one global router-router link
        let global = route
            .iter()
            .find(|l| d.link_capacity_scale(l.src, l.dst) == 2.0)
            .expect("cross-group route must use a global link");
        let local = route.first().unwrap(); // node -> router, 1x
        let fast = s.phase_duration(&[Flow {
            links: vec![s.slot(global.src, global.dst)],
            bytes: 1e9,
        }]);
        let slow = s.phase_duration(&[Flow {
            links: vec![s.slot(local.src, local.dst)],
            bytes: 1e9,
        }]);
        assert!((fast - 0.5).abs() < 1e-6, "fast={fast}");
        assert!((slow - 1.0).abs() < 1e-6, "slow={slow}");
    }

    #[test]
    fn single_flow_bandwidth_bound() {
        let t = Torus::new(TorusDims::new(8, 1, 1));
        let mut s = sim();
        let f = Flow {
            links: vec![s.slot(0, 1)],
            bytes: 1e9,
        };
        let d = s.phase_duration(&[f]);
        assert!((d - (1.0 + 1e-6)).abs() < 1e-6, "d={d}");
        let _ = t;
    }

    #[test]
    fn two_flows_share_one_link() {
        let mut s = sim();
        let l = s.slot(0, 1);
        let flows = vec![
            Flow {
                links: vec![l],
                bytes: 1e9,
            },
            Flow {
                links: vec![l],
                bytes: 1e9,
            },
        ];
        let d = s.phase_duration(&flows);
        // both share 1 GB/s -> 2 s
        assert!((d - 2.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    fn disjoint_flows_run_in_parallel() {
        let mut s = sim();
        let flows = vec![
            Flow {
                links: vec![s.slot(0, 1)],
                bytes: 1e9,
            },
            Flow {
                links: vec![s.slot(4, 5)],
                bytes: 1e9,
            },
        ];
        let d = s.phase_duration(&flows);
        assert!((d - 1.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    fn short_flow_finishes_then_long_speeds_up() {
        let mut s = sim();
        let l = s.slot(0, 1);
        let flows = vec![
            Flow {
                links: vec![l],
                bytes: 0.5e9,
            },
            Flow {
                links: vec![l],
                bytes: 1.5e9,
            },
        ];
        // share until short done at t=1 (0.5 each); long has 1.0 left at
        // full rate -> total 2.0
        let d = s.phase_duration(&flows);
        assert!((d - 2.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    fn multi_hop_adds_latency_and_shares_each_link() {
        let mut s = sim();
        let f = Flow {
            links: vec![s.slot(0, 1), s.slot(1, 2), s.slot(2, 3)],
            bytes: 1e9,
        };
        let d = s.phase_duration(&[f]);
        assert!((d - (1.0 + 3e-6)).abs() < 1e-6, "d={d}");
    }

    #[test]
    fn local_flows_free() {
        let mut s = sim();
        assert_eq!(
            s.phase_duration(&[Flow {
                links: vec![],
                bytes: 1e12
            }]),
            0.0
        );
        assert_eq!(s.phase_duration(&[]), 0.0);
    }

    #[test]
    fn maxmin_bottleneck_distribution() {
        // flows A: link0 only; B: link0+link1; C: link1 only.
        // max-min: link0 splits .5/.5 between A,B; link1: B frozen at .5,
        // C gets remaining .5... then C could take 0.5 (cap 1 - 0.5).
        let mut s = sim();
        let l0 = s.slot(0, 1);
        let l1 = s.slot(1, 2);
        let flows = vec![
            Flow {
                links: vec![l0],
                bytes: 1e9,
            },
            Flow {
                links: vec![l0, l1],
                bytes: 1e9,
            },
            Flow {
                links: vec![l1],
                bytes: 1e9,
            },
        ];
        // All finish at t=2 (every flow gets 0.5 GB/s).
        let d = s.phase_duration(&flows);
        assert!((d - 2.0).abs() < 1e-3, "d={d}");
    }

    #[test]
    fn scratch_reuse_is_clean_across_phases() {
        let mut s = sim();
        let l = s.slot(0, 1);
        let f1 = vec![Flow {
            links: vec![l],
            bytes: 1e9,
        }];
        let d1 = s.phase_duration(&f1);
        let d2 = s.phase_duration(&f1);
        assert!((d1 - d2).abs() < 1e-12);
    }
}
