//! SimGrid-lite: flow-level discrete-event simulation of MPI jobs.
//!
//! The paper evaluates TOFA inside SimGrid/SMPI: computation is charged at
//! a fixed node speed, communication is simulated at flow level over a
//! platform with static routes, and a failed node is emulated by zeroing
//! the capacity of its links (which makes any transmission crossing it
//! fail, aborting the MPI job). This module implements that model:
//!
//! * [`network`] — max-min fair bandwidth sharing over directed torus
//!   links, event-driven within a phase;
//! * [`smpi`] — translation of [`crate::apps::MpiOp`] schedules into
//!   network flow phases under a placement;
//! * [`executor`] — whole-job simulation with phase memoization;
//! * [`cache`] — the shared, concurrency-safe phase-duration cache;
//! * [`fault`] — pluggable fault models (i.i.d. Bernoulli, correlated
//!   domains, Weibull lifetimes, trace replay) behind the
//!   [`fault::FaultModel`] trait.

pub mod cache;
pub mod executor;
pub mod fault;
pub mod network;
pub mod smpi;

pub use cache::PhaseCache;
pub use executor::{simulate_job, JobOutcome, SimStats};
pub use fault::{FaultCtx, FaultModel, FaultScenario, FaultSpec};
