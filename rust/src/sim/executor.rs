//! Whole-job simulation with phase memoization.
//!
//! Phases in the proxied applications repeat across timesteps with
//! identical flow sets, so the executor memoizes comm-phase durations by a
//! content hash of `(node src, node dst, bytes)` triples. This turns the
//! O(timesteps) simulation into O(distinct phases) network solves — the
//! key performance lever for the 2000-instance batch experiments
//! (EXPERIMENTS.md §Perf).
//!
//! The memo lives in a shared [`PhaseCache`] (keyed by phase content plus
//! a platform salt), so simulators cloned across worker threads of the
//! parallel batch engine reuse each other's network solves. Sharing never
//! changes results — cached values are pure functions of the key.

use std::sync::Arc;

use crate::apps::{Metric, MpiApp, MpiOp};
use crate::profiler::Msg;
use crate::sim::cache::PhaseCache;
use crate::sim::network::NetSim;
use crate::sim::smpi::{flows_for_phase, phases_of, Phase};
use crate::topology::Platform;

/// Result of simulating one job instance.
#[derive(Debug, Clone, PartialEq)]
pub enum JobOutcome {
    /// The job ran to completion in `seconds` of simulated time.
    Completed { seconds: f64 },
    /// The job aborted at `at` seconds (a transmission crossed a down
    /// node, or a rank was placed on one).
    Aborted { at: f64 },
}

impl JobOutcome {
    /// Completed duration, if any.
    pub fn seconds(&self) -> Option<f64> {
        match self {
            JobOutcome::Completed { seconds } => Some(*seconds),
            JobOutcome::Aborted { .. } => None,
        }
    }

    /// True if aborted.
    pub fn is_abort(&self) -> bool {
        matches!(self, JobOutcome::Aborted { .. })
    }
}

/// Simulation statistics (phase cache effectiveness, event counts).
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Comm phases encountered.
    pub comm_phases: u64,
    /// Comm phases served from the memo cache.
    pub cache_hits: u64,
    /// Network solves performed.
    pub solves: u64,
}

/// Reusable simulator for (platform, app schedule) pairs.
///
/// Construct once per experiment and call [`Simulator::run`] per
/// (placement, down-set) instance; the phase cache persists across runs
/// keyed by node-level flow content, so identical placements replay in
/// microseconds. The cache sits behind `Arc`, so cloning a simulator (one
/// clone per worker thread in the parallel batch engine) shares it;
/// [`SimStats`] stay per-clone.
#[derive(Clone)]
pub struct Simulator {
    platform: Platform,
    phases: Vec<Phase>,
    metric: Metric,
    timesteps: usize,
    net: NetSim,
    cache: Arc<PhaseCache>,
    salt: u64,
    stats: SimStats,
    route_buf: Vec<crate::topology::Link>,
}

impl Simulator {
    /// Build a simulator for an app on a platform with a private cache.
    pub fn new(app: &dyn MpiApp, platform: &Platform) -> Self {
        Self::with_cache(app, platform, Arc::new(PhaseCache::new()))
    }

    /// Build a simulator that reuses `cache` (shared across simulators
    /// and threads; see [`PhaseCache`] for why that is always safe).
    pub fn with_cache(app: &dyn MpiApp, platform: &Platform, cache: Arc<PhaseCache>) -> Self {
        let ops: Vec<MpiOp> = app.ops();
        Simulator {
            platform: platform.clone(),
            phases: phases_of(&ops),
            metric: app.metric(),
            timesteps: app.timesteps(),
            net: NetSim::new(platform.topology(), platform.bandwidth, platform.latency),
            cache,
            salt: platform_salt(platform),
            stats: SimStats::default(),
            route_buf: Vec::new(),
        }
    }

    /// The shared phase cache handle.
    pub fn cache(&self) -> Arc<PhaseCache> {
        Arc::clone(&self.cache)
    }

    /// Simulate the job under `assignment` with `down` node states.
    pub fn run(&mut self, assignment: &[usize], down: &[bool]) -> JobOutcome {
        // rank on a down node: immediate launch failure
        if assignment.iter().any(|&n| down[n]) {
            return JobOutcome::Aborted { at: 0.0 };
        }
        let mut t = 0.0f64;
        for phase in &self.phases {
            match phase {
                Phase::Compute { flops } => {
                    t += flops / self.platform.flops;
                }
                Phase::Comm { msgs } => {
                    self.stats.comm_phases += 1;
                    let key = phase_key(self.salt, msgs, assignment, down);
                    if let Some(d) = self.cache.get(key) {
                        self.stats.cache_hits += 1;
                        if d.is_nan() {
                            return JobOutcome::Aborted { at: t };
                        }
                        t += d;
                        continue;
                    }
                    let flows = flows_for_phase(
                        self.platform.topology(),
                        &self.net,
                        assignment,
                        down,
                        msgs,
                        &mut self.route_buf,
                    );
                    match flows {
                        None => {
                            self.cache.insert(key, f64::NAN);
                            return JobOutcome::Aborted { at: t };
                        }
                        Some(flows) => {
                            self.stats.solves += 1;
                            let d = self.net.phase_duration(&flows);
                            self.cache.insert(key, d);
                            t += d;
                        }
                    }
                }
            }
        }
        JobOutcome::Completed { seconds: t }
    }

    /// Completion time with no failures (used for restart accounting).
    pub fn success_time(&mut self, assignment: &[usize]) -> f64 {
        let down = vec![false; self.platform.num_nodes()];
        match self.run(assignment, &down) {
            JobOutcome::Completed { seconds } => seconds,
            // invariant: `down` is all-false, so run() can never abort
            JobOutcome::Aborted { .. } => unreachable!("no faults, no abort"),
        }
    }

    /// The application's report metric for a fault-free run.
    pub fn metric_value(&mut self, assignment: &[usize]) -> f64 {
        let secs = self.success_time(assignment);
        match self.metric {
            Metric::CompletionTime => secs,
            Metric::TimestepsPerSec => self.timesteps as f64 / secs,
        }
    }

    /// Cache/solve statistics.
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }
}

/// Precomputed per-(app, placement) job profile: fault-free duration plus
/// the set of nodes any transmission touches (endpoints and transit hops).
///
/// The key observation (matching the SimGrid fault model): a down node
/// either *aborts* the job — iff it hosts a rank or lies on some flow's
/// route — or has **no effect at all** on timing, because links keep their
/// capacity and routes are static. So once `touched` is known, an instance
/// is resolved with one intersection test instead of a full re-simulation.
#[derive(Debug, Clone)]
pub struct JobProfile {
    /// Fault-free completion time.
    pub success_s: f64,
    /// Application timesteps behind `success_s` (completed-steps
    /// accounting for partial-progress re-runs under recovery policies).
    pub steps: usize,
    /// `touched[node]` = some rank lives there or some route crosses it.
    pub touched: Vec<bool>,
}

/// One partial-progress run resolved against a down-state: the remaining
/// work at launch, whether the run aborts, and — unlike the all-or-nothing
/// [`JobProfile::resolve`] — *when* within the remaining work the failure
/// lands (recovery policies bill lost work from this instant).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartialRun {
    /// Fault-free seconds of work remaining at launch.
    pub remaining_s: f64,
    /// True if the run aborts before finishing.
    pub aborted: bool,
    /// In-run failure time (seconds of useful work completed before the
    /// abort), `None` for clean runs.
    pub failure_s: Option<f64>,
}

impl JobProfile {
    /// The fault-sampling context for instance `i` of a batch under this
    /// placement: temporal fault models ([`crate::sim::fault`]) condition
    /// on the fault-free makespan, which is exactly `success_s`.
    pub fn fault_ctx(&self, instance: u64) -> crate::sim::fault::FaultCtx {
        crate::sim::fault::FaultCtx::new(instance, self.success_s)
    }

    /// Resolve one run into the scheduler's event terms: `(duration,
    /// aborted)`. The duration a run **holds its allocation** is the
    /// fault-free makespan either way — a completed run takes `success_s`
    /// and an aborted run costs one success interval before the restart
    /// (the paper's exact accounting) — so this single value feeds the
    /// event heap of [`crate::slurm::sched`] directly.
    pub fn resolve(&self, down: &[bool]) -> (f64, bool) {
        match self.outcome(down) {
            JobOutcome::Completed { seconds } => (seconds, false),
            JobOutcome::Aborted { .. } => (self.success_s, true),
        }
    }

    /// Application timesteps already completed at `progress` (durable
    /// fraction of the job in `[0, 1]`).
    pub fn steps_done(&self, progress: f64) -> usize {
        ((progress.clamp(0.0, 1.0) * self.steps as f64).floor() as usize).min(self.steps)
    }

    /// Fault-free seconds of work remaining at `progress`.
    pub fn remaining_s(&self, progress: f64) -> f64 {
        self.success_s * (1.0 - progress.clamp(0.0, 1.0))
    }

    /// Resolve a partial-progress run: the job launches with `progress`
    /// of its work durably done and `u` (a uniform draw in `[0, 1)` from
    /// the caller's recovery stream) locating the failure instant within
    /// the remaining work when the down-set intersects the touched set.
    /// Pure in `(down, progress, u)`.
    pub fn resolve_partial(&self, down: &[bool], progress: f64, u: f64) -> PartialRun {
        let remaining_s = self.remaining_s(progress);
        match self.outcome(down) {
            JobOutcome::Completed { .. } => PartialRun {
                remaining_s,
                aborted: false,
                failure_s: None,
            },
            JobOutcome::Aborted { .. } => PartialRun {
                remaining_s,
                aborted: true,
                failure_s: Some(u * remaining_s),
            },
        }
    }

    /// Resolve one instance against a down-state vector.
    pub fn outcome(&self, down: &[bool]) -> JobOutcome {
        debug_assert_eq!(down.len(), self.touched.len());
        for (n, (&d, &t)) in down.iter().zip(&self.touched).enumerate() {
            if d && t {
                let _ = n;
                return JobOutcome::Aborted { at: 0.0 };
            }
        }
        JobOutcome::Completed {
            seconds: self.success_s,
        }
    }
}

impl Simulator {
    /// Build the [`JobProfile`] for an assignment: one fault-free
    /// simulation plus a sweep over every phase's routes to collect the
    /// touched-node set.
    pub fn prepare(&mut self, assignment: &[usize]) -> JobProfile {
        let num_nodes = self.platform.num_nodes();
        let mut touched = vec![false; num_nodes];
        for &n in assignment {
            touched[n] = true;
        }
        let topo = self.platform.topology_arc();
        for phase in &self.phases {
            if let Phase::Comm { msgs } = phase {
                for m in msgs {
                    let (u, v) = (assignment[m.src], assignment[m.dst]);
                    if u == v {
                        continue;
                    }
                    topo.route_into(u, v, &mut self.route_buf);
                    for l in &self.route_buf {
                        // transit vertices >= num_nodes are switches;
                        // they never fail, so only compute nodes matter
                        if l.src < num_nodes {
                            touched[l.src] = true;
                        }
                        if l.dst < num_nodes {
                            touched[l.dst] = true;
                        }
                    }
                }
            }
        }
        JobProfile {
            success_s: self.success_time(assignment),
            steps: self.timesteps,
            touched,
        }
    }
}

/// FNV-1a salt capturing the platform parameters that scale a phase's
/// duration. Mixed into every phase key so one [`PhaseCache`] can be
/// shared between simulators on *different* platforms without collisions
/// (app identity is irrelevant: the key already encodes the node-level
/// flow content). The topology contributes its own family/parameter salt.
fn platform_salt(platform: &Platform) -> u64 {
    let mut h = platform.topology().salt();
    for x in [
        platform.flops.to_bits(),
        platform.bandwidth.to_bits(),
        platform.latency.to_bits(),
    ] {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a hash over node-level flow content (platform salt + placement +
/// down set fully determine a comm phase's duration).
fn phase_key(salt: u64, msgs: &[Msg], assignment: &[usize], down: &[bool]) -> u64 {
    let mut h = salt;
    let mut feed = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    };
    for m in msgs {
        feed(assignment[m.src] as u64);
        feed(assignment[m.dst] as u64);
        feed(m.bytes.to_bits());
    }
    // down-state of involved nodes matters (transit nodes too, but those
    // are a function of endpoints; hashing the full down set is cheap and
    // safe)
    for (i, &d) in down.iter().enumerate() {
        if d {
            feed(0x8000_0000_0000_0000 | i as u64);
        }
    }
    h
}

/// One-shot convenience: simulate `app` on `platform` under `assignment`,
/// with `down_nodes` (node ids) in the failed state.
pub fn simulate_job(
    app: &dyn MpiApp,
    platform: &Platform,
    assignment: &[usize],
    down_nodes: &[usize],
) -> JobOutcome {
    let mut down = vec![false; platform.num_nodes()];
    for &n in down_nodes {
        down[n] = true;
    }
    Simulator::new(app, platform).run(assignment, &down)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::lammps_proxy::LammpsProxy;
    use crate::apps::npb_dt::NpbDt;
    use crate::apps::ring::RingApp;
    use crate::mapping::baselines::block_placement;
    use crate::topology::TorusDims;

    #[test]
    fn ring_completes_with_positive_time() {
        let app = RingApp::new(8, 1e6, 5);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(8, 16).unwrap();
        let out = simulate_job(&app, &plat, &p.assignment, &[]);
        let secs = out.seconds().unwrap();
        assert!(secs > 0.0 && secs.is_finite());
    }

    #[test]
    fn compact_placement_beats_spread_on_ring() {
        let app = RingApp::new(8, 1e7, 5);
        let plat = Platform::paper_default(TorusDims::new(8, 8, 1));
        let compact: Vec<usize> = (0..8).collect();
        // stride-3 in x: successive ring neighbours are >= 3 hops apart
        let spread: Vec<usize> = (0..8).map(|i| i * 3).collect();
        let tc = simulate_job(&app, &plat, &compact, &[])
            .seconds()
            .unwrap();
        let ts = simulate_job(&app, &plat, &spread, &[]).seconds().unwrap();
        assert!(tc < ts, "compact {tc} vs spread {ts}");
    }

    #[test]
    fn rank_on_down_node_aborts_immediately() {
        let app = RingApp::new(4, 1e6, 2);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(4, 16).unwrap();
        let out = simulate_job(&app, &plat, &p.assignment, &[2]);
        assert_eq!(out, JobOutcome::Aborted { at: 0.0 });
    }

    #[test]
    fn transit_down_node_aborts_later() {
        let app = RingApp::new(2, 1e6, 1);
        let plat = Platform::paper_default(TorusDims::new(8, 1, 1));
        // ranks on nodes 0 and 2; node 1 down is transit
        let out = simulate_job(&app, &plat, &[0, 2], &[1]);
        assert!(out.is_abort());
    }

    #[test]
    fn unrelated_down_node_harmless() {
        let app = RingApp::new(4, 1e6, 2);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(4, 16).unwrap();
        // node 15 is far from nodes 0..3 ring routes
        let out = simulate_job(&app, &plat, &p.assignment, &[10]);
        assert!(!out.is_abort());
    }

    #[test]
    fn cache_hits_dominate_on_repeated_timesteps() {
        let app = RingApp::new(8, 1e6, 50);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(8, 16).unwrap();
        let mut sim = Simulator::new(&app, &plat);
        let down = vec![false; 16];
        sim.run(&p.assignment, &down);
        let s = sim.stats();
        assert!(s.cache_hits > s.solves, "hits {} solves {}", s.cache_hits, s.solves);
    }

    #[test]
    fn lammps_timesteps_metric() {
        let app = LammpsProxy::tiny(8, 4);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(8, 16).unwrap();
        let mut sim = Simulator::new(&app, &plat);
        let v = sim.metric_value(&p.assignment);
        assert!(v > 0.0, "timesteps/s = {v}");
    }

    #[test]
    fn npb_dt_small_completes() {
        let app = NpbDt::new(
            crate::apps::npb_dt::DtGraph::BlackHole,
            crate::apps::npb_dt::DtClass::S,
            2,
        );
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(app.num_ranks(), 16).unwrap();
        let out = simulate_job(&app, &plat, &p.assignment, &[]);
        assert!(out.seconds().unwrap() > 0.0);
    }

    #[test]
    fn shared_cache_matches_private_memo() {
        let app = LammpsProxy::tiny(8, 4);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(8, 16).unwrap();
        let down = vec![false; 16];
        let mut private = Simulator::new(&app, &plat);
        let want = private.run(&p.assignment, &down);

        let shared = std::sync::Arc::new(crate::sim::cache::PhaseCache::new());
        let mut warm = Simulator::with_cache(&app, &plat, Arc::clone(&shared));
        assert_eq!(warm.run(&p.assignment, &down), want);
        let mut reuse = Simulator::with_cache(&app, &plat, Arc::clone(&shared));
        assert_eq!(reuse.run(&p.assignment, &down), want);
        // the second simulator never solved the network itself
        assert_eq!(reuse.stats().solves, 0);
        assert!(reuse.stats().cache_hits > 0);
    }

    #[test]
    fn profile_resolve_matches_outcome() {
        let app = RingApp::new(4, 1e6, 2);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(4, 16).unwrap();
        let mut sim = Simulator::new(&app, &plat);
        let profile = sim.prepare(&p.assignment);
        let clean = vec![false; 16];
        let (d, aborted) = profile.resolve(&clean);
        assert!(!aborted);
        assert_eq!(d.to_bits(), profile.success_s.to_bits());
        let mut down = clean;
        down[p.assignment[1]] = true;
        let (d, aborted) = profile.resolve(&down);
        assert!(aborted, "down rank host must abort");
        // an aborted run still holds the allocation for one interval
        assert_eq!(d.to_bits(), profile.success_s.to_bits());
    }

    #[test]
    fn partial_runs_report_failure_time_and_remaining_work() {
        let app = LammpsProxy::tiny(4, 8);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(4, 16).unwrap();
        let mut sim = Simulator::new(&app, &plat);
        let profile = sim.prepare(&p.assignment);
        assert_eq!(profile.steps, 8);
        assert_eq!(profile.steps_done(0.0), 0);
        assert_eq!(profile.steps_done(0.5), 4);
        assert_eq!(profile.steps_done(1.0), 8);
        assert_eq!(
            profile.remaining_s(0.0).to_bits(),
            profile.success_s.to_bits()
        );
        assert!((profile.remaining_s(0.75) - 0.25 * profile.success_s).abs() < 1e-12);
        let clean = vec![false; 16];
        let run = profile.resolve_partial(&clean, 0.5, 0.3);
        assert!(!run.aborted && run.failure_s.is_none());
        assert!((run.remaining_s - 0.5 * profile.success_s).abs() < 1e-12);
        let mut down = clean;
        down[p.assignment[1]] = true;
        let run = profile.resolve_partial(&down, 0.5, 0.3);
        assert!(run.aborted);
        // the failure lands at u x remaining, strictly inside the run
        let f = run.failure_s.unwrap();
        assert!((f - 0.3 * run.remaining_s).abs() < 1e-12);
        assert!(f < run.remaining_s);
    }

    #[test]
    fn jobs_run_on_every_topology_family() {
        use crate::topology::{Dragonfly, DragonflyParams, FatTree};
        use std::sync::Arc as StdArc;
        let app = RingApp::new(8, 1e6, 3);
        let platforms = [
            Platform::paper_default(TorusDims::new(4, 4, 1)),
            Platform::paper_default_on(StdArc::new(FatTree::new(4).unwrap())),
            Platform::paper_default_on(StdArc::new(
                Dragonfly::new(DragonflyParams::new(3, 2, 2, 1)).unwrap(),
            )),
        ];
        for plat in &platforms {
            let p = block_placement(8, plat.num_nodes()).unwrap();
            let kind = plat.topology().kind();
            // fault-free run completes deterministically
            let a = simulate_job(&app, plat, &p.assignment, &[]);
            let b = simulate_job(&app, plat, &p.assignment, &[]);
            assert_eq!(a, b, "{kind}");
            assert!(a.seconds().unwrap() > 0.0, "{kind}");
            // a down compute node hosting a rank aborts
            let out = simulate_job(&app, plat, &p.assignment, &[p.assignment[3]]);
            assert!(out.is_abort(), "{kind}");
            // a JobProfile agrees with the simulator on both cases
            let mut sim = Simulator::new(&app, plat);
            let profile = sim.prepare(&p.assignment);
            let clean = vec![false; plat.num_nodes()];
            assert_eq!(
                profile.outcome(&clean).seconds().unwrap(),
                a.seconds().unwrap(),
                "{kind}"
            );
            let mut down = clean.clone();
            down[p.assignment[3]] = true;
            assert!(profile.outcome(&down).is_abort(), "{kind}");
        }
    }

    #[test]
    fn deterministic_simulation() {
        let app = LammpsProxy::tiny(8, 3);
        let plat = Platform::paper_default(TorusDims::new(4, 4, 1));
        let p = block_placement(8, 16).unwrap();
        let a = simulate_job(&app, &plat, &p.assignment, &[]);
        let b = simulate_job(&app, &plat, &p.assignment, &[]);
        assert_eq!(a, b);
    }
}
