//! # TOFA — Topology and Fault-Aware process placement
//!
//! Production-grade reproduction of *"Improving the Performance and
//! Resilience of MPI Parallel Jobs with Topology and Fault-Aware Process
//! Placement"* (Vardas, Ploumidis, Marazakis; ICS-FORTH 2020).
//!
//! The crate is the L3 (Rust) layer of a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution and every
//!   substrate it depends on: a Slurm-lite resource manager with the
//!   paper's five plugins ([`slurm`]), a Scotch-lite dual-recursive-
//!   bipartitioning graph mapper ([`mapping`]), the TOFA placement policy
//!   ([`tofa`]), a SimGrid-lite flow-level discrete-event simulator
//!   ([`sim`]), MPI application proxies ([`apps`]), and the MPI profiling
//!   tool ([`profiler`]).
//! * **L2 (JAX, build-time)** — a batched mapping-cost model lowered to
//!   HLO text artifacts (`python/compile/model.py`).
//! * **L1 (Pallas, build-time)** — the gather-MAC mapping-cost kernel
//!   (`python/compile/kernels/mapping_cost.py`), validated vs a pure-jnp
//!   oracle; loaded and executed from Rust via PJRT ([`runtime`]).
//!
//! ## Quick start
//!
//! ```no_run
//! use tofa::prelude::*;
//!
//! // 8x8x8 torus platform, paper parameters (6 Gflops, 10 Gbps, 1 us).
//! let platform = Platform::paper_default(TorusDims::new(8, 8, 8));
//! // A LAMMPS-like proxy app with 64 ranks.
//! let app = LammpsProxy::rhodopsin(64);
//! // Profile it -> communication graph G_v.
//! let profile = profile_app(&app);
//! // Place with TOFA (no faults known) and simulate.
//! let fault = FaultScenario::none(platform.num_nodes());
//! let placement = TofaPlacer::new(Default::default())
//!     .place(&profile.volume, &platform, &fault.true_outage())
//!     .unwrap();
//! let outcome = simulate_job(&app, &platform, &placement.assignment, &[]);
//! println!("completion: {:?}", outcome);
//! ```
//!
//! ## Parallel batch engine
//!
//! The Section 5.2 batch experiments run on a sharded worker pool — see
//! [`batch::parallel`] for the determinism contract (results are
//! bit-identical for every worker count) and [`sim::PhaseCache`] for the
//! shared phase-solve cache that lets concurrent instances reuse each
//! other's network solves.
//!
//! ## Topologies
//!
//! The platform interconnect is pluggable: [`topology::Topology`] defines
//! the routing function, hop metric, and failure-domain decomposition,
//! with three implementations — the paper's 3-D [`topology::Torus`], a
//! k-ary [`topology::FatTree`], and a Cray-Aries-style
//! [`topology::Dragonfly`]. `repro --topology=...` selects one for the
//! batch sweeps; racks/pods/groups feed the correlated fault model.
//!
//! The distance metric itself is pluggable too ([`topology::metric`]):
//! dense O(n²) matrices as the bit-identity reference up to a size
//! threshold, or the implicit closed-form path (`repro --metric=implicit`)
//! that serves 100k-node platforms in O(n) memory.
//!
//! ## Fault models
//!
//! Down-state generation is pluggable: [`sim::fault`] defines the
//! [`sim::fault::FaultModel`] trait with four implementations — the
//! paper's i.i.d. Bernoulli model (the default), correlated rack
//! domains, Weibull per-node lifetimes coupled to the job makespan, and
//! deterministic trace replay. `repro --fault-model=...` selects one for
//! the Fig. 4/5 batch sweeps.

// Index-heavy numerical kernels (max-min filling, FNV hashing) read more
// clearly with explicit indices; keep clippy's style nit quiet crate-wide.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod apps;
pub mod batch;
pub mod commgraph;
pub mod error;
pub mod mapping;
pub mod profiler;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod slurm;
pub mod tofa;
pub mod topology;

/// Convenient re-exports of the most used types.
pub mod prelude {
    pub use crate::apps::{
        lammps_proxy::LammpsProxy, npb_dt::NpbDt, MpiApp, MpiOp,
    };
    pub use crate::batch::{run_grid, BatchConfig, BatchRunner, Parallelism};
    pub use crate::commgraph::CommMatrix;
    pub use crate::error::{Error, Result};
    pub use crate::mapping::{
        baselines::{block_placement, greedy_placement, random_placement},
        cost::hop_bytes_cost,
        recmap::RecursiveMapper,
        Placement, PlacementPolicy,
    };
    pub use crate::profiler::profile_app;
    pub use crate::rng::Rng;
    pub use crate::sim::fault::{
        CorrelatedDomains, FaultCtx, FaultModel, FaultScenario, FaultSpec, FaultTrace,
        IidBernoulli, TraceReplay, WeibullLifetime,
    };
    pub use crate::sim::{simulate_job, JobOutcome};
    pub use crate::slurm::controller::Controller;
    pub use crate::slurm::sched::{
        ClusterScheduler, NodeLedger, SchedConfig, SchedResult, WorkloadSpec,
    };
    pub use crate::tofa::placer::{TofaConfig, TofaPlacer};
    pub use crate::topology::{
        dragonfly::{Dragonfly, DragonflyParams},
        fattree::FatTree,
        index::{CostWorkspace, TopoIndex},
        metric::{HopOracle, MetricMode, ResolvedMetric},
        platform::Platform,
        torus::{Torus, TorusDims},
        Topology,
    };
}
