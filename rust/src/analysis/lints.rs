//! The determinism lints themselves. Each lint is a function over a
//! prepared [`FileCtx`] (or the whole file set, for cross-file rules)
//! that appends [`Diagnostic`]s; allow-comment suppression and sorting
//! happen once, in [`super::analyze`].
//!
//! These are token-pattern heuristics, not type-checked analyses — they
//! are tuned to the conventions this codebase actually uses (see the
//! table in the [`super`] docs) and err on the side of asking for an
//! explicit `detlint: allow` with a reason when a site is intentional.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{int_value, TokKind};
use super::{Diagnostic, FileCtx, FileRole, Lint};

fn diag(lint: Lint, ctx: &FileCtx, line: u32, msg: String) -> Diagnostic {
    Diagnostic { lint, path: ctx.path.clone(), line, msg }
}

/// Index of the `}` matching the `{` at `open` (or end-of-file for
/// unbalanced input — the linter degrades gracefully, never panics).
fn match_brace(ctx: &FileCtx, open: usize) -> usize {
    let mut depth = 0isize;
    let mut i = open;
    while let Some(t) = ctx.at(i) {
        if t.kind == TokKind::Punct {
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
        }
        i += 1;
    }
    ctx.toks.len()
}

// ---------------------------------------------------------------- rng-stream-registry

/// The crate's stream-base registry: every `const NAME: u64 = <int>;`
/// declared inside a `mod streams { ... }` block of a `Src` file.
pub(crate) struct Registry {
    consts: BTreeMap<String, u128>,
}

impl Registry {
    pub(crate) fn contains(&self, name: &str) -> bool {
        self.consts.contains_key(name)
    }

    /// Collect registry rows, flagging two names that share one value —
    /// that would correlate two "registered" streams, the exact failure
    /// the registry exists to prevent.
    pub(crate) fn extract(ctxs: &[FileCtx], diags: &mut Vec<Diagnostic>) -> Registry {
        let mut consts: BTreeMap<String, u128> = BTreeMap::new();
        let mut by_value: BTreeMap<u128, String> = BTreeMap::new();
        for ctx in ctxs {
            if ctx.role != FileRole::Src {
                continue;
            }
            let mut i = 0;
            while i < ctx.toks.len() {
                if ctx.is_ident(i, "mod")
                    && ctx.is_ident(i + 1, "streams")
                    && ctx.is_punct(i + 2, "{")
                {
                    let end = match_brace(ctx, i + 2);
                    scan_registry_consts(ctx, i + 3, end, &mut consts, &mut by_value, diags);
                    i = end;
                }
                i += 1;
            }
        }
        Registry { consts }
    }
}

fn scan_registry_consts(
    ctx: &FileCtx,
    from: usize,
    to: usize,
    consts: &mut BTreeMap<String, u128>,
    by_value: &mut BTreeMap<u128, String>,
    diags: &mut Vec<Diagnostic>,
) {
    let mut j = from;
    while j < to {
        let shape = ctx.is_ident(j, "const")
            && ctx.at(j + 1).is_some_and(|t| t.kind == TokKind::Ident)
            && ctx.is_punct(j + 2, ":")
            && ctx.is_ident(j + 3, "u64")
            && ctx.is_punct(j + 4, "=")
            && ctx.at(j + 5).is_some_and(|t| t.kind == TokKind::Int)
            && ctx.is_punct(j + 6, ";");
        if !shape {
            j += 1;
            continue;
        }
        let (Some(name), Some(val)) = (ctx.at(j + 1), ctx.at(j + 5)) else {
            j += 1;
            continue;
        };
        if let Some(v) = int_value(&val.text) {
            if let Some(prev) = by_value.get(&v) {
                if prev != &name.text {
                    diags.push(diag(
                        Lint::RngStreamRegistry,
                        ctx,
                        name.line,
                        format!(
                            "stream const `{}` duplicates the value of `{prev}`; registered \
                             bases must be unique",
                            name.text
                        ),
                    ));
                }
            } else {
                by_value.insert(v, name.text.clone());
            }
            consts.insert(name.text.clone(), v);
        }
        j += 7;
    }
}

enum BaseKind {
    RawLiteral(String),
    Named(String),
    Dynamic,
}

fn classify_base(ctx: &FileCtx, arg: &[usize]) -> BaseKind {
    if arg.len() == 1 {
        if let Some(t) = arg.first().and_then(|&k| ctx.at(k)) {
            if t.kind == TokKind::Int {
                return BaseKind::RawLiteral(t.text.clone());
            }
        }
    }
    // a pure path (`streams::FOO_BASE`) ending in a SCREAMING_CASE ident
    let pure_path = !arg.is_empty()
        && arg.iter().all(|&k| {
            ctx.at(k).is_some_and(|t| {
                t.kind == TokKind::Ident || (t.kind == TokKind::Punct && t.text == "::")
            })
        });
    if pure_path {
        if let Some(last) = arg.last().and_then(|&k| ctx.at(k)) {
            if last.kind == TokKind::Ident && is_screaming(&last.text) {
                return BaseKind::Named(last.text.clone());
            }
        }
    }
    BaseKind::Dynamic
}

fn is_screaming(name: &str) -> bool {
    name.chars().any(|c| c.is_ascii_uppercase())
        && name.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
}

/// Lint 1: every literal or named-const base handed to `Rng::stream`
/// must come from the `rng::streams` registry. Computed (runtime) bases
/// are out of scope — those are derived from registered draws already.
pub(crate) fn rng_stream_registry(ctx: &FileCtx, reg: &Registry, diags: &mut Vec<Diagnostic>) {
    if ctx.role == FileRole::Test {
        return;
    }
    for i in 0..ctx.toks.len() {
        let call = ctx.is_ident(i, "Rng")
            && ctx.is_punct(i + 1, "::")
            && ctx.is_ident(i + 2, "stream")
            && ctx.is_punct(i + 3, "(");
        if !call || ctx.is_test(i) {
            continue;
        }
        let Some(site) = ctx.at(i + 2) else { continue };
        // the first argument: tokens up to `,` or `)` at nesting depth 0
        let mut arg = Vec::new();
        let mut depth = 0usize;
        let mut j = i + 4;
        while let Some(t) = ctx.at(j) {
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" if depth == 0 => break,
                    ")" | "]" | "}" => depth -= 1,
                    "," if depth == 0 => break,
                    _ => {}
                }
            }
            arg.push(j);
            j += 1;
        }
        match classify_base(ctx, &arg) {
            BaseKind::RawLiteral(text) => diags.push(diag(
                Lint::RngStreamRegistry,
                ctx,
                site.line,
                format!(
                    "raw literal stream base `{text}`; declare a named const in the \
                     rng::streams registry"
                ),
            )),
            BaseKind::Named(name) => {
                if !reg.contains(&name) {
                    diags.push(diag(
                        Lint::RngStreamRegistry,
                        ctx,
                        site.line,
                        format!(
                            "stream base `{name}` is not declared in the rng::streams registry"
                        ),
                    ));
                }
            }
            BaseKind::Dynamic => {}
        }
    }
}

// ---------------------------------------------------------------- hash-iter-determinism

const HASH_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "into_keys",
    "values",
    "values_mut",
    "into_values",
    "into_iter",
    "drain",
    "retain",
];

/// Lint 2: no iteration over `HashMap`/`HashSet` outside test code.
/// Hash containers are fine as lookup tables (`get`/`insert`/`contains`);
/// the moment their order is observed, determinism is host-dependent.
pub(crate) fn hash_iter_determinism(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    if ctx.role == FileRole::Test {
        return;
    }
    let n = ctx.toks.len();
    // pass 1: names bound or typed as hash containers
    let mut hashed: BTreeSet<&str> = BTreeSet::new();
    for i in 0..n {
        let Some(t) = ctx.at(i) else { continue };
        if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        // `name: [&] [mut] ['a] HashMap<..>` — params, fields, annotated lets
        let mut k = i;
        while k > 0
            && (ctx.is_punct(k - 1, "&")
                || ctx.is_ident(k - 1, "mut")
                || ctx.at(k - 1).is_some_and(|p| p.kind == TokKind::Lifetime))
        {
            k -= 1;
        }
        if k >= 2 && ctx.is_punct(k - 1, ":") {
            if let Some(name) = ctx.at(k - 2) {
                if name.kind == TokKind::Ident {
                    hashed.insert(&name.text);
                }
            }
        }
    }
    // `let [mut] name = ... HashMap/HashSet ... ;`
    for i in 0..n {
        if !ctx.is_ident(i, "let") {
            continue;
        }
        let mut j = i + 1;
        if ctx.is_ident(j, "mut") {
            j += 1;
        }
        let Some(name) = ctx.at(j) else { continue };
        if name.kind != TokKind::Ident {
            continue;
        }
        let mut depth = 0isize;
        let mut k = j + 1;
        let mut mentions_hash = false;
        while let Some(t) = ctx.at(k) {
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" if depth == 0 => break,
                    ")" | "]" | "}" => depth -= 1,
                    ";" if depth == 0 => break,
                    _ => {}
                }
            } else if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                mentions_hash = true;
            }
            k += 1;
        }
        if mentions_hash {
            hashed.insert(&name.text);
        }
    }
    // pass 2: order-observing uses of those names
    for i in 0..n {
        if ctx.is_test(i) {
            continue;
        }
        let Some(t) = ctx.at(i) else { continue };
        if t.kind == TokKind::Ident
            && hashed.contains(t.text.as_str())
            && ctx.is_punct(i + 1, ".")
            && ctx.is_punct(i + 3, "(")
        {
            if let Some(m) = ctx.at(i + 2) {
                if m.kind == TokKind::Ident && HASH_ITER_METHODS.contains(&m.text.as_str()) {
                    diags.push(diag(
                        Lint::HashIterDeterminism,
                        ctx,
                        t.line,
                        format!(
                            "`{}.{}()` observes hash order on a deterministic path; use \
                             BTreeMap/BTreeSet or sort the keys first",
                            t.text, m.text
                        ),
                    ));
                    continue;
                }
            }
        }
        // `for pat in [&] [mut] name { .. }`
        if ctx.is_ident(i, "in") && (i.saturating_sub(12)..i).any(|k| ctx.is_ident(k, "for")) {
            let mut j = i + 1;
            while ctx.is_punct(j, "&") || ctx.is_ident(j, "mut") {
                j += 1;
            }
            if let Some(name) = ctx.at(j) {
                if name.kind == TokKind::Ident
                    && hashed.contains(name.text.as_str())
                    && ctx.is_punct(j + 1, "{")
                {
                    diags.push(diag(
                        Lint::HashIterDeterminism,
                        ctx,
                        name.line,
                        format!(
                            "`for .. in {}` iterates a hash-ordered container on a \
                             deterministic path; use BTreeMap/BTreeSet or sort first",
                            name.text
                        ),
                    ));
                }
            }
        }
    }
}

// ---------------------------------------------------------------- float-discipline

const INT_CAST_TARGETS: &[&str] = &["u64", "i64", "u32", "i32", "usize", "isize"];
const FLOAT_CONSTS: &[&str] = &["NAN", "INFINITY", "NEG_INFINITY"];

/// Lint 3: float hygiene on deterministic paths — no `==`/`!=` against
/// float literals or `f64::NAN`-style consts (bit-identity goes through
/// `to_bits()`), no float→int `as` casts of time-like values (event
/// ordering must be total), and no `/ xs.len() as f64` without an
/// emptiness guard (NaN minted into a metric poisons every downstream
/// aggregate silently).
pub(crate) fn float_discipline(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    if ctx.role == FileRole::Test {
        return;
    }
    for i in 0..ctx.toks.len() {
        if ctx.is_test(i) {
            continue;
        }
        let Some(t) = ctx.at(i) else { continue };
        // (a) float equality
        if t.kind == TokKind::Punct && (t.text == "==" || t.text == "!=") {
            let floaty = |j: usize| {
                ctx.at(j).is_some_and(|s| {
                    s.kind == TokKind::Float
                        || (s.kind == TokKind::Ident && FLOAT_CONSTS.contains(&s.text.as_str()))
                })
            };
            let float_path = ctx
                .at(i + 1)
                .is_some_and(|s| s.kind == TokKind::Ident && (s.text == "f64" || s.text == "f32"))
                && ctx.is_punct(i + 2, "::");
            if (i > 0 && floaty(i - 1)) || floaty(i + 1) || float_path {
                diags.push(diag(
                    Lint::FloatDiscipline,
                    ctx,
                    t.line,
                    format!(
                        "`{}` against a float; compare bit patterns via to_bits() or use an \
                         explicit tolerance",
                        t.text
                    ),
                ));
            }
        }
        // (b) float -> int `as` cast of a time-like value
        let int_cast = ctx.at(i + 1).is_some_and(|s| {
            s.kind == TokKind::Ident && INT_CAST_TARGETS.contains(&s.text.as_str())
        });
        if t.kind == TokKind::Ident && t.text == "as" && int_cast && i > 0 {
            if let Some(prev) = ctx.at(i - 1) {
                let time_like = prev.kind == TokKind::Ident && {
                    let x = prev.text.as_str();
                    x.ends_with("_s")
                        || x.ends_with("_secs")
                        || x.ends_with("_sec")
                        || x == "now"
                        || x == "dt"
                };
                if prev.kind == TokKind::Float || time_like {
                    diags.push(diag(
                        Lint::FloatDiscipline,
                        ctx,
                        t.line,
                        format!(
                            "float-to-int `as` cast of `{}`; event ordering must go through \
                             to_bits() or an explicit, documented rounding",
                            prev.text
                        ),
                    ));
                }
            }
        }
        // (c) unguarded `/ xs.len() as f64`
        if t.kind == TokKind::Punct && t.text == "/" {
            let mut j = i + 1;
            if ctx.is_punct(j, "(") {
                j += 1;
            }
            let mut hops = 0;
            while hops < 6
                && !ctx.is_ident(j, "len")
                && ctx.at(j).is_some_and(|s| s.kind == TokKind::Ident)
                && ctx.is_punct(j + 1, ".")
            {
                j += 2;
                hops += 1;
            }
            if ctx.is_ident(j, "len") && ctx.is_punct(j + 1, "(") && ctx.is_punct(j + 2, ")") {
                let mut k = j + 3;
                if ctx.is_punct(k, ")") {
                    k += 1;
                }
                let cast = ctx.is_ident(k, "as")
                    && (ctx.is_ident(k + 1, "f64") || ctx.is_ident(k + 1, "f32"));
                let guarded = (i.saturating_sub(100)..i).any(|g| {
                    ctx.is_ident(g, "is_empty")
                        || (ctx.is_ident(g, "max")
                            && ctx.is_punct(g + 1, "(")
                            && ctx.at(g + 2).is_some_and(|s| s.kind == TokKind::Int))
                });
                if cast && !guarded {
                    diags.push(diag(
                        Lint::FloatDiscipline,
                        ctx,
                        t.line,
                        "division by `.len() as f64` without an emptiness guard can mint NaN \
                         into metrics; check is_empty() or clamp with `.max(1)`"
                            .to_string(),
                    ));
                }
            }
        }
    }
}

// ---------------------------------------------------------------- panic-policy

const PANIC_METHODS: &[&str] = &["unwrap", "expect"];
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Lint 4: `unwrap`/`expect`/`panic!`-family calls in `rust/src`
/// non-test code must either become typed `Error`s or carry an adjacent
/// `// invariant:` comment stating why the failure is impossible.
pub(crate) fn panic_policy(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    if ctx.role != FileRole::Src {
        return;
    }
    for i in 0..ctx.toks.len() {
        if ctx.is_test(i) {
            continue;
        }
        let Some(t) = ctx.at(i) else { continue };
        if t.kind != TokKind::Ident {
            continue;
        }
        let method = i > 0
            && ctx.is_punct(i - 1, ".")
            && PANIC_METHODS.contains(&t.text.as_str())
            && ctx.is_punct(i + 1, "(");
        let mac = PANIC_MACROS.contains(&t.text.as_str()) && ctx.is_punct(i + 1, "!");
        if !(method || mac) {
            continue;
        }
        if ctx.invariant_justified(t.line) {
            continue;
        }
        let what = if mac { format!("{}!", t.text) } else { format!(".{}()", t.text) };
        diags.push(diag(
            Lint::PanicPolicy,
            ctx,
            t.line,
            format!(
                "`{what}` on a library path; return a typed Error or add an adjacent \
                 `// invariant:` comment stating why it cannot fire"
            ),
        ));
    }
}

// ---------------------------------------------------------------- dense-reference-pairing

fn oracle_name(name: &str) -> bool {
    if name.starts_with("is_") || name.starts_with("has_") {
        return false;
    }
    name.ends_with("_reference") || name.ends_with("_scan") || name.ends_with("_dense")
}

/// Lint 5 (cross-file): every `*_reference`/`*_scan`/`*_dense` function
/// defined in `Src` non-test code must be named by at least one test or
/// bench. These functions exist as bit-identity oracles for optimized
/// paths; an unexercised oracle rots silently.
pub(crate) fn dense_reference_pairing(ctxs: &[FileCtx], diags: &mut Vec<Diagnostic>) {
    let mut defs: Vec<(&FileCtx, usize)> = Vec::new();
    for ctx in ctxs {
        if ctx.role != FileRole::Src {
            continue;
        }
        for i in 0..ctx.toks.len() {
            if !ctx.is_ident(i, "fn") || ctx.is_test(i + 1) {
                continue;
            }
            let Some(name) = ctx.at(i + 1) else { continue };
            if name.kind == TokKind::Ident && oracle_name(&name.text) {
                defs.push((ctx, i + 1));
            }
        }
    }
    if defs.is_empty() {
        return;
    }
    let mut referenced: BTreeSet<&str> = BTreeSet::new();
    for ctx in ctxs {
        for i in 0..ctx.toks.len() {
            let Some(t) = ctx.at(i) else { continue };
            if t.kind != TokKind::Ident || !oracle_name(&t.text) {
                continue;
            }
            let in_test_ctx =
                matches!(ctx.role, FileRole::Test | FileRole::Bench) || ctx.is_test(i);
            let is_def = i > 0 && ctx.is_ident(i - 1, "fn");
            if in_test_ctx && !is_def {
                referenced.insert(&t.text);
            }
        }
    }
    for (ctx, idx) in defs {
        let Some(name) = ctx.at(idx) else { continue };
        if referenced.contains(name.text.as_str()) {
            continue;
        }
        diags.push(diag(
            Lint::DenseReferencePairing,
            ctx,
            name.line,
            format!(
                "reference implementation `{}` is not exercised by any test or bench; \
                 bit-identity oracles must stay paired with a consumer",
                name.text
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use crate::analysis::{analyze, FileRole, Lint, SourceFile};
    use std::path::PathBuf;

    fn file(role: FileRole, text: &str) -> SourceFile {
        SourceFile { path: PathBuf::from("t.rs"), role, text: text.to_string() }
    }

    fn lints_of(files: &[SourceFile]) -> Vec<(Lint, u32)> {
        analyze(files).into_iter().map(|d| (d.lint, d.line)).collect()
    }

    #[test]
    fn rng_raw_literal_and_unregistered_const_flagged() {
        let src = "mod streams { pub const A_BASE: u64 = 7; }\n\
                   fn f(i: u64) { let _ = Rng::stream(0x99, i); }\n\
                   fn g(i: u64) { let _ = Rng::stream(OTHER_BASE, i); }\n\
                   fn h(i: u64) { let _ = Rng::stream(streams::A_BASE, i); }\n\
                   fn k(b: u64, i: u64) { let _ = Rng::stream(b, i); }";
        let got = lints_of(&[file(FileRole::Src, src)]);
        assert_eq!(got, [(Lint::RngStreamRegistry, 2), (Lint::RngStreamRegistry, 3)]);
    }

    #[test]
    fn rng_duplicate_registry_values_flagged() {
        let src = "mod streams {\n\
                   pub const A_BASE: u64 = 7;\n\
                   pub const B_BASE: u64 = 0x7;\n\
                   }";
        let got = lints_of(&[file(FileRole::Src, src)]);
        assert_eq!(got, [(Lint::RngStreamRegistry, 3)]);
    }

    #[test]
    fn hash_iteration_flagged_lookup_clean() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u64, u64>) -> u64 {\n\
                   let mut s = 0;\n\
                   for (_k, v) in m.iter() { s += v; }\n\
                   s + m.get(&0).copied().unwrap_or(0)\n\
                   }";
        let got = lints_of(&[file(FileRole::Bench, src)]);
        assert_eq!(got, [(Lint::HashIterDeterminism, 4)]);
    }

    #[test]
    fn hash_for_loop_over_binding_flagged() {
        let src = "fn f() {\n\
                   let mut set = std::collections::HashSet::new();\n\
                   set.insert(1u64);\n\
                   for x in &set { let _ = x; }\n\
                   }";
        let got = lints_of(&[file(FileRole::Src, src)]);
        assert_eq!(got, [(Lint::HashIterDeterminism, 4)]);
    }

    #[test]
    fn btree_iteration_is_clean() {
        let src = "use std::collections::BTreeMap;\n\
                   fn f(m: &BTreeMap<u64, u64>) -> u64 { m.values().sum() }";
        assert!(lints_of(&[file(FileRole::Src, src)]).is_empty());
    }

    #[test]
    fn float_equality_flagged() {
        let src = "fn f(x: f64) -> bool { x == 0.5 }\n\
                   fn g(x: f64) -> bool { x != f64::NAN }\n\
                   fn h(x: f64, y: f64) -> bool { x.to_bits() == y.to_bits() }";
        let got = lints_of(&[file(FileRole::Src, src)]);
        assert_eq!(got, [(Lint::FloatDiscipline, 1), (Lint::FloatDiscipline, 2)]);
    }

    #[test]
    fn unguarded_len_division_flagged_guarded_clean() {
        let bad = "fn mean(xs: &[f64]) -> f64 { xs.iter().sum::<f64>() / xs.len() as f64 }";
        let got = lints_of(&[file(FileRole::Src, bad)]);
        assert_eq!(got, [(Lint::FloatDiscipline, 1)]);
        let good = "fn mean(xs: &[f64]) -> f64 {\n\
                    if xs.is_empty() { return 0.0; }\n\
                    xs.iter().sum::<f64>() / xs.len() as f64\n\
                    }";
        assert!(lints_of(&[file(FileRole::Src, good)]).is_empty());
    }

    #[test]
    fn time_like_float_cast_flagged() {
        let src = "fn f(arrival_s: f64) -> u64 { arrival_s as u64 }";
        let got = lints_of(&[file(FileRole::Src, src)]);
        assert_eq!(got, [(Lint::FloatDiscipline, 1)]);
    }

    #[test]
    fn panic_needs_invariant_justification() {
        let bad = "fn f(v: &[u64]) -> u64 { *v.first().unwrap() }";
        let got = lints_of(&[file(FileRole::Src, bad)]);
        assert_eq!(got, [(Lint::PanicPolicy, 1)]);
        let good = "fn f(v: &[u64]) -> u64 {\n\
                    // invariant: callers pass non-empty slices (checked in new())\n\
                    *v.first().unwrap()\n\
                    }";
        assert!(lints_of(&[file(FileRole::Src, good)]).is_empty());
    }

    #[test]
    fn panic_policy_is_src_only() {
        let src = "fn f(v: &[u64]) -> u64 { *v.first().unwrap() }";
        assert!(lints_of(&[file(FileRole::Bench, src)]).is_empty());
        assert!(lints_of(&[file(FileRole::Example, src)]).is_empty());
    }

    #[test]
    fn unpaired_oracle_flagged_paired_clean() {
        let bad = "pub fn cost_reference(x: u64) -> u64 { x }";
        let got = lints_of(&[file(FileRole::Src, bad)]);
        assert_eq!(got, [(Lint::DenseReferencePairing, 1)]);
        let good = "pub fn cost_reference(x: u64) -> u64 { x }\n\
                    #[cfg(test)]\n\
                    mod tests {\n\
                    #[test]\n\
                    fn t() { assert_eq!(super::cost_reference(1), 1); }\n\
                    }";
        assert!(lints_of(&[file(FileRole::Src, good)]).is_empty());
    }

    #[test]
    fn oracle_referenced_from_separate_test_file_is_clean() {
        let src = file(FileRole::Src, "pub fn cost_reference(x: u64) -> u64 { x }");
        let mut test = file(FileRole::Test, "fn t() { let _ = cost_reference(1); }");
        test.path = PathBuf::from("tests.rs");
        assert!(lints_of(&[src, test]).is_empty());
    }

    #[test]
    fn predicate_suffixes_are_not_oracles() {
        let src = "pub fn is_dense(x: u64) -> bool { x > 0 }\n\
                   pub fn has_scan(x: u64) -> bool { x > 0 }";
        assert!(lints_of(&[file(FileRole::Src, src)]).is_empty());
    }
}
