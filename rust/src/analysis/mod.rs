//! `detlint` — the project's determinism & invariant static-analysis pass.
//!
//! Every headline number in this reproduction rests on hand-maintained
//! conventions: `Rng::stream` bases must never collide, deterministic
//! paths must never iterate a hash map, float bit-identity must go through
//! `to_bits`, library panics must be documented invariants, and every
//! dense/reference implementation must stay paired with a test that proves
//! the optimized path bit-identical. None of that is checked by `rustc` or
//! clippy — so this module checks it. It is a project-specific lint pass:
//! a lightweight token scanner ([`lexer`]) plus a small engine that walks
//! `rust/src`, `rust/tests`, `benches/`, and `examples/` and reports typed
//! `file:line` diagnostics. Zero dependencies, like the rest of the crate.
//!
//! Run it as `repro lint` (the required `detlint` CI job) or call
//! [`analyze_tree`] directly. Suppress a finding with an in-source
//! comment naming the lint *and* a reason:
//!
//! ```text
//! // detlint: allow(float-discipline, exact-zero sentinel for "no traffic")
//! ```
//!
//! The lints (see [`Lint`] and [`lints`] for the precise rules):
//!
//! | lint | invariant it guards |
//! |------|---------------------|
//! | `rng-stream-registry` | every literal/const `Rng::stream` base is declared (and unique) in `rng::streams::STREAM_BASES` |
//! | `hash-iter-determinism` | no iteration over `HashMap`/`HashSet` on deterministic paths |
//! | `float-discipline` | no `==`/`!=` against float literals, no float→int `as` casts of time-like values, no unguarded `/ len()` aggregates |
//! | `panic-policy` | `unwrap`/`expect`/`panic!` in `rust/src` non-test code carries a `// invariant:` justification |
//! | `dense-reference-pairing` | every `*_reference`/`*_scan`/`*_dense` fn is exercised by a test or bench |
//! | `allow-syntax` | suppression comments are well-formed (known lint, non-empty reason) |
//!
//! ```
//! use tofa::analysis::{analyze, FileRole, SourceFile};
//! let f = SourceFile {
//!     path: "demo.rs".into(),
//!     role: FileRole::Src,
//!     text: "fn f(v: &[f64]) -> bool { v[0] == 0.5 }".to_string(),
//! };
//! let diags = analyze(&[f]);
//! assert_eq!(diags.len(), 1);
//! assert_eq!(diags[0].lint.name(), "float-discipline");
//! ```

pub mod lexer;
pub mod lints;

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::report::bench::JsonValue;
use lexer::{lex, Comment, Tok, TokKind};

/// The determinism lints. `allow-syntax` is the engine's own hygiene
/// check: a malformed suppression comment would otherwise silently
/// suppress nothing (or the wrong thing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lint {
    RngStreamRegistry,
    HashIterDeterminism,
    FloatDiscipline,
    PanicPolicy,
    DenseReferencePairing,
    AllowSyntax,
}

impl Lint {
    /// The kebab-case name used in diagnostics and allow comments.
    pub fn name(self) -> &'static str {
        match self {
            Lint::RngStreamRegistry => "rng-stream-registry",
            Lint::HashIterDeterminism => "hash-iter-determinism",
            Lint::FloatDiscipline => "float-discipline",
            Lint::PanicPolicy => "panic-policy",
            Lint::DenseReferencePairing => "dense-reference-pairing",
            Lint::AllowSyntax => "allow-syntax",
        }
    }

    /// All lints, in reporting order.
    pub fn all() -> [Lint; 6] {
        [
            Lint::RngStreamRegistry,
            Lint::HashIterDeterminism,
            Lint::FloatDiscipline,
            Lint::PanicPolicy,
            Lint::DenseReferencePairing,
            Lint::AllowSyntax,
        ]
    }

    /// Parse a lint name as written in an allow comment.
    pub fn parse(name: &str) -> Option<Lint> {
        Lint::all().into_iter().find(|l| l.name() == name)
    }
}

impl fmt::Display for Lint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// What kind of code a file holds — decides which lints apply where.
/// `Test` code is exempt from most rules (tests may iterate hash maps,
/// unwrap freely, and pin literal stream bases); `Bench` and `Example`
/// code runs on deterministic paths and is held to `Src` rules except for
/// the panic policy (a bench aborting loudly is fine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileRole {
    Src,
    Test,
    Bench,
    Example,
}

impl FileRole {
    fn parse(s: &str) -> Option<FileRole> {
        match s {
            "src" => Some(FileRole::Src),
            "test" => Some(FileRole::Test),
            "bench" => Some(FileRole::Bench),
            "example" => Some(FileRole::Example),
            _ => None,
        }
    }
}

/// One source file queued for analysis.
#[derive(Debug)]
pub struct SourceFile {
    pub path: PathBuf,
    pub role: FileRole,
    pub text: String,
}

/// One lint finding at a `file:line`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub lint: Lint,
    pub path: PathBuf,
    pub line: u32,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path.display(),
            self.line,
            self.lint.name(),
            self.msg
        )
    }
}

/// A prepared (lexed + annotated) file, shared by all lints.
pub(crate) struct FileCtx {
    pub path: PathBuf,
    pub role: FileRole,
    pub toks: Vec<Tok>,
    /// Per-token: inside a `#[cfg(test)]` item.
    pub test_mask: Vec<bool>,
    /// Lines that carry at least one non-comment token.
    pub token_lines: BTreeSet<u32>,
    /// Line -> concatenated comment text for that line.
    pub comment_text: BTreeMap<u32, String>,
    /// Line -> lint names suppressed by a well-formed allow comment.
    pub allows: BTreeMap<u32, Vec<&'static str>>,
}

impl FileCtx {
    /// Token at `i`, if in range.
    pub fn at(&self, i: usize) -> Option<&Tok> {
        self.toks.get(i)
    }

    /// Is token `i` the given punctuation/operator?
    pub fn is_punct(&self, i: usize, p: &str) -> bool {
        self.at(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == p)
    }

    /// Is token `i` the given identifier?
    pub fn is_ident(&self, i: usize, name: &str) -> bool {
        self.at(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == name)
    }

    /// Non-test at token index (whole file for `Test` roles).
    pub fn is_test(&self, i: usize) -> bool {
        self.role == FileRole::Test || self.test_mask.get(i).copied().unwrap_or(false)
    }

    /// Does the comment block justify a panic at `line`? True when a
    /// comment containing `invariant:` sits on the same line or in the
    /// contiguous comment-only block directly above it.
    pub fn invariant_justified(&self, line: u32) -> bool {
        if self.comment_has(line, "invariant:") {
            return true;
        }
        let mut l = line.saturating_sub(1);
        while l > 0 && self.comment_text.contains_key(&l) && !self.token_lines.contains(&l) {
            if self.comment_has(l, "invariant:") {
                return true;
            }
            l -= 1;
        }
        false
    }

    fn comment_has(&self, line: u32, needle: &str) -> bool {
        self.comment_text.get(&line).is_some_and(|t| t.contains(needle))
    }
}

/// Marker comment that pins a fixture file's role regardless of its path:
/// `// detlint-fixture: role=src`. Committed lint fixtures live under
/// `rust/tests/data/lint/` (a path that would otherwise classify as test
/// code and exempt them from everything).
const ROLE_MARKER: &str = "detlint-fixture: role=";

fn prepare(file: &SourceFile, diags: &mut Vec<Diagnostic>) -> FileCtx {
    let lexer::Lexed { toks, comments } = lex(&file.text);
    let mut role = file.role;
    let mut token_lines = BTreeSet::new();
    for t in &toks {
        token_lines.insert(t.line);
    }
    let mut comment_text: BTreeMap<u32, String> = BTreeMap::new();
    let mut allows: BTreeMap<u32, Vec<&'static str>> = BTreeMap::new();
    for c in &comments {
        if let Some(rest) = c.text.trim().strip_prefix(ROLE_MARKER) {
            if let Some(r) = FileRole::parse(rest.trim()) {
                role = r;
            }
        }
        parse_allows(c, &file.path, &mut allows, diags);
        comment_text
            .entry(c.line)
            .and_modify(|t| {
                t.push(' ');
                t.push_str(&c.text);
            })
            .or_insert_with(|| c.text.clone());
    }
    let test_mask = cfg_test_mask(&toks);
    FileCtx {
        path: file.path.clone(),
        role,
        toks,
        test_mask,
        token_lines,
        comment_text,
        allows,
    }
}

/// Parse every `detlint: allow(<lint>, <reason>)` occurrence in a comment.
/// Malformed allows (unknown lint, missing reason, unclosed paren) become
/// `allow-syntax` diagnostics — a suppression that silently fails to
/// suppress is worse than none.
fn parse_allows(
    c: &Comment,
    path: &Path,
    allows: &mut BTreeMap<u32, Vec<&'static str>>,
    diags: &mut Vec<Diagnostic>,
) {
    const KEY: &str = "detlint: allow(";
    let mut rest = c.text.as_str();
    while let Some(pos) = rest.find(KEY) {
        let inner = &rest[pos + KEY.len()..];
        let Some(close) = inner.find(')') else {
            diags.push(Diagnostic {
                lint: Lint::AllowSyntax,
                path: path.to_path_buf(),
                line: c.line,
                msg: "unclosed `detlint: allow(`".to_string(),
            });
            return;
        };
        let body = &inner[..close];
        match body.split_once(',') {
            Some((name, reason)) if !reason.trim().is_empty() => match Lint::parse(name.trim()) {
                Some(lint) => allows.entry(c.line).or_default().push(lint.name()),
                None => diags.push(Diagnostic {
                    lint: Lint::AllowSyntax,
                    path: path.to_path_buf(),
                    line: c.line,
                    msg: format!("unknown lint `{}` in allow comment", name.trim()),
                }),
            },
            _ => diags.push(Diagnostic {
                lint: Lint::AllowSyntax,
                path: path.to_path_buf(),
                line: c.line,
                msg: format!("allow comment needs a reason: `allow({body}, <why>)`"),
            }),
        }
        rest = &inner[close..];
    }
}

/// Mark every token inside a `#[cfg(test)]` item (attribute through the
/// item's closing brace or trailing semicolon).
fn cfg_test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let is = |i: usize, p: &str| -> bool {
        toks.get(i).is_some_and(|t| {
            (t.kind == TokKind::Punct || t.kind == TokKind::Ident) && t.text == p
        })
    };
    let mut i = 0;
    while i < toks.len() {
        let attr = is(i, "#")
            && is(i + 1, "[")
            && is(i + 2, "cfg")
            && is(i + 3, "(")
            && is(i + 4, "test")
            && is(i + 5, ")")
            && is(i + 6, "]");
        if !attr {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + 7;
        // skip any further attributes on the same item
        while is(j, "#") && is(j + 1, "[") {
            let mut depth = 0usize;
            let mut k = j + 1;
            while k < toks.len() {
                if is(k, "[") {
                    depth += 1;
                } else if is(k, "]") {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            j = k + 1;
        }
        // first `{` (item body) or `;` (braceless item) at bracket depth 0
        let mut depth = 0isize;
        let mut body = None;
        let mut k = j;
        while k < toks.len() {
            if is(k, "(") || is(k, "[") {
                depth += 1;
            } else if is(k, ")") || is(k, "]") {
                depth -= 1;
            } else if depth == 0 && is(k, "{") {
                body = Some(k);
                break;
            } else if depth == 0 && is(k, ";") {
                break;
            }
            k += 1;
        }
        let end = match body {
            Some(open) => {
                let mut braces = 0isize;
                let mut m = open;
                while m < toks.len() {
                    if is(m, "{") {
                        braces += 1;
                    } else if is(m, "}") {
                        braces -= 1;
                        if braces == 0 {
                            break;
                        }
                    }
                    m += 1;
                }
                m
            }
            None => k,
        };
        for slot in mask.iter_mut().take((end + 1).min(toks.len())).skip(start) {
            *slot = true;
        }
        i = j.max(i + 7);
    }
    mask
}

/// Analyze an explicit file set. This is the engine entry the tests use;
/// [`analyze_tree`] wraps it with the repo's directory layout. Returned
/// diagnostics are sorted by `(path, line, lint)` and already filtered
/// through allow-comment suppression.
pub fn analyze(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let ctxs: Vec<FileCtx> = files.iter().map(|f| prepare(f, &mut diags)).collect();
    let registry = lints::Registry::extract(&ctxs, &mut diags);
    for ctx in &ctxs {
        lints::rng_stream_registry(ctx, &registry, &mut diags);
        lints::hash_iter_determinism(ctx, &mut diags);
        lints::float_discipline(ctx, &mut diags);
        lints::panic_policy(ctx, &mut diags);
    }
    lints::dense_reference_pairing(&ctxs, &mut diags);
    // allow-comment suppression: same line or the line directly above
    let suppressed = |d: &Diagnostic| -> bool {
        if d.lint == Lint::AllowSyntax {
            return false;
        }
        ctxs.iter().filter(|c| c.path == d.path).any(|c| {
            [d.line, d.line.saturating_sub(1)]
                .iter()
                .any(|l| c.allows.get(l).is_some_and(|v| v.contains(&d.lint.name())))
        })
    };
    diags.retain(|d| !suppressed(d));
    diags.sort_by(|a, b| {
        (&a.path, a.line, a.lint.name()).cmp(&(&b.path, b.line, b.lint.name()))
    });
    diags
}

/// The directories `analyze_tree` walks, with the role their files get.
/// `rust/tests/data` is excluded: committed lint fixtures are violating
/// on purpose.
const TREE: &[(&str, FileRole)] = &[
    ("rust/src", FileRole::Src),
    ("rust/tests", FileRole::Test),
    ("benches", FileRole::Bench),
    ("examples", FileRole::Example),
];

/// Walk the repo layout under `root` and analyze every `.rs` file.
pub fn analyze_tree(root: &Path) -> Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    for (dir, role) in TREE {
        let base = root.join(dir);
        if base.is_dir() {
            collect_rs(&base, *role, &mut files)?;
        }
    }
    // report repo-relative paths so diagnostics are stable across machines
    for f in &mut files {
        if let Ok(rel) = f.path.strip_prefix(root) {
            f.path = rel.to_path_buf();
        }
    }
    files.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(analyze(&files))
}

fn collect_rs(dir: &Path, role: FileRole, out: &mut Vec<SourceFile>) -> Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            // fixtures under tests/data are violating on purpose
            if path.file_name().is_some_and(|n| n == "data") {
                continue;
            }
            collect_rs(&path, role, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let text = std::fs::read_to_string(&path)?;
            out.push(SourceFile { path, role, text });
        }
    }
    Ok(())
}

/// Load one explicit path (file or directory) with a role inferred from
/// its path segments, overridable by a `detlint-fixture: role=` marker.
fn load_path(path: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    if path.is_dir() {
        let role = infer_role(path);
        return collect_rs(path, role, out);
    }
    let text = std::fs::read_to_string(path)?;
    out.push(SourceFile { path: path.to_path_buf(), role: infer_role(path), text });
    Ok(())
}

fn infer_role(path: &Path) -> FileRole {
    let has = |seg: &str| path.iter().any(|c| c == seg);
    if has("benches") {
        FileRole::Bench
    } else if has("examples") {
        FileRole::Example
    } else if has("tests") {
        FileRole::Test
    } else {
        FileRole::Src
    }
}

/// `repro lint [--format=json] [--root=<dir>] [paths...]` — returns the
/// process exit code: 0 clean, 1 diagnostics reported, 2 bad usage / IO.
pub fn run_cli(args: &[String]) -> i32 {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    for a in args {
        if a == "--format=json" {
            json = true;
        } else if a == "--format=text" {
            json = false;
        } else if let Some(v) = a.strip_prefix("--root=") {
            root = Some(PathBuf::from(v));
        } else if a.starts_with("--") {
            eprintln!("error: unknown lint option: {a}");
            return 2;
        } else {
            paths.push(PathBuf::from(a));
        }
    }
    let analyzed = if paths.is_empty() {
        let root = root.unwrap_or_else(crate::report::bench::repo_root);
        analyze_tree(&root)
    } else {
        let mut files = Vec::new();
        let mut io = None;
        for p in &paths {
            if let Err(e) = load_path(p, &mut files) {
                io = Some((p.clone(), e));
                break;
            }
        }
        match io {
            Some((p, e)) => {
                eprintln!("error: {}: {e}", p.display());
                return 2;
            }
            None => Ok(analyze(&files)),
        }
    };
    let diags = match analyzed {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if json {
        println!("{}", to_json(&diags).render());
    } else {
        for d in &diags {
            println!("{d}");
        }
        let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
        for d in &diags {
            *counts.entry(d.lint.name()).or_default() += 1;
        }
        if diags.is_empty() {
            println!("detlint: clean");
        } else {
            let by_lint: Vec<String> =
                counts.iter().map(|(l, n)| format!("{l}: {n}")).collect();
            println!("detlint: {} finding(s) ({})", diags.len(), by_lint.join(", "));
        }
    }
    i32::from(!diags.is_empty())
}

/// Diagnostics as a machine-readable document (the `--format=json` shape,
/// consumed by the CI annotation step).
pub fn to_json(diags: &[Diagnostic]) -> JsonValue {
    let items: Vec<JsonValue> = diags
        .iter()
        .map(|d| {
            JsonValue::obj()
                .set("lint", JsonValue::Str(d.lint.name().to_string()))
                .set("path", JsonValue::Str(d.path.display().to_string()))
                .set("line", JsonValue::Int(u64::from(d.line)))
                .set("message", JsonValue::Str(d.msg.clone()))
        })
        .collect();
    JsonValue::obj()
        .set("findings", JsonValue::Int(diags.len() as u64))
        .set("diagnostics", JsonValue::Arr(items))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(text: &str) -> SourceFile {
        SourceFile { path: PathBuf::from("t.rs"), role: FileRole::Src, text: text.to_string() }
    }

    #[test]
    fn cfg_test_items_are_masked() {
        let f = src("fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests { fn b() { y.unwrap(); } }");
        let diags = analyze(&[f]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 1);
    }

    #[test]
    fn allow_comment_suppresses_same_and_next_line() {
        let above = src(
            "// detlint: allow(panic-policy, demo reason)\nfn a() { x.unwrap(); }",
        );
        assert!(analyze(&[above]).is_empty());
        let trailing =
            src("fn a() { x.unwrap(); } // detlint: allow(panic-policy, demo reason)");
        assert!(analyze(&[trailing]).is_empty());
    }

    #[test]
    fn malformed_allow_is_reported() {
        let missing_reason = src("// detlint: allow(panic-policy)\nfn a() {}");
        let d = analyze(&[missing_reason]);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].lint, Lint::AllowSyntax);
        let unknown = src("// detlint: allow(not-a-lint, reason)\nfn a() {}");
        let d = analyze(&[unknown]);
        assert_eq!(d.len(), 1);
        assert!(d[0].msg.contains("not-a-lint"));
    }

    #[test]
    fn fixture_role_marker_overrides_path_role() {
        let f = SourceFile {
            path: PathBuf::from("rust/tests/data/lint/x.rs"),
            role: FileRole::Test,
            text: "// detlint-fixture: role=src\nfn a() { x.unwrap(); }".to_string(),
        };
        let diags = analyze(&[f]);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].lint, Lint::PanicPolicy);
    }

    #[test]
    fn diagnostics_are_sorted_and_stable() {
        let f = src("fn a() { x.unwrap(); y.unwrap(); }\nfn b() { panic!(\"x\"); }");
        let diags = analyze(&[f]);
        let lines: Vec<u32> = diags.iter().map(|d| d.line).collect();
        let mut sorted = lines.clone();
        sorted.sort_unstable();
        assert_eq!(lines, sorted);
    }
}
