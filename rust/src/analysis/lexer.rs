//! A lightweight Rust token scanner for the determinism lint pass.
//!
//! This is deliberately **not** a full Rust lexer (no `syn`, no proc-macro
//! machinery — the crate is zero-dep by design). It produces exactly the
//! token stream the lints in [`crate::analysis::lints`] need: identifiers,
//! integer/float literals, multi-char operators, and punctuation, with
//! comments and string/char literals recognised and set aside so their
//! *contents* can never produce false lint matches. Comments are collected
//! separately (with line numbers) because two of the lint mechanisms —
//! `// detlint: allow(...)` suppressions and `// invariant:` panic
//! justifications — live in comments.
//!
//! ```
//! use tofa::analysis::lexer::{lex, TokKind};
//! let out = lex("let x = m.len(); // detlint: allow(float-discipline, demo)");
//! assert_eq!(out.toks[1].text, "x");
//! assert!(matches!(out.toks[0].kind, TokKind::Ident));
//! assert!(out.comments[0].text.contains("detlint: allow"));
//! ```

/// Token classification. `Str`/`Char` keep their raw text but lints treat
/// them as opaque, so a string mentioning `unwrap` can never trip a lint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`let`, `HashMap`, `unwrap`, ...).
    Ident,
    /// Integer literal (`42`, `0x5eed_5c4e_d011`, `7u64`).
    Int,
    /// Float literal (`0.02`, `1e9`, `2.5f32`).
    Float,
    /// String literal of any flavour (`"x"`, `r#"x"#`, `b"x"`).
    Str,
    /// Char literal (`'a'`, `'\n'`).
    Char,
    /// Lifetime or loop label (`'a`, `'outer`).
    Lifetime,
    /// Operator or punctuation; multi-char operators (`==`, `::`, `..=`)
    /// are single tokens so lints can match them directly.
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment (line `//...` or block `/*...*/`), anchored at the line it
/// starts on. The text excludes the comment markers.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// The lexed view of one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Multi-char operators, longest first so maximal munch works.
const OPS: &[&str] = &[
    "..=", "...", "<<=", ">>=", "==", "!=", "<=", ">=", "&&", "||", "::", "->", "=>", "..", "+=",
    "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

struct Cursor<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
}

impl<'a> Cursor<'a> {
    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.src.get(self.pos).copied();
        if let Some(c) = b {
            self.pos += 1;
            if c == b'\n' {
                self.line += 1;
            }
        }
        b
    }

    fn starts_with(&self, s: &str) -> bool {
        self.src[self.pos..].starts_with(s.as_bytes())
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_char(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Lex `src` into tokens and comments. Unterminated strings/comments are
/// tolerated (the rest of the file becomes that literal/comment): the
/// linter must degrade gracefully on any input rather than panic.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor { src: src.as_bytes(), pos: 0, line: 1 };
    let mut out = Lexed::default();
    while let Some(c) = cur.peek(0) {
        // whitespace
        if c.is_ascii_whitespace() {
            cur.bump();
            continue;
        }
        // line comment (also doc comments /// and //!)
        if c == b'/' && cur.peek(1) == Some(b'/') {
            let line = cur.line;
            cur.bump();
            cur.bump();
            let start = cur.pos;
            while let Some(n) = cur.peek(0) {
                if n == b'\n' {
                    break;
                }
                cur.bump();
            }
            out.comments.push(Comment {
                line,
                text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
            });
            continue;
        }
        // block comment, nesting-aware
        if c == b'/' && cur.peek(1) == Some(b'*') {
            let line = cur.line;
            cur.bump();
            cur.bump();
            let start = cur.pos;
            let mut depth = 1usize;
            let mut end = cur.pos;
            while let Some(n) = cur.peek(0) {
                if n == b'/' && cur.peek(1) == Some(b'*') {
                    depth += 1;
                    cur.bump();
                    cur.bump();
                } else if n == b'*' && cur.peek(1) == Some(b'/') {
                    depth -= 1;
                    end = cur.pos;
                    cur.bump();
                    cur.bump();
                    if depth == 0 {
                        break;
                    }
                } else {
                    cur.bump();
                }
                end = cur.pos;
            }
            out.comments.push(Comment {
                line,
                text: String::from_utf8_lossy(&cur.src[start..end]).into_owned(),
            });
            continue;
        }
        // raw / byte strings: r"..", r#".."#, b"..", br#".."#
        if (c == b'r' || c == b'b') && raw_string_ahead(&cur) {
            lex_raw_or_byte_string(&mut cur, &mut out);
            continue;
        }
        // identifier / keyword
        if is_ident_start(c) {
            let line = cur.line;
            let start = cur.pos;
            while cur.peek(0).is_some_and(is_ident_char) {
                cur.bump();
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
                line,
            });
            continue;
        }
        // number literal
        if c.is_ascii_digit() {
            lex_number(&mut cur, &mut out);
            continue;
        }
        // plain string
        if c == b'"' {
            let line = cur.line;
            let start = cur.pos;
            cur.bump();
            while let Some(n) = cur.peek(0) {
                if n == b'\\' {
                    cur.bump();
                    cur.bump();
                } else if n == b'"' {
                    cur.bump();
                    break;
                } else {
                    cur.bump();
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
                line,
            });
            continue;
        }
        // char literal vs lifetime/label
        if c == b'\'' {
            lex_quote(&mut cur, &mut out);
            continue;
        }
        // multi-char operators, maximal munch
        if let Some(op) = OPS.iter().find(|op| cur.starts_with(op)) {
            let line = cur.line;
            for _ in 0..op.len() {
                cur.bump();
            }
            out.toks.push(Tok { kind: TokKind::Punct, text: (*op).to_string(), line });
            continue;
        }
        // single-char punctuation
        let line = cur.line;
        cur.bump();
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: (c as char).to_string(),
            line,
        });
    }
    out
}

/// Does the cursor sit on a raw/byte string opener (`r"`, `r#"`, `b"`,
/// `br"`, `br#"`)? Called only when the current byte is `r` or `b`.
fn raw_string_ahead(cur: &Cursor<'_>) -> bool {
    let mut i = 1;
    if cur.peek(0) == Some(b'b') && cur.peek(1) == Some(b'r') {
        i = 2;
    } else if cur.peek(0) == Some(b'b') {
        // plain byte string b"..."
        return cur.peek(1) == Some(b'"');
    }
    // r or br: allow hashes then a quote
    let mut j = i;
    while cur.peek(j) == Some(b'#') {
        j += 1;
    }
    // `r` alone (i==1) with no hash and no quote is just an ident like `r`
    cur.peek(j) == Some(b'"') && (j > i || i == 2 || cur.peek(0) == Some(b'r'))
}

fn lex_raw_or_byte_string(cur: &mut Cursor<'_>, out: &mut Lexed) {
    let line = cur.line;
    let start = cur.pos;
    // consume prefix letters
    while cur.peek(0).is_some_and(|c| c == b'r' || c == b'b') {
        cur.bump();
    }
    let mut hashes = 0usize;
    while cur.peek(0) == Some(b'#') {
        hashes += 1;
        cur.bump();
    }
    let raw = hashes > 0 || cur.src[start] == b'r' || cur.src.get(start + 1) == Some(&b'r');
    cur.bump(); // opening quote
    loop {
        match cur.peek(0) {
            None => break,
            Some(b'\\') if !raw => {
                cur.bump();
                cur.bump();
            }
            Some(b'"') => {
                cur.bump();
                // need `hashes` trailing #s to close a raw string
                let mut k = 0;
                while k < hashes && cur.peek(0) == Some(b'#') {
                    cur.bump();
                    k += 1;
                }
                if k == hashes {
                    break;
                }
            }
            Some(_) => {
                cur.bump();
            }
        }
    }
    out.toks.push(Tok {
        kind: TokKind::Str,
        text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
        line,
    });
}

fn lex_number(cur: &mut Cursor<'_>, out: &mut Lexed) {
    let line = cur.line;
    let start = cur.pos;
    let mut is_float = false;
    if cur.starts_with("0x") || cur.starts_with("0o") || cur.starts_with("0b") {
        cur.bump();
        cur.bump();
        while cur.peek(0).is_some_and(|c| c.is_ascii_alphanumeric() || c == b'_') {
            cur.bump();
        }
    } else {
        while cur.peek(0).is_some_and(|c| c.is_ascii_digit() || c == b'_') {
            cur.bump();
        }
        // fractional part: a `.` NOT followed by another `.` (range) or an
        // identifier start (method call / tuple field)
        if cur.peek(0) == Some(b'.')
            && cur.peek(1) != Some(b'.')
            && !cur.peek(1).is_some_and(is_ident_start)
        {
            is_float = true;
            cur.bump();
            while cur.peek(0).is_some_and(|c| c.is_ascii_digit() || c == b'_') {
                cur.bump();
            }
        }
        // exponent
        if cur.peek(0).is_some_and(|c| c == b'e' || c == b'E') {
            let sign = usize::from(matches!(cur.peek(1), Some(b'+') | Some(b'-')));
            if cur.peek(1 + sign).is_some_and(|c| c.is_ascii_digit()) {
                is_float = true;
                cur.bump();
                if sign == 1 {
                    cur.bump();
                }
                while cur.peek(0).is_some_and(|c| c.is_ascii_digit() || c == b'_') {
                    cur.bump();
                }
            }
        }
        // type suffix (f64 marks a float even without `.`)
        if cur.peek(0) == Some(b'f') {
            is_float = true;
        }
        while cur.peek(0).is_some_and(is_ident_char) {
            cur.bump();
        }
    }
    out.toks.push(Tok {
        kind: if is_float { TokKind::Float } else { TokKind::Int },
        text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
        line,
    });
}

fn lex_quote(cur: &mut Cursor<'_>, out: &mut Lexed) {
    let line = cur.line;
    let start = cur.pos;
    cur.bump(); // the opening '
    match cur.peek(0) {
        // escape: definitely a char literal
        Some(b'\\') => {
            cur.bump();
            while let Some(n) = cur.peek(0) {
                cur.bump();
                if n == b'\'' {
                    break;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Char,
                text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
                line,
            });
        }
        // 'x' char vs 'ident lifetime
        Some(c) if is_ident_start(c) => {
            if cur.peek(1) == Some(b'\'') {
                cur.bump();
                cur.bump();
                out.toks.push(Tok {
                    kind: TokKind::Char,
                    text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
                    line,
                });
            } else {
                while cur.peek(0).is_some_and(is_ident_char) {
                    cur.bump();
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
                    line,
                });
            }
        }
        // 'c' where c is punctuation: a char literal like '(' or ' '
        Some(_) => {
            cur.bump();
            if cur.peek(0) == Some(b'\'') {
                cur.bump();
            }
            out.toks.push(Tok {
                kind: TokKind::Char,
                text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
                line,
            });
        }
        None => out.toks.push(Tok {
            kind: TokKind::Punct,
            text: "'".to_string(),
            line,
        }),
    }
}

/// Parse a Rust integer literal's value (`0x5eed`, `1_000`, `7u64`).
pub fn int_value(text: &str) -> Option<u128> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    let (digits, radix) = if let Some(h) = t.strip_prefix("0x") {
        (h, 16)
    } else if let Some(o) = t.strip_prefix("0o") {
        (o, 8)
    } else if let Some(b) = t.strip_prefix("0b") {
        (b, 2)
    } else {
        (t.as_str(), 10)
    };
    // strip a type suffix (u8..u128, usize, i8..); hex digits are consumed
    // greedily above, so only non-digit-led suffixes remain
    let end = digits
        .find(|c: char| !c.is_digit(radix))
        .unwrap_or(digits.len());
    if end == 0 {
        return None;
    }
    u128::from_str_radix(&digits[..end], radix).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_and_strings_are_not_tokens() {
        let out = lex("a /* b */ \"c == d\" // e\nf");
        let idents: Vec<&str> = out
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["a", "f"]);
        assert_eq!(out.comments.len(), 2);
        assert_eq!(out.toks.last().map(|t| t.line), Some(2));
    }

    #[test]
    fn nested_block_comments() {
        let out = lex("/* a /* b */ c */ x");
        assert_eq!(out.toks.len(), 1);
        assert_eq!(out.toks[0].text, "x");
    }

    #[test]
    fn raw_strings_swallow_quotes() {
        let out = lex(r####"let s = r#"a " b"# ; y"####);
        let last = out.toks.last().map(|t| t.text.clone());
        assert_eq!(last.as_deref(), Some("y"));
        assert!(out.toks.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn lifetimes_vs_chars() {
        let ks = kinds("'a 'x' '\\n' 'outer");
        assert_eq!(ks[0].0, TokKind::Lifetime);
        assert_eq!(ks[1].0, TokKind::Char);
        assert_eq!(ks[2].0, TokKind::Char);
        assert_eq!(ks[3].0, TokKind::Lifetime);
    }

    #[test]
    fn numbers_classify() {
        let ks = kinds("1 2.5 0x5eed 1e9 3usize 4.0f64 1..3 v.0");
        assert_eq!(ks[0].0, TokKind::Int);
        assert_eq!(ks[1].0, TokKind::Float);
        assert_eq!(ks[2].0, TokKind::Int);
        assert_eq!(ks[3].0, TokKind::Float);
        assert_eq!(ks[4].0, TokKind::Int);
        assert_eq!(ks[5].0, TokKind::Float);
        // 1..3 must lex as Int, Punct(..), Int — not floats
        let range: Vec<&str> = ks[6..9].iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(range, ["1", "..", "3"]);
        // v.0 is a tuple field access, not a float
        assert_eq!(ks[10].1, ".");
        assert_eq!(ks[11].0, TokKind::Int);
    }

    #[test]
    fn multichar_ops_are_single_tokens() {
        let ks = kinds("a == b != c :: d ..= e");
        let ops: Vec<&str> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Punct)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(ops, ["==", "!=", "::", "..="]);
    }

    #[test]
    fn int_values_parse() {
        assert_eq!(int_value("0x5eed_5c4e_d011"), Some(0x5eed_5c4e_d011));
        assert_eq!(int_value("1_000"), Some(1000));
        assert_eq!(int_value("7u64"), Some(7));
        assert_eq!(int_value("0b101"), Some(5));
    }
}
