//! Traffic heatmap rendering (the paper's Figure 1).
//!
//! The profiling tool's heatmap "allows for visual inspection of the
//! application's communication pattern". We render to (a) an ASCII/ANSI
//! grid for terminals and (b) a PGM image for files — both driven from the
//! `repro fig1` subcommand and the `heatmaps` example.

use super::matrix::CommMatrix;

/// Greyscale ramp, light -> dark (paper: "the darker, the more traffic").
const RAMP: &[u8] = b" .:-=+*#%@";

/// Render an ASCII heatmap, downsampling to at most `max_cells` per side.
pub fn ascii(m: &CommMatrix, max_cells: usize) -> String {
    let n = m.len();
    let cells = n.min(max_cells).max(1);
    let mut grid = vec![0.0f64; cells * cells];
    let scale = n as f64 / cells as f64;
    for i in 0..n {
        for j in 0..n {
            let ci = ((i as f64 / scale) as usize).min(cells - 1);
            let cj = ((j as f64 / scale) as usize).min(cells - 1);
            grid[ci * cells + cj] += m.get(i, j);
        }
    }
    let max = grid.iter().cloned().fold(0.0, f64::max);
    let mut out = String::with_capacity(cells * (cells + 1));
    for ci in 0..cells {
        for cj in 0..cells {
            let v = grid[ci * cells + cj];
            let idx = if max > 0.0 {
                // log scale: traffic spans orders of magnitude
                let t = (1.0 + v).ln() / (1.0 + max).ln();
                ((t * (RAMP.len() - 1) as f64).round() as usize).min(RAMP.len() - 1)
            } else {
                0
            };
            out.push(RAMP[idx] as char);
        }
        out.push('\n');
    }
    out
}

/// Render a binary PGM (P5) image, one pixel per rank pair, dark = heavy.
pub fn pgm(m: &CommMatrix) -> Vec<u8> {
    let n = m.len();
    let max = m.max();
    let mut out = format!("P5\n{n} {n}\n255\n").into_bytes();
    for i in 0..n {
        for j in 0..n {
            let v = m.get(i, j);
            let t = if max > 0.0 {
                (1.0 + v).ln() / (1.0 + max).ln()
            } else {
                0.0
            };
            out.push(255 - (t * 255.0).round() as u8);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn banded(n: usize) -> CommMatrix {
        let mut m = CommMatrix::new(n);
        for i in 0..n - 1 {
            m.add_sym(i, i + 1, 1000.0);
        }
        m
    }

    #[test]
    fn ascii_dimensions() {
        let m = banded(32);
        let s = ascii(&m, 16);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 16);
        assert!(lines.iter().all(|l| l.chars().count() == 16));
    }

    #[test]
    fn ascii_diagonal_darker_than_corners() {
        let m = banded(32);
        let s = ascii(&m, 32);
        let lines: Vec<&str> = s.lines().collect();
        let diag = lines[1].as_bytes()[2]; // near-diagonal cell
        let corner = lines[0].as_bytes()[31];
        let rank = |c: u8| RAMP.iter().position(|&r| r == c).unwrap();
        assert!(rank(diag) > rank(corner));
    }

    #[test]
    fn pgm_header_and_size() {
        let m = banded(8);
        let img = pgm(&m);
        assert!(img.starts_with(b"P5\n8 8\n255\n"));
        assert_eq!(img.len(), b"P5\n8 8\n255\n".len() + 64);
    }

    #[test]
    fn empty_matrix_renders_blank() {
        let m = CommMatrix::new(4);
        let s = ascii(&m, 4);
        assert!(s.chars().all(|c| c == ' ' || c == '\n'));
    }
}
