//! Dense symmetric communication matrix.

/// `N x N` dense matrix of pairwise communication weight (bytes or message
/// counts). Stored row-major in f64 to absorb large byte totals without
/// precision loss; converted to f32 only at the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct CommMatrix {
    n: usize,
    data: Vec<f64>,
}

impl CommMatrix {
    /// Zero matrix for `n` ranks.
    pub fn new(n: usize) -> Self {
        CommMatrix {
            n,
            data: vec![0.0; n * n],
        }
    }

    /// Build from a row-major slice (must be `n*n` long).
    pub fn from_rows(n: usize, rows: &[f64]) -> Self {
        assert_eq!(rows.len(), n * n);
        CommMatrix {
            n,
            data: rows.to_vec(),
        }
    }

    /// Rank count.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if zero ranks.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Entry `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    /// Set entry `(i, j)` (no symmetry enforcement — prefer `add_sym`).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, w: f64) {
        self.data[i * self.n + j] = w;
    }

    /// Add `w` to both `(i, j)` and `(j, i)`.
    #[inline]
    pub fn add_sym(&mut self, i: usize, j: usize, w: f64) {
        self.data[i * self.n + j] += w;
        self.data[j * self.n + i] += w;
    }

    /// Row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Sum of all entries (2x the undirected pair total, since symmetric).
    pub fn total(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Largest entry.
    pub fn max(&self) -> f64 {
        self.data.iter().cloned().fold(0.0, f64::max)
    }

    /// True when `get(i,j) == get(j,i)` for all pairs.
    pub fn is_symmetric(&self) -> bool {
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if (self.get(i, j) - self.get(j, i)).abs() > 1e-9 {
                    return false;
                }
            }
        }
        true
    }

    /// Undirected weighted edge list `(i, j, w)` with `i < j`, `w > 0`.
    pub fn edges(&self) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::new();
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                let w = self.get(i, j);
                if w > 0.0 {
                    out.push((i, j, w));
                }
            }
        }
        out
    }

    /// Raw row-major data.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// "Bandedness" statistic in [0, 1]: fraction of total weight within
    /// `k` of the diagonal. LAMMPS-like regular patterns score high,
    /// NPB-DT-like irregular ones low — quantifies the Figure 1 contrast.
    pub fn diagonal_mass(&self, k: usize) -> f64 {
        let total = self.total();
        // detlint: allow(float-discipline, exact 0.0 guard against division, not a comparison)
        if total == 0.0 {
            return 0.0;
        }
        let mut near = 0.0;
        for i in 0..self.n {
            for j in 0..self.n {
                if i.abs_diff(j) <= k {
                    near += self.get(i, j);
                }
            }
        }
        near / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sym_keeps_symmetry() {
        let mut m = CommMatrix::new(5);
        m.add_sym(1, 3, 10.0);
        m.add_sym(3, 1, 2.5);
        assert!(m.is_symmetric());
        assert_eq!(m.get(1, 3), 12.5);
    }

    #[test]
    fn edges_upper_triangle_only() {
        let mut m = CommMatrix::new(4);
        m.add_sym(0, 1, 5.0);
        m.add_sym(2, 3, 7.0);
        let e = m.edges();
        assert_eq!(e, vec![(0, 1, 5.0), (2, 3, 7.0)]);
    }

    #[test]
    fn diagonal_mass_detects_banded() {
        let mut banded = CommMatrix::new(16);
        for i in 0..15 {
            banded.add_sym(i, i + 1, 1.0);
        }
        let mut spread = CommMatrix::new(16);
        for i in 0..8 {
            spread.add_sym(i, i + 8, 1.0);
        }
        assert!(banded.diagonal_mass(2) > 0.99);
        assert!(spread.diagonal_mass(2) < 0.01);
    }

    #[test]
    fn total_counts_both_triangles() {
        let mut m = CommMatrix::new(3);
        m.add_sym(0, 1, 4.0);
        assert_eq!(m.total(), 8.0);
    }
}
