//! Communication graphs (`G_v` bytes, `G_m` messages) and tooling.
//!
//! These are the outputs of the paper's MPI profiling tool: `N x N`
//! matrices where entry `(i, j)` is the total bytes (resp. messages)
//! exchanged between world ranks `i` and `j` in either direction.

pub mod heatmap;
pub mod io;
pub mod matrix;
pub mod sparse;

pub use matrix::CommMatrix;
pub use sparse::SparseComm;

/// The pair of graphs the profiling tool emits.
#[derive(Debug, Clone)]
pub struct CommProfile {
    /// `G_v`: bytes exchanged per pair (symmetric).
    pub volume: CommMatrix,
    /// `G_m`: message count per pair (symmetric).
    pub messages: CommMatrix,
}

impl CommProfile {
    /// Empty profile for `n` ranks.
    pub fn new(n: usize) -> Self {
        CommProfile {
            volume: CommMatrix::new(n),
            messages: CommMatrix::new(n),
        }
    }

    /// Record one point-to-point message of `bytes` from `src` to `dst`
    /// (world ranks). Updates both graphs symmetrically, as the paper's
    /// tool does (`G_v(i,j)` = bytes i->j plus bytes j->i).
    pub fn record(&mut self, src: usize, dst: usize, bytes: f64) {
        if src == dst {
            return; // self-messages do not cross the interconnect
        }
        self.volume.add_sym(src, dst, bytes);
        self.messages.add_sym(src, dst, 1.0);
    }

    /// Rank count.
    pub fn num_ranks(&self) -> usize {
        self.volume.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_updates_both_graphs_symmetrically() {
        let mut p = CommProfile::new(4);
        p.record(0, 2, 100.0);
        p.record(2, 0, 50.0);
        assert_eq!(p.volume.get(0, 2), 150.0);
        assert_eq!(p.volume.get(2, 0), 150.0);
        assert_eq!(p.messages.get(0, 2), 2.0);
    }

    #[test]
    fn self_message_ignored() {
        let mut p = CommProfile::new(2);
        p.record(1, 1, 1e9);
        assert_eq!(p.volume.total(), 0.0);
    }
}
