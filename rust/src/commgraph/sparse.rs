//! Sparse (CSR) communication graphs for million-rank mapping.
//!
//! [`CommMatrix`] is dense — `n x n` f64 entries — which is the right
//! shape for the paper's 64–256-rank jobs but caps out around a few
//! thousand ranks (a 1M-rank matrix would be 8 TB). The multilevel
//! mapper ([`crate::mapping::multilevel`]) instead consumes this
//! compressed-sparse-row form: O(n + m) memory for `n` ranks and `m`
//! communicating pairs, which is what real MPI communication graphs look
//! like (stencils, rings, low-degree collectives).
//!
//! The graph is undirected but stored with both directed arcs, so
//! `adj(v)` enumerates every neighbor of `v` exactly once; neighbor lists
//! are sorted by target id and parallel edges are pre-summed, making
//! every iteration order — and therefore every f64 accumulation order —
//! deterministic.

use super::CommMatrix;

/// Undirected weighted communication graph in CSR form. Both directed
/// arcs of each edge are stored; neighbor lists are sorted ascending and
/// duplicate-free.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseComm {
    n: usize,
    offsets: Vec<usize>,
    targets: Vec<u32>,
    weights: Vec<f64>,
}

impl SparseComm {
    /// Build from an undirected edge list. Self-loops and non-positive
    /// weights are dropped; parallel edges are summed. `targets` are
    /// `u32`, so `n` must fit (checked).
    pub fn from_edges(n: usize, edges: &[(usize, usize, f64)]) -> Self {
        assert!(n <= u32::MAX as usize, "vertex ids must fit u32");
        let mut deg = vec![0usize; n];
        for &(u, v, w) in edges {
            if u != v && w > 0.0 {
                deg[u] += 1;
                deg[v] += 1;
            }
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for d in &deg {
            acc += d;
            offsets.push(acc);
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0u32; acc];
        let mut weights = vec![0.0f64; acc];
        for &(u, v, w) in edges {
            if u != v && w > 0.0 {
                targets[cursor[u]] = v as u32;
                weights[cursor[u]] = w;
                cursor[u] += 1;
                targets[cursor[v]] = u as u32;
                weights[cursor[v]] = w;
                cursor[v] += 1;
            }
        }
        // sort each adjacency by target and fold parallel edges; the
        // compacted arrays are rebuilt in one pass so offsets stay exact
        let mut ct = Vec::with_capacity(acc);
        let mut cw = Vec::with_capacity(acc);
        let mut co = Vec::with_capacity(n + 1);
        co.push(0);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for v in 0..n {
            scratch.clear();
            for k in offsets[v]..offsets[v + 1] {
                scratch.push((targets[k], weights[k]));
            }
            scratch.sort_by_key(|p| p.0);
            for &(t, w) in scratch.iter() {
                let merged = ct.len() > co[v] && ct.last() == Some(&t);
                match cw.last_mut() {
                    Some(w0) if merged => *w0 += w,
                    _ => {
                        ct.push(t);
                        cw.push(w);
                    }
                }
            }
            co.push(ct.len());
        }
        SparseComm {
            n,
            offsets: co,
            targets: ct,
            weights: cw,
        }
    }

    /// Build from a dense [`CommMatrix`] (strictly-positive upper-triangle
    /// entries become edges).
    pub fn from_matrix(m: &CommMatrix) -> Self {
        Self::from_edges(m.len(), &m.edges())
    }

    /// Rebuild a CSR graph from raw parts. Intended for algorithms (like
    /// the multilevel coarsener) that produce already-sorted, already
    /// duplicate-free adjacency arrays; invariants are debug-asserted.
    pub fn from_raw(n: usize, offsets: Vec<usize>, targets: Vec<u32>, weights: Vec<f64>) -> Self {
        debug_assert_eq!(offsets.len(), n + 1);
        debug_assert_eq!(offsets[0], 0);
        // invariant: offsets.len() == n + 1 >= 1 (asserted above), so a
        // last element always exists
        debug_assert_eq!(*offsets.last().unwrap(), targets.len());
        debug_assert_eq!(targets.len(), weights.len());
        #[cfg(debug_assertions)]
        for v in 0..n {
            let ts = &targets[offsets[v]..offsets[v + 1]];
            debug_assert!(ts.windows(2).all(|p| p[0] < p[1]), "unsorted adjacency");
            debug_assert!(ts.iter().all(|&t| (t as usize) < n && t as usize != v));
        }
        SparseComm {
            n,
            offsets,
            targets,
            weights,
        }
    }

    /// Ring of `n` ranks, each talking `w` bytes to its successor.
    pub fn ring(n: usize, w: f64) -> Self {
        let edges: Vec<(usize, usize, f64)> = (0..n).map(|i| (i, (i + 1) % n, w)).collect();
        Self::from_edges(n, &edges)
    }

    /// `px x py` 2-D stencil (4-neighbor, non-periodic), `w` bytes per
    /// edge. Rank `(x, y)` is `y * px + x`.
    pub fn stencil2d(px: usize, py: usize, w: f64) -> Self {
        let mut edges = Vec::with_capacity(2 * px * py);
        for y in 0..py {
            for x in 0..px {
                let v = y * px + x;
                if x + 1 < px {
                    edges.push((v, v + 1, w));
                }
                if y + 1 < py {
                    edges.push((v, v + px, w));
                }
            }
        }
        Self::from_edges(px * py, &edges)
    }

    /// Vertex count.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the graph has zero vertices.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Undirected edge count.
    pub fn num_edges(&self) -> usize {
        self.targets.len() / 2
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Sorted neighbor ids and matching weights of `v`.
    #[inline]
    pub fn adj(&self, v: usize) -> (&[u32], &[f64]) {
        let r = self.offsets[v]..self.offsets[v + 1];
        (&self.targets[r.clone()], &self.weights[r])
    }

    /// Total undirected communication volume (each edge counted once).
    pub fn total_volume(&self) -> f64 {
        self.weights.iter().sum::<f64>() / 2.0
    }

    /// Densify (tests and the coarse-solve path; `n` must be small).
    pub fn to_matrix(&self) -> CommMatrix {
        let mut m = CommMatrix::new(self.n);
        for v in 0..self.n {
            let (ts, ws) = self.adj(v);
            for (&t, &w) in ts.iter().zip(ws) {
                // each undirected edge visits twice (v->t and t->v)
                m.set(v, t as usize, w);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_sorts_folds_and_symmetrizes() {
        let g = SparseComm::from_edges(
            4,
            &[(0, 2, 3.0), (2, 0, 1.0), (1, 3, 2.0), (2, 2, 9.0), (0, 1, 0.0)],
        );
        assert_eq!(g.len(), 4);
        assert_eq!(g.num_edges(), 2, "self-loop and zero-weight dropped");
        let (ts, ws) = g.adj(0);
        assert_eq!(ts, &[2]);
        assert_eq!(ws, &[4.0], "parallel edges summed");
        let (ts, _) = g.adj(2);
        assert_eq!(ts, &[0]);
        assert_eq!(g.total_volume(), 6.0);
    }

    #[test]
    fn round_trips_through_the_dense_matrix() {
        let mut m = CommMatrix::new(5);
        m.add_sym(0, 1, 10.0);
        m.add_sym(1, 4, 2.5);
        m.add_sym(2, 3, 7.0);
        let g = SparseComm::from_matrix(&m);
        assert_eq!(g.to_matrix(), m);
        assert_eq!(g.total_volume() * 2.0, m.total());
    }

    #[test]
    fn synthetic_generators_have_expected_shape() {
        let r = SparseComm::ring(8, 5.0);
        assert_eq!(r.num_edges(), 8);
        assert!((0..8).all(|v| r.degree(v) == 2));
        assert_eq!(r.total_volume(), 40.0);

        let s = SparseComm::stencil2d(4, 3, 1.0);
        assert_eq!(s.len(), 12);
        // 2D grid: px*(py-1) + (px-1)*py edges
        assert_eq!(s.num_edges(), 4 * 2 + 3 * 3);
        let corner_deg = s.degree(0);
        assert_eq!(corner_deg, 2);
        assert_eq!(s.degree(5), 4, "interior vertex");
    }

    #[test]
    fn degenerate_sizes() {
        let g = SparseComm::from_edges(0, &[]);
        assert!(g.is_empty());
        assert_eq!(g.total_volume(), 0.0);
        let g = SparseComm::ring(1, 3.0);
        assert_eq!(g.len(), 1);
        assert_eq!(g.num_edges(), 0, "ring(1) is a self-loop, dropped");
        let g = SparseComm::ring(2, 3.0);
        // 0->1 and 1->0 fold into one edge of weight 6
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.adj(0).1, &[6.0]);
    }
}
