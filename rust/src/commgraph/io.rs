//! Load/store communication graphs.
//!
//! The paper's LoadMatrix SPANK plugin ships the communication graph from
//! a compute node to slurmctld as a file; this module defines that wire
//! format: a simple self-describing text format (one header line, then one
//! row per line), plus JSON for interop with the Python tooling.

use std::io::{BufRead, BufReader, Read, Write};

use super::matrix::CommMatrix;
use crate::error::{Error, Result};

/// Serialize in the srun `--load-matrix` text format:
/// line 1: `tofa-commgraph v1 <n>`; lines 2..n+1: row-major f64 values.
pub fn write_text<W: Write>(m: &CommMatrix, w: &mut W) -> Result<()> {
    writeln!(w, "tofa-commgraph v1 {}", m.len())?;
    for i in 0..m.len() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(" "))?;
    }
    Ok(())
}

/// Parse the text format written by [`write_text`].
pub fn read_text<R: Read>(r: R) -> Result<CommMatrix> {
    let mut lines = BufReader::new(r).lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Io(std::io::Error::other("empty comm graph file")))??;
    let parts: Vec<&str> = header.split_whitespace().collect();
    if parts.len() != 3 || parts[0] != "tofa-commgraph" || parts[1] != "v1" {
        return Err(Error::Slurm(format!("bad comm graph header: {header}")));
    }
    let n: usize = parts[2]
        .parse()
        .map_err(|_| Error::Slurm(format!("bad comm graph size: {header}")))?;
    let mut m = CommMatrix::new(n);
    for i in 0..n {
        let line = lines
            .next()
            .ok_or_else(|| Error::Slurm(format!("comm graph truncated at row {i}")))??;
        let vals: Vec<&str> = line.split_whitespace().collect();
        if vals.len() != n {
            return Err(Error::Slurm(format!(
                "row {i} has {} values, expected {n}",
                vals.len()
            )));
        }
        for (j, v) in vals.iter().enumerate() {
            let w: f64 = v
                .parse()
                .map_err(|_| Error::Slurm(format!("bad value at ({i},{j}): {v}")))?;
            m.set(i, j, w);
        }
    }
    Ok(m)
}

/// Save to a file path.
pub fn save(m: &CommMatrix, path: &std::path::Path) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    write_text(m, &mut f)
}

/// Load from a file path.
pub fn load(path: &std::path::Path) -> Result<CommMatrix> {
    read_text(std::fs::File::open(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = CommMatrix::new(5);
        m.add_sym(0, 4, 123.5);
        m.add_sym(1, 2, 7.0);
        let mut buf = Vec::new();
        write_text(&m, &mut buf).unwrap();
        let back = read_text(&buf[..]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(read_text(&b"nonsense\n"[..]).is_err());
        assert!(read_text(&b"tofa-commgraph v2 4\n"[..]).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let input = b"tofa-commgraph v1 2\n0 1\n";
        assert!(read_text(&input[..]).is_err());
    }

    #[test]
    fn rejects_ragged_rows() {
        let input = b"tofa-commgraph v1 2\n0 1\n0\n";
        assert!(read_text(&input[..]).is_err());
    }
}
