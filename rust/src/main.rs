//! `repro` — the TOFA reproduction CLI.
//!
//! Subcommands regenerate every table and figure of the paper plus utility
//! operations (profiling, placement, single-job simulation). See
//! `repro help` and EXPERIMENTS.md.

use std::path::PathBuf;

mod experiments;

const USAGE: &str = "\
repro — TOFA: topology & fault-aware MPI process placement (paper reproduction)

USAGE: repro <command> [options]

COMMANDS:
  fig1                 Figure 1: traffic heatmaps (LAMMPS 128p, NPB-DT 85p)
  fig3a                Figure 3a: NPB-DT exec time across placement policies
  fig3b                Figure 3b: LAMMPS timesteps/s for 32..256 processes
  table1               Table 1: LAMMPS 256p across torus arrangements
  fig4                 Figure 4: NPB-DT batches, 16 faulty nodes @ 2%
  fig5a                Figure 5a: LAMMPS batches, 8 faulty nodes @ 2%
  fig5b                Figure 5b: LAMMPS batches, 16 faulty nodes @ 2%
  sched                cluster-level event-driven scheduler: concurrent
                       jobs on shared allocation state (FIFO/backfill)
  campaign             trace-driven heavy-traffic campaign: day-long job
                       streams, wait/slowdown percentiles per cell
  all                  run every experiment in sequence
  profile              print an app's comm-graph stats + heatmap
  place                compare mapping quality across policies
  runtime              PJRT artifact smoke check + cross-validation
  lint                 detlint: determinism & invariant static analysis
                       over rust/src, rust/tests, benches/, examples/
                       (lint [--format=json] [--root=<dir>] [paths...];
                       exits 1 on findings, see ARCHITECTURE.md)
  help                 this text

OPTIONS:
  --results=<dir>      CSV output directory        (default: results)
  --seed=<u64>         base RNG seed               (default: 42)
  --batches=<n>        batches for fig4/fig5       (default: 10)
  --instances=<n>      instances per batch         (default: 100)
  --workers=<n>        worker threads for batch sweeps; results are
                       identical for any value     (default: 0 = all cores)
  --app=<spec>         app for profile/place: lammps:<ranks> | npb-dt |
                       stencil:<px>x<py> | ring:<ranks>   (default: lammps:64)

TOPOLOGY (fig4/fig5a/fig5b/place/all):
  --topology=<t>       torus | fattree | dragonfly (default: torus)
  --torus=<XxYxZ>      torus dims                  (default: 8x8x8)
  --fattree-k=<k>      fat-tree arity, k even; k^3/4 nodes (default: 8)
  --dragonfly=<GxAxPxH> groups x routers x hosts x global links per router
                       (default: 9x4x4x2)
  --metric=<m>         distance metric: auto | dense | implicit
                       (auto: dense up to 4096 nodes, implicit beyond)
                       (default: auto)

FAULT MODEL (fig4/fig5a/fig5b/all):
  --fault-model=<m>    iid | correlated | weibull | trace  (default: iid)
  --p-f=<f>            per-node outage probability (iid) or probability at
                       the horizon (weibull)       (default: 0.02)
  --domains=<n>        faulty racks for correlated (default: n_f / 8)
  --p-domain=<f>       whole-rack outage probability (default: 0.05)
  --weibull-shape=<k>  Weibull shape               (default: 0.7)
  --fault-horizon=<s>  Weibull planning horizon, simulated seconds
                       (default: 1.0)
  --fault-trace=<path> down-interval trace file, required for trace
                       (format: header 'nodes N', then 'node start end')

SCHEDULER (sched):
  --jobs=<n>           workload size                (default: 100)
  --arrival=<s>        mean interarrival gap; 0 = all jobs at t=0
                       (default: 0)
  --policy=<p>         sched: fifo | backfill       (default: fifo)
                       place: default-slurm | random | greedy | scotch |
                       tofa | multilevel   (default: compare them all)
  --backfill           shorthand for --policy=backfill
  --mix=<r:w,...>      job-size mix, ranks:weight pairs
                       (default: n/32, n/16, n/8 at 50/30/20%)
  --n-faulty=<n>       faulty nodes for the fault model (default: 16)
  --hb-period=<s>      heartbeat health-epoch period; 0 = off (default: 0)
  --max-restarts=<n>   per-job restart budget       (default: 100)
  --recovery=<p>       in-job recovery policy: abort | ckpt:<interval> |
                       shrink                       (default: abort)
  --ckpt-cost=<s>      checkpoint write cost, simulated seconds
                       (default: 0.05)
  --smoke              reduced-size CI smoke run

CAMPAIGN (campaign; also honours --jobs/--arrival/--mix/--n-faulty/
          --hb-period/--max-restarts/--recovery/--ckpt-cost/--smoke
          above, with --jobs defaulting to 2000 and --arrival to 0.05):
  --arrivals=<p>       batch | poisson | diurnal | flash (default: poisson)
  --day=<s>            diurnal cycle length, simulated seconds
                       (default: 240)
  --peak-trough=<f>    diurnal peak-to-trough arrival-rate ratio
                       (default: 4)
  --bursts=<n>         flash-crowd burst count      (default: 4)
  --burst-jobs=<n>     jobs dumped per burst        (default: 50)
  --burst-span=<s>     seconds each burst spans     (default: 1)
  --trace=<path>       replay a workload trace (.swf or .tsv) instead of
                       generating jobs
  --arrival-scale=<f>  compress (<1) / stretch (>1) trace arrival gaps
                       (default: 1)
  --emit-json          write BENCH_campaign.json with per-cell metrics
";

struct Opts {
    results: PathBuf,
    seed: u64,
    batches: usize,
    instances: usize,
    workers: usize,
    app: String,
    topo: experiments::TopoCliOpts,
    fault: experiments::FaultCliOpts,
    sched: experiments::SchedCliOpts,
    campaign: experiments::CampaignCliOpts,
    /// `--policy=` as seen by `place` (a placement-policy name there;
    /// the same flag selects fifo/backfill for `sched`).
    place_policy: Option<String>,
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut o = Opts {
        results: PathBuf::from("results"),
        seed: 42,
        batches: 10,
        instances: 100,
        workers: 0,
        app: "lammps:64".to_string(),
        topo: experiments::TopoCliOpts::default(),
        fault: experiments::FaultCliOpts::default(),
        sched: experiments::SchedCliOpts::default(),
        campaign: experiments::CampaignCliOpts::default(),
        place_policy: None,
    };
    for a in args {
        if let Some(v) = a.strip_prefix("--results=") {
            o.results = PathBuf::from(v);
        } else if let Some(v) = a.strip_prefix("--seed=") {
            o.seed = v.parse().map_err(|_| format!("bad --seed: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--batches=") {
            o.batches = v.parse().map_err(|_| format!("bad --batches: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--instances=") {
            o.instances = v.parse().map_err(|_| format!("bad --instances: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--workers=") {
            o.workers = v.parse().map_err(|_| format!("bad --workers: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--app=") {
            o.app = v.to_string();
        } else if let Some(v) = a.strip_prefix("--topology=") {
            o.topo.topology = v.to_string();
        } else if let Some(v) = a.strip_prefix("--torus=") {
            o.topo.torus = v.to_string();
        } else if let Some(v) = a.strip_prefix("--fattree-k=") {
            o.topo.fattree_k = v.parse().map_err(|_| format!("bad --fattree-k: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--dragonfly=") {
            o.topo.dragonfly = v.to_string();
        } else if let Some(v) = a.strip_prefix("--metric=") {
            o.topo.metric = v.to_string();
        } else if let Some(v) = a.strip_prefix("--fault-model=") {
            o.fault.model = v.to_string();
        } else if let Some(v) = a.strip_prefix("--p-f=") {
            o.fault.p_f = v.parse().map_err(|_| format!("bad --p-f: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--domains=") {
            o.fault.domains = v.parse().map_err(|_| format!("bad --domains: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--p-domain=") {
            o.fault.p_domain = v.parse().map_err(|_| format!("bad --p-domain: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--weibull-shape=") {
            o.fault.weibull_shape = v.parse().map_err(|_| format!("bad --weibull-shape: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--fault-horizon=") {
            o.fault.horizon_s = v.parse().map_err(|_| format!("bad --fault-horizon: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--fault-trace=") {
            o.fault.trace_path = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--jobs=") {
            o.sched.jobs = v.parse().map_err(|_| format!("bad --jobs: {v}"))?;
            o.campaign.jobs = o.sched.jobs;
        } else if let Some(v) = a.strip_prefix("--arrival=") {
            o.sched.arrival_s = v.parse().map_err(|_| format!("bad --arrival: {v}"))?;
            o.campaign.mean_gap_s = o.sched.arrival_s;
        } else if let Some(v) = a.strip_prefix("--policy=") {
            o.sched.policy = v.to_string();
            o.place_policy = Some(v.to_string());
        } else if a == "--backfill" {
            o.sched.policy = "backfill".to_string();
        } else if let Some(v) = a.strip_prefix("--mix=") {
            o.sched.mix = v.to_string();
            o.campaign.mix = o.sched.mix.clone();
        } else if let Some(v) = a.strip_prefix("--n-faulty=") {
            o.sched.n_faulty = v.parse().map_err(|_| format!("bad --n-faulty: {v}"))?;
            o.campaign.n_faulty = o.sched.n_faulty;
        } else if let Some(v) = a.strip_prefix("--hb-period=") {
            o.sched.hb_period_s = v.parse().map_err(|_| format!("bad --hb-period: {v}"))?;
            o.campaign.hb_period_s = o.sched.hb_period_s;
        } else if let Some(v) = a.strip_prefix("--max-restarts=") {
            o.sched.max_restarts = v.parse().map_err(|_| format!("bad --max-restarts: {v}"))?;
            o.campaign.max_restarts = o.sched.max_restarts;
        } else if let Some(v) = a.strip_prefix("--recovery=") {
            o.sched.recovery = v.to_string();
            o.campaign.recovery = o.sched.recovery.clone();
        } else if let Some(v) = a.strip_prefix("--ckpt-cost=") {
            o.sched.ckpt_cost_s = v.parse().map_err(|_| format!("bad --ckpt-cost: {v}"))?;
            o.campaign.ckpt_cost_s = o.sched.ckpt_cost_s;
        } else if a == "--smoke" {
            o.sched.smoke = true;
            o.campaign.smoke = true;
        } else if let Some(v) = a.strip_prefix("--arrivals=") {
            o.campaign.arrivals = v.to_string();
        } else if let Some(v) = a.strip_prefix("--day=") {
            o.campaign.day_s = v.parse().map_err(|_| format!("bad --day: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--peak-trough=") {
            o.campaign.peak_to_trough = v.parse().map_err(|_| format!("bad --peak-trough: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--bursts=") {
            o.campaign.bursts = v.parse().map_err(|_| format!("bad --bursts: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--burst-jobs=") {
            o.campaign.burst_jobs = v.parse().map_err(|_| format!("bad --burst-jobs: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--burst-span=") {
            o.campaign.burst_span_s = v.parse().map_err(|_| format!("bad --burst-span: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--trace=") {
            o.campaign.trace_path = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--arrival-scale=") {
            o.campaign.arrival_scale = v.parse().map_err(|_| format!("bad --arrival-scale: {v}"))?;
        } else if a == "--emit-json" {
            o.campaign.emit_json = true;
        } else {
            return Err(format!("unknown option: {a}"));
        }
    }
    Ok(o)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    // `lint` takes its own argument set (bare paths allowed), so dispatch
    // it before the experiment-option parser gets a chance to reject them.
    if cmd == "lint" {
        std::process::exit(tofa::analysis::run_cli(&args[1..]));
    }
    let opts = match parse_opts(&args[1..]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    std::fs::create_dir_all(&opts.results).ok();
    let r = &opts.results;
    match cmd {
        "fig1" => experiments::fig1(r)?,
        "fig3a" => experiments::fig3a(r, opts.seed)?,
        "fig3b" => experiments::fig3b(r, opts.seed)?,
        "table1" => experiments::table1(r, opts.seed)?,
        "fig4" => experiments::fig4(
            r,
            opts.seed,
            opts.batches,
            opts.instances,
            opts.workers,
            &opts.topo,
            &opts.fault,
        )?,
        "fig5a" => experiments::fig5(
            r,
            opts.seed,
            8,
            opts.batches,
            opts.instances,
            "5a",
            opts.workers,
            &opts.topo,
            &opts.fault,
        )?,
        "fig5b" => experiments::fig5(
            r,
            opts.seed,
            16,
            opts.batches,
            opts.instances,
            "5b",
            opts.workers,
            &opts.topo,
            &opts.fault,
        )?,
        "sched" => experiments::sched(
            r,
            opts.seed,
            opts.workers,
            &opts.topo,
            &opts.fault,
            &opts.sched,
        )?,
        "campaign" => experiments::campaign(
            r,
            opts.seed,
            opts.workers,
            &opts.topo,
            &opts.fault,
            &opts.campaign,
        )?,
        "all" => {
            experiments::fig1(r)?;
            experiments::fig3a(r, opts.seed)?;
            experiments::fig3b(r, opts.seed)?;
            experiments::table1(r, opts.seed)?;
            let (b, i, w) = (opts.batches, opts.instances, opts.workers);
            let (t, f) = (&opts.topo, &opts.fault);
            experiments::fig4(r, opts.seed, b, i, w, t, f)?;
            experiments::fig5(r, opts.seed, 8, b, i, "5a", w, t, f)?;
            experiments::fig5(r, opts.seed, 16, b, i, "5b", w, t, f)?;
        }
        "profile" => experiments::profile(&opts.app)?,
        "place" => {
            experiments::place(&opts.app, &opts.topo, opts.seed, opts.place_policy.as_deref())?
        }
        "runtime" => experiments::runtime_check()?,
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command: {other}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
