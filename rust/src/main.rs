//! `repro` — the TOFA reproduction CLI.
//!
//! Subcommands regenerate every table and figure of the paper plus utility
//! operations (profiling, placement, single-job simulation). See
//! `repro help` and EXPERIMENTS.md.

use std::path::PathBuf;

mod experiments;

const USAGE: &str = "\
repro — TOFA: topology & fault-aware MPI process placement (paper reproduction)

USAGE: repro <command> [options]

COMMANDS:
  fig1                 Figure 1: traffic heatmaps (LAMMPS 128p, NPB-DT 85p)
  fig3a                Figure 3a: NPB-DT exec time across placement policies
  fig3b                Figure 3b: LAMMPS timesteps/s for 32..256 processes
  table1               Table 1: LAMMPS 256p across torus arrangements
  fig4                 Figure 4: NPB-DT batches, 16 faulty nodes @ 2%
  fig5a                Figure 5a: LAMMPS batches, 8 faulty nodes @ 2%
  fig5b                Figure 5b: LAMMPS batches, 16 faulty nodes @ 2%
  all                  run every experiment in sequence
  profile              print an app's comm-graph stats + heatmap
  place                compare mapping quality across policies
  runtime              PJRT artifact smoke check + cross-validation
  help                 this text

OPTIONS:
  --results=<dir>      CSV output directory        (default: results)
  --seed=<u64>         base RNG seed               (default: 42)
  --batches=<n>        batches for fig4/fig5       (default: 10)
  --instances=<n>      instances per batch         (default: 100)
  --workers=<n>        worker threads for batch sweeps; results are
                       identical for any value     (default: 0 = all cores)
  --app=<spec>         app for profile/place: lammps:<ranks> | npb-dt |
                       stencil:<px>x<py> | ring:<ranks>   (default: lammps:64)
  --torus=<XxYxZ>      torus dims for place        (default: 8x8x8)
";

struct Opts {
    results: PathBuf,
    seed: u64,
    batches: usize,
    instances: usize,
    workers: usize,
    app: String,
    torus: String,
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut o = Opts {
        results: PathBuf::from("results"),
        seed: 42,
        batches: 10,
        instances: 100,
        workers: 0,
        app: "lammps:64".to_string(),
        torus: "8x8x8".to_string(),
    };
    for a in args {
        if let Some(v) = a.strip_prefix("--results=") {
            o.results = PathBuf::from(v);
        } else if let Some(v) = a.strip_prefix("--seed=") {
            o.seed = v.parse().map_err(|_| format!("bad --seed: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--batches=") {
            o.batches = v.parse().map_err(|_| format!("bad --batches: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--instances=") {
            o.instances = v.parse().map_err(|_| format!("bad --instances: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--workers=") {
            o.workers = v.parse().map_err(|_| format!("bad --workers: {v}"))?;
        } else if let Some(v) = a.strip_prefix("--app=") {
            o.app = v.to_string();
        } else if let Some(v) = a.strip_prefix("--torus=") {
            o.torus = v.to_string();
        } else {
            return Err(format!("unknown option: {a}"));
        }
    }
    Ok(o)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let opts = match parse_opts(&args[1..]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    std::fs::create_dir_all(&opts.results).ok();
    let r = &opts.results;
    match cmd {
        "fig1" => experiments::fig1(r)?,
        "fig3a" => experiments::fig3a(r, opts.seed)?,
        "fig3b" => experiments::fig3b(r, opts.seed)?,
        "table1" => experiments::table1(r, opts.seed)?,
        "fig4" => experiments::fig4(r, opts.seed, opts.batches, opts.instances, opts.workers)?,
        "fig5a" => {
            experiments::fig5(r, opts.seed, 8, opts.batches, opts.instances, "5a", opts.workers)?
        }
        "fig5b" => {
            experiments::fig5(r, opts.seed, 16, opts.batches, opts.instances, "5b", opts.workers)?
        }
        "all" => {
            experiments::fig1(r)?;
            experiments::fig3a(r, opts.seed)?;
            experiments::fig3b(r, opts.seed)?;
            experiments::table1(r, opts.seed)?;
            experiments::fig4(r, opts.seed, opts.batches, opts.instances, opts.workers)?;
            experiments::fig5(r, opts.seed, 8, opts.batches, opts.instances, "5a", opts.workers)?;
            experiments::fig5(r, opts.seed, 16, opts.batches, opts.instances, "5b", opts.workers)?;
        }
        "profile" => experiments::profile(&opts.app)?,
        "place" => experiments::place(&opts.app, &opts.torus, opts.seed)?,
        "runtime" => experiments::runtime_check()?,
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command: {other}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
