//! Multilevel coarsen–map–refine process mapping for million-rank jobs.
//!
//! The flat mappers in this tree ([`super::recmap`], [`super::kl`]) are
//! the paper-era Scotch substitutes: quadratic in the rank count, which
//! is fine for the paper's 64–256-rank jobs and hopeless for the
//! million-rank comm graphs the roadmap targets. This module implements
//! the multilevel lineage instead (Schulz–Träff sparse-QAP mapping,
//! arXiv 1702.04164; Schulz–Woydt shared-memory hierarchical mapping,
//! arXiv 2504.01726):
//!
//! 1. **Coarsen** the sparse communication graph ([`SparseComm`]) by
//!    heavy-edge matching down to roughly the platform's rack/pod/group
//!    count, with a vertex-weight cap keeping coarse vertices balanced.
//! 2. **Map** the coarse graph with the existing [`RecursiveMapper`]
//!    (recursive bisection + KL) over *representative hosts* — one per
//!    equal chunk of the chosen host window — so the coarse solve sees
//!    real topology distances while only ever materializing a `K x K`
//!    matrix (`K` ≤ a few hundred), never `nodes x nodes`.
//! 3. **Uncoarsen**, splitting each parent interval between its two
//!    children and running a KL-style pairwise-swap refinement at every
//!    level.
//!
//! Total cost is `O(E log N)`-ish — near-linear in graph size — and no
//! step builds `O(ranks²)` or `O(nodes²)` state, so it composes with the
//! implicit [`HopOracle`] metric on 100k-node platforms.
//!
//! # Determinism
//!
//! Refinement gain evaluation and matching preferences run on the PR-1
//! scoped-thread pool (`batch::parallel::run_sharded`) *within a single
//! placement call*, but every parallel phase is a pure function of the
//! vertex index over state frozen at the start of the phase, with
//! randomness drawn from static per-level/per-pass streams
//! (`Rng::stream`); all applications of proposals happen serially in
//! ascending vertex order. Results are therefore bit-identical for any
//! worker count — the same contract the batch engine keeps across
//! instances, pushed down into one placement.
//!
//! # Host windows and oversubscription
//!
//! Candidate hosts (the scheduler's free list) are taken as an ascending
//! id list; the mapper picks the *tightest id-span window* of the needed
//! size, which is meaningful because the [`Topology`] contract keeps
//! consecutive node ids physically close. With `max_per_node = c > 1`,
//! each window host contributes `c` consecutive slots, so ranks pack
//! onto nodes (intra-node hops are zero) and the one-process-per-node
//! invariant is asserted only when `c == 1`.
//!
//! [`Topology`]: crate::topology::Topology
//! [`HopOracle`]: crate::topology::HopOracle
//!
//! # Example
//!
//! ```
//! use tofa::commgraph::SparseComm;
//! use tofa::mapping::multilevel::MultilevelMapper;
//! use tofa::topology::{MetricMode, Platform, TorusDims};
//!
//! // 12-rank ring on a 16-node torus served by the implicit metric:
//! // no dense distance matrix is ever built.
//! let platform =
//!     Platform::paper_default(TorusDims::new(4, 4, 1)).with_metric(MetricMode::Implicit);
//! let graph = SparseComm::ring(12, 1e6);
//! let hosts: Vec<usize> = (0..platform.num_nodes()).collect();
//! let oracle = platform.hop_oracle();
//! let placement = MultilevelMapper::default()
//!     .map_sparse(&graph, &oracle, &hosts)
//!     .unwrap();
//! placement.validate(platform.num_nodes()).unwrap();
//! ```

use super::recmap::RecursiveMapper;
use super::Placement;
use crate::batch::parallel::{run_sharded, Parallelism};
use crate::commgraph::{CommMatrix, SparseComm};
use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::topology::{DistanceMatrix, HopOracle};

/// Coarse-graph size the coarsening loop aims for when the platform
/// exposes no usable rack count (dense-matrix entry points).
pub const DEFAULT_COARSE_TARGET: usize = 128;
/// Clamp range applied to the platform's rack/pod/group count when
/// auto-sizing the coarse graph.
const MIN_COARSE_TARGET: usize = 32;
const MAX_COARSE_TARGET: usize = 512;
/// Swap gains this close to zero are treated as noise, not improvements.
const GAIN_EPS: f64 = 1e-9;

/// One level of the coarsening hierarchy. Level 0 is the input graph;
/// each subsequent level contracts matched pairs of the previous one.
#[derive(Debug, Clone)]
pub struct CoarseLevel {
    /// The (coarsened) communication graph at this level.
    pub graph: SparseComm,
    /// Ranks folded into each vertex; sums to the input rank count.
    pub vweight: Vec<u32>,
    /// Cumulative comm volume contracted inside vertices so far. The
    /// conservation invariant — property-tested — is
    /// `graph.total_volume() + internal == input.total_volume()`.
    pub internal: f64,
    /// Previous level's vertex -> this level's vertex (empty at level 0).
    pub map_down: Vec<u32>,
}

/// Distance source for the mapper: a dense matrix or the implicit
/// oracle. `HopOracle` serves bit-identical values in both of its own
/// modes, so the two arms here agree wherever both are usable.
enum Metric<'a> {
    Dense(&'a DistanceMatrix),
    Oracle(&'a HopOracle<'a>),
}

impl Metric<'_> {
    #[inline]
    fn hops(&self, u: usize, v: usize) -> f32 {
        match self {
            Metric::Dense(d) => d.get(u, v),
            Metric::Oracle(o) => o.hops(u, v),
        }
    }

    fn extract(&self, subset: &[usize]) -> DistanceMatrix {
        match self {
            Metric::Dense(d) => d.extract(subset),
            Metric::Oracle(o) => o.extract(subset),
        }
    }

    /// Rack/pod/group count when the topology is reachable, else 0.
    fn racks(&self) -> usize {
        match self {
            Metric::Dense(_) => 0,
            Metric::Oracle(o) => o.topology().num_racks(),
        }
    }
}

/// Shared per-level state for refinement, bundled so helpers stay under
/// the argument-count lint and the parallel closures capture one thing.
struct LevelCtx<'a, F: Fn(usize) -> usize + Sync> {
    g: &'a SparseComm,
    vw: &'a [u32],
    metric: &'a Metric<'a>,
    slot_host: &'a F,
    workers: usize,
}

/// Coarsen–map–refine mapper. See the module docs for the algorithm and
/// the determinism contract; all fields are plain knobs.
#[derive(Debug, Clone)]
pub struct MultilevelMapper {
    /// Stop coarsening at roughly this many vertices. `0` = auto: the
    /// platform's rack count clamped to `[32, 512]` (or 128 when no
    /// topology is reachable).
    pub coarse_target: usize,
    /// Refinement sweeps per level (each sweep is propose-then-apply).
    pub refine_passes: usize,
    /// Heaviest equal-weight comm partners tried as swap candidates.
    pub swap_candidates: usize,
    /// Additional random equal-weight swap candidates per vertex, drawn
    /// from the per-pass RNG stream.
    pub rand_candidates: usize,
    /// Worker threads for the parallel phases. `0` = all cores; the
    /// result is bit-identical for any value.
    pub workers: usize,
    /// Base RNG stream; every level/pass derives a static sub-stream.
    pub seed: u64,
    /// Ranks allowed per node (1 = the paper's one-process-per-node).
    pub max_per_node: usize,
}

impl Default for MultilevelMapper {
    fn default() -> Self {
        MultilevelMapper {
            coarse_target: 0,
            refine_passes: 2,
            swap_candidates: 6,
            rand_candidates: 2,
            workers: 1,
            seed: 0x746f_6661_6d6c, // "tofaml"
            max_per_node: 1,
        }
    }
}

impl MultilevelMapper {
    /// Map onto all nodes of a dense distance matrix (the
    /// [`super::place`] entry point, mirroring [`RecursiveMapper::map`]).
    pub fn map(&self, comm: &CommMatrix, dist: &DistanceMatrix) -> Result<Placement> {
        let hosts: Vec<usize> = (0..dist.len()).collect();
        self.map_onto(comm, dist, &hosts)
    }

    /// Map onto an ascending subset of a dense matrix's nodes.
    pub fn map_onto(
        &self,
        comm: &CommMatrix,
        dist: &DistanceMatrix,
        hosts: &[usize],
    ) -> Result<Placement> {
        let g = SparseComm::from_matrix(comm);
        self.run(&g, &Metric::Dense(dist), hosts)
    }

    /// Map a sparse comm graph onto `hosts` (ascending node ids) using
    /// the metric oracle. This is the scalable path: nothing larger than
    /// the coarse `K x K` representative matrix is materialized, so it
    /// works on implicit 100k-node platforms.
    pub fn map_sparse(
        &self,
        g: &SparseComm,
        oracle: &HopOracle<'_>,
        hosts: &[usize],
    ) -> Result<Placement> {
        self.run(g, &Metric::Oracle(oracle), hosts)
    }

    /// Build the coarsening hierarchy (level 0 = `g`). `target == 0`
    /// uses [`DEFAULT_COARSE_TARGET`]. Public so property tests can
    /// check the per-level conservation invariants directly.
    pub fn coarsen(&self, g: &SparseComm, target: usize) -> Vec<CoarseLevel> {
        let target = match target {
            0 => DEFAULT_COARSE_TARGET,
            t => t,
        };
        let total = g.len() as u64;
        let mut levels = vec![CoarseLevel {
            graph: g.clone(),
            vweight: vec![1u32; g.len()],
            internal: 0.0,
            map_down: Vec::new(),
        }];
        loop {
            // invariant: `levels` is seeded with level 0 above and only
            // ever grows, so a last element always exists
            let last = levels.last().unwrap();
            if last.graph.len() <= target {
                break;
            }
            let next = self.coarsen_once(last, total, target);
            if next.graph.len() == last.graph.len() {
                break; // weight caps forbid any further contraction
            }
            levels.push(next);
        }
        levels
    }

    fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            Parallelism::auto().effective()
        } else {
            self.workers
        }
    }

    /// One contraction step: heavy-edge matching (parallel preference
    /// scan, serial deterministic resolution), a forced pairing fallback
    /// for edge-poor graphs, then the contracted CSR build.
    fn coarsen_once(&self, prev: &CoarseLevel, total: u64, target: usize) -> CoarseLevel {
        let g = &prev.graph;
        let vw = &prev.vweight;
        let n = g.len();
        // cap combined weights at twice the average coarse vertex so the
        // final level stays balanced; >= 2 so unit pairs always fit
        let cap: u64 = (2 * total / target as u64).max(2);

        // parallel phase: each vertex's heaviest admissible neighbor
        // (pure function of the index; ascending scan keeps ties on the
        // smaller id)
        let (pref, _) = run_sharded(n, self.effective_workers(), |v| {
            let (ts, ws) = g.adj(v);
            let mut best = u32::MAX;
            let mut best_w = 0.0f64;
            for (&t, &w) in ts.iter().zip(ws) {
                if u64::from(vw[v]) + u64::from(vw[t as usize]) > cap {
                    continue;
                }
                if w > best_w {
                    best = t;
                    best_w = w;
                }
            }
            best
        });

        // serial phase: greedy matching in ascending id order; a taken
        // preference falls back to the heaviest still-unmatched neighbor
        let mut mate: Vec<u32> = vec![u32::MAX; n];
        let mut matched = 0usize;
        for v in 0..n {
            if mate[v] != u32::MAX {
                continue;
            }
            let mut chosen = u32::MAX;
            let p = pref[v];
            if p != u32::MAX && mate[p as usize] == u32::MAX {
                chosen = p;
            } else {
                let (ts, ws) = g.adj(v);
                let mut best_w = 0.0f64;
                for (&t, &w) in ts.iter().zip(ws) {
                    if mate[t as usize] != u32::MAX
                        || u64::from(vw[v]) + u64::from(vw[t as usize]) > cap
                    {
                        continue;
                    }
                    if w > best_w {
                        chosen = t;
                        best_w = w;
                    }
                }
            }
            if chosen != u32::MAX {
                mate[v] = chosen;
                mate[chosen as usize] = v as u32;
                matched += 2;
            }
        }

        // fallback: edge-poor graphs stall the matching, so pair the
        // lightest unmatched vertices directly — guarantees progress
        // whenever n > target (the two lightest always fit under `cap`)
        if matched * 5 < n {
            let mut un: Vec<u32> = Vec::new();
            for v in 0..n as u32 {
                if mate[v as usize] == u32::MAX {
                    un.push(v);
                }
            }
            un.sort_by_key(|&v| (vw[v as usize], v));
            let mut i = 0;
            while i + 1 < un.len() {
                let (a, b) = (un[i], un[i + 1]);
                if u64::from(vw[a as usize]) + u64::from(vw[b as usize]) > cap {
                    break; // sorted ascending: no later pair fits either
                }
                mate[a as usize] = b;
                mate[b as usize] = a;
                i += 2;
            }
        }

        // contract: coarse ids in ascending order of smaller member
        let mut map_down: Vec<u32> = vec![u32::MAX; n];
        let mut members: Vec<(u32, u32)> = Vec::with_capacity(n / 2 + 1);
        for v in 0..n {
            if map_down[v] != u32::MAX {
                continue;
            }
            let c = members.len() as u32;
            map_down[v] = c;
            let m = mate[v];
            if m != u32::MAX {
                map_down[m as usize] = c;
                members.push((v as u32, m));
            } else {
                members.push((v as u32, u32::MAX));
            }
        }
        let nc = members.len();
        let mut vweight: Vec<u32> = Vec::with_capacity(nc);
        for &(a, b) in &members {
            let mut w = vw[a as usize];
            if b != u32::MAX {
                w += vw[b as usize];
            }
            vweight.push(w);
        }

        // contracted CSR, built row by row with a scratch accumulator;
        // weights are > 0, so `agg == 0.0` doubles as the touched test
        let mut agg: Vec<f64> = vec![0.0; nc];
        let mut touched: Vec<u32> = Vec::new();
        let mut offsets: Vec<usize> = Vec::with_capacity(nc + 1);
        let mut targets: Vec<u32> = Vec::new();
        let mut weights: Vec<f64> = Vec::new();
        let mut internal2 = 0.0f64; // internal volume, double-counted
        offsets.push(0);
        for (c, &(a, b)) in members.iter().enumerate() {
            for v in [a, b] {
                if v == u32::MAX {
                    continue;
                }
                let (ts, ws) = g.adj(v as usize);
                for (&t, &w) in ts.iter().zip(ws) {
                    let ct = map_down[t as usize];
                    if ct as usize == c {
                        internal2 += w;
                        continue;
                    }
                    // detlint: allow(float-discipline, exact 0.0 sentinel: slots reset after drain)
                    if agg[ct as usize] == 0.0 {
                        touched.push(ct);
                    }
                    agg[ct as usize] += w;
                }
            }
            touched.sort_unstable();
            for &ct in &touched {
                targets.push(ct);
                weights.push(agg[ct as usize]);
                agg[ct as usize] = 0.0;
            }
            touched.clear();
            offsets.push(targets.len());
        }
        CoarseLevel {
            graph: SparseComm::from_raw(nc, offsets, targets, weights),
            vweight,
            internal: prev.internal + internal2 / 2.0,
            map_down,
        }
    }

    fn run(&self, g: &SparseComm, metric: &Metric<'_>, hosts: &[usize]) -> Result<Placement> {
        let n = g.len();
        if n == 0 {
            return Ok(Placement::new(Vec::new()));
        }
        let cap = self.max_per_node.max(1);
        let need = n.div_ceil(cap);
        if need > hosts.len() {
            return Err(Error::Placement(format!(
                "{n} ranks at {cap} per node cannot fit {} candidate hosts",
                hosts.len()
            )));
        }
        debug_assert!(
            hosts.windows(2).all(|p| p[0] < p[1]),
            "candidate hosts must be strictly ascending"
        );
        let window = tightest_window(hosts, need);
        let slot_host = move |s: usize| window[s / cap];
        self.run_in_window(g, metric, &slot_host)
    }

    fn run_in_window<F: Fn(usize) -> usize + Sync>(
        &self,
        g: &SparseComm,
        metric: &Metric<'_>,
        slot_host: &F,
    ) -> Result<Placement> {
        let n = g.len();
        let auto = match metric.racks() {
            0 => DEFAULT_COARSE_TARGET,
            r => r.clamp(MIN_COARSE_TARGET, MAX_COARSE_TARGET),
        };
        let chosen = if self.coarse_target > 0 {
            self.coarse_target
        } else {
            auto
        };
        let target = chosen.clamp(1, n);
        let levels = self.coarsen(g, target);

        // coarse solve: recmap + KL over one representative host per
        // equal slot chunk — the only distance matrix ever materialized
        // invariant: coarsen() always returns at least level 0
        let top = levels.last().unwrap();
        let k = top.graph.len();
        let reps: Vec<usize> = (0..k)
            .map(|c| {
                let lo = c * n / k;
                let hi = ((c + 1) * n / k).max(lo + 1);
                slot_host((lo + hi - 1) / 2)
            })
            .collect();
        let rep_dist = metric.extract(&reps);
        let coarse_comm = top.graph.to_matrix();
        let local: Vec<usize> = (0..k).collect();
        let coarse_solver = RecursiveMapper::default();
        let sol = coarse_solver.map_onto(&coarse_comm, &rep_dist, &local)?;

        // lay coarse vertices out along the window in representative
        // order, sized by their actual rank weight
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by_key(|&c| sol.assignment[c]);
        let mut starts: Vec<usize> = vec![0; k];
        let mut acc = 0usize;
        for &c in &order {
            starts[c] = acc;
            acc += top.vweight[c] as usize;
        }
        debug_assert_eq!(acc, n, "vertex weights must sum to the rank count");

        let workers = self.effective_workers();
        let top_ctx = LevelCtx {
            g: &top.graph,
            vw: &top.vweight,
            metric,
            slot_host,
            workers,
        };
        self.refine_level(&top_ctx, &mut starts, levels.len() - 1);

        // uncoarsen: split parent intervals between children (order
        // chosen by estimated attraction to neighbor parents), refine
        for li in (1..levels.len()).rev() {
            let fine = &levels[li - 1];
            let coarse = &levels[li];
            let fine_n = fine.graph.len();
            let mut child_a: Vec<u32> = vec![u32::MAX; coarse.graph.len()];
            let mut child_b: Vec<u32> = vec![u32::MAX; coarse.graph.len()];
            for v in 0..fine_n {
                let c = coarse.map_down[v] as usize;
                if child_a[c] == u32::MAX {
                    child_a[c] = v as u32;
                } else {
                    child_b[c] = v as u32;
                }
            }
            // attraction of placing fine vertex `v` as an interval at
            // `start`: comm-weighted distance to every neighbor's
            // *parent* interval center (children aren't placed yet)
            let attract = |v: usize, start: usize, w: usize| -> f64 {
                let my = slot_host(start + w / 2);
                let (ts, ws) = fine.graph.adj(v);
                let mut cost = 0.0f64;
                for (&t, &wt) in ts.iter().zip(ws) {
                    let pc = coarse.map_down[t as usize] as usize;
                    let center = starts[pc] + coarse.vweight[pc] as usize / 2;
                    cost += wt * f64::from(metric.hops(my, slot_host(center)));
                }
                cost
            };
            let mut fstarts = vec![0usize; fine_n];
            for c in 0..coarse.graph.len() {
                let s = starts[c];
                let a = child_a[c] as usize;
                if child_b[c] == u32::MAX {
                    fstarts[a] = s;
                    continue;
                }
                let b = child_b[c] as usize;
                let wa = fine.vweight[a] as usize;
                let wb = fine.vweight[b] as usize;
                let ab = attract(a, s, wa) + attract(b, s + wa, wb);
                let ba = attract(b, s, wb) + attract(a, s + wb, wa);
                if ba < ab {
                    fstarts[b] = s;
                    fstarts[a] = s + wb;
                } else {
                    fstarts[a] = s;
                    fstarts[b] = s + wa;
                }
            }
            starts = fstarts;
            let ctx = LevelCtx {
                g: &fine.graph,
                vw: &fine.vweight,
                metric,
                slot_host,
                workers,
            };
            self.refine_level(&ctx, &mut starts, li - 1);
        }

        let assignment: Vec<usize> = starts.iter().map(|&s| slot_host(s)).collect();
        Ok(Placement::new(assignment))
    }

    /// KL-style pairwise-swap refinement of one level. Proposals are
    /// computed in parallel against centers frozen at pass start, then
    /// applied serially in ascending vertex order (first-come-first-
    /// served), so the outcome is independent of the worker count.
    fn refine_level<F: Fn(usize) -> usize + Sync>(
        &self,
        ctx: &LevelCtx<'_, F>,
        starts: &mut [usize],
        level: usize,
    ) {
        let n = ctx.g.len();
        if n < 2 {
            return;
        }
        let level_seed = Rng::stream(self.seed, level as u64).next_u64();
        for pass in 0..self.refine_passes {
            let pass_seed = Rng::stream(level_seed, pass as u64).next_u64();
            let host_of: Vec<usize> = (0..n)
                .map(|v| (ctx.slot_host)(starts[v] + ctx.vw[v] as usize / 2))
                .collect();
            let frozen = &host_of;
            let (proposals, _) = run_sharded(n, ctx.workers, |v| {
                self.best_swap(ctx, frozen, pass_seed, v)
            });
            let mut moved = vec![false; n];
            let mut improved = false;
            for (v, prop) in proposals.iter().enumerate() {
                if let Some((u, _gain)) = *prop {
                    let u = u as usize;
                    if moved[v] || moved[u] {
                        continue;
                    }
                    starts.swap(v, u);
                    moved[v] = true;
                    moved[u] = true;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
    }

    /// Best strictly-improving equal-weight swap partner for `v`, or
    /// `None`. Pure in `(frozen state, pass_seed, v)` — safe to evaluate
    /// on any shard.
    fn best_swap<F: Fn(usize) -> usize + Sync>(
        &self,
        ctx: &LevelCtx<'_, F>,
        host_of: &[usize],
        pass_seed: u64,
        v: usize,
    ) -> Option<(u32, f64)> {
        let n = ctx.g.len();
        let (ts, ws) = ctx.g.adj(v);
        let mut cands: Vec<u32> = Vec::with_capacity(self.swap_candidates + self.rand_candidates);
        if self.swap_candidates > 0 {
            let mut pairs: Vec<(f64, u32)> = ts
                .iter()
                .zip(ws)
                .filter(|&(&t, _)| ctx.vw[t as usize] == ctx.vw[v])
                .map(|(&t, &w)| (w, t))
                .collect();
            pairs.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            cands.extend(pairs.iter().take(self.swap_candidates).map(|&(_, t)| t));
        }
        let mut rng = Rng::stream(pass_seed, v as u64);
        for _ in 0..self.rand_candidates {
            let r = rng.below_usize(n);
            if r != v && ctx.vw[r] == ctx.vw[v] {
                cands.push(r as u32);
            }
        }
        let mut best = u32::MAX;
        let mut best_gain = -GAIN_EPS;
        for &u in &cands {
            let d = swap_delta(ctx, host_of, v, u as usize);
            if d < best_gain {
                best = u;
                best_gain = d;
            }
        }
        (best != u32::MAX).then_some((best, best_gain))
    }
}

/// Cost change of exchanging the (equal-length) intervals of `v` and
/// `u`, evaluated against frozen interval-center hosts. The direct
/// `v`–`u` edge keeps its distance under the exchange, so it is skipped.
fn swap_delta<F: Fn(usize) -> usize + Sync>(
    ctx: &LevelCtx<'_, F>,
    host_of: &[usize],
    v: usize,
    u: usize,
) -> f64 {
    let hv = host_of[v];
    let hu = host_of[u];
    if hv == hu {
        return 0.0;
    }
    let mut delta = 0.0f64;
    for (a, b) in [(v, u), (u, v)] {
        let (ha, hb) = (host_of[a], host_of[b]);
        let (ts, ws) = ctx.g.adj(a);
        for (&t, &w) in ts.iter().zip(ws) {
            let t = t as usize;
            if t == b {
                continue;
            }
            let ht = host_of[t];
            delta += w * f64::from(ctx.metric.hops(hb, ht) - ctx.metric.hops(ha, ht));
        }
    }
    delta
}

/// The `w` consecutive entries of ascending `hosts` with the smallest
/// node-id span (ties: leftmost). Locality-preserving ids make id span a
/// metric-free proxy for physical compactness.
fn tightest_window(hosts: &[usize], w: usize) -> &[usize] {
    debug_assert!((1..=hosts.len()).contains(&w));
    let mut best_i = 0;
    let mut best_span = usize::MAX;
    for i in 0..=hosts.len() - w {
        let span = hosts[i + w - 1] - hosts[i];
        if span < best_span {
            best_span = span;
            best_i = i;
        }
    }
    &hosts[best_i..best_i + w]
}

/// Eq. 1-style hop-bytes cost over a sparse comm graph: each undirected
/// edge contributes `weight x hops(assign[u], assign[v])` once. The
/// sparse analogue of [`super::cost::hop_bytes_cost`]; `hops` can close
/// over a dense matrix or a [`HopOracle`].
pub fn hop_bytes_sparse<F: Fn(usize, usize) -> f64>(
    g: &SparseComm,
    assignment: &[usize],
    hops: F,
) -> f64 {
    debug_assert_eq!(g.len(), assignment.len());
    let mut total = 0.0f64;
    for v in 0..g.len() {
        let (ts, ws) = g.adj(v);
        for (&t, &w) in ts.iter().zip(ws) {
            if (t as usize) > v {
                total += w * hops(assignment[v], assignment[t as usize]);
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{MetricMode, Platform, TorusDims};

    fn torus_16() -> Platform {
        Platform::paper_default(TorusDims::new(4, 4, 1))
    }

    #[test]
    fn tightest_window_prefers_the_smallest_id_span() {
        // spans: [0,1,9]=9, [1,9,10]=9, [9,10,11]=2
        let hosts = [0, 1, 9, 10, 11];
        assert_eq!(tightest_window(&hosts, 3), &[9, 10, 11]);
        // ties resolve to the leftmost window
        let hosts = [0, 1, 2, 3];
        assert_eq!(tightest_window(&hosts, 2), &[0, 1]);
        assert_eq!(tightest_window(&hosts, 4), &[0, 1, 2, 3]);
    }

    #[test]
    fn finest_intervals_partition_the_slot_range() {
        let g = SparseComm::stencil2d(4, 4, 10.0);
        let platform = torus_16();
        let hosts: Vec<usize> = (0..16).collect();
        let oracle = platform.hop_oracle();
        let mapper = MultilevelMapper::default();
        let p = mapper.map_sparse(&g, &oracle, &hosts).unwrap();
        p.validate(16).unwrap();
        let mut nodes = p.assignment.clone();
        nodes.sort_unstable();
        assert_eq!(nodes, hosts, "16 ranks on 16 nodes uses every node once");
    }

    #[test]
    fn edgeless_graphs_coarsen_via_forced_pairing() {
        let g = SparseComm::from_edges(64, &[]);
        let mapper = MultilevelMapper::default();
        let levels = mapper.coarsen(&g, 8);
        assert!(levels.last().unwrap().graph.len() <= 8);
        for lvl in &levels {
            assert_eq!(lvl.internal, 0.0);
            assert_eq!(lvl.graph.total_volume(), 0.0);
        }
        // weights still account for every rank
        let last = levels.last().unwrap();
        let total: u64 = last.vweight.iter().map(|&w| u64::from(w)).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn too_few_hosts_is_a_typed_error() {
        let g = SparseComm::ring(8, 1.0);
        let platform = torus_16();
        let oracle = platform.hop_oracle();
        let mapper = MultilevelMapper::default();
        let err = mapper.map_sparse(&g, &oracle, &[0, 1, 2]).unwrap_err();
        assert!(err.to_string().contains("cannot fit"));
    }

    #[test]
    fn oversubscription_packs_within_the_per_node_cap() {
        let g = SparseComm::stencil2d(10, 5, 3.0); // 50 ranks
        let platform = torus_16();
        let hosts: Vec<usize> = (0..16).collect();
        let oracle = platform.hop_oracle();
        let mapper = MultilevelMapper {
            max_per_node: 4,
            ..MultilevelMapper::default()
        };
        let p = mapper.map_sparse(&g, &oracle, &hosts).unwrap();
        let mut counts = vec![0usize; 16];
        for &node in &p.assignment {
            counts[node] += 1;
        }
        assert!(counts.iter().all(|&c| c <= 4));
        assert_eq!(counts.iter().sum::<usize>(), 50);
    }

    #[test]
    fn masked_hosts_are_respected() {
        let g = SparseComm::ring(6, 5.0);
        let platform = torus_16();
        let hosts: Vec<usize> = (0..16).filter(|h| h % 2 == 0).collect();
        let oracle = platform.hop_oracle();
        let mapper = MultilevelMapper::default();
        let p = mapper.map_sparse(&g, &oracle, &hosts).unwrap();
        p.validate(16).unwrap();
        assert!(p.assignment.iter().all(|a| a % 2 == 0));
    }

    #[test]
    fn dense_and_implicit_metrics_place_identically() {
        let g = SparseComm::stencil2d(5, 3, 7.0);
        let dense = torus_16();
        let implicit = torus_16().with_metric(MetricMode::Implicit);
        let hosts: Vec<usize> = (0..16).collect();
        let mapper = MultilevelMapper::default();
        let od = dense.hop_oracle();
        let oi = implicit.hop_oracle();
        let pd = mapper.map_sparse(&g, &od, &hosts).unwrap();
        let pi = mapper.map_sparse(&g, &oi, &hosts).unwrap();
        assert_eq!(pd, pi);
    }

    #[test]
    fn worker_count_does_not_change_the_result() {
        let g = SparseComm::stencil2d(6, 5, 2.0);
        let platform = torus_16();
        let hosts: Vec<usize> = (0..16).collect();
        let oracle = platform.hop_oracle();
        let mapper = MultilevelMapper {
            max_per_node: 2,
            ..MultilevelMapper::default()
        };
        let reference = mapper.map_sparse(&g, &oracle, &hosts).unwrap();
        for workers in [2, 4] {
            let m = MultilevelMapper {
                workers,
                ..mapper.clone()
            };
            assert_eq!(m.map_sparse(&g, &oracle, &hosts).unwrap(), reference);
        }
    }

    #[test]
    fn empty_and_single_rank_jobs() {
        let platform = torus_16();
        let hosts: Vec<usize> = (0..16).collect();
        let oracle = platform.hop_oracle();
        let mapper = MultilevelMapper::default();
        let empty = SparseComm::from_edges(0, &[]);
        let p = mapper.map_sparse(&empty, &oracle, &hosts).unwrap();
        assert!(p.assignment.is_empty());
        let single = SparseComm::from_edges(1, &[]);
        let p = mapper.map_sparse(&single, &oracle, &hosts).unwrap();
        assert_eq!(p.assignment.len(), 1);
        assert!(p.assignment[0] < 16);
    }
}
