//! The paper's comparison placement policies (Section 5.1).

use super::Placement;
use crate::commgraph::CommMatrix;
use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::topology::DistanceMatrix;

/// Slurm's default policy: iterate over available nodes sequentially and
/// assign ranks in order — rank `i` lands on the `i`-th available node.
pub fn block_placement(n_ranks: usize, n_nodes: usize) -> Result<Placement> {
    if n_ranks > n_nodes {
        return Err(Error::Placement(format!(
            "{n_ranks} ranks > {n_nodes} nodes"
        )));
    }
    Ok(Placement::new((0..n_ranks).collect()))
}

/// Block placement over an explicit available-node list (Slurm skips nodes
/// marked DOWN but is otherwise sequential).
pub fn block_placement_avail(n_ranks: usize, avail: &[usize]) -> Result<Placement> {
    if n_ranks > avail.len() {
        return Err(Error::Placement(format!(
            "{n_ranks} ranks > {} available nodes",
            avail.len()
        )));
    }
    Ok(Placement::new(avail[..n_ranks].to_vec()))
}

/// Uniformly random distinct nodes.
pub fn random_placement(n_ranks: usize, n_nodes: usize, rng: &mut Rng) -> Result<Placement> {
    if n_ranks > n_nodes {
        return Err(Error::Placement(format!(
            "{n_ranks} ranks > {n_nodes} nodes"
        )));
    }
    Ok(Placement::new(rng.sample_distinct(n_nodes, n_ranks)))
}

/// The paper's greedy heuristic: sort process pairs by traffic descending;
/// iterate, placing each pair's endpoints as close as possible (starting
/// from one hop).
pub fn greedy_placement(comm: &CommMatrix, dist: &DistanceMatrix) -> Result<Placement> {
    let n = comm.len();
    let m = dist.len();
    if n > m {
        return Err(Error::Placement(format!("{n} ranks > {m} nodes")));
    }
    let mut pairs = comm.edges();
    pairs.sort_by(|a, b| b.2.total_cmp(&a.2));

    let mut assign = vec![usize::MAX; n];
    let mut node_used = vec![false; m];

    let mut nearest_free = |anchor: usize, node_used: &[bool]| -> usize {
        (0..m)
            .filter(|&v| !node_used[v])
            .min_by(|&a, &b| {
                dist.get(anchor, a)
                    .total_cmp(&dist.get(anchor, b))
                    .then(a.cmp(&b))
            })
            // invariant: the n <= m capacity check above guarantees at
            // least one free node whenever a rank is still unplaced
            .expect("free node available by capacity check")
    };

    for (i, j, _) in pairs {
        match (assign[i] == usize::MAX, assign[j] == usize::MAX) {
            (false, false) => {}
            (true, true) => {
                // place i on the first free node, j as close as possible
                // invariant: n <= m (checked on entry) leaves a free node
                // for every unplaced rank
                let a = (0..m).find(|&v| !node_used[v]).unwrap();
                node_used[a] = true;
                assign[i] = a;
                let b = nearest_free(a, &node_used);
                node_used[b] = true;
                assign[j] = b;
            }
            (true, false) => {
                let b = nearest_free(assign[j], &node_used);
                node_used[b] = true;
                assign[i] = b;
            }
            (false, true) => {
                let b = nearest_free(assign[i], &node_used);
                node_used[b] = true;
                assign[j] = b;
            }
        }
    }
    // isolated ranks (no traffic): fill sequentially
    for a in assign.iter_mut() {
        if *a == usize::MAX {
            // invariant: n <= m (checked on entry) leaves a free node
            // for every unplaced rank
            let v = (0..m).find(|&v| !node_used[v]).unwrap();
            node_used[v] = true;
            *a = v;
        }
    }
    Ok(Placement::new(assign))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::cost::hop_bytes_cost;
    use crate::topology::{Torus, TorusDims};

    #[test]
    fn block_is_sequential() {
        let p = block_placement(5, 10).unwrap();
        assert_eq!(p.assignment, vec![0, 1, 2, 3, 4]);
        assert!(block_placement(11, 10).is_err());
    }

    #[test]
    fn block_avail_skips_down_nodes() {
        let avail = vec![0, 2, 3, 7, 9];
        let p = block_placement_avail(3, &avail).unwrap();
        assert_eq!(p.assignment, vec![0, 2, 3]);
    }

    #[test]
    fn random_is_valid_and_seed_deterministic() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = random_placement(20, 64, &mut r1).unwrap();
        let b = random_placement(20, 64, &mut r2).unwrap();
        assert_eq!(a, b);
        a.validate(64).unwrap();
    }

    #[test]
    fn greedy_places_heavy_pair_adjacent() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let d = crate::topology::DistanceMatrix::from_torus_hops(&t);
        let mut c = CommMatrix::new(4);
        c.add_sym(0, 3, 1000.0); // heaviest
        c.add_sym(1, 2, 10.0);
        let p = greedy_placement(&c, &d).unwrap();
        p.validate(64).unwrap();
        assert_eq!(d.get(p.assignment[0], p.assignment[3]), 1.0);
    }

    #[test]
    fn greedy_beats_random_on_clustered() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let d = crate::topology::DistanceMatrix::from_torus_hops(&t);
        let mut c = CommMatrix::new(16);
        for k in 0..8 {
            c.add_sym(2 * k, 2 * k + 1, 500.0);
        }
        let g = greedy_placement(&c, &d).unwrap();
        let mut rng = Rng::new(3);
        let r = random_placement(16, 64, &mut rng).unwrap();
        assert!(
            hop_bytes_cost(&c, &d, &g.assignment) < hop_bytes_cost(&c, &d, &r.assignment)
        );
    }

    #[test]
    fn greedy_handles_zero_traffic() {
        let t = Torus::new(TorusDims::new(2, 2, 2));
        let d = crate::topology::DistanceMatrix::from_torus_hops(&t);
        let c = CommMatrix::new(4);
        let p = greedy_placement(&c, &d).unwrap();
        p.validate(8).unwrap();
    }

    #[test]
    fn oversized_requests_return_typed_errors_not_panics() {
        // regression guard for the panic-policy pass: every baseline must
        // reject ranks > nodes with Error::Placement up front — the
        // in-body unwrap/expect calls rely on that capacity invariant
        assert!(matches!(block_placement(11, 10), Err(Error::Placement(_))));
        assert!(matches!(block_placement_avail(3, &[1, 2]), Err(Error::Placement(_))));
        let mut rng = Rng::new(1);
        assert!(matches!(random_placement(9, 8, &mut rng), Err(Error::Placement(_))));
        let t = Torus::new(TorusDims::new(2, 2, 2));
        let d = crate::topology::DistanceMatrix::from_torus_hops(&t);
        let mut c = CommMatrix::new(9);
        c.add_sym(0, 8, 5.0);
        assert!(matches!(greedy_placement(&c, &d), Err(Error::Placement(_))));
    }
}
