//! Dual recursive bipartitioning mapper (the Scotch-substitute).
//!
//! Scotch maps a guest graph onto an architecture by recursively splitting
//! both at once: the architecture is bisected, the guest graph is bisected
//! with part sizes matching the two architecture halves' capacities, and
//! each guest part recurses into its architecture half. We implement the
//! same scheme over a dense host distance matrix (which is how the paper's
//! fault-aware weights (Eq. 1) are expressed) and finish with a
//! Kernighan–Lin refinement sweep over the complete mapping.

use super::bisect::bisect;
use super::kl::refine;
use super::Placement;
use crate::commgraph::CommMatrix;
use crate::error::{Error, Result};
use crate::topology::DistanceMatrix;

/// Configurable recursive mapper.
#[derive(Debug, Clone)]
pub struct RecursiveMapper {
    /// Run the final KL refinement sweep (on by default).
    pub refine: bool,
    /// Maximum KL refinement passes.
    pub refine_passes: usize,
}

impl Default for RecursiveMapper {
    fn default() -> Self {
        RecursiveMapper {
            refine: true,
            refine_passes: 12,
        }
    }
}

impl RecursiveMapper {
    /// Map all `comm.len()` guest vertices onto distinct hosts
    /// `0..dist.len()` (requires `comm.len() <= dist.len()`).
    pub fn map(&self, comm: &CommMatrix, dist: &DistanceMatrix) -> Result<Placement> {
        let hosts: Vec<usize> = (0..dist.len()).collect();
        self.map_onto(comm, dist, &hosts)
    }

    /// Map onto an explicit host subset (the `ScotchExtract` + `ScotchMap`
    /// path of TOFA's Listing 1.1).
    ///
    /// When the job is smaller than the host set, a *compact allocation* of
    /// exactly `n` hosts is carved out first by greedy region growing
    /// (lowest total distance to the growing region). This mirrors what a
    /// resource manager does before rank mapping, and — because the growth
    /// criterion reads the (possibly Eq.-1-inflated) distance matrix —
    /// flaky nodes are naturally excluded on the fault-weighted path.
    pub fn map_onto(
        &self,
        comm: &CommMatrix,
        dist: &DistanceMatrix,
        hosts: &[usize],
    ) -> Result<Placement> {
        let n = comm.len();
        if n > hosts.len() {
            return Err(Error::Placement(format!(
                "{n} ranks cannot fit {} hosts (one process per node)",
                hosts.len()
            )));
        }
        let region;
        let hosts = if n < hosts.len() {
            region = compact_subset(dist, hosts, n);
            &region[..]
        } else {
            hosts
        };
        let mut assignment = vec![usize::MAX; n];
        let verts: Vec<usize> = (0..n).collect();
        self.recurse(comm, dist, &verts, hosts, &mut assignment);
        debug_assert!(assignment.iter().all(|&a| a != usize::MAX));

        if self.refine && n >= 2 {
            refine(comm, dist, &mut assignment, hosts, self.refine_passes);
        }
        Ok(Placement::new(assignment))
    }

    fn recurse(
        &self,
        comm: &CommMatrix,
        dist: &DistanceMatrix,
        verts: &[usize],
        hosts: &[usize],
        assignment: &mut [usize],
    ) {
        match (verts.len(), hosts.len()) {
            (0, _) => {}
            // invariant: recursion splits guests proportionally to host
            // capacities (t0 <= h0.len(), nv - t0 <= h1.len()), so a
            // non-empty guest part always receives a non-empty host part
            (_, 0) => unreachable!("capacity invariant violated"),
            (_, 1) => {
                debug_assert_eq!(verts.len(), 1);
                assignment[verts[0]] = hosts[0];
            }
            (1, _) => {
                // single vertex: pick the host closest to the subset's
                // "centre" (min total distance to the other hosts) so deep
                // recursion tails stay compact.
                let best = *hosts
                    .iter()
                    .min_by(|&&a, &&b| {
                        let da: f32 = hosts.iter().map(|&h| dist.get(a, h)).sum();
                        let db: f32 = hosts.iter().map(|&h| dist.get(b, h)).sum();
                        da.total_cmp(&db)
                    })
                    // invariant: this match arm requires hosts.len() >= 2
                    .unwrap();
                assignment[verts[0]] = best;
            }
            (nv, nh) => {
                let (h0, h1) = split_hosts(dist, hosts);
                // guest part sizes proportional to host capacities, clamped
                // so each side fits its half.
                let ideal = (nv as f64 * h0.len() as f64 / nh as f64).round() as usize;
                let min0 = nv.saturating_sub(h1.len());
                let t0 = ideal.clamp(min0, h0.len().min(nv));
                let b = bisect(comm, verts, t0);
                let g0: Vec<usize> = b.part0.iter().map(|&i| verts[i]).collect();
                let g1: Vec<usize> = b.part1.iter().map(|&i| verts[i]).collect();
                self.recurse(comm, dist, &g0, &h0, assignment);
                self.recurse(comm, dist, &g1, &h1, assignment);
            }
        }
    }
}

/// Greedily grow a compact region of `k` hosts: seed at the host with the
/// lowest total distance to all hosts (the centre of the available set),
/// then repeatedly absorb the free host with the lowest total distance to
/// the region. O(k * |hosts|) with incremental totals.
pub fn compact_subset(dist: &DistanceMatrix, hosts: &[usize], k: usize) -> Vec<usize> {
    debug_assert!(k <= hosts.len());
    if k == hosts.len() {
        return hosts.to_vec();
    }
    let seed = *hosts
        .iter()
        .min_by(|&&a, &&b| {
            let da: f32 = hosts.iter().map(|&h| dist.get(a, h)).sum();
            let db: f32 = hosts.iter().map(|&h| dist.get(b, h)).sum();
            da.total_cmp(&db).then(a.cmp(&b))
        })
        // invariant: k < hosts.len() here and k >= 0, so hosts is non-empty
        .unwrap();
    let mut region = vec![seed];
    // total distance from each free host to the region
    let mut to_region: Vec<(usize, f32)> = hosts
        .iter()
        .filter(|&&h| h != seed)
        .map(|&h| (h, dist.get(h, seed)))
        .collect();
    while region.len() < k {
        let (idx, _) = to_region
            .iter()
            .enumerate()
            .min_by(|(_, (ha, da)), (_, (hb, db))| da.total_cmp(db).then(ha.cmp(hb)))
            // invariant: region.len() < k <= hosts.len(), so at least one
            // free host remains in to_region
            .unwrap();
        let (h, _) = to_region.swap_remove(idx);
        for (f, d) in to_region.iter_mut() {
            *d += dist.get(*f, h);
        }
        region.push(h);
    }
    region.sort_unstable();
    region
}

/// Bisect a host subset by distance geometry: seed with the two mutually
/// farthest hosts, then greedily assign each host to the seed it is closer
/// to, balancing sizes (|h0| = ceil(h/2)).
///
/// On a fault-weighted matrix (Eq. 1) paths through flaky nodes look ~100x
/// longer, so this split naturally quarantines flaky regions into one side.
fn split_hosts(dist: &DistanceMatrix, hosts: &[usize]) -> (Vec<usize>, Vec<usize>) {
    let h = hosts.len();
    debug_assert!(h >= 2);
    // farthest pair (O(h^2), h <= 512)
    let (mut sa, mut sb, mut best) = (hosts[0], hosts[1], -1.0f32);
    for (i, &a) in hosts.iter().enumerate() {
        for &b in &hosts[i + 1..] {
            let d = dist.get(a, b);
            if d > best {
                best = d;
                sa = a;
                sb = b;
            }
        }
    }
    // order hosts by (d(x, sa) - d(x, sb)): most-sa-side first
    let mut order: Vec<usize> = hosts.to_vec();
    order.sort_by(|&x, &y| {
        let kx = dist.get(x, sa) - dist.get(x, sb);
        let ky = dist.get(y, sa) - dist.get(y, sb);
        kx.total_cmp(&ky).then(x.cmp(&y))
    });
    let half = h.div_ceil(2);
    let h0 = order[..half].to_vec();
    let h1 = order[half..].to_vec();
    (h0, h1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::cost::hop_bytes_cost;
    use crate::topology::{Torus, TorusDims};

    fn ring_comm(n: usize) -> CommMatrix {
        let mut c = CommMatrix::new(n);
        for i in 0..n {
            c.add_sym(i, (i + 1) % n, 100.0);
        }
        c
    }

    #[test]
    fn maps_are_valid_placements() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let d = DistanceMatrix::from_torus_hops(&t);
        for n in [2usize, 7, 16, 31, 64] {
            let c = ring_comm(n);
            let p = RecursiveMapper::default().map(&c, &d).unwrap();
            p.validate(64).unwrap();
            assert_eq!(p.num_ranks(), n);
        }
    }

    #[test]
    fn beats_random_on_ring() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let d = DistanceMatrix::from_torus_hops(&t);
        let c = ring_comm(32);
        let p = RecursiveMapper::default().map(&c, &d).unwrap();
        let mapped = hop_bytes_cost(&c, &d, &p.assignment);

        let mut rng = crate::rng::Rng::new(1);
        let mut rand_costs = Vec::new();
        for _ in 0..20 {
            let r = crate::mapping::baselines::random_placement(32, 64, &mut rng).unwrap();
            rand_costs.push(hop_bytes_cost(&c, &d, &r.assignment));
        }
        let rand_avg: f64 = rand_costs.iter().sum::<f64>() / rand_costs.len() as f64;
        assert!(
            mapped < 0.7 * rand_avg,
            "mapper {mapped} vs random avg {rand_avg}"
        );
    }

    #[test]
    fn clique_pairs_land_adjacent() {
        // 4 heavy pairs: each pair should sit on adjacent nodes.
        let mut c = CommMatrix::new(8);
        for k in 0..4 {
            c.add_sym(2 * k, 2 * k + 1, 1000.0);
        }
        let t = Torus::new(TorusDims::new(4, 4, 1));
        let d = DistanceMatrix::from_torus_hops(&t);
        let p = RecursiveMapper::default().map(&c, &d).unwrap();
        for k in 0..4 {
            let dist = d.get(p.assignment[2 * k], p.assignment[2 * k + 1]);
            assert!(dist <= 2.0, "pair {k} at distance {dist}");
        }
    }

    #[test]
    fn map_onto_subset_uses_only_subset() {
        let t = Torus::new(TorusDims::new(4, 4, 4));
        let d = DistanceMatrix::from_torus_hops(&t);
        let c = ring_comm(6);
        let hosts: Vec<usize> = (10..26).collect();
        let p = RecursiveMapper::default()
            .map_onto(&c, &d, &hosts)
            .unwrap();
        for &a in &p.assignment {
            assert!(hosts.contains(&a));
        }
    }

    #[test]
    fn too_many_ranks_errors() {
        let t = Torus::new(TorusDims::new(2, 2, 1));
        let d = DistanceMatrix::from_torus_hops(&t);
        let c = ring_comm(5);
        assert!(RecursiveMapper::default().map(&c, &d).is_err());
    }

    #[test]
    fn split_hosts_balanced() {
        let t = Torus::new(TorusDims::new(4, 4, 2));
        let d = DistanceMatrix::from_torus_hops(&t);
        let hosts: Vec<usize> = (0..32).collect();
        let (h0, h1) = split_hosts(&d, &hosts);
        assert_eq!(h0.len(), 16);
        assert_eq!(h1.len(), 16);
        // disjoint, covering
        let mut all: Vec<usize> = h0.iter().chain(h1.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, hosts);
    }
}
