//! Mapping quality metrics: hop-bytes cost, dilation, congestion.
//!
//! `hop_bytes_cost` is the objective both the mapper and the PJRT-offloaded
//! L1 kernel compute; the Rust implementation here is the scalar reference
//! the runtime tests cross-check against.

use crate::commgraph::CommMatrix;
use crate::topology::{DistanceMatrix, Topology};

/// Hop-bytes objective: `1/2 * sum_{i,j} C[i,j] * D[a_i, a_j]`.
pub fn hop_bytes_cost(comm: &CommMatrix, dist: &DistanceMatrix, assign: &[usize]) -> f64 {
    debug_assert_eq!(comm.len(), assign.len());
    let n = comm.len();
    let mut total = 0.0;
    for i in 0..n {
        let row = comm.row(i);
        let di = dist.row(assign[i]);
        let mut acc = 0.0;
        for j in 0..n {
            acc += row[j] * di[assign[j]] as f64;
        }
        total += acc;
    }
    0.5 * total
}

/// Per-vertex contributions `contrib[i] = sum_j C[i,j] * D[a_i, a_j]`
/// (total cost = contrib.sum() / 2). Mirrors the L1 `vertex_cost` kernel.
pub fn vertex_contributions(
    comm: &CommMatrix,
    dist: &DistanceMatrix,
    assign: &[usize],
) -> Vec<f64> {
    let n = comm.len();
    (0..n)
        .map(|i| {
            let row = comm.row(i);
            let di = dist.row(assign[i]);
            (0..n).map(|j| row[j] * di[assign[j]] as f64).sum()
        })
        .collect()
}

/// Dilation statistics: average and maximum hop distance over communicating
/// pairs, weighted (avg) by traffic.
pub fn dilation(comm: &CommMatrix, dist: &DistanceMatrix, assign: &[usize]) -> (f64, f64) {
    let mut weighted = 0.0;
    let mut weight = 0.0;
    let mut max_d = 0.0f64;
    for (i, j, w) in comm.edges() {
        let d = dist.get(assign[i], assign[j]) as f64;
        weighted += w * d;
        weight += w;
        max_d = max_d.max(d);
    }
    // detlint: allow(float-discipline, exact 0.0 guard against division, not a comparison)
    if weight == 0.0 {
        (0.0, 0.0)
    } else {
        (weighted / weight, max_d)
    }
}

/// Maximum per-link traffic (congestion) when every pair's traffic follows
/// the topology's fixed route. Returns (max link bytes, mean link bytes
/// over used links).
pub fn congestion(comm: &CommMatrix, topo: &dyn Topology, assign: &[usize]) -> (f64, f64) {
    let (index, num_links) = topo.link_index();
    let n_vertices = topo.num_vertices();
    let mut load = vec![0.0f64; num_links];
    let mut route = Vec::new();
    for (i, j, w) in comm.edges() {
        topo.route_into(assign[i], assign[j], &mut route);
        for l in &route {
            load[index[l.src * n_vertices + l.dst] as usize] += w;
        }
    }
    let max = load.iter().cloned().fold(0.0, f64::max);
    let used: Vec<f64> = load.iter().cloned().filter(|&x| x > 0.0).collect();
    let mean = if used.is_empty() {
        0.0
    } else {
        used.iter().sum::<f64>() / used.len() as f64
    };
    (max, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Torus, TorusDims};

    fn tiny() -> (CommMatrix, DistanceMatrix) {
        let mut c = CommMatrix::new(3);
        c.add_sym(0, 1, 10.0);
        c.add_sym(1, 2, 5.0);
        let t = Torus::new(TorusDims::new(4, 1, 1));
        (c, DistanceMatrix::from_torus_hops(&t))
    }

    #[test]
    fn hop_bytes_hand_computed() {
        let (c, d) = tiny();
        // nodes 0,1,2 in a 4-ring: d(0,1)=1, d(1,2)=1, d(0,2)=2
        let cost = hop_bytes_cost(&c, &d, &[0, 1, 2]);
        assert_eq!(cost, 10.0 + 5.0);
        // spread out: 0 -> 0, 1 -> 2, 2 -> 1
        let cost2 = hop_bytes_cost(&c, &d, &[0, 2, 1]);
        assert_eq!(cost2, 10.0 * 2.0 + 5.0);
    }

    #[test]
    fn contributions_sum_to_twice_cost() {
        let (c, d) = tiny();
        let a = vec![0, 1, 3];
        let contribs = vertex_contributions(&c, &d, &a);
        let sum: f64 = contribs.iter().sum();
        assert!((sum / 2.0 - hop_bytes_cost(&c, &d, &a)).abs() < 1e-9);
    }

    #[test]
    fn dilation_stats() {
        let (c, d) = tiny();
        let (avg, max) = dilation(&c, &d, &[0, 1, 3]);
        // d(0,1)=1 w=10; d(1,3)=2 w=5
        assert!((avg - (10.0 + 10.0) / 15.0).abs() < 1e-9);
        assert_eq!(max, 2.0);
    }

    #[test]
    fn congestion_counts_route_overlap() {
        let torus = Torus::new(TorusDims::new(4, 1, 1));
        let mut c = CommMatrix::new(2);
        c.add_sym(0, 1, 100.0);
        // ranks on nodes 0 and 2: route 0->1->2 loads two links
        let (max, mean) = congestion(&c, &torus, &[0, 2]);
        assert_eq!(max, 100.0);
        assert!(mean > 0.0);
    }
}
