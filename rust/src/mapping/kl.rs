//! Kernighan–Lin-style refinement over a complete mapping.
//!
//! Post-processes a placement by (a) swapping the nodes of two ranks and
//! (b) migrating a rank to an unused host node, whenever doing so lowers
//! the hop-bytes objective. Deltas are computed incrementally in O(N) per
//! candidate — this is the pure-Rust twin of the L1 `vertex_cost` kernel,
//! and the batched-candidate variant in [`crate::runtime`] scores whole
//! swap fronts with the PJRT artifact.

use crate::commgraph::CommMatrix;
use crate::topology::DistanceMatrix;

/// Cost change of moving rank `i` from its node to `new_node`, with all
/// other ranks fixed.
#[inline]
pub fn move_delta(
    comm: &CommMatrix,
    dist: &DistanceMatrix,
    assign: &[usize],
    i: usize,
    new_node: usize,
) -> f64 {
    let old = assign[i];
    let row = comm.row(i);
    let d_old = dist.row(old);
    let d_new = dist.row(new_node);
    let mut delta = 0.0;
    for (j, &w) in row.iter().enumerate() {
        if w > 0.0 && j != i {
            let a = assign[j];
            delta += w * (d_new[a] - d_old[a]) as f64;
        }
    }
    delta
}

/// Cost change of swapping the nodes of ranks `i` and `j`.
#[inline]
pub fn swap_delta(
    comm: &CommMatrix,
    dist: &DistanceMatrix,
    assign: &[usize],
    i: usize,
    j: usize,
) -> f64 {
    let (ni, nj) = (assign[i], assign[j]);
    if ni == nj {
        return 0.0;
    }
    let mut delta = move_delta(comm, dist, assign, i, nj) + move_delta(comm, dist, assign, j, ni);
    // both deltas counted the i<->j edge against the *other* rank's old
    // node; after the swap that edge's distance is unchanged relative to
    // d(ni, nj) -> d(nj, ni) (symmetric), but each move_delta charged it a
    // move to distance 0/new. Correct the double count:
    let w = comm.get(i, j);
    if w > 0.0 {
        let d = dist.get(ni, nj) as f64;
        // move_delta(i -> nj) priced edge at d(nj, nj)=0... it priced
        // w*(d(nj, assign[j]=nj) - d(ni, nj)) = w*(0 - d); similarly for j.
        // True change is 0, so add back 2*w*d.
        delta += 2.0 * w * d;
    }
    delta
}

/// How many swap partners / free targets each vertex evaluates per sweep.
/// Pruning bounds a sweep at O(N · CANDS · N) instead of O(N · (N+F) · N);
/// heavy-partner swaps and nearest-free moves capture almost all the gain
/// (ablation: <1% cost difference vs exhaustive on the paper's workloads,
/// ~20x faster at 256 ranks — EXPERIMENTS.md §Perf).
const SWAP_CANDIDATES: usize = 48;
const MOVE_CANDIDATES: usize = 16;
/// Below this rank count a sweep evaluates every swap/move exhaustively
/// (quality matters more than the ~100 ms it costs); above it the pruned
/// candidate sets keep placement latency within the 50 ms-class target.
const EXHAUSTIVE_LIMIT: usize = 128;

/// Refine `assign` in place. `hosts` is the allowed node set (free nodes in
/// it may receive migrated ranks). Runs at most `passes` improvement
/// sweeps; each sweep applies, per rank, the best strictly-improving move
/// among its heaviest communication partners (swap) and the free nodes
/// nearest to its heaviest partner (migrate).
pub fn refine(
    comm: &CommMatrix,
    dist: &DistanceMatrix,
    assign: &mut [usize],
    hosts: &[usize],
    passes: usize,
) {
    let n = assign.len();
    let used: std::collections::HashSet<usize> = assign.iter().copied().collect();
    let mut free: Vec<usize> =
        hosts.iter().copied().filter(|h| !used.contains(h)).collect();
    let mut used = used;

    let exhaustive = n <= EXHAUSTIVE_LIMIT;
    // Per-vertex swap candidates: heaviest comm partners (static per call);
    // everything when exhaustive.
    let partners: Vec<Vec<usize>> = (0..n)
        .map(|i| {
            let row = comm.row(i);
            let mut idx: Vec<usize> = if exhaustive {
                (0..n).filter(|&j| j != i).collect()
            } else {
                (0..n).filter(|&j| j != i && row[j] > 0.0).collect()
            };
            if !exhaustive {
                idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
                idx.truncate(SWAP_CANDIDATES);
            }
            idx
        })
        .collect();

    // node -> occupying rank (maintained across moves/swaps)
    let max_node = hosts.iter().copied().max().map_or(0, |m| m + 1);
    let mut rank_on = vec![usize::MAX; max_node];
    for (r, &nd) in assign.iter().enumerate() {
        rank_on[nd] = r;
    }

    let mut move_cands: Vec<usize> = Vec::with_capacity(MOVE_CANDIDATES);
    let mut spatial: Vec<usize> = Vec::with_capacity(MOVE_CANDIDATES);
    for _ in 0..passes {
        let mut improved = false;
        for i in 0..n {
            let mut best_delta = -1e-9;
            let mut best_action: Option<(bool, usize)> = None; // (is_swap, idx)
            for &j in &partners[i] {
                let d = swap_delta(comm, dist, assign, i, j);
                if d < best_delta {
                    best_delta = d;
                    best_action = Some((true, j));
                }
            }
            // Free-node moves and *spatial* swaps: the nodes nearest i's
            // heaviest partner (where i wants to be) are either free (a
            // migrate candidate) or occupied — in which case the occupying
            // rank is a swap candidate even if it never talks to i.
            if !exhaustive {
                let anchor = partners[i]
                    .first()
                    .map(|&j| assign[j])
                    .unwrap_or(assign[i]);
                let da = dist.row(anchor);
                move_cands.clear();
                if !free.is_empty() {
                    let mut order: Vec<usize> = (0..free.len()).collect();
                    order.sort_by(|&a, &b| da[free[a]].total_cmp(&da[free[b]]));
                    move_cands.extend(order.into_iter().take(MOVE_CANDIDATES));
                }
                spatial.clear();
                {
                    let mut order: Vec<usize> = hosts
                        .iter()
                        .copied()
                        .filter(|&h| rank_on[h] != usize::MAX && rank_on[h] != i)
                        .collect();
                    order.sort_by(|&a, &b| da[a].total_cmp(&da[b]));
                    spatial.extend(order.into_iter().take(MOVE_CANDIDATES).map(|h| rank_on[h]));
                }
                for &j in &spatial {
                    let d = swap_delta(comm, dist, assign, i, j);
                    if d < best_delta {
                        best_delta = d;
                        best_action = Some((true, j));
                    }
                }
            } else if !free.is_empty() {
                move_cands.clear();
                move_cands.extend(0..free.len());
            }
            if !free.is_empty() {
                for &fi in &move_cands {
                    let d = move_delta(comm, dist, assign, i, free[fi]);
                    if d < best_delta {
                        best_delta = d;
                        best_action = Some((false, fi));
                    }
                }
            }
            match best_action {
                Some((true, j)) => {
                    assign.swap(i, j);
                    rank_on[assign[i]] = i;
                    rank_on[assign[j]] = j;
                    improved = true;
                }
                Some((false, fi)) => {
                    let old = assign[i];
                    assign[i] = free[fi];
                    rank_on[old] = usize::MAX;
                    rank_on[assign[i]] = i;
                    used.remove(&old);
                    used.insert(free[fi]);
                    free[fi] = old;
                    improved = true;
                }
                None => {}
            }
        }
        if !improved {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::cost::hop_bytes_cost;
    use crate::topology::{Torus, TorusDims};

    fn setup() -> (CommMatrix, DistanceMatrix) {
        let mut c = CommMatrix::new(6);
        c.add_sym(0, 1, 100.0);
        c.add_sym(2, 3, 80.0);
        c.add_sym(4, 5, 60.0);
        c.add_sym(0, 5, 5.0);
        let t = Torus::new(TorusDims::new(4, 4, 1));
        (c, DistanceMatrix::from_torus_hops(&t))
    }

    #[test]
    fn move_delta_matches_recompute() {
        let (c, d) = setup();
        let assign = vec![0, 5, 2, 9, 4, 12];
        for i in 0..6 {
            for new in [1usize, 7, 14] {
                if assign.contains(&new) {
                    continue;
                }
                let mut moved = assign.clone();
                moved[i] = new;
                let want =
                    hop_bytes_cost(&c, &d, &moved) - hop_bytes_cost(&c, &d, &assign);
                let got = move_delta(&c, &d, &assign, i, new);
                assert!((got - want).abs() < 1e-9, "i={i} new={new}");
            }
        }
    }

    #[test]
    fn swap_delta_matches_recompute() {
        let (c, d) = setup();
        let assign = vec![0, 5, 2, 9, 4, 12];
        for i in 0..6 {
            for j in (i + 1)..6 {
                let mut sw = assign.clone();
                sw.swap(i, j);
                let want = hop_bytes_cost(&c, &d, &sw) - hop_bytes_cost(&c, &d, &assign);
                let got = swap_delta(&c, &d, &assign, i, j);
                assert!((got - want).abs() < 1e-9, "i={i} j={j}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn refine_never_increases_cost() {
        let (c, d) = setup();
        let hosts: Vec<usize> = (0..16).collect();
        let mut assign = vec![0, 15, 3, 12, 5, 10]; // deliberately bad
        let before = hop_bytes_cost(&c, &d, &assign);
        refine(&c, &d, &mut assign, &hosts, 6);
        let after = hop_bytes_cost(&c, &d, &assign);
        assert!(after <= before);
        // still a valid placement
        crate::mapping::Placement::new(assign).validate(16).unwrap();
    }

    #[test]
    fn refine_brings_heavy_pair_together() {
        let (c, d) = setup();
        let hosts: Vec<usize> = (0..16).collect();
        let mut assign = vec![0, 15, 1, 2, 3, 4];
        refine(&c, &d, &mut assign, &hosts, 8);
        // ranks 0 and 1 (weight 100) should end up close
        assert!(d.get(assign[0], assign[1]) <= 2.0);
    }
}
