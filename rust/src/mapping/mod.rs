//! Graph mapping: assigning guest (process) graphs onto host (platform)
//! graphs.
//!
//! This is the Scotch-substitute substrate (the paper delegates the actual
//! mapping problem to the Scotch library's dual recursive bipartitioning).
//! [`recmap::RecursiveMapper`] implements the same algorithm family:
//! simultaneous recursive bisection of the guest communication graph and
//! the host architecture, followed by a Kernighan–Lin-style refinement
//! sweep ([`kl`]). [`baselines`] provides the paper's comparison policies
//! (default-slurm block placement, random, greedy).

pub mod baselines;
pub mod bisect;
pub mod cost;
pub mod kl;
pub mod multilevel;
pub mod recmap;

use crate::commgraph::CommMatrix;
use crate::error::Result;
use crate::rng::Rng;
use crate::topology::DistanceMatrix;

/// A process -> node assignment. `assignment[rank] = node id`.
///
/// One process per node (the paper's setting); the invariant that all
/// assigned nodes are distinct is checked by [`Placement::validate`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// `assignment[rank]` is the platform node hosting `rank`.
    pub assignment: Vec<usize>,
}

impl Placement {
    /// Wrap an assignment vector.
    pub fn new(assignment: Vec<usize>) -> Self {
        Placement { assignment }
    }

    /// Ranks placed.
    pub fn num_ranks(&self) -> usize {
        self.assignment.len()
    }

    /// Check the one-process-per-node invariant and node-id bounds.
    pub fn validate(&self, num_nodes: usize) -> Result<()> {
        use crate::error::Error;
        let mut seen = vec![false; num_nodes];
        for (rank, &node) in self.assignment.iter().enumerate() {
            if node >= num_nodes {
                return Err(Error::Placement(format!(
                    "rank {rank} assigned to node {node} >= {num_nodes}"
                )));
            }
            if seen[node] {
                return Err(Error::Placement(format!(
                    "node {node} assigned to more than one rank"
                )));
            }
            seen[node] = true;
        }
        Ok(())
    }
}

/// The placement policies compared in the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Slurm's default sequential block placement.
    DefaultSlurm,
    /// Uniform random node choice.
    Random,
    /// Heaviest-pair-first greedy (Section 5.1).
    Greedy,
    /// Scotch-style recursive bipartitioning (topology-aware, not
    /// fault-aware).
    Scotch,
    /// Full TOFA: topology + fault aware (Listing 1.1).
    Tofa,
    /// Post-paper: multilevel coarsen–map–refine mapping
    /// ([`multilevel::MultilevelMapper`]), near-linear in graph size.
    Multilevel,
}

impl PlacementPolicy {
    /// Parse a CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "default" | "default-slurm" | "slurm" | "block" => Some(Self::DefaultSlurm),
            "random" => Some(Self::Random),
            "greedy" => Some(Self::Greedy),
            "scotch" => Some(Self::Scotch),
            "tofa" => Some(Self::Tofa),
            "multilevel" | "ml" => Some(Self::Multilevel),
            _ => None,
        }
    }

    /// All policies, in the paper's Figure 3 order.
    pub fn all() -> [PlacementPolicy; 5] {
        [
            Self::DefaultSlurm,
            Self::Random,
            Self::Greedy,
            Self::Scotch,
            Self::Tofa,
        ]
    }

    /// The paper's five plus the post-paper multilevel mapper.
    pub fn extended() -> [PlacementPolicy; 6] {
        [
            Self::DefaultSlurm,
            Self::Random,
            Self::Greedy,
            Self::Scotch,
            Self::Tofa,
            Self::Multilevel,
        ]
    }
}

impl std::fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::DefaultSlurm => "default-slurm",
            Self::Random => "random",
            Self::Greedy => "greedy",
            Self::Scotch => "scotch",
            Self::Tofa => "tofa",
            Self::Multilevel => "multilevel",
        };
        // f.pad honours width/alignment flags ({:<16} etc. in reports)
        f.pad(s)
    }
}

/// Place `comm` onto nodes with distance matrix `dist` using `policy`.
/// Fault-unaware entry point (used by Section 5.1 experiments); TOFA
/// placement lives in [`crate::tofa::placer`].
pub fn place(
    policy: PlacementPolicy,
    comm: &CommMatrix,
    dist: &DistanceMatrix,
    rng: &mut Rng,
) -> Result<Placement> {
    let n = comm.len();
    let m = dist.len();
    match policy {
        PlacementPolicy::DefaultSlurm => baselines::block_placement(n, m),
        PlacementPolicy::Random => baselines::random_placement(n, m, rng),
        PlacementPolicy::Greedy => baselines::greedy_placement(comm, dist),
        PlacementPolicy::Scotch | PlacementPolicy::Tofa => {
            recmap::RecursiveMapper::default().map(comm, dist)
        }
        PlacementPolicy::Multilevel => multilevel::MultilevelMapper::default().map(comm, dist),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_duplicates_and_bounds() {
        assert!(Placement::new(vec![0, 1, 2]).validate(4).is_ok());
        assert!(Placement::new(vec![0, 0]).validate(4).is_err());
        assert!(Placement::new(vec![5]).validate(4).is_err());
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(
            PlacementPolicy::parse("TOFA"),
            Some(PlacementPolicy::Tofa)
        );
        assert_eq!(
            PlacementPolicy::parse("default-slurm"),
            Some(PlacementPolicy::DefaultSlurm)
        );
        assert_eq!(PlacementPolicy::parse("bogus"), None);
        let ml = Some(PlacementPolicy::Multilevel);
        assert_eq!(PlacementPolicy::parse("multilevel"), ml);
        assert_eq!(PlacementPolicy::parse("ml"), ml);
        assert_eq!(PlacementPolicy::Multilevel.to_string(), "multilevel");
    }

    #[test]
    fn extended_is_all_plus_multilevel() {
        let all = PlacementPolicy::all();
        let ext = PlacementPolicy::extended();
        assert_eq!(&ext[..all.len()], &all[..]);
        assert_eq!(ext[all.len()], PlacementPolicy::Multilevel);
    }
}
