//! Weighted graph bisection with exact part sizes.
//!
//! Building block of the dual recursive bipartitioning mapper: split a set
//! of guest vertices into two parts of prescribed sizes while minimizing
//! the cut weight. Initialization is greedy graph growing (seeded from a
//! heavy vertex); refinement is a Kernighan–Lin swap pass, which preserves
//! the exact part sizes required by host-capacity constraints (classic FM
//! single moves would drift the sizes).

use crate::commgraph::CommMatrix;

/// Result of a bisection: vertex index lists for part 0 and part 1
/// (indices into the `verts` slice handed to [`bisect`]).
#[derive(Debug, Clone)]
pub struct Bisection {
    pub part0: Vec<usize>,
    pub part1: Vec<usize>,
    pub cut: f64,
}

/// Split `verts` (global vertex ids into `comm`) into parts of exactly
/// `target0` and `verts.len() - target0` vertices, minimizing the weight of
/// edges crossing the cut.
pub fn bisect(comm: &CommMatrix, verts: &[usize], target0: usize) -> Bisection {
    let n = verts.len();
    assert!(target0 <= n);
    if target0 == 0 || target0 == n {
        let all: Vec<usize> = verts.to_vec();
        return Bisection {
            part0: if target0 == 0 { Vec::new() } else { all.clone() },
            part1: if target0 == 0 { all } else { Vec::new() },
            cut: 0.0,
        };
    }

    // --- greedy graph growing ---------------------------------------
    // Seed part0 with the heaviest-degree vertex, then repeatedly absorb
    // the outside vertex with the largest connection into part0.
    let weight_between = |a: usize, b: usize| comm.get(verts[a], verts[b]);

    let seed = (0..n)
        .max_by(|&a, &b| {
            let wa: f64 = (0..n).map(|j| weight_between(a, j)).sum();
            let wb: f64 = (0..n).map(|j| weight_between(b, j)).sum();
            wa.total_cmp(&wb)
        })
        // invariant: 0 < target0 < n (early return above), so n >= 1 and
        // the range is non-empty
        .unwrap();

    let mut in0 = vec![false; n];
    in0[seed] = true;
    let mut gain_to0: Vec<f64> = (0..n).map(|i| weight_between(i, seed)).collect();
    let mut size0 = 1;
    while size0 < target0 {
        let next = (0..n)
            .filter(|&i| !in0[i])
            .max_by(|&a, &b| gain_to0[a].total_cmp(&gain_to0[b]))
            // invariant: size0 < target0 < n, so at least one vertex is
            // still outside part0
            .unwrap();
        in0[next] = true;
        size0 += 1;
        for i in 0..n {
            if !in0[i] {
                gain_to0[i] += weight_between(i, next);
            }
        }
    }

    // --- KL swap refinement ------------------------------------------
    // external - internal connectivity per vertex; a swap (u in 0, v in 1)
    // improves the cut by gain(u) + gain(v) - 2 w(u, v).
    let mut ext = vec![0.0f64; n];
    let mut int = vec![0.0f64; n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let w = weight_between(i, j);
            if in0[i] == in0[j] {
                int[i] += w;
            } else {
                ext[i] += w;
            }
        }
    }

    const MAX_PASSES: usize = 8;
    for _ in 0..MAX_PASSES {
        let mut best_gain = 1e-12;
        let mut best_pair: Option<(usize, usize)> = None;
        for u in 0..n {
            if !in0[u] {
                continue;
            }
            let gu = ext[u] - int[u];
            for v in 0..n {
                if in0[v] {
                    continue;
                }
                let gain = gu + (ext[v] - int[v]) - 2.0 * weight_between(u, v);
                if gain > best_gain {
                    best_gain = gain;
                    best_pair = Some((u, v));
                }
            }
        }
        let Some((u, v)) = best_pair else { break };
        // swap u <-> v and update ext/int incrementally
        in0[u] = false;
        in0[v] = true;
        for i in 0..n {
            if i == u || i == v {
                continue;
            }
            let wu = weight_between(i, u);
            let wv = weight_between(i, v);
            // u left part0: edges i-u flip category relative to i's side
            if in0[i] {
                // i in part0: u now external, v now internal
                ext[i] += wu - wv;
                int[i] += wv - wu;
            } else {
                ext[i] += wv - wu;
                int[i] += wu - wv;
            }
        }
        // recompute u and v fully (cheap)
        for x in [u, v] {
            ext[x] = 0.0;
            int[x] = 0.0;
            for j in 0..n {
                if j == x {
                    continue;
                }
                let w = weight_between(x, j);
                if in0[x] == in0[j] {
                    int[x] += w;
                } else {
                    ext[x] += w;
                }
            }
        }
    }

    let mut part0 = Vec::with_capacity(target0);
    let mut part1 = Vec::with_capacity(n - target0);
    for i in 0..n {
        if in0[i] {
            part0.push(i);
        } else {
            part1.push(i);
        }
    }
    let cut = cut_weight(comm, verts, &part0, &part1);
    Bisection { part0, part1, cut }
}

/// Cut weight between two local-index parts.
pub fn cut_weight(
    comm: &CommMatrix,
    verts: &[usize],
    part0: &[usize],
    part1: &[usize],
) -> f64 {
    let mut cut = 0.0;
    for &a in part0 {
        for &b in part1 {
            cut += comm.get(verts[a], verts[b]);
        }
    }
    cut
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two 4-cliques joined by one light edge: the obvious bisection.
    fn two_cliques() -> CommMatrix {
        let mut c = CommMatrix::new(8);
        for i in 0..4 {
            for j in (i + 1)..4 {
                c.add_sym(i, j, 10.0);
                c.add_sym(i + 4, j + 4, 10.0);
            }
        }
        c.add_sym(0, 4, 1.0);
        c
    }

    #[test]
    fn finds_natural_cut() {
        let c = two_cliques();
        let verts: Vec<usize> = (0..8).collect();
        let b = bisect(&c, &verts, 4);
        assert_eq!(b.part0.len(), 4);
        assert_eq!(b.part1.len(), 4);
        assert_eq!(b.cut, 1.0);
        // parts are the two cliques
        let mut p0: Vec<usize> = b.part0.iter().map(|&i| verts[i]).collect();
        p0.sort_unstable();
        assert!(p0 == vec![0, 1, 2, 3] || p0 == vec![4, 5, 6, 7]);
    }

    #[test]
    fn respects_exact_sizes() {
        let c = two_cliques();
        let verts: Vec<usize> = (0..8).collect();
        for t in 0..=8 {
            let b = bisect(&c, &verts, t);
            assert_eq!(b.part0.len(), t);
            assert_eq!(b.part1.len(), 8 - t);
        }
    }

    #[test]
    fn works_on_subset_of_vertices() {
        let c = two_cliques();
        let verts = vec![0, 1, 4, 5];
        let b = bisect(&c, &verts, 2);
        assert_eq!(b.part0.len() + b.part1.len(), 4);
        // natural cut separates {0,1} from {4,5} with weight 1 (only 0-4)
        assert!(b.cut <= 1.0 + 1e-9);
    }

    #[test]
    fn chain_graph_cut_minimal() {
        // path 0-1-2-3-4-5 with unit weights: best 3|3 cut = 1 edge
        let mut c = CommMatrix::new(6);
        for i in 0..5 {
            c.add_sym(i, i + 1, 1.0);
        }
        let verts: Vec<usize> = (0..6).collect();
        let b = bisect(&c, &verts, 3);
        assert_eq!(b.cut, 1.0);
    }

    #[test]
    fn zero_weight_graph_is_fine() {
        let c = CommMatrix::new(5);
        let verts: Vec<usize> = (0..5).collect();
        let b = bisect(&c, &verts, 2);
        assert_eq!(b.part0.len(), 2);
        assert_eq!(b.cut, 0.0);
    }
}
