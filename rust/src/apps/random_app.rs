//! Random-sparse communication proxy: the adversarial pattern.
//!
//! Each rank talks to `degree` uniformly random peers. No placement can
//! exploit locality structure, which makes this the stress case for the
//! mapper's balance handling and a control in the ablation benches.

use super::{Metric, MpiApp, MpiOp};
use crate::profiler::Msg;
use crate::rng::Rng;

/// Random sparse-pattern app (deterministic given the seed).
#[derive(Debug, Clone)]
pub struct RandomApp {
    ranks: usize,
    peers: Vec<Vec<usize>>,
    /// Bytes per edge per iteration.
    pub bytes: f64,
    /// Iterations.
    pub iters: usize,
    /// Flops per rank per iteration.
    pub flops: f64,
}

impl RandomApp {
    /// Build with `degree` random peers per rank.
    pub fn new(ranks: usize, degree: usize, seed: u64, iters: usize) -> Self {
        let mut rng = Rng::new(seed);
        let peers = (0..ranks)
            .map(|i| {
                let mut ps = Vec::with_capacity(degree);
                while ps.len() < degree.min(ranks - 1) {
                    let p = rng.below_usize(ranks);
                    if p != i && !ps.contains(&p) {
                        ps.push(p);
                    }
                }
                ps
            })
            .collect();
        RandomApp {
            ranks,
            peers,
            bytes: 64.0 * 1024.0,
            iters,
            flops: 5e6,
        }
    }
}

impl MpiApp for RandomApp {
    fn name(&self) -> &str {
        "random"
    }

    fn num_ranks(&self) -> usize {
        self.ranks
    }

    fn metric(&self) -> Metric {
        Metric::CompletionTime
    }

    fn ops(&self) -> Vec<MpiOp> {
        let mut ops = Vec::new();
        for _ in 0..self.iters {
            ops.push(MpiOp::Compute { flops: self.flops });
            ops.push(MpiOp::PointToPoint {
                msgs: self
                    .peers
                    .iter()
                    .enumerate()
                    .flat_map(|(i, ps)| {
                        ps.iter().map(move |&p| Msg {
                            src: i,
                            dst: p,
                            bytes: self.bytes,
                        })
                    })
                    .collect(),
            });
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::profile_app;

    #[test]
    fn deterministic_given_seed() {
        let a = profile_app(&RandomApp::new(16, 3, 7, 2));
        let b = profile_app(&RandomApp::new(16, 3, 7, 2));
        assert_eq!(a.volume, b.volume);
    }

    #[test]
    fn degree_respected() {
        let app = RandomApp::new(20, 4, 1, 1);
        for ps in &app.peers {
            assert_eq!(ps.len(), 4);
        }
    }
}
