//! NPB-DT (Data Traffic) benchmark.
//!
//! DT is the NAS Parallel Benchmark for "unstructured computation, parallel
//! I/O and data movement": a task DAG where each node is an MPI rank and
//! edges carry large feature-vector streams. The graph families are
//! **BH** (black hole — 4-ary fan-in layers), **WH** (white hole — fan-out)
//! and **SH** (shuffle). Class C of BH/WH uses 85 ranks: a quaternary tree
//! with layers 64 -> 16 -> 4 -> 1 (64+16+4+1 = 85 = (4^4-1)/3).
//!
//! The communication pattern is pure point-to-point and — because layer
//! membership, not rank adjacency, determines who talks to whom — lands
//! far off the rank diagonal, reproducing the irregular heatmap of the
//! paper's Fig. 1b.

use super::{Metric, MpiApp, MpiOp};
use crate::profiler::Msg;

/// DT graph families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DtGraph {
    /// Fan-in: wide source layer reducing 4:1 per layer to one sink.
    BlackHole,
    /// Fan-out: one source expanding 1:4 per layer.
    WhiteHole,
    /// Shuffle: equal-width layers with stride-shuffle edges.
    Shuffle,
}

/// NPB problem classes (set layer widths and payload sizes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DtClass {
    S,
    W,
    A,
    B,
    C,
}

impl DtClass {
    /// Number of quaternary-tree levels for BH/WH (width 4^(levels-1)).
    fn levels(self) -> usize {
        match self {
            DtClass::S => 2,  // 4 + 1 = 5 ranks
            DtClass::W => 3,  // 16 + 4 + 1 = 21
            DtClass::A => 3,  // 21 ranks
            DtClass::B => 4,  // 85? B uses 43 in real NPB; proxy keeps 4 levels
            DtClass::C => 4,  // 64 + 16 + 4 + 1 = 85 ranks (paper's 85)
        }
    }

    /// Bytes per graph edge per iteration (feature-vector stream chunk).
    fn edge_bytes(self) -> f64 {
        match self {
            DtClass::S => 64.0 * 1024.0,
            DtClass::W => 128.0 * 1024.0,
            DtClass::A => 256.0 * 1024.0,
            DtClass::B => 512.0 * 1024.0,
            DtClass::C => 1_280.0 * 1024.0,
        }
    }
}

/// One directed DAG edge between world ranks.
#[derive(Debug, Clone, Copy)]
struct DagEdge {
    src: usize,
    dst: usize,
}

/// NPB-DT application model.
#[derive(Debug, Clone)]
pub struct NpbDt {
    graph: DtGraph,
    class: DtClass,
    ranks: usize,
    layers: Vec<Vec<usize>>,
    edges: Vec<DagEdge>,
    /// Stream iterations (cells pushed through the DAG).
    pub iterations: usize,
    /// Flops per task per received/produced cell.
    pub flops_per_cell: f64,
}

impl NpbDt {
    /// The paper's configuration: BH graph, class C, 85 ranks.
    pub fn class_c() -> Self {
        Self::new(DtGraph::BlackHole, DtClass::C, 20)
    }

    /// Build a DT instance.
    pub fn new(graph: DtGraph, class: DtClass, iterations: usize) -> Self {
        let levels = class.levels();
        // Layer widths, wide end first: 4^(levels-1), ..., 4, 1.
        let widths: Vec<usize> = (0..levels).map(|l| 4usize.pow((levels - 1 - l) as u32)).collect();
        let (layers, edges) = match graph {
            DtGraph::BlackHole => Self::tree_layers(&widths, false),
            DtGraph::WhiteHole => {
                let mut w = widths.clone();
                w.reverse(); // 1, 4, ..., 4^(levels-1)
                Self::tree_layers(&w, true)
            }
            DtGraph::Shuffle => Self::shuffle_layers(4usize.pow((levels - 1) as u32), levels),
        };
        let ranks = layers.iter().map(|l| l.len()).sum();
        NpbDt {
            graph,
            class,
            ranks,
            layers,
            edges,
            iterations,
            flops_per_cell: 2.0e7,
        }
    }

    /// Rank ids assigned layer-by-layer; edges connect consecutive layers
    /// 4:1 (fan-in) or 1:4 (fan-out).
    fn tree_layers(widths: &[usize], fan_out: bool) -> (Vec<Vec<usize>>, Vec<DagEdge>) {
        let mut layers = Vec::with_capacity(widths.len());
        let mut next_id = 0usize;
        for &w in widths {
            layers.push((next_id..next_id + w).collect::<Vec<_>>());
            next_id += w;
        }
        let mut edges = Vec::new();
        for l in 0..layers.len() - 1 {
            let (a, b) = (&layers[l], &layers[l + 1]);
            if !fan_out {
                // fan-in: 4 members of layer l feed 1 member of layer l+1
                for (i, &src) in a.iter().enumerate() {
                    edges.push(DagEdge {
                        src,
                        dst: b[i / 4],
                    });
                }
            } else {
                // fan-out: 1 member of layer l feeds 4 of layer l+1
                for (i, &dst) in b.iter().enumerate() {
                    edges.push(DagEdge {
                        src: a[i / 4],
                        dst,
                    });
                }
            }
        }
        (layers, edges)
    }

    /// Shuffle graph: `levels` equal-width layers, perfect-shuffle stride
    /// edges between consecutive layers.
    fn shuffle_layers(width: usize, levels: usize) -> (Vec<Vec<usize>>, Vec<DagEdge>) {
        let mut layers = Vec::with_capacity(levels);
        for l in 0..levels {
            layers.push((l * width..(l + 1) * width).collect::<Vec<_>>());
        }
        let mut edges = Vec::new();
        for l in 0..levels - 1 {
            for i in 0..width {
                let peer = (i * 4 + i / (width / 4).max(1)) % width;
                edges.push(DagEdge {
                    src: layers[l][i],
                    dst: layers[l + 1][peer],
                });
            }
        }
        (layers, edges)
    }

    /// Graph family.
    pub fn graph(&self) -> DtGraph {
        self.graph
    }

    /// Problem class.
    pub fn class(&self) -> DtClass {
        self.class
    }

    /// Number of DAG layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

impl MpiApp for NpbDt {
    fn name(&self) -> &str {
        match self.graph {
            DtGraph::BlackHole => "npb-dt-bh",
            DtGraph::WhiteHole => "npb-dt-wh",
            DtGraph::Shuffle => "npb-dt-sh",
        }
    }

    fn num_ranks(&self) -> usize {
        self.ranks
    }

    fn metric(&self) -> Metric {
        Metric::CompletionTime
    }

    fn ops(&self) -> Vec<MpiOp> {
        let bytes = self.class.edge_bytes();
        let mut ops = Vec::new();
        for _ in 0..self.iterations {
            // layer-by-layer: compute at layer l, then stream to layer l+1
            for l in 0..self.layers.len() {
                ops.push(MpiOp::Compute {
                    flops: self.flops_per_cell,
                });
                if l + 1 < self.layers.len() {
                    let lset: std::collections::HashSet<usize> =
                        self.layers[l].iter().copied().collect();
                    let msgs: Vec<Msg> = self
                        .edges
                        .iter()
                        .filter(|e| lset.contains(&e.src))
                        .map(|e| Msg {
                            src: e.src,
                            dst: e.dst,
                            bytes,
                        })
                        .collect();
                    if !msgs.is_empty() {
                        ops.push(MpiOp::PointToPoint { msgs });
                    }
                }
            }
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::profile_app;

    #[test]
    fn class_c_bh_has_85_ranks() {
        let dt = NpbDt::class_c();
        assert_eq!(dt.num_ranks(), 85);
        assert_eq!(dt.num_layers(), 4);
    }

    #[test]
    fn wh_mirrors_bh_rank_count() {
        let wh = NpbDt::new(DtGraph::WhiteHole, DtClass::C, 1);
        assert_eq!(wh.num_ranks(), 85);
    }

    #[test]
    fn bh_edges_are_4_to_1() {
        let dt = NpbDt::new(DtGraph::BlackHole, DtClass::W, 1);
        // 16 + 4 + 1 = 21 ranks; 16 + 4 = 20 edges
        assert_eq!(dt.num_ranks(), 21);
        assert_eq!(dt.edges.len(), 20);
        // sink (rank 20) receives exactly 4 edges
        assert_eq!(dt.edges.iter().filter(|e| e.dst == 20).count(), 4);
    }

    #[test]
    fn pattern_is_irregular_off_diagonal() {
        // The paper's Fig. 1b property: little mass near the diagonal.
        let dt = NpbDt::class_c();
        let p = profile_app(&dt);
        let mass = p.volume.diagonal_mass(4);
        assert!(mass < 0.3, "diagonal mass too high for DT: {mass}");
        assert!(p.volume.total() > 0.0);
    }

    #[test]
    fn pure_point_to_point() {
        let dt = NpbDt::class_c();
        assert!(dt
            .ops()
            .iter()
            .all(|op| !matches!(op, MpiOp::Collective { .. })));
    }

    #[test]
    fn shuffle_graph_constructs() {
        let sh = NpbDt::new(DtGraph::Shuffle, DtClass::W, 1);
        assert_eq!(sh.num_ranks(), 16 * 3);
        let p = profile_app(&sh);
        assert!(p.volume.total() > 0.0);
    }

    #[test]
    fn volume_scales_with_iterations() {
        let a = profile_app(&NpbDt::new(DtGraph::BlackHole, DtClass::S, 1));
        let b = profile_app(&NpbDt::new(DtGraph::BlackHole, DtClass::S, 3));
        assert!((b.volume.total() - 3.0 * a.volume.total()).abs() < 1e-6);
    }
}
