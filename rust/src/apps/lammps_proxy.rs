//! LAMMPS proxy: short-range MD with 3-D spatial decomposition.
//!
//! Models the communication structure of a LAMMPS run of the *rhodopsin*
//! benchmark (32k-atom protein, PPPM long-range electrostatics):
//!
//! * per timestep, **halo (ghost-atom) exchange** with the 6 face
//!   neighbours of the process grid, one dimension at a time (LAMMPS'
//!   `comm->forward_comm()` structure) — this produces the regular,
//!   near-diagonal traffic band of the paper's Fig. 1a;
//! * per timestep, a small **allreduce** (energy/virial accumulation);
//! * per timestep, an **alltoall**-based FFT transpose for PPPM — the
//!   "significant amount of collective traffic" the paper attributes to
//!   LAMMPS;
//! * every `reneighbor_every` steps, a larger boundary/exchange phase and
//!   a tiny allgather (load stats).
//!
//! Constants are calibrated in `DESIGN.md` so that, on the paper's
//! simulated platform (6 Gflops, 10 Gbps), communication is a significant
//! but not dominant fraction of the timestep — the regime where placement
//! matters (Section 5.1 of the paper).

use super::{factor3, Metric, MpiApp, MpiOp};
use crate::profiler::{CollectiveKind, Communicator, Msg};

/// LAMMPS-like molecular dynamics proxy.
#[derive(Debug, Clone)]
pub struct LammpsProxy {
    ranks: usize,
    grid: (usize, usize, usize),
    /// Total atoms in the system.
    pub atoms: usize,
    /// MD timesteps to run.
    pub steps: usize,
    /// Reneighboring period in steps.
    pub reneighbor_every: usize,
    /// Flops per atom per timestep (pair + bonded + PPPM grid work).
    pub flops_per_atom: f64,
    /// Bytes per ghost atom exchanged per face.
    pub bytes_per_ghost: f64,
    /// Per-rank payload of the PPPM FFT transpose (bytes per pair block).
    pub fft_block_bytes: f64,
}

impl LammpsProxy {
    /// The rhodopsin benchmark shape used in the paper (Section 5.2),
    /// scaled for the 6 Gflops simulated nodes.
    pub fn rhodopsin(ranks: usize) -> Self {
        LammpsProxy {
            ranks,
            grid: factor3(ranks),
            atoms: 32_000,
            steps: 100,
            reneighbor_every: 10,
            flops_per_atom: 40_000.0,
            bytes_per_ghost: 2_000.0,
            fft_block_bytes: 16_384.0,
        }
    }

    /// Shorter run for unit tests.
    pub fn tiny(ranks: usize, steps: usize) -> Self {
        let mut a = Self::rhodopsin(ranks);
        a.steps = steps;
        a
    }

    /// Process grid (px, py, pz).
    pub fn grid(&self) -> (usize, usize, usize) {
        self.grid
    }

    fn rank_of(&self, ix: usize, iy: usize, iz: usize) -> usize {
        let (px, py, _) = self.grid;
        ix + px * (iy + py * iz)
    }

    /// Ghost atoms crossing one face ~ (atoms per rank)^(2/3) style surface
    /// scaling, times the per-dimension anisotropy of the subdomain.
    fn face_bytes(&self) -> f64 {
        let per_rank = self.atoms as f64 / self.ranks as f64;
        // ~40% of a subdomain's atoms are within one cutoff of a face for
        // rhodopsin-like densities; split across 6 faces.
        per_rank.powf(2.0 / 3.0) * self.bytes_per_ghost
    }

    /// The six-neighbour halo-exchange messages, one phase per dimension
    /// (forward then backward), mirroring LAMMPS' staged exchange.
    fn halo_phases(&self, scale: f64) -> Vec<MpiOp> {
        let (px, py, pz) = self.grid;
        let bytes = self.face_bytes() * scale;
        let mut phases = Vec::with_capacity(3);
        for dim in 0..3usize {
            let mut msgs = Vec::with_capacity(self.ranks * 2);
            for iz in 0..pz {
                for iy in 0..py {
                    for ix in 0..px {
                        let me = self.rank_of(ix, iy, iz);
                        let (fwd, bwd) = match dim {
                            0 => {
                                if px == 1 {
                                    continue;
                                }
                                (
                                    self.rank_of((ix + 1) % px, iy, iz),
                                    self.rank_of((ix + px - 1) % px, iy, iz),
                                )
                            }
                            1 => {
                                if py == 1 {
                                    continue;
                                }
                                (
                                    self.rank_of(ix, (iy + 1) % py, iz),
                                    self.rank_of(ix, (iy + py - 1) % py, iz),
                                )
                            }
                            _ => {
                                if pz == 1 {
                                    continue;
                                }
                                (
                                    self.rank_of(ix, iy, (iz + 1) % pz),
                                    self.rank_of(ix, iy, (iz + pz - 1) % pz),
                                )
                            }
                        };
                        if fwd != me {
                            msgs.push(Msg {
                                src: me,
                                dst: fwd,
                                bytes,
                            });
                        }
                        if bwd != me && bwd != fwd {
                            msgs.push(Msg {
                                src: me,
                                dst: bwd,
                                bytes,
                            });
                        }
                    }
                }
            }
            if !msgs.is_empty() {
                phases.push(MpiOp::PointToPoint { msgs });
            }
        }
        phases
    }
}

impl LammpsProxy {
    /// PPPM transpose phases: split the world into contiguous pencil
    /// groups of ~sqrt(n) ranks; run a pairwise alltoall inside each
    /// group, with the groups' rounds merged so they proceed concurrently.
    fn fft_transpose_phases(&self) -> Vec<MpiOp> {
        use crate::profiler::{expand, CollectiveKind};
        let n = self.ranks;
        let mut g = 1usize;
        while g * g < n {
            g *= 2;
        }
        let group = g.min(n); // group size ~ sqrt(n), power of two
        if group <= 1 {
            return Vec::new();
        }
        let rounds_template = expand(CollectiveKind::Alltoall, group, self.fft_block_bytes);
        let n_groups = n / group;
        let mut phases: Vec<Vec<Msg>> = vec![Vec::new(); rounds_template.len()];
        for gi in 0..n_groups {
            let base = gi * group;
            for (r, round) in rounds_template.iter().enumerate() {
                phases[r].extend(round.iter().map(|m| Msg {
                    src: base + m.src,
                    dst: base + m.dst,
                    bytes: m.bytes,
                }));
            }
        }
        phases
            .into_iter()
            .filter(|p| !p.is_empty())
            .map(|msgs| MpiOp::PointToPoint { msgs })
            .collect()
    }
}

impl MpiApp for LammpsProxy {
    fn name(&self) -> &str {
        "lammps"
    }

    fn num_ranks(&self) -> usize {
        self.ranks
    }

    fn metric(&self) -> Metric {
        Metric::TimestepsPerSec
    }

    fn timesteps(&self) -> usize {
        self.steps
    }

    fn ops(&self) -> Vec<MpiOp> {
        let world = Communicator::world(self.ranks);
        let per_rank_flops = self.flops_per_atom * self.atoms as f64 / self.ranks as f64;
        let mut ops = Vec::new();
        for step in 0..self.steps {
            // force computation
            ops.push(MpiOp::Compute {
                flops: per_rank_flops,
            });
            // ghost exchange (x, y, z staged)
            ops.extend(self.halo_phases(1.0));
            // PPPM FFT transpose: pairwise alltoall *within* FFT pencil
            // groups (contiguous rank blocks), groups concurrent — LAMMPS
            // transposes within rows/planes of the FFT decomposition, not
            // across the whole world.
            ops.extend(self.fft_transpose_phases());
            // energy/virial accumulation
            ops.push(MpiOp::Collective {
                comm: world.clone(),
                kind: CollectiveKind::Allreduce,
                bytes: 48.0,
            });
            if step % self.reneighbor_every == self.reneighbor_every - 1 {
                // atom migration: heavier halo + neighbor-list rebuild
                ops.extend(self.halo_phases(2.0));
                ops.push(MpiOp::Compute {
                    flops: per_rank_flops * 0.5,
                });
                // per-rank load stats
                ops.push(MpiOp::Collective {
                    comm: world.clone(),
                    kind: CollectiveKind::Allgather,
                    bytes: 16.0,
                });
            }
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::profile_app;

    #[test]
    fn grid_covers_ranks() {
        for n in [32usize, 64, 128, 256] {
            let a = LammpsProxy::rhodopsin(n);
            let (x, y, z) = a.grid();
            assert_eq!(x * y * z, n);
        }
    }

    #[test]
    fn pattern_is_regular_near_diagonal() {
        // The paper's Fig. 1a property: most traffic within a small band.
        let a = LammpsProxy::tiny(64, 5);
        let p = profile_app(&a);
        // Band = px*py (the largest neighbour stride in the rank grid).
        let (px, py, _) = a.grid();
        let mass = p.volume.diagonal_mass(px * py);
        assert!(mass > 0.6, "diagonal mass too low: {mass}");
    }

    #[test]
    fn halo_is_symmetric_neighbors() {
        let a = LammpsProxy::tiny(27, 1);
        let p = profile_app(&a);
        assert!(p.volume.is_symmetric());
        assert!(p.volume.total() > 0.0);
    }

    #[test]
    fn ops_scale_with_steps() {
        let a1 = LammpsProxy::tiny(8, 1).ops().len();
        let a10 = LammpsProxy::tiny(8, 10).ops().len();
        assert!(a10 > 5 * a1);
    }

    #[test]
    fn collective_traffic_significant() {
        // Paper: "LAMMPS exhibits a significant amount of collective
        // traffic". The PPPM transpose is emitted as merged p2p rounds,
        // so measure it by differencing against an fft-less variant.
        let full_app = LammpsProxy::tiny(64, 10);
        let mut nofft = full_app.clone();
        nofft.fft_block_bytes = 0.0;
        let full = crate::profiler::profile_app(&full_app).volume.total();
        let wo = crate::profiler::profile_app(&nofft).volume.total();
        let frac = (full - wo) / full;
        assert!(frac > 0.1, "collective (fft) fraction {frac}");
    }
}
