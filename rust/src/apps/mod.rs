//! MPI application models.
//!
//! The paper evaluates with LAMMPS (rhodopsin) and NPB-DT class C. We
//! cannot run the real codes inside this repo, so each application is
//! modelled as its *communication + computation schedule*: an ordered list
//! of [`MpiOp`] phases. This is exactly the abstraction level SimGrid/SMPI
//! relies on for timing (computation as flops, communication as message
//! sets), and the profiler consumes the same stream, so `G_v`/`G_m` and the
//! simulated timings are mutually consistent.
//!
//! The proxies reproduce the properties the paper's evaluation hinges on
//! (Section 5.1): communication/computation ratio, point-to-point vs
//! collective mix, and pattern regularity (Fig. 1a vs 1b).

pub mod lammps_proxy;
pub mod npb_dt;
pub mod random_app;
pub mod ring;
pub mod stencil;

use crate::profiler::{CollectiveKind, Communicator, Msg};

/// One phase of an application schedule.
///
/// Phases are barrier-ordered: a phase starts when the previous one has
/// completed on all ranks (the BSP structure of the proxied codes).
#[derive(Debug, Clone)]
pub enum MpiOp {
    /// Local computation; `flops` per rank (uniform across ranks).
    Compute { flops: f64 },
    /// A set of concurrent point-to-point messages (world ranks).
    PointToPoint { msgs: Vec<Msg> },
    /// A collective over `comm`, emulated per algorithm (see
    /// [`crate::profiler::collectives`]). `bytes` is the per-rank payload.
    Collective {
        comm: Communicator,
        kind: CollectiveKind,
        bytes: f64,
    },
}

/// Which scalar the paper reports for an application.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Job completion time in seconds (NPB-DT).
    CompletionTime,
    /// Simulated timesteps per second (LAMMPS).
    TimestepsPerSec,
}

/// A static-profile MPI application: its processes coexist for the whole
/// execution and its schedule does not depend on data values.
pub trait MpiApp {
    /// Short identifier (used in reports and artifact names).
    fn name(&self) -> &str;
    /// World size.
    fn num_ranks(&self) -> usize;
    /// The full schedule, in order.
    fn ops(&self) -> Vec<MpiOp>;
    /// Reporting metric. Defaults to completion time.
    fn metric(&self) -> Metric {
        Metric::CompletionTime
    }
    /// Number of application timesteps (for [`Metric::TimestepsPerSec`]).
    fn timesteps(&self) -> usize {
        1
    }
}

/// Factor `n` into a 3-D grid `(px, py, pz)` with `px*py*pz == n`,
/// as close to cubic as possible (LAMMPS' processor-grid heuristic).
pub fn factor3(n: usize) -> (usize, usize, usize) {
    let mut best = (n, 1, 1);
    let mut best_score = usize::MAX;
    for px in 1..=n {
        if n % px != 0 {
            continue;
        }
        let rem = n / px;
        for py in 1..=rem {
            if rem % py != 0 {
                continue;
            }
            let pz = rem / py;
            // minimize surface ~ spread of dims; tie-break towards
            // descending (px >= py >= pz), matching LAMMPS' convention of
            // fastest-varying dimension first.
            let score = px.max(py).max(pz) - px.min(py).min(pz);
            if score < best_score || (score == best_score && (px, py) > (best.0, best.1))
            {
                best_score = score;
                best = (px, py, pz);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor3_products() {
        for n in [1usize, 8, 12, 64, 85, 128, 256] {
            let (x, y, z) = factor3(n);
            assert_eq!(x * y * z, n, "n={n}");
        }
    }

    #[test]
    fn factor3_cubic_when_possible() {
        assert_eq!(factor3(64), (4, 4, 4));
        assert_eq!(factor3(8), (2, 2, 2));
        let (x, y, z) = factor3(128);
        let dims = {
            let mut d = [x, y, z];
            d.sort_unstable();
            d
        };
        assert_eq!(dims, [4, 4, 8]);
    }

    #[test]
    fn factor3_ties_break_descending() {
        // 256 = 8*8*4 preferred over 4*8*8 so block placement on an
        // 8x8x8 torus aligns the rank grid with node enumeration.
        assert_eq!(factor3(256), (8, 8, 4));
    }
}
