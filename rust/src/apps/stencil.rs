//! 2-D 5-point stencil proxy (extra workload beyond the paper's pair).
//!
//! Jacobi-style halo exchange on a 2-D process grid with a convergence
//! allreduce — the canonical "regular, neighbour-dominated" pattern used
//! in the quickstart example and ablation benches.

use super::{Metric, MpiApp, MpiOp};
use crate::profiler::{CollectiveKind, Communicator, Msg};

/// 2-D stencil application.
#[derive(Debug, Clone)]
pub struct Stencil2D {
    px: usize,
    py: usize,
    /// Grid points per rank per side.
    pub local_side: usize,
    /// Sweeps to run.
    pub iters: usize,
    /// Flops per grid point per sweep.
    pub flops_per_point: f64,
}

impl Stencil2D {
    /// Build over a `px x py` process grid.
    pub fn new(px: usize, py: usize, local_side: usize, iters: usize) -> Self {
        Stencil2D {
            px,
            py,
            local_side,
            iters,
            flops_per_point: 8.0,
        }
    }

    fn rank(&self, x: usize, y: usize) -> usize {
        x + self.px * y
    }
}

impl MpiApp for Stencil2D {
    fn name(&self) -> &str {
        "stencil2d"
    }

    fn num_ranks(&self) -> usize {
        self.px * self.py
    }

    fn metric(&self) -> Metric {
        Metric::TimestepsPerSec
    }

    fn timesteps(&self) -> usize {
        self.iters
    }

    fn ops(&self) -> Vec<MpiOp> {
        let world = Communicator::world(self.num_ranks());
        let halo_bytes = self.local_side as f64 * 8.0;
        let flops = (self.local_side * self.local_side) as f64 * self.flops_per_point;
        let mut ops = Vec::new();
        for it in 0..self.iters {
            let mut msgs = Vec::new();
            for y in 0..self.py {
                for x in 0..self.px {
                    let me = self.rank(x, y);
                    if self.px > 1 {
                        msgs.push(Msg {
                            src: me,
                            dst: self.rank((x + 1) % self.px, y),
                            bytes: halo_bytes,
                        });
                        msgs.push(Msg {
                            src: me,
                            dst: self.rank((x + self.px - 1) % self.px, y),
                            bytes: halo_bytes,
                        });
                    }
                    if self.py > 1 {
                        msgs.push(Msg {
                            src: me,
                            dst: self.rank(x, (y + 1) % self.py),
                            bytes: halo_bytes,
                        });
                        msgs.push(Msg {
                            src: me,
                            dst: self.rank(x, (y + self.py - 1) % self.py),
                            bytes: halo_bytes,
                        });
                    }
                }
            }
            if !msgs.is_empty() {
                ops.push(MpiOp::PointToPoint { msgs });
            }
            ops.push(MpiOp::Compute { flops });
            if it % 10 == 9 {
                // convergence check
                ops.push(MpiOp::Collective {
                    comm: world.clone(),
                    kind: CollectiveKind::Allreduce,
                    bytes: 8.0,
                });
            }
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::profile_app;

    #[test]
    fn neighbor_traffic_only() {
        let s = Stencil2D::new(4, 4, 64, 3);
        let p = profile_app(&s);
        for i in 0..16 {
            for j in 0..16 {
                if p.volume.get(i, j) > 0.0 {
                    let (xi, yi) = (i % 4, i / 4);
                    let (xj, yj) = (j % 4, j / 4);
                    let dx = (xi as i64 - xj as i64).rem_euclid(4).min((xj as i64 - xi as i64).rem_euclid(4));
                    let dy = (yi as i64 - yj as i64).rem_euclid(4).min((yj as i64 - yi as i64).rem_euclid(4));
                    assert!(dx + dy <= 1, "non-neighbour traffic ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn ranks_and_metric() {
        let s = Stencil2D::new(8, 4, 32, 10);
        assert_eq!(s.num_ranks(), 32);
        assert_eq!(s.metric(), Metric::TimestepsPerSec);
        assert_eq!(s.timesteps(), 10);
    }
}
