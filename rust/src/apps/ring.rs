//! Ring-pipeline proxy app: nearest-neighbour token passing.
//!
//! The simplest regular pattern; used in tests and the quickstart to show
//! that block placement is already near-optimal for it.

use super::{Metric, MpiApp, MpiOp};
use crate::profiler::Msg;

/// Unidirectional ring with fixed message size.
#[derive(Debug, Clone)]
pub struct RingApp {
    ranks: usize,
    /// Bytes per hop per iteration.
    pub bytes: f64,
    /// Iterations.
    pub iters: usize,
    /// Flops per rank per iteration.
    pub flops: f64,
}

impl RingApp {
    /// Build a ring app.
    pub fn new(ranks: usize, bytes: f64, iters: usize) -> Self {
        RingApp {
            ranks,
            bytes,
            iters,
            flops: 1e6,
        }
    }
}

impl MpiApp for RingApp {
    fn name(&self) -> &str {
        "ring"
    }

    fn num_ranks(&self) -> usize {
        self.ranks
    }

    fn metric(&self) -> Metric {
        Metric::CompletionTime
    }

    fn ops(&self) -> Vec<MpiOp> {
        let mut ops = Vec::new();
        for _ in 0..self.iters {
            ops.push(MpiOp::Compute { flops: self.flops });
            ops.push(MpiOp::PointToPoint {
                msgs: (0..self.ranks)
                    .map(|i| Msg {
                        src: i,
                        dst: (i + 1) % self.ranks,
                        bytes: self.bytes,
                    })
                    .collect(),
            });
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::profile_app;

    #[test]
    fn ring_profile_is_circulant() {
        let p = profile_app(&RingApp::new(8, 1000.0, 2));
        for i in 0..8 {
            assert_eq!(p.volume.get(i, (i + 1) % 8), 2000.0);
        }
        assert_eq!(p.volume.total(), 8.0 * 2.0 * 2000.0);
    }
}
