//! Deterministic RNG for reproducible experiments.
//!
//! All stochastic behaviour in the repo (failure sampling, random
//! placement, batch composition) flows through this splitmix64/xoshiro256**
//! generator so every figure regenerates bit-identically from its seed.

/// xoshiro256** seeded via splitmix64. Small, fast, and good enough for
/// simulation workloads (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per batch, per instance).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Derive the stream for item `index` of a run whose base draw is
    /// `base` (typically one [`Rng::next_u64`] from the run's generator).
    ///
    /// Unlike [`Rng::fork`] this mutates no parent generator, so shards of
    /// the parallel batch engine can derive their per-instance streams in
    /// any order — on any worker — and still reproduce the serial run
    /// bit-for-bit (the determinism contract of `batch::parallel`).
    pub fn stream(base: u64, index: u64) -> Rng {
        Rng::new(base ^ index.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `0..n` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }
}

/// Central registry of every named RNG stream base in the crate.
///
/// `Rng::stream(base, index)` keeps parallel work bit-identical, but only
/// if no two consumers ever share a `base`. PR 9 added a third base by
/// convention alone; a silent collision would correlate draws without
/// failing a single test. So the bases live here — one table, one salt,
/// one derivation function — and the `rng-stream-registry` detlint rule
/// (see `tofa::analysis`) rejects any literal or const base that is not
/// declared in this module.
pub mod streams {
    use super::Rng;

    /// Salt folded into the scheduler's seed before drawing stream bases,
    /// so scheduler streams can never collide with an unsalted consumer
    /// of the same user seed.
    pub const SCHED_SALT: u64 = 0x5eed_5c4e_d011;

    /// Draw index of the per-job placement/runtime stream base.
    pub const SCHED_JOB_DRAW: u64 = 0;
    /// Draw index of the heartbeat health-epoch stream base.
    pub const SCHED_HEARTBEAT_DRAW: u64 = 1;
    /// Draw index of the in-job recovery (checkpoint/shrink) stream base.
    pub const SCHED_RECOVERY_DRAW: u64 = 2;

    /// One registered stream base: where it comes from and who consumes it.
    #[derive(Debug, Clone, Copy)]
    pub struct StreamBase {
        /// Registry name (matches the `*_DRAW` const).
        pub name: &'static str,
        /// Sequential draw index off the salted seeding RNG.
        pub draw: u64,
        /// The code path that forks per-item streams off this base.
        pub consumer: &'static str,
    }

    /// Every stream base in the crate, one row per draw. Extend this
    /// table (and add a `*_DRAW` const) when introducing a new stream;
    /// never reuse a draw index — bit-compatibility of recorded runs
    /// depends on the existing order.
    pub const STREAM_BASES: &[StreamBase] = &[
        StreamBase {
            name: "SCHED_JOB_DRAW",
            draw: SCHED_JOB_DRAW,
            consumer: "slurm::sched job placement + runtime jitter (Rng::stream per job id)",
        },
        StreamBase {
            name: "SCHED_HEARTBEAT_DRAW",
            draw: SCHED_HEARTBEAT_DRAW,
            consumer: "slurm::sched heartbeat health epochs (Rng::stream per epoch)",
        },
        StreamBase {
            name: "SCHED_RECOVERY_DRAW",
            draw: SCHED_RECOVERY_DRAW,
            consumer: "slurm::sched in-job recovery decisions (Rng::stream per job id)",
        },
    ];

    /// Derive the registered scheduler stream base for `draw` from the
    /// user seed: the `(draw + 1)`-th sequential `next_u64` off
    /// `Rng::new(seed ^ SCHED_SALT)`. This is exactly the historical
    /// inline derivation (three sequential draws), so every recorded
    /// trace stays bit-identical.
    pub fn sched_base(seed: u64, draw: u64) -> u64 {
        let mut r = Rng::new(seed ^ SCHED_SALT);
        let mut v = r.next_u64();
        for _ in 0..draw {
            v = r.next_u64();
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_rate_roughly_p() {
        let mut r = Rng::new(3);
        let hits = (0..20_000).filter(|_| r.bernoulli(0.25)).count();
        let rate = hits as f64 / 20_000.0;
        assert!((rate - 0.25).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(512, 16);
        assert_eq!(s.len(), 16);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 16);
        assert!(t.iter().all(|&x| x < 512));
    }

    #[test]
    fn forked_streams_differ() {
        let mut base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_are_deterministic_and_distinct() {
        let mut a = Rng::stream(99, 3);
        let mut b = Rng::stream(99, 3);
        let mut c = Rng::stream(99, 4);
        let (xa, xb, xc) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_eq!(xa, xb);
        assert_ne!(xa, xc);
    }

    #[test]
    fn registry_covers_every_draw_exactly_once() {
        let mut draws: Vec<u64> = streams::STREAM_BASES.iter().map(|b| b.draw).collect();
        draws.sort_unstable();
        let expected: Vec<u64> = (0..streams::STREAM_BASES.len() as u64).collect();
        assert_eq!(draws, expected, "draw indices must be 0..n with no gaps or reuse");
        assert_eq!(streams::STREAM_BASES.len(), 3);
    }

    #[test]
    fn sched_bases_match_historical_sequential_draws() {
        // the pre-registry scheduler drew three sequential values off
        // Rng::new(seed ^ SALT); bit-compatibility of recorded traces
        // depends on sched_base reproducing exactly that
        for seed in [0u64, 1, 42, 0xdead_beef] {
            let mut r = Rng::new(seed ^ streams::SCHED_SALT);
            let (a, b, c) = (r.next_u64(), r.next_u64(), r.next_u64());
            assert_eq!(streams::sched_base(seed, streams::SCHED_JOB_DRAW), a);
            assert_eq!(streams::sched_base(seed, streams::SCHED_HEARTBEAT_DRAW), b);
            assert_eq!(streams::sched_base(seed, streams::SCHED_RECOVERY_DRAW), c);
        }
    }

    #[test]
    fn sched_bases_are_pairwise_distinct_at_runtime() {
        for seed in [0u64, 7, 42, 1234, u64::MAX] {
            let bases: Vec<u64> = streams::STREAM_BASES
                .iter()
                .map(|b| streams::sched_base(seed, b.draw))
                .collect();
            let mut uniq = bases.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), bases.len(), "stream bases collide for seed {seed}");
        }
    }
}
