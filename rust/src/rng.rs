//! Deterministic RNG for reproducible experiments.
//!
//! All stochastic behaviour in the repo (failure sampling, random
//! placement, batch composition) flows through this splitmix64/xoshiro256**
//! generator so every figure regenerates bit-identically from its seed.

/// xoshiro256** seeded via splitmix64. Small, fast, and good enough for
/// simulation workloads (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per batch, per instance).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Derive the stream for item `index` of a run whose base draw is
    /// `base` (typically one [`Rng::next_u64`] from the run's generator).
    ///
    /// Unlike [`Rng::fork`] this mutates no parent generator, so shards of
    /// the parallel batch engine can derive their per-instance streams in
    /// any order — on any worker — and still reproduce the serial run
    /// bit-for-bit (the determinism contract of `batch::parallel`).
    pub fn stream(base: u64, index: u64) -> Rng {
        Rng::new(base ^ index.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `0..n` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_rate_roughly_p() {
        let mut r = Rng::new(3);
        let hits = (0..20_000).filter(|_| r.bernoulli(0.25)).count();
        let rate = hits as f64 / 20_000.0;
        assert!((rate - 0.25).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(512, 16);
        assert_eq!(s.len(), 16);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 16);
        assert!(t.iter().all(|&x| x < 512));
    }

    #[test]
    fn forked_streams_differ() {
        let mut base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_are_deterministic_and_distinct() {
        let mut a = Rng::stream(99, 3);
        let mut b = Rng::stream(99, 3);
        let mut c = Rng::stream(99, 4);
        let (xa, xb, xc) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_eq!(xa, xb);
        assert_ne!(xa, xc);
    }
}
