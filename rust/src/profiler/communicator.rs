//! MPI communicators and rank translation.
//!
//! The paper's profiling tool "records traffic through communicators other
//! than the default one [by transforming] the rank of a process in a
//! communicator other than MPI_COMM_WORLD to the rank in MPI_COMM_WORLD".
//! This module is that translation layer.

/// A communicator: an ordered subset of world ranks. Local rank `i` maps
/// to `world_ranks[i]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Communicator {
    world_ranks: Vec<usize>,
}

impl Communicator {
    /// `MPI_COMM_WORLD` over `n` ranks.
    pub fn world(n: usize) -> Self {
        Communicator {
            world_ranks: (0..n).collect(),
        }
    }

    /// Sub-communicator from an explicit world-rank list.
    pub fn from_ranks(world_ranks: Vec<usize>) -> Self {
        debug_assert!(
            {
                let mut s = world_ranks.clone();
                s.sort_unstable();
                s.dedup();
                s.len() == world_ranks.len()
            },
            "duplicate world ranks in communicator"
        );
        Communicator { world_ranks }
    }

    /// `MPI_Comm_split`-style: members of `world` whose `color(rank)`
    /// matches, ordered by world rank (key = rank).
    pub fn split(n_world: usize, color: impl Fn(usize) -> bool) -> Self {
        Communicator {
            world_ranks: (0..n_world).filter(|&r| color(r)).collect(),
        }
    }

    /// Communicator size.
    pub fn size(&self) -> usize {
        self.world_ranks.len()
    }

    /// True if no members.
    pub fn is_empty(&self) -> bool {
        self.world_ranks.is_empty()
    }

    /// Translate a communicator-local rank to its world rank
    /// (`R_comm_world` in the paper).
    #[inline]
    pub fn to_world(&self, local: usize) -> usize {
        self.world_ranks[local]
    }

    /// Member world ranks.
    pub fn ranks(&self) -> &[usize] {
        &self.world_ranks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_is_identity() {
        let c = Communicator::world(8);
        for i in 0..8 {
            assert_eq!(c.to_world(i), i);
        }
    }

    #[test]
    fn split_even_ranks() {
        let c = Communicator::split(10, |r| r % 2 == 0);
        assert_eq!(c.size(), 5);
        assert_eq!(c.to_world(0), 0);
        assert_eq!(c.to_world(4), 8);
    }

    #[test]
    fn from_ranks_preserves_order() {
        let c = Communicator::from_ranks(vec![7, 3, 5]);
        assert_eq!(c.to_world(0), 7);
        assert_eq!(c.to_world(1), 3);
        assert_eq!(c.to_world(2), 5);
    }
}
