//! The MPI profiling tool (Section 3 of the paper).
//!
//! A library that "intercepts all calls to MPI primitives that initiate
//! traffic — point-to-point, collective, and one-sided" and emits the
//! `G_v` / `G_m` communication graphs plus a traffic heatmap. Our
//! applications are simulated schedules ([`crate::apps::MpiApp`]), so the
//! interposition point is the op stream rather than a PMPI shim; the
//! accounting — collective algorithm emulation, sub-communicator rank
//! translation, symmetric byte/message counting — is the same.

pub mod collectives;
pub mod communicator;

pub use collectives::{expand, schedule_bytes, CollectiveKind, Msg, Round};
pub use communicator::Communicator;

use crate::apps::{MpiApp, MpiOp};
use crate::commgraph::CommProfile;

/// Run the profiler over an application's op stream, producing its
/// communication profile (`G_v`, `G_m`).
pub fn profile_app(app: &dyn MpiApp) -> CommProfile {
    let mut profile = CommProfile::new(app.num_ranks());
    for op in app.ops() {
        record_op(&mut profile, &op);
    }
    profile
}

/// Account a single MPI operation into the profile.
pub fn record_op(profile: &mut CommProfile, op: &MpiOp) {
    match op {
        MpiOp::Compute { .. } => {}
        MpiOp::PointToPoint { msgs } => {
            for m in msgs {
                profile.record(m.src, m.dst, m.bytes);
            }
        }
        MpiOp::Collective { comm, kind, bytes } => {
            for round in expand(*kind, comm.size(), *bytes) {
                for m in round {
                    // translate communicator-local ranks to world ranks
                    profile.record(comm.to_world(m.src), comm.to_world(m.dst), m.bytes);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{MpiApp, MpiOp};

    struct TinyApp;
    impl MpiApp for TinyApp {
        fn name(&self) -> &str {
            "tiny"
        }
        fn num_ranks(&self) -> usize {
            4
        }
        fn ops(&self) -> Vec<MpiOp> {
            vec![
                MpiOp::PointToPoint {
                    msgs: vec![Msg {
                        src: 0,
                        dst: 3,
                        bytes: 100.0,
                    }],
                },
                MpiOp::Collective {
                    comm: Communicator::world(4),
                    kind: CollectiveKind::Allreduce,
                    bytes: 8.0,
                },
            ]
        }
    }

    #[test]
    fn profile_counts_p2p_and_collective() {
        let p = profile_app(&TinyApp);
        // recursive doubling on 4 ranks: rounds {0<->1, 2<->3} then
        // {0<->2, 1<->3}; rank pair (0,3) never exchanges in RD.
        assert_eq!(p.volume.get(0, 3), 100.0); // p2p only
        assert!(p.volume.is_symmetric());
        assert!(p.messages.is_symmetric());
        assert_eq!(p.volume.get(0, 1), 16.0); // both directions of round 0
        assert_eq!(p.volume.get(0, 2), 16.0); // both directions of round 1
    }

    #[test]
    fn subcommunicator_traffic_lands_on_world_ranks() {
        let mut profile = CommProfile::new(8);
        let odd = Communicator::split(8, |r| r % 2 == 1); // world 1,3,5,7
        record_op(
            &mut profile,
            &MpiOp::Collective {
                comm: odd,
                kind: CollectiveKind::Bcast { root: 0 },
                bytes: 10.0,
            },
        );
        // traffic only between odd world ranks
        for i in 0..8 {
            for j in 0..8 {
                if profile.volume.get(i, j) > 0.0 {
                    assert!(i % 2 == 1 && j % 2 == 1, "({i},{j})");
                }
            }
        }
        assert!(profile.volume.total() > 0.0);
    }
}
