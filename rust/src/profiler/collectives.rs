//! Collective-algorithm emulation.
//!
//! The paper's profiling tool "is tuned to emulate the appropriate
//! algorithm for each collective [so] it is able to accurately capture the
//! traffic exchanged between each pair of processes during each phase of
//! that collective's schedule". This module implements those schedules —
//! the classic MPICH algorithm choices — as explicit per-round message
//! lists. Both the profiler (traffic accounting) and the SMPI-like
//! simulator (timing) consume the same schedules, so profile and simulation
//! are consistent by construction.
//!
//! All ranks are communicator-local `0..n`; `bytes` is the per-rank payload
//! (see each constructor for its exact semantics).

/// One point-to-point message within a schedule round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Msg {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
}

/// One synchronization phase: all messages in a round are concurrent, and
/// a round completes before the next starts.
pub type Round = Vec<Msg>;

/// Collective operations supported by the emulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// Binomial-tree broadcast from `root`.
    Bcast { root: usize },
    /// Binomial-tree reduction to `root`.
    Reduce { root: usize },
    /// Recursive-doubling allreduce (MPICH default for short/medium).
    Allreduce,
    /// Ring allgather; `bytes` = each rank's contribution.
    Allgather,
    /// Recursive-halving reduce-scatter; `bytes` = per-rank result block.
    ReduceScatter,
    /// Pairwise-exchange alltoall; `bytes` = per-pair block.
    Alltoall,
    /// Dissemination barrier (token messages).
    Barrier,
    /// Binomial-tree gather to `root`; `bytes` = per-rank contribution.
    Gather { root: usize },
    /// Binomial-tree scatter from `root`; `bytes` = per-rank block.
    Scatter { root: usize },
}

/// Expand a collective into its round schedule for `n` ranks.
pub fn expand(kind: CollectiveKind, n: usize, bytes: f64) -> Vec<Round> {
    if n <= 1 {
        return Vec::new();
    }
    match kind {
        CollectiveKind::Bcast { root } => binomial_rounds(n, root, false, |_| bytes),
        CollectiveKind::Reduce { root } => {
            let mut r = binomial_rounds(n, root, true, |_| bytes);
            r.reverse();
            r
        }
        CollectiveKind::Allreduce => allreduce_recursive_doubling(n, bytes),
        CollectiveKind::Allgather => allgather_ring(n, bytes),
        CollectiveKind::ReduceScatter => reduce_scatter(n, bytes),
        CollectiveKind::Alltoall => alltoall_pairwise(n, bytes),
        CollectiveKind::Barrier => barrier_dissemination(n),
        CollectiveKind::Gather { root } => binomial_rounds(n, root, true, |sub| bytes * sub as f64)
            .into_iter()
            .rev()
            .collect(),
        CollectiveKind::Scatter { root } => binomial_rounds(n, root, false, |sub| bytes * sub as f64),
    }
}

/// Total bytes a schedule puts on the network (sum over all messages).
pub fn schedule_bytes(rounds: &[Round]) -> f64 {
    rounds
        .iter()
        .flat_map(|r| r.iter())
        .map(|m| m.bytes)
        .sum()
}

/// Binomial tree rounds relative to `root`.
///
/// In broadcast orientation (`reversed = false`), round `k` has messages
/// `vrank-mask -> vrank` for `vrank in [mask, 2*mask)`; the payload of an
/// edge is `sizer(subtree)` where `subtree` is the size of the subtree the
/// edge transfers (1 for bcast, the receiver's subtree for scatter/gather).
/// `reversed = true` flips message direction (gather/reduce orientation).
fn binomial_rounds(
    n: usize,
    root: usize,
    reversed: bool,
    sizer: impl Fn(usize) -> f64,
) -> Vec<Round> {
    let mut rounds = Vec::new();
    let mut mask = 1usize;
    while mask < n {
        let mut round = Vec::new();
        for vrank in mask..(2 * mask).min(n) {
            let parent_v = vrank - mask;
            // subtree rooted at vrank under this schedule
            let subtree = subtree_size(vrank, n);
            let a = (parent_v + root) % n;
            let b = (vrank + root) % n;
            let (src, dst) = if reversed { (b, a) } else { (a, b) };
            round.push(Msg {
                src,
                dst,
                bytes: sizer(subtree),
            });
        }
        rounds.push(round);
        mask <<= 1;
    }
    rounds
}

/// Size of the binomial subtree rooted at virtual rank `v` among `n`.
fn subtree_size(v: usize, n: usize) -> usize {
    if v == 0 {
        return n;
    }
    // lowest set bit of v bounds the subtree; clipped by n.
    let span = v & v.wrapping_neg();
    span.min(n - v)
}

/// MPICH recursive-doubling allreduce with the non-power-of-two preamble.
fn allreduce_recursive_doubling(n: usize, bytes: f64) -> Vec<Round> {
    let pof2 = n.next_power_of_two() >> if n.is_power_of_two() { 0 } else { 1 };
    let rem = n - pof2;
    let mut rounds = Vec::new();

    // Preamble: first 2*rem ranks fold odd ranks into even ones.
    if rem > 0 {
        rounds.push(
            (0..rem)
                .map(|i| Msg {
                    src: 2 * i + 1,
                    dst: 2 * i,
                    bytes,
                })
                .collect(),
        );
    }
    // Participating rank for virtual id v among pof2 participants.
    let real = |v: usize| if v < rem { 2 * v } else { v + rem };

    let mut mask = 1usize;
    while mask < pof2 {
        let mut round = Vec::with_capacity(pof2);
        for v in 0..pof2 {
            let peer = v ^ mask;
            round.push(Msg {
                src: real(v),
                dst: real(peer),
                bytes,
            });
        }
        rounds.push(round);
        mask <<= 1;
    }
    // Postamble: results pushed back to the folded odd ranks.
    if rem > 0 {
        rounds.push(
            (0..rem)
                .map(|i| Msg {
                    src: 2 * i,
                    dst: 2 * i + 1,
                    bytes,
                })
                .collect(),
        );
    }
    rounds
}

/// Ring allgather: `n - 1` rounds, each rank forwards one block to its
/// successor.
fn allgather_ring(n: usize, bytes: f64) -> Vec<Round> {
    (0..n - 1)
        .map(|_| {
            (0..n)
                .map(|i| Msg {
                    src: i,
                    dst: (i + 1) % n,
                    bytes,
                })
                .collect()
        })
        .collect()
}

/// Reduce-scatter: recursive halving for powers of two, ring otherwise.
/// `bytes` is the per-rank result block, so the full vector is `n * bytes`.
fn reduce_scatter(n: usize, bytes: f64) -> Vec<Round> {
    if n.is_power_of_two() {
        let mut rounds = Vec::new();
        let mut mask = n >> 1;
        let mut chunk = bytes * (n as f64) / 2.0;
        while mask >= 1 {
            let round = (0..n)
                .map(|i| Msg {
                    src: i,
                    dst: i ^ mask,
                    bytes: chunk,
                })
                .collect();
            rounds.push(round);
            mask >>= 1;
            chunk /= 2.0;
        }
        rounds
    } else {
        // ring: n-1 rounds of per-rank blocks
        (0..n - 1)
            .map(|_| {
                (0..n)
                    .map(|i| Msg {
                        src: i,
                        dst: (i + n - 1) % n,
                        bytes,
                    })
                    .collect()
            })
            .collect()
    }
}

/// Pairwise-exchange alltoall: round `k` pairs `i` with `i ^ k` (power of
/// two) or shifts by `k` (otherwise).
fn alltoall_pairwise(n: usize, bytes: f64) -> Vec<Round> {
    (1..n)
        .map(|k| {
            (0..n)
                .filter_map(|i| {
                    let peer = if n.is_power_of_two() { i ^ k } else { (i + k) % n };
                    (peer != i).then_some(Msg {
                        src: i,
                        dst: peer,
                        bytes,
                    })
                })
                .collect()
        })
        .collect()
}

/// Dissemination barrier: ceil(log2 n) rounds of 4-byte tokens.
fn barrier_dissemination(n: usize) -> Vec<Round> {
    let mut rounds = Vec::new();
    let mut k = 1usize;
    while k < n {
        rounds.push(
            (0..n)
                .map(|i| Msg {
                    src: i,
                    dst: (i + k) % n,
                    bytes: 4.0,
                })
                .collect(),
        );
        k <<= 1;
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total(kind: CollectiveKind, n: usize, bytes: f64) -> f64 {
        schedule_bytes(&expand(kind, n, bytes))
    }

    #[test]
    fn bcast_binomial_message_count() {
        // n-1 messages total, ceil(log2 n) rounds.
        for n in [2usize, 3, 4, 7, 8, 16, 85] {
            let rounds = expand(CollectiveKind::Bcast { root: 0 }, n, 1.0);
            let msgs: usize = rounds.iter().map(|r| r.len()).sum();
            assert_eq!(msgs, n - 1, "n={n}");
            assert_eq!(rounds.len(), (n as f64).log2().ceil() as usize);
        }
    }

    #[test]
    fn bcast_every_rank_reached() {
        for root in [0usize, 3, 7] {
            let rounds = expand(CollectiveKind::Bcast { root }, 12, 8.0);
            let mut have = vec![false; 12];
            have[root] = true;
            for r in &rounds {
                for m in r {
                    assert!(have[m.src], "sender {} before receiving", m.src);
                    have[m.dst] = true;
                }
            }
            assert!(have.iter().all(|&h| h));
        }
    }

    #[test]
    fn reduce_mirrors_bcast() {
        let b = expand(CollectiveKind::Bcast { root: 2 }, 9, 5.0);
        let r = expand(CollectiveKind::Reduce { root: 2 }, 9, 5.0);
        let b_msgs: usize = b.iter().map(|x| x.len()).sum();
        let r_msgs: usize = r.iter().map(|x| x.len()).sum();
        assert_eq!(b_msgs, r_msgs);
        // every reduce message flows *towards* the root's tree.
        let all_dst: Vec<usize> = r.iter().flatten().map(|m| m.dst).collect();
        assert!(all_dst.contains(&2));
    }

    #[test]
    fn allreduce_pow2_rounds_and_symmetry() {
        let rounds = expand(CollectiveKind::Allreduce, 8, 10.0);
        assert_eq!(rounds.len(), 3);
        for r in &rounds {
            assert_eq!(r.len(), 8);
            // pairwise exchange: src set == dst set
            for m in r {
                assert!(r.iter().any(|x| x.src == m.dst && x.dst == m.src));
            }
        }
    }

    #[test]
    fn allreduce_non_pow2_has_pre_and_postamble() {
        let rounds = expand(CollectiveKind::Allreduce, 6, 1.0);
        // rem = 2: preamble + 2 doubling rounds + postamble
        assert_eq!(rounds.len(), 4);
        assert_eq!(rounds[0].len(), 2); // 2 fold messages
        assert_eq!(rounds[3].len(), 2);
        // all ranks touched
        let mut touched = vec![false; 6];
        for r in &rounds {
            for m in r {
                touched[m.src] = true;
                touched[m.dst] = true;
            }
        }
        assert!(touched.iter().all(|&t| t));
    }

    #[test]
    fn allgather_ring_totals() {
        // each rank sends (n-1) blocks
        let n = 10;
        assert_eq!(
            total(CollectiveKind::Allgather, n, 100.0),
            (n * (n - 1)) as f64 * 100.0
        );
        let rounds = expand(CollectiveKind::Allgather, n, 100.0);
        assert_eq!(rounds.len(), n - 1);
        // neighbour-only traffic
        for r in &rounds {
            for m in r {
                assert_eq!(m.dst, (m.src + 1) % n);
            }
        }
    }

    #[test]
    fn reduce_scatter_halving_volume() {
        // total vector n*b halved each round: n*b/2 * n msgs... verify
        // per-rank sent volume: b*(n-1) as in the lower bound.
        let n = 8;
        let b = 16.0;
        let rounds = expand(CollectiveKind::ReduceScatter, n, b);
        assert_eq!(rounds.len(), 3);
        let per_rank: f64 = rounds.iter().map(|r| r[0].bytes).sum();
        assert_eq!(per_rank, b * (n as f64 - 1.0)); // 64+32+16 = 112 = 16*7
    }

    #[test]
    fn alltoall_covers_all_pairs() {
        for n in [4usize, 6, 8] {
            let rounds = expand(CollectiveKind::Alltoall, n, 1.0);
            let mut seen = std::collections::HashSet::new();
            for r in &rounds {
                for m in r {
                    assert!(seen.insert((m.src, m.dst)), "dup pair {:?}", (m.src, m.dst));
                }
            }
            assert_eq!(seen.len(), n * (n - 1));
        }
    }

    #[test]
    fn barrier_rounds_logarithmic() {
        assert_eq!(expand(CollectiveKind::Barrier, 8, 0.0).len(), 3);
        assert_eq!(expand(CollectiveKind::Barrier, 9, 0.0).len(), 4);
    }

    #[test]
    fn gather_volume_matches_subtree_sizes() {
        // Each binomial edge carries the receiver-side subtree's blocks, so
        // total traffic = sum of subtree sizes (>= the n-1 lower bound).
        for n in [4usize, 7, 16] {
            let want: f64 = (1..n).map(|v| subtree_size(v, n) as f64 * 10.0).sum();
            let got = total(CollectiveKind::Gather { root: 0 }, n, 10.0);
            assert_eq!(got, want, "n={n}");
            assert!(got >= 10.0 * (n as f64 - 1.0));
        }
    }

    #[test]
    fn scatter_volume_equals_gather() {
        for n in [4usize, 7, 16] {
            assert_eq!(
                total(CollectiveKind::Scatter { root: 0 }, n, 10.0),
                total(CollectiveKind::Gather { root: 0 }, n, 10.0)
            );
        }
    }

    #[test]
    fn single_rank_collectives_are_empty() {
        for kind in [
            CollectiveKind::Bcast { root: 0 },
            CollectiveKind::Allreduce,
            CollectiveKind::Alltoall,
        ] {
            assert!(expand(kind, 1, 10.0).is_empty());
        }
    }
}
